"""Repo-root launcher shims: ``python -m launch.tune`` / ``launch.serve``.

Makes the ``src/repro/launch`` entry points runnable from the repository
root without exporting PYTHONPATH — each submodule here adds ``src`` to
``sys.path`` and delegates to the real ``repro.launch`` module.
"""
import os
import sys

_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                    "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
