"""Shim: ``python -m launch.tune`` -> ``repro.launch.tune`` (see there)."""
import sys

from repro.launch.tune import main

if __name__ == "__main__":
    sys.exit(main())
