"""Shim: ``python -m launch.serve`` -> ``repro.launch.serve`` (see there)."""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main())
