"""Hardware constants for the roofline + energy models.

TPU v5e numbers are the assignment's target constants. A100 / FlightLLM /
ReRAM-PIM constants parameterize the paper-§IV end-to-end comparison
methodology (energy per byte moved / per MAC, peak throughput, power).
Energy-per-bit figures follow the usual architecture-literature values
(HBM2e ~ 3.5-7 pJ/bit, DDR4 ~ 15-20 pJ/bit, on-chip SRAM ~ 0.1-0.2 pJ/bit);
compute energy from peak-power / peak-throughput.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class Device:
    name: str
    peak_flops: float        # FLOP/s (bf16/fp16 dense)
    hbm_bw: float            # bytes/s
    mem_pj_per_byte: float   # off-chip access energy
    mac_pj: float            # energy per MAC (2 FLOPs)
    power_w: float           # board power (throughput/W comparisons)
    # static/leakage board power burned regardless of slot occupancy —
    # what a serving step pays for its IDLE rows (charged against the
    # measured slot-utilization trace in bench_e2e_energy). Rough
    # ~30% -of-board figures for the accelerators (clock gating leaves
    # leakage + HBM refresh + interconnect idle), lower for the FPGA/CIM
    # parts whose static share is small by construction.
    idle_w: float = 0.0


TPU_V5E = Device(
    name="tpu-v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    mem_pj_per_byte=30.0,     # ~3.75 pJ/bit HBM2e class
    mac_pj=0.56,              # ~220W core budget / 197 TFLOP/s (2 FLOP/MAC)
    power_w=220.0,
    idle_w=66.0,
)

ICI_BW = 50e9        # bytes/s per link, v5e
DCN_BW = 6.25e9      # bytes/s per host, cross-pod (50 Gbit)

A100 = Device(
    name="a100-80g",
    peak_flops=312e12,        # fp16 tensor core (dense)
    hbm_bw=2.0e12,
    mem_pj_per_byte=35.0,
    mac_pj=1.3,               # ~400W / 312 TFLOP/s
    power_w=400.0,
    idle_w=110.0,
)

FLIGHTLLM = Device(
    name="flightllm-u280",
    peak_flops=1.5e12,        # sparse-aware FPGA engine, effective
    hbm_bw=460e9,
    mem_pj_per_byte=35.0,
    mac_pj=2.0,
    power_w=45.0,
    idle_w=8.0,
)

# The paper's ReRAM/DCIM design: weights stationary in CIM macros (near-zero
# weight movement), 89 TOPS/W-class digital CIM macro [ISSCC'21 ref 40 in
# the paper] => ~0.011 pJ/MAC core; KV/activation movement dominates.
PIM = Device(
    name="reram-pim",
    peak_flops=20e12,
    hbm_bw=100e9,             # off-chip only for spilled KV cache
    mem_pj_per_byte=30.0,
    mac_pj=0.022,             # 89 TOPS/W digital CIM
    power_w=25.0,
    idle_w=3.0,
)
