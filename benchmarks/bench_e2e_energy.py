"""End-to-end OPT-6.7B inference energy/throughput model (paper §IV table).

Reproduces the paper's comparison METHODOLOGY (their numbers come from a
ReRAM-PIM simulator; ours from an analytical latency/energy model with
published device constants — see benchmarks/hw.py):

  energy/token = moved_bytes * pj_per_byte + MACs * pj_per_mac
  time/token   = max(MACs*2 / peak_flops, moved_bytes / mem_bw)

Configurations:
  a100-dense          weights + bf16 KV over HBM (the paper's GPU baseline)
  flightllm           FPGA baseline (paper's accelerator baseline)
  pim-t1t2            the paper's design: weights stationary in CIM,
                      T1 decomposition (no K/V rewrite; X cache), T2 CPQ
                      4-bit+prune cache, sparse CE
  tpu-v5e-dense       our target hardware, vanilla serving
  tpu-v5e-t1t2        our TPU-native adaptation (X-cache + CPQ cache)

Paper's headline: PIM vs A100 = 159.9x energy / 49.6x throughput;
vs FlightLLM = 34.8x / 29.2x. We print ours next to those.
"""
from __future__ import annotations

import dataclasses

from benchmarks.hw import A100, FLIGHTLLM, PIM, TPU_V5E, Device
from repro.common.param import count_params
from repro.configs import get_config
from repro.configs.base import CPQCfg
from repro.core.cpq import cpq_bytes_per_token
from repro.models.model import model_defs


@dataclasses.dataclass
class TrafficCfg:
    """Per-variant traffic knobs of the analytical model (renamed from the
    old ``ServingCfg`` — that name now means the continuous-batching serving
    config in configs/base.py)."""

    ctx: int = 2048
    batch: int = 1
    weights_stationary: bool = False   # PIM: weights never leave the macros
    kv_bytes_per_token_layer: float = 0.0  # set per variant
    extra_kv_write_penalty: float = 0.0    # CWC rewrite energy (ReRAM baseline)
    # paged serving: chunked prefill writes the prompt's cache payload into
    # the arena exactly once; amortized here over the generated tokens
    # (prompt_ctx tokens written per gen_tokens generated). 0 = not modeled —
    # the pre-serving variants charge decode reads only.
    prefill_ctx: int = 0
    gen_tokens: int = 256
    prefill_write_bytes_per_token_layer: float = 0.0
    # mesh-sharded paged serving: per-head attention-output partials
    # concatenated across the model axis — (mp-1)/mp of the head outputs
    # cross the interconnect per generated token per cache layer (the
    # paper's "only small per-head partials cross the interconnect",
    # measured by ContinuousServeEngine's ``interconnect_bytes`` stat)
    interconnect_bytes_per_token_layer: float = 0.0
    # idle-vs-active serving utilization: the mean fraction of batch slots
    # that emit a useful token per decode step, measured from the engine's
    # per-tick ``trace_active_rows`` series (bench_serving). Below 1.0 a
    # useful token pays (a) the 1/u amplification of the per-step weight
    # stream (idle rows ride the same step) and (b) the idle share of the
    # board's static power. 1.0 (the default) reproduces the pre-trace
    # model exactly.
    slot_util: float = 1.0


def decode_token_cost(dev: Device, n_params: float, L: int, cfg: TrafficCfg):
    """Per generated token (per sequence), amortized over the batch."""
    u = min(max(cfg.slot_util, 1e-6), 1.0)
    macs = n_params + 0.0  # linear layers: one MAC per weight per token
    kv_bytes = cfg.kv_bytes_per_token_layer * L * cfg.ctx
    attn_macs = cfg.kv_bytes_per_token_layer / 2 * L * cfg.ctx  # ~1 MAC/elem
    # weight streaming is a PER-STEP cost: idle slots still ride the step,
    # so per USEFUL token it amortizes over batch * slot_util live rows
    w_bytes = 0.0 if cfg.weights_stationary else 2.0 * n_params / (cfg.batch * u)
    # chunked-prefill arena writes: one write per prompt token per layer,
    # amortized per generated token (matches ContinuousServeEngine's
    # ``prefill_write_bytes`` accounting)
    pf_bytes = (cfg.prefill_write_bytes_per_token_layer * L * cfg.prefill_ctx
                / max(cfg.gen_tokens, 1))
    # caveat: interconnect bytes are charged at HBM bandwidth/energy — an
    # OPTIMISTIC lower bound (v5e ICI is slower and costlier per byte than
    # HBM); the column exists for the movement accounting, and the partial
    # concat is small enough that the ranking is insensitive to the constant
    icnx_bytes = cfg.interconnect_bytes_per_token_layer * L
    bytes_moved = (w_bytes + kv_bytes + pf_bytes + icnx_bytes
                   + cfg.extra_kv_write_penalty)
    t = max(2.0 * (macs + attn_macs) / dev.peak_flops,
            bytes_moved / dev.hbm_bw)
    e = (bytes_moved * dev.mem_pj_per_byte + (macs + attn_macs) * dev.mac_pj) * 1e-12
    # the idle rows' share of static board power over the token's time
    # slice (zero at full occupancy — pre-trace rows are unchanged)
    e += dev.idle_w * t * (1.0 - u)
    return t, e


def measured_paged_utilization(n_requests: int = 10, rate: float = 1.0):
    """Run the REAL continuous engine on the bench_serving mixed-length
    Poisson trace (smoke model) and reduce its per-tick utilization traces
    to the means the analytical model charges: (slot_util, arena_util,
    ticks). Falls back to recorded smoke-run constants when the engine
    cannot run (e.g. no jax in a stripped environment)."""
    try:
        import jax

        from benchmarks.bench_serving import (equal_arena_serving,
                                              make_workload, run_continuous)
        from repro.configs import ARCHS, smoke_config
        from repro.models import model as M

        cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        work = make_workload(0, n_requests, cfg.vocab_size, rate)
        max_len = max(len(w.prompt) + w.target for w in work)
        r = run_continuous(cfg, params, work,
                           equal_arena_serving(4, max_len, page_size=8))
        trace = r["trace_active_rows"]
        return (float(trace.mean()) / 4.0,
                float(r["trace_arena_util"].mean()), int(len(trace)))
    except Exception:  # pragma: no cover - jax-less fallback
        return 0.72, 0.55, 0


def main(emit):
    cfg = get_config("opt-6.7b")
    n_params = count_params(model_defs(cfg))
    L = cfg.num_layers
    kv_dense = 2.0 * cfg.num_kv_heads * cfg.head_dim * 2       # K+V bf16
    kv_x = float(cfg.d_model * 2)                              # T1 X-cache (no rope)
    kv_cpq = 2 * cpq_bytes_per_token(CPQCfg(prune_ratio=0.4, bits=4),
                                     cfg.num_kv_heads, cfg.head_dim)
    kv_x_cpq = cpq_bytes_per_token(CPQCfg(prune_ratio=0.4, bits=4), 1,
                                   cfg.d_model)
    # paged-arena accounting (serving subsystem): same payload through the
    # same API, plus the amortized block-table entry per page
    from repro.serving import paged_cache as pgc
    page_size = 16
    paged_dense = pgc.init_paged_dense(2, page_size, cfg.num_kv_heads, cfg.head_dim)
    kv_paged = pgc.bytes_per_token(paged_dense, page_size)

    # idle-vs-active utilization measured from the serving engine's per-tick
    # traces (bench_serving's workload): the paged rows charge the 1/u
    # weight-stream amplification and the idle static-power share instead of
    # assuming every slot emits a token every step
    slot_u, arena_u, ticks = measured_paged_utilization()
    emit("e2e_paged_utilization", 0.0,
         f"slot_util={slot_u:.3f};arena_util={arena_u:.3f};ticks={ticks}"
         + (";MEASURED" if ticks else ";FALLBACK"))

    for batch in (1, 8):
        variants = {
            "a100-dense": (A100, TrafficCfg(batch=batch,
                                            kv_bytes_per_token_layer=kv_dense)),
            "flightllm": (FLIGHTLLM, TrafficCfg(batch=batch,
                                                kv_bytes_per_token_layer=kv_dense)),
            "pim-t1t2": (PIM, TrafficCfg(batch=batch, weights_stationary=True,
                                         kv_bytes_per_token_layer=kv_x_cpq)),
            "tpu-v5e-dense": (TPU_V5E, TrafficCfg(batch=batch,
                                                  kv_bytes_per_token_layer=kv_dense)),
            "tpu-v5e-t1": (TPU_V5E, TrafficCfg(batch=batch,
                                               kv_bytes_per_token_layer=kv_x)),
            "tpu-v5e-t1t2": (TPU_V5E, TrafficCfg(batch=batch,
                                                 kv_bytes_per_token_layer=kv_x_cpq)),
            # continuous-batching serving: paged dense arena (block-table
            # overhead included; the serving win is utilization, not bytes).
            # Decode reads PLUS the chunked-prefill arena writes: every
            # prompt token's K/V lands in the pages exactly once (no scratch
            # cache and no pack re-copy), amortized per generated token —
            # the serving-level half of the energy story. Charged at the
            # MEASURED slot utilization: idle slots amplify the per-step
            # weight stream 1/u and bill their share of static board power.
            "tpu-v5e-paged": (TPU_V5E, TrafficCfg(
                batch=batch, kv_bytes_per_token_layer=kv_paged,
                prefill_ctx=2048, gen_tokens=256,
                prefill_write_bytes_per_token_layer=kv_paged,
                slot_util=slot_u)),
            # mesh-sharded paged serving (PER-DEVICE traffic, mp=4 model
            # sharding as in bench_serving --mesh): each device sweeps only
            # its kv-head quarter of the arena (reads AND prefill writes
            # shrink 1/mp) and in exchange ships (mp-1)/mp of the per-head
            # output partials over the interconnect per generated token —
            # the paper's off-chip-movement accounting applied to the
            # partial concat. Weights stay replicated (engine places params
            # with P()), so w_bytes is unchanged per device.
            "tpu-v5e-paged-mp4": (TPU_V5E, TrafficCfg(
                batch=batch, kv_bytes_per_token_layer=kv_paged / 4,
                prefill_ctx=2048, gen_tokens=256,
                prefill_write_bytes_per_token_layer=kv_paged / 4,
                interconnect_bytes_per_token_layer=(
                    3 / 4 * cfg.num_heads * cfg.head_dim * 2),
                slot_util=slot_u)),
        }
        res = {}
        for name, (dev, sc) in variants.items():
            t, e = decode_token_cost(dev, n_params, L, sc)
            res[name] = (t, e)
            emit(f"e2e_b{batch}_{name}", t * 1e6,
                 f"tok_per_s={1 / t:.1f};mJ_per_tok={e * 1e3:.3f};"
                 f"icnx_B_per_tok={sc.interconnect_bytes_per_token_layer * L:.0f};"
                 f"slot_util={sc.slot_util:.2f}")
        ee = lambda a, b: (res[b][1] / res[a][1], res[b][0] / res[a][0])  # noqa: E731
        e_a, th_a = ee("pim-t1t2", "a100-dense")
        e_f, th_f = ee("pim-t1t2", "flightllm")
        emit(f"e2e_b{batch}_pim_vs_a100", 0.0,
             f"energy_eff={e_a:.1f}x(paper:159.9x);throughput={th_a:.1f}x(paper:49.6x)")
        emit(f"e2e_b{batch}_pim_vs_flightllm", 0.0,
             f"energy_eff={e_f:.1f}x(paper:34.8x);throughput={th_f:.1f}x(paper:29.2x)")
        e_t, th_t = ee("tpu-v5e-t1t2", "tpu-v5e-dense")
        emit(f"e2e_b{batch}_tpu_t1t2_vs_dense", 0.0,
             f"energy_eff={e_t:.2f}x;throughput={th_t:.2f}x (beyond-paper TPU adaptation)")
