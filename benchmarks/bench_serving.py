"""Continuous-batching vs static serving benchmark.

Workload: mixed prompt lengths + mixed target generation lengths, Poisson
arrivals (arrival gaps exponential in decode-step units). Both engines get
EQUAL ARENA BYTES: the static engine provisions ``num_slots`` contiguous rows
of the worst-case request length; the continuous engine gets the same token
capacity as a shared page pool.

Metrics per arrival rate:
  * token throughput (useful generated tokens per decode step, and per second)
  * mean/p90 completion latency in decode steps (arrival -> last token)
  * time-to-first-token and inter-token-latency p50/p95 in engine ticks —
    the head-of-line metrics chunked paged prefill exists to fix: a one-shot
    admission stalls every running row for the whole prompt's
    chunk-equivalents, a chunked admission interleaves one chunk per tick
  * arena utilization (valid tokens / provisioned tokens)

The static engine is the paper-baseline batch server: FIFO batches of
``num_slots`` requests, right-padded prompts, each batch runs until its
LONGEST target finishes (rows past their own target produce waste tokens).
Continuous batching retires rows at their target and refills the slot.

Workload builders and the continuous-run harness live in
``repro.serving.trace`` (importable: the auto-tuner and tests reuse them);
this file is the comparison/reporting CLI on top — plus the static-engine
baseline, which only the benchmarks care about. ``run_continuous`` /
``make_workload`` / ``equal_arena_serving`` etc. stay re-exported here for
back-compat.

  PYTHONPATH=src python benchmarks/bench_serving.py [--smoke]
"""
from __future__ import annotations

import argparse
import dataclasses
import sys
import time

from repro.launch._bootstrap import ensure_host_devices_for_mesh

# --mesh needs emulated host devices BEFORE the jax backend initializes
ensure_host_devices_for_mesh(sys.argv)

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.engine import ContinuousServeEngine, GenerationConfig, ServeEngine
from repro.serving.paged_cache import pages_needed
from repro.serving.scheduler import Request
from repro.serving.trace import (WorkItem, class_tails, equal_arena_serving,
                                 make_burst_workload, make_loopy_workload,
                                 make_slo_workload, make_templated_workload,
                                 make_workload, run_trace)

# back-compat alias: the continuous-run harness moved to repro.serving.trace
run_continuous = run_trace

__all__ = [
    "WorkItem", "class_tails", "equal_arena_serving", "make_burst_workload",
    "make_loopy_workload", "make_slo_workload", "make_templated_workload",
    "make_workload", "run_trace", "run_continuous", "run_static", "compare",
    "compare_admission", "templated_compare", "speculate_compare",
    "policy_sweep", "score_policy_run", "replica_sweep", "run_router",
    "failure_drill", "mesh_sweep", "main",
]


def run_static(cfg, params, work: list[WorkItem], num_slots: int, max_len: int,
               mode_rt=None):
    """FIFO batches of ``num_slots``; each batch decodes to its longest
    target. Useful tokens = per-request targets; the rest is padding waste."""
    eng = ServeEngine(cfg, params, rt=mode_rt, max_len=max_len)
    useful = waste = decode_steps = 0
    latencies = []
    clock = 0.0  # decode-step clock
    t0 = time.time()
    for i in range(0, len(work), num_slots):
        batch = work[i:i + num_slots]
        S = max(len(w.prompt) for w in batch)
        toks = np.stack([np.pad(w.prompt, (0, S - len(w.prompt)), mode="edge")
                         for w in batch])
        max_t = max(w.target for w in batch)
        gen = GenerationConfig(max_new_tokens=max_t)
        # the batch cannot start before its last member arrives
        clock = max(clock, max(w.arrival for w in batch))
        out, stats = eng.generate({"tokens": jnp.asarray(toks)}, gen)
        decode_steps += stats["decode_steps"]
        clock += stats["decode_steps"]
        for w in batch:
            useful += w.target
            waste += max_t - w.target
            latencies.append(clock - w.arrival)
    wall = time.time() - t0
    provisioned = num_slots * max_len
    return {
        "engine": "static",
        "useful_tokens": useful,
        "waste_tokens": waste,
        "decode_steps": decode_steps,
        "tokens_per_step": useful / max(decode_steps, 1),
        "latency_mean": float(np.mean(latencies)),
        "latency_p90": float(np.percentile(latencies, 90)),
        "arena_utilization": useful / max(decode_steps * provisioned, 1) * num_slots,
        "wall_time_s": wall,
        "tokens_per_s": useful / max(wall, 1e-9),
    }


def compare(cfg, params, *, rate: float, n_requests: int, num_slots: int,
            seed: int = 0, mode_rt=None, prefill_chunk: int = 16,
            long_prompts: bool = False):
    kw = dict(long_prompt=(40, 72), p_long_prompt=0.3) if long_prompts else {}
    work = make_workload(seed, n_requests, cfg.vocab_size, rate, **kw)
    max_len = max(len(w.prompt) + w.target for w in work)
    serving = equal_arena_serving(num_slots, max_len, page_size=8,
                                  prefill_chunk=prefill_chunk)
    st = run_static(cfg, params, work, num_slots, max_len, mode_rt)
    ct = run_continuous(cfg, params, work, serving, mode_rt)
    return st, ct


def compare_admission(cfg, params, *, rate: float, n_requests: int,
                      num_slots: int, seed: int = 0, prefill_chunk: int = 16):
    """Chunked vs one-shot admission on the SAME long-prompt Poisson workload
    at equal arena bytes: the interleaving win shows up as lower tail
    inter-token latency (p95 ITL) for the rows that keep decoding while a
    long prompt streams in."""
    work = make_workload(seed, n_requests, cfg.vocab_size, rate,
                         long_prompt=(40, 72), p_long_prompt=0.3)
    max_len = max(len(w.prompt) + w.target for w in work)
    chunked = run_continuous(cfg, params, work, equal_arena_serving(
        num_slots, max_len, page_size=8, prefill_chunk=prefill_chunk))
    oneshot = run_continuous(cfg, params, work, equal_arena_serving(
        num_slots, max_len, page_size=8, prefill_chunk=0,
        bucket=prefill_chunk))
    return chunked, oneshot


def templated_compare(cfg, params, emit, *, rate: float = 1.0,
                      n_sessions: int = 4, num_slots: int = 4, seed: int = 0,
                      smoke: bool = False):
    """Prefix sharing on the shared-system-prompt multi-turn trace: the SAME
    continuous engine with sharing ON vs OFF (token-exact by construction),
    plus the static baseline for the acceptance bar. Reported per arm:
    prefill bytes actually written per request (mounted pages write nothing),
    the fraction of prompt pages served from the index instead of recomputed,
    and tail TTFT — the turns that resend a resident conversation start
    decoding after prefilling only their unshared tail."""
    work = make_templated_workload(seed, n_sessions, cfg.vocab_size, rate)
    max_len = max(len(w.prompt) + w.target for w in work)
    base = equal_arena_serving(num_slots, max_len, page_size=8,
                               prefill_chunk=16)
    on = run_continuous(cfg, params, work,
                        dataclasses.replace(base, share_prefix=True))
    off = run_continuous(cfg, params, work, base)
    st = run_static(cfg, params, work, num_slots, max_len)
    prompt_pages = sum(pages_needed(len(w.prompt), base.page_size)
                       for w in work)
    for tag, r in (("shared", on), ("unshared", off)):
        frac = r["shared_prefix_pages"] / max(prompt_pages, 1)
        emit(f"serving_templated_{tag}", r["wall_time_s"] * 1e6,
             f"tok_per_step={r['tokens_per_step']:.2f};"
             f"prefill_write_bytes_per_req="
             f"{r['prefill_write_bytes'] / len(work):.0f};"
             f"shared_page_fraction={frac:.3f};"
             f"prefix_hits={r['prefix_hits']};cow={r['cow_copies']};"
             f"ttft_p50={r['ttft_p50']:.1f};ttft_p95={r['ttft_p95']:.1f}")
    emit("serving_templated_static", st["wall_time_s"] * 1e6,
         f"tok_per_step={st['tokens_per_step']:.2f};"
         f"lat_p90={st['latency_p90']:.1f}")
    ratio = on["tokens_per_step"] / max(st["tokens_per_step"], 1e-9)
    emit("serving_templated_speedup", 0.0,
         f"continuous_vs_static={ratio:.2f}x (target >= 1.5x)")
    if smoke:
        # sharing is an allocator optimization, not a model change: the
        # streams must be bit-identical with it on or off
        assert np.array_equal(on["tokens"], off["tokens"]), (
            "prefix sharing changed generated tokens on the templated trace")
        assert on["prefix_hits"] > 0, (
            "templated trace produced no prefix hits with sharing on")
        assert on["prefill_write_bytes"] < off["prefill_write_bytes"], (
            f"sharing did not reduce prefill writes: "
            f"{on['prefill_write_bytes']} vs {off['prefill_write_bytes']}")
        assert on["ttft_p95"] < off["ttft_p95"], (
            f"shared TTFT p95 {on['ttft_p95']:.1f} not better than "
            f"unshared {off['ttft_p95']:.1f}")
        assert ratio >= 1.5, (
            f"templated continuous-vs-static {ratio:.2f}x < 1.5x floor")
        emit("serving_templated_smoke", 0.0,
             f"PASS ttft_p95 {on['ttft_p95']:.1f} < {off['ttft_p95']:.1f}; "
             f"write_bytes {on['prefill_write_bytes']} < "
             f"{off['prefill_write_bytes']}; speedup={ratio:.2f}x")
    return on, off, st


def speculate_compare(cfg, params, emit, *, seed: int = 0, spec_k: int = 4,
                      smoke: bool = False):
    """Speculative decoding on vs off at equal arena bytes, at the two
    occupancy extremes the clock model distinguishes:

    * LOW occupancy (serialized trace, 1 resident row): decode is
      weight-stream-bound — one model invocation per token. The verify
      chunk scores ``k`` drafted tokens in that same single invocation, so
      every acceptance is a free token: ITL (ticks between committed
      tokens) drops below 1 and tokens/step rises by the accept rate.
    * HIGH occupancy (Poisson trace filling all slots): the batched decode
      already amortizes the weight stream over the resident rows, while
      each speculative row pays a PRIVATE verify invocation — speculation
      is reported honestly as a loss here (the engine-level takeaway:
      gate speculation on occupancy; ``SamplingParams.speculate`` is the
      per-request switch).

    Both arms assert greedy bit-parity speculative on-vs-off (f32 — same
    recast contract as ``mesh_sweep``); ``--smoke`` additionally asserts
    the low-occupancy ITL win and that continuous serving keeps the 1.5x
    over static on the high-occupancy trace."""
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = jax.tree.map(lambda a: a.astype(jnp.float32)
                          if a.dtype == jnp.bfloat16 else a, params)

    def pair(work, num_slots):
        max_len = max(len(w.prompt) + w.target for w in work)
        base = equal_arena_serving(num_slots, max_len, page_size=8)
        off = run_continuous(cfg, params, work, base)
        on = run_continuous(cfg, params, work,
                            dataclasses.replace(base, spec_len=spec_k))
        assert np.array_equal(on["tokens"], off["tokens"]), (
            "speculative decoding changed greedy tokens (verify draws must "
            "be bit-identical to the decode path)")
        return off, on, max_len

    def row(tag, r):
        emit(f"serving_spec_{tag}", r["wall_time_s"] * 1e6,
             f"tok_per_step={r['tokens_per_step']:.2f};"
             f"itl_mean={r['itl_mean']:.2f};itl_p50={r['itl_p50']:.1f};"
             f"itl_p95={r['itl_p95']:.1f};"
             f"accept_rate={r['spec_accept_rate']:.2f};"
             f"accepted_per_step={r['spec_accepted_per_step']:.2f};"
             f"verify_steps={r['spec_steps']}")

    # low occupancy: arrivals spaced far past each request's lifetime
    work_low = make_loopy_workload(seed, 3, cfg.vocab_size, gap=400.0)
    low_off, low_on, _ = pair(work_low, num_slots=4)
    row("low_off", low_off)
    row("low_on", low_on)

    # high occupancy: the acceptance suite's mixed heavy-tailed Poisson
    # trace keeping all 4 slots busy (and the static engine padding)
    work_high = make_workload(seed, 24, cfg.vocab_size, rate=4.0)
    high_off, high_on, max_len = pair(work_high, num_slots=4)
    st = run_static(cfg, params, work_high, 4, max_len)
    row("high_off", high_off)
    row("high_on", high_on)
    emit("serving_spec_static", st["wall_time_s"] * 1e6,
         f"tok_per_step={st['tokens_per_step']:.2f}")
    bar = high_off["tokens_per_step"] / max(st["tokens_per_step"], 1e-9)
    bar_on = high_on["tokens_per_step"] / max(st["tokens_per_step"], 1e-9)
    emit("serving_spec_bar", 0.0,
         f"continuous_vs_static={bar:.2f}x;spec_arm={bar_on:.2f}x "
         f"(target >= 1.5x)")

    if smoke:
        assert low_on["itl_p95"] <= low_off["itl_p95"], (
            f"spec p95 ITL {low_on['itl_p95']:.2f} worse than baseline "
            f"{low_off['itl_p95']:.2f} at low occupancy")
        assert low_on["itl_mean"] < low_off["itl_mean"], (
            f"spec mean ITL {low_on['itl_mean']:.2f} not better than "
            f"baseline {low_off['itl_mean']:.2f} at low occupancy")
        assert low_on["spec_accept_rate"] > 0, (
            "loopy trace produced zero accepted draft tokens")
        assert bar >= 1.5, (
            f"continuous-vs-static {bar:.2f}x < 1.5x floor on the "
            f"speculative high-occupancy trace")
        emit("serving_spec_smoke", 0.0,
             f"PASS itl_mean {low_on['itl_mean']:.2f} < "
             f"{low_off['itl_mean']:.2f}; itl_p95 {low_on['itl_p95']:.1f} "
             f"<= {low_off['itl_p95']:.1f}; "
             f"accept_rate={low_on['spec_accept_rate']:.2f}; "
             f"bar={bar:.2f}x >= 1.5x")
    return low_off, low_on, high_off, high_on


def score_policy_run(run: dict, work: list[WorkItem], slos) -> dict:
    """Per-class latency + SLO-attainment % + Jain fairness for one policy
    run. A request attains its SLO when its TTFT meets ``ttft_target`` AND
    its p95 inter-token gap meets ``itl_target`` (both in engine ticks).
    Jain's index is computed over per-request service rates
    (tokens / resident time): 1.0 = perfectly even service, 1/n = one
    request got everything."""
    res = run["results"]
    ttft_by_class: dict[str, list] = {}
    attained = 0
    rates = []
    for w, slo in zip(work, slos):
        r = res[w.rid]
        if r["first_token_step"] < 0:
            # never produced a token (oom / unschedulable): a hard SLO miss
            # and zero service — excluded from the TTFT percentiles (its
            # sentinel -1 stamp is not a latency), counted everywhere else
            rates.append(0.0)
            continue
        ttft = r["first_token_step"] - w.arrival
        gaps = (np.diff(r["token_steps"])
                if len(r["token_steps"]) > 1 else np.zeros(1))
        ok = (ttft <= slo.ttft_target
              and float(np.percentile(gaps, 95)) <= slo.itl_target)
        attained += bool(ok)
        ttft_by_class.setdefault(slo.name, []).append(ttft)
        rates.append(len(r["tokens"]) / max(r["done_step"] - w.arrival, 1e-9))
    x = np.asarray(rates, np.float64)
    out = {
        "policy": run["policy"],
        "tokens_per_step": run["tokens_per_step"],
        "slo_attained_pct": 100.0 * attained / len(work),
        "jain_fairness": float(x.sum() ** 2 / (len(x) * (x ** 2).sum() + 1e-12)),
        "preemptions": run["preemptions"],
        "deescalations": run["deescalations"],
    }
    for name, vals in ttft_by_class.items():
        out[f"ttft_p50_{name}"] = float(np.percentile(vals, 50))
        out[f"ttft_p95_{name}"] = float(np.percentile(vals, 95))
    return out


def policy_sweep(cfg, params, emit, *, rate: float = 2.0,
                 n_requests: int = 24, num_slots: int = 4, seed: int = 0,
                 policies=("fifo", "priority", "slo")):
    """``--policy`` comparison table: the same mixed-class Poisson trace
    through each scheduler policy at equal arena bytes, scored on per-class
    p95 TTFT, SLO-attainment %, and Jain fairness — plus the static-engine
    baseline for the throughput bar. Returns {policy: scores} + 'static'."""
    work, slos = make_slo_workload(seed, n_requests, cfg.vocab_size, rate)
    max_len = max(len(w.prompt) + w.target for w in work)
    serving = equal_arena_serving(num_slots, max_len, page_size=8)
    st = run_static(cfg, params, work, num_slots, max_len)
    rows = {"static": st}
    for pol in policies:
        run = run_continuous(cfg, params, work, serving, policy=pol,
                             slos=slos)
        s = rows[pol] = score_policy_run(run, work, slos)
        emit(f"serving_policy_{pol}", run["wall_time_s"] * 1e6,
             f"tok_per_step={s['tokens_per_step']:.2f};"
             f"slo_attained={s['slo_attained_pct']:.0f}%;"
             f"jain={s['jain_fairness']:.3f};"
             f"ttft_p95_hi={s.get('ttft_p95_interactive', 0.0):.1f};"
             f"ttft_p95_lo={s.get('ttft_p95_batch', 0.0):.1f};"
             f"preempt={s['preemptions']}")
    emit("serving_policy_static", st["wall_time_s"] * 1e6,
         f"tok_per_step={st['tokens_per_step']:.2f} (baseline)")
    return rows


def run_router(cfg, params, work: list[WorkItem], serving: ServingCfg, *,
               num_replicas: int, placement: str = "load", slos=None,
               donor=None):
    """One ``ReplicaRouter`` run over the trace. Every replica gets its own
    ``serving`` arena (data-parallel scale-out: capacity grows with replica
    count, the paper's add-a-DIMM story). ``donor`` (any engine of the same
    (cfg, rt)) shares its jitted step functions with every replica —
    sweeping replica counts compiles once."""
    from repro.serving.router import ReplicaRouter

    router = ReplicaRouter(cfg, params, num_replicas=num_replicas,
                           serving=serving, placement=placement)
    if donor is not None:
        for eng in router.engines:
            eng.adopt_compiled(donor)
    reqs = [Request(rid=w.rid, prompt=w.prompt, max_new_tokens=w.target,
                    arrival=w.arrival,
                    slo=None if slos is None else slos[i])
            for i, w in enumerate(work)]
    res, stats = router.serve(reqs, GenerationConfig(max_new_tokens=max(
        w.target for w in work)))
    out = {
        "replicas": num_replicas,
        "placement": stats["placement"],
        "useful_tokens": stats["generated_tokens"],
        "decode_steps_max": stats["decode_steps_max"],
        "tokens_per_step": stats["tokens_per_step"],
        "wall_time_s": stats["wall_time_s"],
        "tokens_per_s": stats["tokens_per_s"],
        "preemptions": stats["preemptions"],
        "defrags": stats["defrags"],
        "arena_bytes_total": stats["arena_bytes_total"],
        "interconnect_bytes_per_token": stats["interconnect_bytes_per_token"],
        "migrated_requests": stats["migrated_requests"],
        "per_replica": stats["per_replica"],
        "tokens": np.concatenate([res[w.rid]["tokens"] for w in work]),
        "results": res,
    }
    # per-SLO-class tail TTFT on each replica's own tick clock (replicas
    # tick in lockstep, so the clocks are comparable)
    if slos is not None:
        by_class: dict[str, list] = {}
        for w, slo in zip(work, slos):
            r = res[w.rid]
            if r["first_token_step"] >= 0:
                by_class.setdefault(slo.name, []).append(
                    r["first_token_step"] - w.arrival)
        for name, vals in by_class.items():
            out[f"ttft_p95_{name}"] = float(np.percentile(vals, 95))
    return out


def replica_sweep(cfg, params, emit, *, counts=(1, 2, 4),
                  placement: str = "load", rate: float = 6.0,
                  n_requests: int = 64, num_slots: int = 4, seed: int = 0):
    """Throughput-vs-replica-count table on ONE heavy-tailed burst trace:
    aggregate tokens/step (total generated over the busiest replica's decode
    clock) and per-SLO-class p95 TTFT at each count, with the per-replica
    breakdown inline. Greedy decoding is asserted token-identical across
    counts — placement moves requests between replicas, never changes what
    they generate. Returns {count: run}."""
    work, slos = make_burst_workload(seed, n_requests, cfg.vocab_size, rate)
    max_len = max(len(w.prompt) + w.target for w in work)
    serving = equal_arena_serving(num_slots, max_len, page_size=8)
    # one never-served engine donates its jit wrappers to every replica of
    # every count — the whole sweep compiles each step function once
    donor = ContinuousServeEngine(cfg, params, serving=serving)
    rows = {}
    for n in counts:
        r = rows[n] = run_router(cfg, params, work, serving, num_replicas=n,
                                 placement=placement, slos=slos, donor=donor)
        assert np.array_equal(rows[counts[0]]["tokens"], r["tokens"]), (
            f"replicas={n} broke greedy token parity vs replicas={counts[0]}")
        breakdown = "|".join(
            f"r{p['replica']}:{p['generated_tokens']}tok"
            f"@{p['tokens_per_step']:.2f}/step" for p in r["per_replica"])
        emit(f"serving_router_n{n}", r["wall_time_s"] * 1e6,
             f"placement={placement};"
             f"agg_tok_per_step={r['tokens_per_step']:.2f};"
             f"steps_max={r['decode_steps_max']};"
             f"ttft_p95_hi={r.get('ttft_p95_interactive', 0.0):.1f};"
             f"ttft_p95_lo={r.get('ttft_p95_batch', 0.0):.1f};"
             f"arena_MiB_total={r['arena_bytes_total'] / 2**20:.3f};"
             f"per_replica={breakdown}")
    base = rows[counts[0]]
    for n in counts[1:]:
        emit(f"serving_router_scaling_n{n}", 0.0,
             f"agg_vs_single={rows[n]['tokens_per_step'] / max(base['tokens_per_step'], 1e-9):.2f}x"
             f" (ideal {n}.0x)")
    return rows


def failure_drill(cfg, params, emit, *, seed: int = 0, rate: float = 6.0,
                  n_requests: int = 48, num_slots: int = 4,
                  smoke: bool = False):
    """Kill a replica mid-burst and measure the recovery: the SAME heavy-
    tailed burst trace through a 2-replica router fault-free (the reference)
    and with an injected crash window on replica 0 (probe auto-drain ->
    snapshot migration -> backoff recovery probe -> re-admission). Reported:
    ticks from auto-drain to re-admission, the goodput dip while degraded
    (tokens/tick at 1 replica vs the fault-free mean), and the robustness
    counters. With ``smoke``: every output delivered exactly once, token
    streams bit-identical to the fault-free run, nothing timed out or shed
    (deadlines off), and the fault-FREE arm keeps the 1.5x
    continuous-vs-static bar on this trace."""
    from repro.serving.faults import FaultEvent, FaultPlan
    from repro.serving.router import ReplicaRouter

    work, slos = make_burst_workload(seed, n_requests, cfg.vocab_size, rate)
    max_len = max(len(w.prompt) + w.target for w in work)
    serving = dataclasses.replace(
        equal_arena_serving(num_slots, max_len, page_size=8),
        probe_interval=2, probe_failures=2, probe_backoff=2, auto_drain=True)
    donor = ContinuousServeEngine(cfg, params, serving=serving)

    def run(plans):
        router = ReplicaRouter(cfg, params, num_replicas=2, serving=serving,
                               placement="load", fault_plans=plans)
        for eng in router.engines:
            eng.adopt_compiled(donor)
        router.reset()
        reqs = [Request(rid=w.rid, prompt=w.prompt, max_new_tokens=w.target,
                        arrival=w.arrival, slo=slos[i])
                for i, w in enumerate(work)]
        t0 = time.time()
        for r in sorted(reqs, key=lambda r: r.arrival):
            router.add_request(r)
        trace = []                    # useful tokens emitted per router tick
        drain_tick = recover_tick = -1
        for t in range(4000):
            if not router.has_unfinished():
                break
            evs = router.step()
            trace.append(sum(1 for e in evs if e.token >= 0))
            if drain_tick < 0 and router._draining:
                drain_tick = t
            if drain_tick >= 0 and recover_tick < 0 and not router._draining:
                recover_tick = t
        else:
            raise AssertionError("failure drill did not converge")
        wall = time.time() - t0
        return router, np.asarray(trace), drain_tick, recover_tick, wall

    # fault-free reference (and the static baseline for the acceptance bar)
    ref_router, ref_trace, _, _, ref_wall = run(None)
    ref_res = ref_router.results()
    st = run_static(cfg, params, work, num_slots, max_len)
    ref_stats = ref_router.stats()
    bar = ref_stats["tokens_per_step"] / max(st["tokens_per_step"], 1e-9)
    emit("serving_failures_reference", ref_wall * 1e6,
         f"agg_tok_per_step={ref_stats['tokens_per_step']:.2f};"
         f"vs_static={bar:.2f}x (target >= 1.5x);"
         f"ticks={len(ref_trace)}")

    # injected run: a crash window opens on replica 0 mid-burst, long enough
    # for the monitor to hit its threshold and short enough to recover
    plan = FaultPlan((FaultEvent(6, "crash", 6),))
    router, trace, drain_tick, recover_tick, wall = run([plan, None])
    res = router.results()
    stats = router.stats()
    assert drain_tick >= 0, "crash window never tripped the auto-drain"
    assert recover_tick > drain_tick, "replica never re-admitted"
    recovery_ticks = recover_tick - drain_tick
    degraded = trace[drain_tick:recover_tick]
    dip = (float(np.mean(degraded)) / max(float(np.mean(ref_trace)), 1e-9)
           if len(degraded) else 1.0)
    emit("serving_failures_injected", wall * 1e6,
         f"recovery_ticks={recovery_ticks};"
         f"goodput_degraded_vs_ref={dip:.2f}x;"
         f"auto_drains={stats['auto_drains']};"
         f"recoveries={stats['recoveries']};"
         f"migrated={stats['migrated_requests']};"
         f"ticks={len(trace)} (+{len(trace) - len(ref_trace)} vs ref)")

    if smoke:
        # exactly-once delivery under the crash: every generated token index
        # seen once and gapless, one finished event per request
        seen: dict[int, list] = {}
        finished: dict[int, int] = {}
        for ev in router.pending_outputs():
            if ev.token >= 0:
                seen.setdefault(ev.rid, []).append(ev.index)
            if ev.finished:
                finished[ev.rid] = finished.get(ev.rid, 0) + 1
        assert set(res) == set(ref_res), "lost or phantom requests"
        for w in work:
            toks = list(ref_res[w.rid]["tokens"])
            assert list(res[w.rid]["tokens"]) == toks, (
                f"rid {w.rid} diverged across the crash (replay not exact)")
            assert sorted(seen.get(w.rid, [])) == list(range(len(toks))), (
                f"rid {w.rid} outputs lost or duplicated")
            assert finished.get(w.rid, 0) == 1
        assert stats["timeouts"] == 0 and stats["shed"] == 0
        assert stats["dense_pages_leaked"] == 0
        assert bar >= 1.5, (
            f"fault-free router {bar:.2f}x vs static < 1.5x floor")
        emit("serving_failures_smoke", 0.0,
             f"PASS exactly-once x{len(work)}; parity bit-exact; "
             f"recovery={recovery_ticks} ticks; bar={bar:.2f}x >= 1.5x")
    return stats


def paged_decode_step_latency(cfg, params, serving: ServingCfg, *,
                              use_paged_kernels: bool, n_iters: int = 30
                              ) -> float:
    """Median per-step decode latency (s) of the jitted continuous decode
    step on a FULL machine: every slot occupied at near-capacity length, so
    the measured work is the per-token cache sweep — fused paged kernels vs
    the jnp gather path at identical arena bytes (same ServingCfg, only the
    kernel flag differs)."""
    rt = dataclasses.replace(cfg.attention, paged_kernels=use_paged_kernels)
    caches = M.init_paged_caches(cfg, rt, serving)
    B, mb = serving.num_slots, serving.max_blocks_per_slot
    assert serving.num_pages > B * mb, "latency probe wants a full machine"
    bt = np.arange(1, B * mb + 1, dtype=np.int32).reshape(B, mb)
    rows = pgc.RowState(
        lengths=jnp.full((B,), serving.page_size * mb - 1, jnp.int32),
        block_table=jnp.asarray(bt),
        active=jnp.ones((B,), bool),
        tier=jnp.zeros((B,), jnp.int32))
    from functools import partial
    decode = jax.jit(partial(M.decode_step_rows, cfg, rt))
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, _ = decode(params, tok, rows, caches)   # compile
    jax.block_until_ready(logits)
    times = []
    for _ in range(n_iters):
        t0 = time.perf_counter()
        logits, _ = decode(params, tok, rows, caches)
        jax.block_until_ready(logits)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def compare_decode_latency(cfg, params, *, num_slots: int = 4,
                           max_len: int = 128, page_size: int = 8,
                           n_iters: int = 30) -> tuple[float, float]:
    """(fused, gather) median decode-step latency at equal arena bytes."""
    serving = equal_arena_serving(num_slots, max_len, page_size)
    fused = paged_decode_step_latency(cfg, params, serving,
                                      use_paged_kernels=True, n_iters=n_iters)
    gather = paged_decode_step_latency(cfg, params, serving,
                                       use_paged_kernels=False, n_iters=n_iters)
    return fused, gather


def mesh_sweep(cfg, params, emit, *, n_requests: int = 10, rate: float = 1.0):
    """1/2/4-way model sharding of the paged arenas on emulated host devices
    (--mesh): per-device arena bytes shrink ~1/mp (each device holds its
    kv-head slice of every page) while tokens/step stays flat — plus the
    interconnect cost (per-head partial concat bytes per generated token),
    mirroring the paper's off-chip-movement accounting. The throughput
    acceptance bar stays on the unsharded path (CPU emulation serializes
    shards, so sharded wall clock is not meaningful here)."""
    from repro.launch.mesh import make_serve_mesh

    # f32: the greedy-parity assert below is token-exact at f32 (the same
    # contract tests/test_serving_sharded.py pins); bf16 argmax ties can flip
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = jax.tree.map(lambda a: a.astype(jnp.float32)
                          if a.dtype == jnp.bfloat16 else a, params)
    work = make_workload(0, n_requests, cfg.vocab_size, rate)
    max_len = max(len(w.prompt) + w.target for w in work)
    serving = equal_arena_serving(4, max_len, page_size=8)
    base_tokens = None
    for mp in (1, 2, 4):
        mesh = make_serve_mesh(1, mp) if mp > 1 else None
        r = run_continuous(cfg, params, work, serving,
                           mode_rt=dataclasses.replace(cfg.attention, mesh=mesh))
        if base_tokens is None:
            base_tokens = r["tokens"]
        else:
            assert np.array_equal(base_tokens, r["tokens"]), (
                f"mesh mp={mp} broke greedy parity vs single device")
        emit(f"serving_mesh_mp{mp}", r["wall_time_s"] * 1e6,
             f"tok_per_step={r['tokens_per_step']:.2f};"
             f"arena_MiB_per_device={r['arena_bytes_per_device'] / 2**20:.3f};"
             f"arena_MiB_total={r['arena_bytes_total'] / 2**20:.3f};"
             f"icnx_B_per_tok={r['interconnect_bytes_per_token']:.1f}")


def main(emit, smoke: bool = False, mesh: bool = False,
         policies=("fifo", "priority", "slo"), replicas: int = 0,
         placement: str = "load", workload: str = "mixed",
         failures: bool = False, speculate: bool = False):
    from repro import kernels as K

    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    if speculate:
        # speculative-decoding measurement (low vs high occupancy, on vs
        # off); the throughput suite below is a separate invocation
        speculate_compare(cfg, params, emit, smoke=smoke)
        return
    if failures:
        # fault-injection drill (kill a replica mid-burst, measure recovery);
        # the throughput suite below is a separate invocation
        failure_drill(cfg, params, emit, smoke=smoke)
        return
    if workload == "templated":
        # prefix-sharing measurement on the shared-system-prompt trace; the
        # mixed-traffic suite below is a separate invocation
        templated_compare(cfg, params, emit, smoke=smoke)
        return
    if mesh:
        mesh_sweep(cfg, params, emit)

    # multi-replica router sweep on the heavy-tailed burst trace: aggregate
    # tokens/step and per-class tail TTFT vs replica count
    router_rows = None
    if replicas:
        counts = tuple(sorted({c for c in (1, 2, 4) if c <= replicas}
                              | {replicas}))
        # 96 requests: enough depth per replica that the end-of-trace drain
        # (a ~fixed straggler cost) doesn't cap the measured scaling
        router_rows = replica_sweep(cfg, params, emit, counts=counts,
                                    placement=placement, n_requests=96)
    rates = (1.0,) if smoke else (0.25, 1.0, 4.0)
    n_requests = 12 if smoke else 32
    worst = 0.0
    for rate in rates:
        st, ct = compare(cfg, params, rate=rate, n_requests=n_requests,
                         num_slots=4)
        ratio = ct["tokens_per_step"] / max(st["tokens_per_step"], 1e-9)
        worst = ratio if worst == 0 else min(worst, ratio)
        for r in (st, ct):
            lat = ""
            if "itl_p95" in r:
                lat = (f";ttft_p50={r['ttft_p50']:.1f};ttft_p95={r['ttft_p95']:.1f}"
                       f";itl_p50={r['itl_p50']:.1f};itl_p95={r['itl_p95']:.1f}")
            emit(f"serving_rate{rate}_{r['engine']}", r["wall_time_s"] * 1e6,
                 f"tok_per_step={r['tokens_per_step']:.2f};"
                 f"tok_per_s={r['tokens_per_s']:.1f};"
                 f"lat_mean={r['latency_mean']:.1f};lat_p90={r['latency_p90']:.1f};"
                 f"arena_util={r['arena_utilization']:.3f}" + lat)
        emit(f"serving_rate{rate}_speedup", 0.0,
             f"continuous_vs_static={ratio:.2f}x (target >= 1.5x)")

    # per-tick idle-vs-active utilization trace summary (rate=1.0 run):
    # the measured series bench_e2e_energy folds into its device model so
    # the paged rows charge idle energy honestly (not peak-utilization)
    emit("serving_util_trace", 0.0,
         f"slot_util={ct['slot_utilization']:.3f};"
         f"active_rows_mean={float(np.mean(ct['trace_active_rows'])):.2f};"
         f"arena_util_mean={float(np.mean(ct['trace_arena_util'])):.3f};"
         f"ticks={len(ct['trace_active_rows'])}")

    # scheduler-policy comparison on the mixed-class (interactive vs batch)
    # trace: SLO-attainment %, Jain fairness, per-class tail TTFT
    policy_rows = policy_sweep(cfg, params, emit,
                               n_requests=16 if smoke else 32,
                               policies=policies)

    # chunked vs one-shot admission on long-prompt traffic at equal arena
    # bytes and equal clock quantum — the head-of-line removal measurement
    chunked, oneshot = compare_admission(cfg, params, rate=1.0,
                                         n_requests=n_requests, num_slots=4)
    for r in (chunked, oneshot):
        emit(f"serving_admission_{r['engine']}", r["wall_time_s"] * 1e6,
             f"tok_per_step={r['tokens_per_step']:.2f};"
             f"ttft_p50={r['ttft_p50']:.1f};ttft_p95={r['ttft_p95']:.1f};"
             f"itl_p50={r['itl_p50']:.1f};itl_p95={r['itl_p95']:.1f};"
             f"chunks={r['prefill_chunks']}")
    emit("serving_admission_itl", 0.0,
         f"chunked_vs_oneshot_p95_itl={chunked['itl_p95']:.1f}/"
         f"{oneshot['itl_p95']:.1f} (target <=)")

    # per-step decode latency with/without the fused paged kernels at equal
    # arena bytes — the gather-overhead-removal measurement
    fused, gather = compare_decode_latency(cfg, params, num_slots=4,
                                           max_len=128, page_size=8,
                                           n_iters=10 if smoke else 30)
    emit("serving_decode_step_fused", fused * 1e6,
         f"interpret={K.INTERPRET}")
    emit("serving_decode_step_gather", gather * 1e6,
         f"fused_vs_gather={fused / gather:.2f}x (target <= 1.0x on TPU)")

    if smoke:
        assert worst >= 1.5, (
            f"continuous batching speedup {worst:.2f}x < 1.5x acceptance floor")
        # chunked admission must improve the decode tail (p95 ITL) on the
        # mixed-length Poisson workload — the interleave is the whole point
        assert chunked["itl_p95"] <= oneshot["itl_p95"], (
            f"chunked p95 ITL {chunked['itl_p95']:.1f} worse than one-shot "
            f"{oneshot['itl_p95']:.1f}")
        emit("serving_admission_smoke", 0.0,
             f"PASS itl_p95 {chunked['itl_p95']:.1f} <= {oneshot['itl_p95']:.1f}")
        if {"fifo", "priority"} <= set(policy_rows):
            # priority scheduling must strictly improve the high class's
            # tail TTFT over FIFO on the mixed trace — without giving back
            # the continuous-batching throughput bar vs the static engine
            hi_f = policy_rows["fifo"]["ttft_p95_interactive"]
            hi_p = policy_rows["priority"]["ttft_p95_interactive"]
            assert hi_p < hi_f, (
                f"priority p95 interactive TTFT {hi_p:.1f} not better than "
                f"fifo {hi_f:.1f}")
            bar = (policy_rows["priority"]["tokens_per_step"]
                   / max(policy_rows["static"]["tokens_per_step"], 1e-9))
            assert bar >= 1.5, (
                f"priority policy throughput {bar:.2f}x vs static < 1.5x")
            emit("serving_policy_smoke", 0.0,
                 f"PASS ttft_p95_hi {hi_p:.1f} < {hi_f:.1f} (fifo); "
                 f"throughput {bar:.2f}x >= 1.5x")
        if not K.INTERPRET:
            # compiled kernels: fused decode must not be slower than
            # materializing the logical views (small timer slack)
            assert fused <= gather * 1.05, (
                f"fused paged-kernel decode {fused * 1e3:.2f}ms slower than "
                f"gather path {gather * 1e3:.2f}ms at equal arena bytes")
            emit("serving_kernel_smoke", 0.0,
                 f"PASS fused_vs_gather={fused / gather:.2f}x")
        else:
            # interpret mode emulates the kernel op-by-op — timing it would
            # benchmark the emulator, not the kernel; report only
            emit("serving_kernel_smoke", 0.0,
                 "SKIP latency bar (interpret mode; compiled-TPU only)")
        if router_rows is not None and len(router_rows) > 1:
            counts = sorted(router_rows)
            hi, lo = counts[-1], counts[0]
            scale = (router_rows[hi]["tokens_per_step"]
                     / max(router_rows[lo]["tokens_per_step"], 1e-9))
            # data-parallel scale-out bar: 4 replicas must deliver >= 3x the
            # single-replica aggregate tokens/step on the burst trace
            floor = 3.0 if hi >= 4 * max(lo, 1) else 0.75 * hi / max(lo, 1)
            assert scale >= floor, (
                f"router scaling {scale:.2f}x at {hi} replicas < "
                f"{floor:.1f}x floor")
            emit("serving_router_smoke", 0.0,
                 f"PASS n{hi}_vs_n{lo}={scale:.2f}x >= {floor:.1f}x; "
                 f"ttft_p95_hi={router_rows[hi].get('ttft_p95_interactive', 0.0):.1f}")
        emit("serving_smoke", 0.0, f"PASS speedup={worst:.2f}x")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one small rate; asserts the >=1.5x acceptance bar")
    ap.add_argument("--mesh", action="store_true",
                    help="sweep 1/2/4-way model sharding of the paged arenas "
                         "on emulated host devices (reports per-device arena "
                         "bytes, tokens/step, interconnect bytes/token)")
    ap.add_argument("--policy", default="all",
                    choices=["all", "fifo", "priority", "slo"],
                    help="scheduler policies to compare on the mixed-class "
                         "trace (SLO-attainment %% / Jain fairness table); "
                         "default runs all three")
    ap.add_argument("--replicas", type=int, default=0,
                    help="sweep the multi-replica router at 1..N replicas "
                         "(subset of {1,2,4} plus N) on a heavy-tailed burst "
                         "trace; with --smoke, 4 replicas must hit >= 3x the "
                         "single-replica aggregate tokens/step (0 = skip)")
    ap.add_argument("--placement", default="load",
                    choices=["rr", "load", "slo"],
                    help="router placement policy for --replicas")
    ap.add_argument("--failures", action="store_true",
                    help="fault-injection drill: the burst trace through a "
                         "2-replica router fault-free vs with a crash window "
                         "on replica 0 (auto-drain -> migrate -> recover); "
                         "reports recovery ticks + goodput dip; with --smoke "
                         "asserts exactly-once delivery, bit-exact parity "
                         "with the fault-free run, and the 1.5x bar on the "
                         "fault-free arm")
    ap.add_argument("--speculate", action="store_true",
                    help="speculative-decoding arm: spec on vs off at equal "
                         "arena bytes on a serialized low-occupancy trace "
                         "(where decode is weight-stream-bound and accepted "
                         "drafts cut ITL) and the mixed high-occupancy trace "
                         "(reported honestly as a loss — batching already "
                         "amortizes the weight stream); with --smoke asserts "
                         "greedy bit-parity on-vs-off, the low-occupancy ITL "
                         "win, and the 1.5x continuous-vs-static bar")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also dump every emitted row (name, us, parsed "
                         "derived metrics) as JSON to PATH")
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "templated"],
                    help="'templated' runs the shared-system-prompt "
                         "multi-turn trace with prefix sharing on vs off "
                         "(prefill bytes written/request, shared-page "
                         "fraction, TTFT p95); with --smoke the shared arm "
                         "must strictly improve TTFT p95 and prefill bytes "
                         "and keep the 1.5x continuous-vs-static bar")
    args = ap.parse_args()

    rows = []

    def emit(name, us, derived=""):
        print(f"{name},{us:.2f},{derived}")
        rows.append({"name": name, "us": round(us, 2), "derived": derived})

    def _parse_derived(derived: str) -> dict:
        """'k=v;k=v' derived strings -> {k: float|str} (units like 'x' or
        trailing prose stripped where the value parses as a number)."""
        out = {}
        for part in derived.split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            k = k.strip()
            if not k.isidentifier():
                continue    # trailing prose like "(target >= 1.5x)"
            v = v.strip().split()[0] if v.strip() else ""
            try:
                out[k] = float(v.rstrip("x%"))
            except ValueError:
                out[k] = v
        return out

    pols = (("fifo", "priority", "slo") if args.policy == "all"
            else (args.policy,))
    main(emit, smoke=args.smoke, mesh=args.mesh, policies=pols,
         replicas=args.replicas, placement=args.placement,
         workload=args.workload, failures=args.failures,
         speculate=args.speculate)

    if args.json:
        import json

        for r in rows:
            r["metrics"] = _parse_derived(r["derived"])
        with open(args.json, "w") as f:
            json.dump({"bench": "serving", "argv": sys.argv[1:],
                       "rows": rows}, f, indent=1)
        print(f"[bench_serving] wrote {len(rows)} rows to {args.json}")
