"""T2 CPQ benchmark (paper §IV / Fig. 4-5): compression ratio, reconstruction
error, HQE level growth over decode, end-to-end attention-output error —
against the baselines the paper positions itself to (KIVI-style
quantize-only at 8/4 bit, ThinK-style prune-only)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CPQCfg
from repro.core import cpq as C
from repro.core.attention import dense_attention
from repro.core.cpq import cpq_bytes_per_token, dense_bytes_per_token
from repro.kernels.cpq_dequant_attn.kernel import cpq_decode_fwd


def _attn_err(kx, vx, q, cfgq: CPQCfg):
    """Attention-output error vs exact bf16 K/V."""
    N = kx.shape[1]
    tk = C.cpq_compress_prefill(kx, cfgq, N)
    tv = C.cpq_compress_prefill(vx, cfgq, N)
    kh = C.cpq_dequant(tk, jnp.float32)
    vh = C.cpq_dequant(tv, jnp.float32)
    ln = jnp.asarray(N, jnp.int32)
    ref = dense_attention(q, kx, vx, 0.125, causal=False, kv_length=ln)
    out = dense_attention(q, kh, vh, 0.125, causal=False, kv_length=ln)
    return float(jnp.abs(out - ref).max()), float(
        jnp.sqrt(jnp.mean((out - ref) ** 2)))


def main(emit):
    key = jax.random.PRNGKey(0)
    B, N, KV, Dh, H = 2, 512, 8, 64, 16
    ks = jax.random.split(key, 3)
    kx = jax.random.normal(ks[0], (B, N, KV, Dh))
    vx = jax.random.normal(ks[1], (B, N, KV, Dh))
    q = jax.random.normal(ks[2], (B, 1, H, Dh))

    dense_b = dense_bytes_per_token(KV, Dh)
    variants = {
        "cpq_4b_p40": CPQCfg(prune_ratio=0.4, bits=4),
        "cpq_8b_p40": CPQCfg(prune_ratio=0.4, bits=8),
        "kivi_style_8b": CPQCfg(prune_ratio=0.0, bits=8),   # quantize-only
        "kivi_style_4b": CPQCfg(prune_ratio=0.0, bits=4),
        "think_style_prune60": CPQCfg(prune_ratio=0.6, bits=8),
    }
    for name, cq in variants.items():
        mx, rms = _attn_err(kx, vx, q, cq)
        ratio = dense_b / cpq_bytes_per_token(cq, KV, Dh)
        emit(f"t2_{name}", 0.0,
             f"compress={ratio:.2f}x;attn_max_err={mx:.4f};attn_rms={rms:.5f}")

    # HQE level growth across 64 decode appends (drifting distribution)
    cq = CPQCfg(prune_ratio=0.4, bits=4, max_levels=4)
    t = C.cpq_compress_prefill(kx, cq, N + 64)
    for i in range(64):
        tok = (1.0 + i * 0.1) * jax.random.normal(
            jax.random.fold_in(key, i), (B, 1, KV, Dh))
        t = C.cpq_append_decode(t, tok, jnp.asarray(N + i, jnp.int32), cq)
    emit("t2_hqe_levels_after_64_drifting_tokens", 0.0,
         f"mean_levels={float(jnp.mean(t.num_levels)):.2f};"
         f"max_levels={int(jnp.max(t.num_levels))}")

    # fused dequant-attention kernel wall time (interpret mode, trend only)
    cq = CPQCfg(prune_ratio=0.4, bits=8)
    tk = C.cpq_compress_prefill(kx, cq, N)
    tv = C.cpq_compress_prefill(vx, cq, N)
    qg = q[:, 0].reshape(B, KV, H // KV, Dh)
    ln = jnp.asarray(N, jnp.int32)
    f = jax.jit(lambda: cpq_decode_fwd(
        qg, tk.codes, tv.codes, tk.scale, tk.zero, tv.scale, tv.zero,
        tk.level, tv.level, ln, scale=0.125, block_n=128))
    f().block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        f().block_until_ready()
    emit("t2_dequant_kernel_interp", (time.perf_counter() - t0) / 3 * 1e6, "")
