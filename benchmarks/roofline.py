"""Roofline analysis (deliverable g): three-term model per (arch x shape x
mesh) from the dry-run artifacts.

    compute_term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory_term     = HLO_bytes_per_device / HBM_bw
    collective_term = collective_bytes_per_device / ICI_link_bw

(The dry-run records are PER DEVICE — the SPMD program of one chip — so the
"/ chips" in the assignment's global formulation is already applied.)

MODEL_FLOPS uses 6*N_active*D for train and 2*N_active per generated token
for decode (+dense-equivalent prefill), so the MODEL_FLOPS/HLO_FLOPs ratio
exposes remat recompute and redundant work.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.hw import ICI_BW, TPU_V5E
from repro.common.param import count_params
from repro.configs import SHAPES, get_config
from repro.models.model import model_defs

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def param_counts(cfg) -> tuple[float, float]:
    """(total, active) parameters; active discounts unrouted experts."""
    total = float(count_params(model_defs(cfg)))
    if cfg.moe is None:
        return total, total
    m = cfg.moe
    n_moe_layers = sum(1 for _, mlp in cfg.layer_kinds if mlp == "moe")
    routed = 3.0 * m.num_experts * cfg.d_model * m.d_ff_expert * n_moe_layers
    active = total - routed * (1.0 - m.top_k / m.num_experts)
    return total, active


def model_flops(cfg, shape, devices: int) -> float:
    """Per-device useful FLOPs of one step."""
    _, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens / devices
    # decode: one token per sequence (+ attention reads ~ included in HLO)
    return 2.0 * active * shape.global_batch / devices


def analyze_record(rec: dict) -> dict:
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    ct = rec["flops_per_device"] / TPU_V5E.peak_flops
    mt = rec["bytes_per_device"] / TPU_V5E.hbm_bw
    lt = rec["collective_total"] / ICI_BW
    dom = max((ct, "compute"), (mt, "memory"), (lt, "collective"))[1]
    mf = model_flops(cfg, shape, rec["devices"])
    useful = mf / max(rec["flops_per_device"], 1.0)
    step_t = max(ct, mt, lt)
    # achieved fraction of the dominant roofline resource doing useful work
    mfu = (mf / TPU_V5E.peak_flops) / step_t if step_t else 0.0
    advice = {
        "compute": "cut recompute (remat policy) / raise useful-FLOP ratio",
        "memory": "shrink bytes: fuse (Pallas), quantize cache (T2), X-cache (T1)",
        "collective": "reshard to cut all-gathers; overlap (ring/flash-decoding)",
    }[dom]
    return dict(
        rec,
        compute_term_s=ct,
        memory_term_s=mt,
        collective_term_s=lt,
        dominant=dom,
        model_flops_per_device=mf,
        useful_flop_ratio=useful,
        roofline_fraction=min(mfu, 1.0),
        advice=advice,
    )


def load_all(dryrun_dir: Path = DRYRUN_DIR) -> list[dict]:
    out = []
    for p in sorted(dryrun_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("skipped"):
            continue
        out.append(analyze_record(rec))
    return out


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | mode | compute s | memory s | coll s | "
           "dominant | useful FLOP ratio | roofline frac | temp GB |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    body = ""
    for r in rows:
        body += (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['mode']} "
            f"| {r['compute_term_s']:.2e} | {r['memory_term_s']:.2e} "
            f"| {r['collective_term_s']:.2e} | **{r['dominant']}** "
            f"| {r['useful_flop_ratio']:.3f} | {r['roofline_fraction']:.3f} "
            f"| {(r['memory'].get('temp_bytes') or 0) / 1e9:.1f} |\n")
    return hdr + body


def main(emit):
    rows = load_all()
    if not rows:
        emit("roofline", 0.0, "no dryrun artifacts; run repro.launch.dryrun --all")
        return
    for r in rows:
        if r["mesh"] != "16x16":
            continue  # the roofline table is single-pod per the brief
        emit(f"roofline_{r['arch']}_{r['shape']}_{r['mode']}",
             max(r["compute_term_s"], r["memory_term_s"],
                 r["collective_term_s"]) * 1e6,
             f"dom={r['dominant']};useful={r['useful_flop_ratio']:.3f};"
             f"frac={r['roofline_fraction']:.3f}")
