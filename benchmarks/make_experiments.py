"""Regenerate the roofline table inside EXPERIMENTS.md from the dry-run
records (between the ROOFLINE_TABLE marker and the next section)."""
from __future__ import annotations

from pathlib import Path

from benchmarks.roofline import load_all, markdown_table

ROOT = Path(__file__).resolve().parents[1]
MARK = "<!-- ROOFLINE_TABLE -->"


def main():
    rows = load_all()
    single = [r for r in rows if r["mesh"] == "16x16"]
    multi = [r for r in rows if r["mesh"] != "16x16"]
    single.sort(key=lambda r: (r["arch"], r["shape"], r["mode"]))

    block = [MARK, "", "### Single-pod (16x16 = 256 chips) — the roofline table", "",
             markdown_table(single), "",
             "### Multi-pod (2x16x16 = 512 chips) — dry-run proof "
             "(pod axis shards; per-device terms)", ""]
    multi.sort(key=lambda r: (r["arch"], r["shape"], r["mode"]))
    block.append(markdown_table(multi))
    text = (ROOT / "EXPERIMENTS.md").read_text()
    pre, _, rest = text.partition(MARK)
    # cut everything up to the next markdown section header after the marker
    idx = rest.find("\nReading of the final table")
    tail = rest[idx:] if idx >= 0 else rest
    (ROOT / "EXPERIMENTS.md").write_text(pre + "\n".join(block) + "\n" + tail)
    print(f"wrote roofline table: {len(single)} single-pod + {len(multi)} "
          f"multi-pod rows")


if __name__ == "__main__":
    main()
