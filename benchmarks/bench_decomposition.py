"""T1 benchmark (paper §III / Fig. 2): decode-attention cache traffic and
modeled latency, standard K/V vs decomposed X-cache, per assigned arch.

Also times the actual jnp decode-attention paths on a mid-size config (CPU
wall time — trend check only; the roofline model carries the TPU numbers).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.hw import TPU_V5E
from repro.configs import ARCHS
from repro.core.attention import dense_attention
from repro.core.decomposed_attention import decomposed_attention
from repro.models.attention_layer import decoupled_rope_dims


def traffic_rows():
    rows = []
    for name, cfg in ARCHS.items():
        if cfg.attention_free:
            continue
        r = decoupled_rope_dims(cfg)
        dense_b = 2 * cfg.num_kv_heads * cfg.head_dim * 2          # K+V bf16
        x_b = (cfg.d_model + cfg.num_kv_heads * r) * 2             # X + rope keys
        # per-token per-layer decode latency at HBM bw (memory-bound regime)
        t_dense = dense_b / TPU_V5E.hbm_bw
        t_x = x_b / TPU_V5E.hbm_bw
        # extra FLOPs of the decomposed form per cached token:
        # H*d_model (scores) + H*d_model (values) vs 2*H*head_dim MACs
        f_dense = 2 * 2 * cfg.num_heads * cfg.head_dim * 2
        f_x = 2 * 2 * cfg.num_heads * cfg.d_model * 2
        t_x_compute = f_x / TPU_V5E.peak_flops
        win = t_dense / max(t_x, t_x_compute)
        rows.append({
            "arch": name,
            "kv": cfg.num_kv_heads,
            "heads": cfg.num_heads,
            "dense_B_per_tok": dense_b,
            "xcache_B_per_tok": x_b,
            "traffic_ratio": round(dense_b / x_b, 3),
            "modeled_speedup": round(win, 3),
            "flops_ratio": round(f_x / f_dense, 1),
            "applicable": x_b < dense_b,
        })
    return rows


def timed_paths(n: int = 4096, d_model: int = 512, h: int = 8, reps: int = 5):
    """CPU wall time of one decode attention, dense vs decomposed (MHA)."""
    kv, dh = h, d_model // h
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (1, n, d_model), jnp.float32)
    wk = jax.random.normal(ks[1], (d_model, kv, dh)) / d_model**0.5
    wv = jax.random.normal(ks[2], (d_model, kv, dh)) / d_model**0.5
    q = jax.random.normal(ks[3], (1, 1, h, dh))
    k = jnp.einsum("bnm,mkd->bnkd", x, wk)
    v = jnp.einsum("bnm,mkd->bnkd", x, wv)
    ln = jnp.asarray(n, jnp.int32)

    f_dense = jax.jit(lambda q, k, v: dense_attention(
        q, k, v, dh**-0.5, causal=False, kv_length=ln))
    f_dec = jax.jit(lambda q, x: decomposed_attention(
        q, jnp.zeros((1, 1, h, 0)), x, jnp.zeros((1, n, kv, 0)), wk, wv, ln,
        dh**-0.5))
    f_dense(q, k, v).block_until_ready()
    f_dec(q, x).block_until_ready()

    def t(f, *a):
        t0 = time.perf_counter()
        for _ in range(reps):
            f(*a).block_until_ready()
        return (time.perf_counter() - t0) / reps * 1e6

    return t(f_dense, q, k, v), t(f_dec, q, x)


def main(emit):
    us_d, us_x = timed_paths()
    emit("t1_decode_dense_jnp", us_d, "")
    emit("t1_decode_decomposed_jnp", us_x, "")
    for r in traffic_rows():
        emit(f"t1_traffic_{r['arch']}", 0.0,
             f"ratio={r['traffic_ratio']};speedup={r['modeled_speedup']};"
             f"applicable={r['applicable']}")
