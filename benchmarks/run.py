"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import traceback


def emit(name: str, us: float, derived: str = ""):
    print(f"{name},{us:.2f},{derived}")


def main() -> None:
    from benchmarks import (bench_cpq, bench_decomposition, bench_e2e_energy,
                            bench_pipeline, bench_retrieval, bench_serving,
                            roofline)

    modules = [
        ("bench_decomposition", bench_decomposition),   # paper §III / Fig. 2
        ("bench_pipeline", bench_pipeline),             # paper Fig. 3
        ("bench_cpq", bench_cpq),                       # paper §IV Fig. 4-5
        ("bench_retrieval", bench_retrieval),           # paper §V
        ("bench_e2e_energy", bench_e2e_energy),         # paper §IV table
        ("bench_serving", bench_serving),               # continuous batching
        ("roofline", roofline),                         # deliverable (g)
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        try:
            mod.main(emit)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
