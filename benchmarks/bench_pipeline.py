"""Sub-matrix pipeline benchmark (paper Fig. 3): layer-level vs sub-matrix
latency/utilization across sub-matrix counts, plus the cross-chip analogue
(GPipe bubble fractions)."""
from __future__ import annotations

from repro.core.submatrix_pipeline import (
    StageCost, layer_level_latency, speedup, submatrix_latency, utilization)
from repro.distributed.pipeline import bubble_fraction


def main(emit):
    for n in (2, 4, 8, 16, 64, 256):
        for c in (StageCost(1.0, 1.0), StageCost(1.0, 0.5), StageCost(0.5, 1.0)):
            ll = layer_level_latency(n, c)
            sm = submatrix_latency(n, c)
            emit(f"fig3_nsub{n}_s1{c.t_stage1}_s2{c.t_stage2}", 0.0,
                 f"layer={ll:.1f};submatrix={sm:.1f};"
                 f"speedup={speedup(n, c):.3f};"
                 f"util_layer={utilization(n, c, ll):.3f};"
                 f"util_sub={utilization(n, c, sm):.3f}")
    for m in (4, 8, 32):
        for s in (2, 4):
            emit(f"gpipe_bubble_m{m}_s{s}", 0.0,
                 f"bubble={bubble_fraction(m, s):.3f}")
