"""T3 retrieval-attention benchmark (paper §V): proxy recall@K, attention
error vs K, and similarity/V-read traffic reduction vs dense attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RetrievalCfg
from repro.core import retrieval_attention as R
from repro.core.attention import dense_attention


def main(emit):
    key = jax.random.PRNGKey(0)
    B, N, KV, Dh, H = 2, 2048, 8, 64, 16
    ks = jax.random.split(key, 3)
    k = jax.random.normal(ks[0], (B, N, KV, Dh))
    v = jax.random.normal(ks[1], (B, N, KV, Dh))
    q = jax.random.normal(ks[2], (B, 1, H, Dh))
    ln = jnp.asarray(N, jnp.int32)
    ref = dense_attention(q, k, v, Dh**-0.5, causal=False, kv_length=ln)

    codes, ps, pz = R.fit_proxy(k, 8)
    sp = R.proxy_scores(q, codes, ps, pz)
    g = H // KV
    qg = q.reshape(B, 1, KV, g, Dh)
    se = jnp.einsum("btkgd,bnkd->btkgn", qg, k).reshape(B, 1, H, N)

    for K in (64, 256, 512):
        _, ip = jax.lax.top_k(sp, K)
        _, ie = jax.lax.top_k(se.astype(jnp.float32), K)
        recall = np.mean([
            len(set(np.asarray(ip)[b, 0, h]) & set(np.asarray(ie)[b, 0, h])) / K
            for b in range(B) for h in range(H)])
        cfg = RetrievalCfg(top_k=K, recent_window=64)
        out = R.retrieval_attention(q, k, v, codes, ps, pz, ln, cfg, Dh**-0.5)
        err = float(jnp.abs(out - ref).max())
        # traffic: dense reads N*(K+V) bf16; retrieval reads N proxy bytes + K*(K+V)
        dense_b = N * 2 * KV * Dh * 2
        ret_b = N * KV * Dh * 1 + K * 2 * KV * Dh * 2
        emit(f"t3_top{K}", 0.0,
             f"recall={recall:.3f};attn_max_err={err:.4f};"
             f"traffic_reduction={dense_b / ret_b:.2f}x")
