"""T1 matrix decomposition: algebraic exactness properties (paper §III)."""
from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.attention import dense_attention
from repro.core.decomposed_attention import (
    decomposed_attention,
    decomposed_query_transform,
    decomposed_scores,
    decomposed_values,
)

dims = st.sampled_from([(4, 2, 8, 32, 48), (8, 8, 16, 64, 64), (6, 3, 8, 24, 40)])


@hypothesis.given(dims=dims, seed=st.integers(0, 2**16))
@hypothesis.settings(max_examples=12, deadline=None)
def test_decomposition_exact_vs_dense(dims, seed):
    """Out = Q K^T == (Q W_K^T) X^T and S V == (S X) W_V, for any GQA config
    with K = X W_K, V = X W_V (no positional rotation)."""
    H, KV, Dh, Dm, N = dims
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (2, N, Dm), jnp.float32)
    wk = jax.random.normal(ks[1], (Dm, KV, Dh)) / np.sqrt(Dm)
    wv = jax.random.normal(ks[2], (Dm, KV, Dh)) / np.sqrt(Dm)
    q = jax.random.normal(ks[3], (2, 1, H, Dh))
    k = jnp.einsum("bnm,mkd->bnkd", x, wk)
    v = jnp.einsum("bnm,mkd->bnkd", x, wv)
    length = jnp.asarray(N, jnp.int32)
    ref = dense_attention(q, k, v, Dh**-0.5, causal=False, kv_length=length)
    dec = decomposed_attention(q, jnp.zeros((2, 1, H, 0)), x,
                               jnp.zeros((2, N, KV, 0)), wk, wv, length, Dh**-0.5)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dec), atol=2e-5)


def test_cascaded_matmuls_associativity(rng):
    """R = Q W_K^T then R X^T equals Q (X W_K)^T elementwise (pre-softmax)."""
    H, KV, Dh, Dm, N = 8, 4, 16, 64, 32
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (2, 3, H, Dh))
    x = jax.random.normal(ks[1], (2, N, Dm))
    wk = jax.random.normal(ks[2], (Dm, KV, Dh))
    r = decomposed_query_transform(q, wk)
    s1 = decomposed_scores(r, x)
    k = jnp.einsum("bnm,mkd->bnkd", x, wk)
    g = H // KV
    s2 = jnp.einsum("btkgd,bnkd->btkgn", q.reshape(2, 3, KV, g, Dh), k)
    s2 = s2.reshape(2, 3, H, N)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4, atol=2e-4)


def test_value_stage(rng):
    """S V == (S X) W_V."""
    H, KV, Dh, Dm, N = 4, 4, 16, 32, 24
    ks = jax.random.split(rng, 3)
    s = jax.nn.softmax(jax.random.normal(ks[0], (2, 1, H, N)), -1)
    x = jax.random.normal(ks[1], (2, N, Dm))
    wv = jax.random.normal(ks[2], (Dm, KV, Dh))
    v = jnp.einsum("bnm,mkd->bnkd", x, wv)
    out1 = decomposed_values(s, x, wv)
    out2 = jnp.einsum("btkgn,bnkd->btkgd",
                      s.reshape(2, 1, KV, 1, N), v).reshape(2, 1, H, Dh)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=2e-4, atol=2e-4)


def test_mla_absorbed_equals_naive_f32():
    """DeepSeek MLA absorbed decode (= paper's decomposition over the learned
    latent) matches the naive path exactly in f32."""
    import dataclasses
    from repro.configs import ARCHS, smoke_config
    from repro.common.param import init_tree
    from repro.models import mla as mla_lib

    cfg = dataclasses.replace(smoke_config(ARCHS["deepseek-v2-lite-16b"]),
                              dtype="float32")
    key = jax.random.PRNGKey(1)
    p = init_tree(mla_lib.mla_defs(cfg), key)
    B, S = 2, 12
    x = jax.random.normal(key, (B, S + 1, cfg.d_model), jnp.float32)
    full = mla_lib.mla_train(cfg, p, x, jnp.arange(S + 1))
    cache = mla_lib.init_mla_cache(cfg, cfg.attention, B, S + 4)
    _, cache = mla_lib.mla_prefill(cfg, cfg.attention, p, x[:, :S],
                                   jnp.arange(S), cache)
    y, cache = mla_lib.mla_decode(cfg, cfg.attention, p, x[:, S:S + 1],
                                  jnp.asarray(S, jnp.int32), cache)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(full[:, S]),
                               atol=3e-5)


def test_decode_cache_traffic_wins_for_mha():
    """The T1 X-cache halves per-token decode traffic exactly when
    kv_heads * head_dim == d_model (MHA archs; DESIGN.md §5 table)."""
    from repro.configs import ARCHS
    from repro.models.attention_layer import decoupled_rope_dims

    for name in ("musicgen-large", "deepseek-moe-16b", "qwen1.5-0.5b", "opt-6.7b"):
        cfg = ARCHS[name]
        dense_b = 2 * cfg.num_kv_heads * cfg.head_dim
        x_b = cfg.d_model + cfg.num_kv_heads * decoupled_rope_dims(cfg)
        assert x_b < dense_b, name
    for name in ("gemma-2b", "phi4-mini-3.8b", "qwen3-4b"):
        cfg = ARCHS[name]
        dense_b = 2 * cfg.num_kv_heads * cfg.head_dim
        assert cfg.d_model >= dense_b, name  # GQA/MQA: decomposition off
