"""Fault-injection suite: FaultPlan/FaultyReplica harness units, a
deterministic crash-drain-recover regression, and the chaos property — for
ANY seeded fault schedule (crash / stall / exhaust at arbitrary ticks) over
a mixed greedy+seeded trace, every request finishes exactly once with token
streams identical to the fault-free run, and the allocator invariants hold
on every surviving replica."""
import numpy as np
import pytest

import jax

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine
from repro.serving.faults import (FaultEvent, FaultPlan, FaultyReplica,
                                  ReplicaFault)
from repro.serving.paged_cache import NULL_PAGE
from repro.serving.request import (BATCH, INTERACTIVE, SamplingParams,
                                   ServeRequest)
from repro.serving.router import ReplicaRouter


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


SERVING = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4,
                     probe_interval=2, probe_failures=2, probe_backoff=2,
                     auto_drain=True)


@pytest.fixture(scope="module")
def donor(model):
    cfg, params = model
    return ContinuousServeEngine(cfg, params, serving=SERVING)


def _router(model, donor, n, plans=None, serving=SERVING, placement="rr"):
    cfg, params = model
    r = ReplicaRouter(cfg, params, num_replicas=n, serving=serving,
                      placement=placement, fault_plans=plans)
    for eng in r.engines:
        eng.adopt_compiled(donor)
    return r


def _trace(n=6, max_tokens=6):
    """Fixed mixed-class, mixed-sampling trace (greedy AND seeded rows)."""
    rng = np.random.default_rng(42)
    out = []
    for i in range(n):
        sp = (SamplingParams(temperature=0.8, top_k=10, seed=11 + i,
                             max_tokens=max_tokens) if i % 3 == 0
              else SamplingParams(max_tokens=max_tokens))
        out.append(ServeRequest(
            prompt=rng.integers(1, 200, size=int(rng.integers(3, 10))),
            sampling=sp, slo=INTERACTIVE if i % 2 else BATCH,
            arrival=float(i // 2)))
    return out


@pytest.fixture(scope="module")
def reference(model, donor):
    """Fault-free token streams for the fixed trace (the parity oracle)."""
    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    res, _ = eng.serve(_trace())
    return {rid: list(rec["tokens"]) for rid, rec in res.items()}


def _check_alloc(eng):
    sched = eng._st.sched
    owned = [p for r in sched.occupied() if r.tier == 0 for p in r.pages]
    assert len(set(owned)) == len(owned), "double-owned page"
    assert NULL_PAGE not in owned
    assert sched.dense_alloc.num_used == len(owned), "leaked/phantom pages"


def _run_to_completion(router, cap=800):
    for _ in range(cap):
        if not router.has_unfinished():
            return
        router.step()
    raise AssertionError(f"router did not finish within {cap} steps "
                         f"(backlog={len(router._backlog)}, "
                         f"draining={sorted(router._draining)})")


# ------------------------------------------------------------ plan units


def test_fault_plan_is_seed_deterministic():
    a = FaultPlan.random(123, horizon=40, n_events=4)
    b = FaultPlan.random(123, horizon=40, n_events=4)
    assert a == b
    assert all(e.kind in ("crash", "stall", "exhaust") for e in a.events)
    assert all(1 <= e.tick < 40 for e in a.events)


def test_fault_event_windows():
    ev = FaultEvent(tick=3, kind="stall", duration=2)
    assert not ev.active_at(2)
    assert ev.active_at(3) and ev.active_at(4)
    assert not ev.active_at(5)
    with pytest.raises(AssertionError):
        FaultEvent(tick=0, kind="meteor")
    with pytest.raises(AssertionError):
        FaultEvent(tick=-1, kind="crash")


def test_fault_plan_overlap_and_horizon():
    plan = FaultPlan((FaultEvent(2, "stall", 4), FaultEvent(3, "crash", 1)))
    assert plan.active_at(1) is None
    assert plan.active_at(3).kind == "stall"  # earliest event governs
    assert plan.horizon() == 6
    assert FaultPlan().active_at(0) is None and FaultPlan().horizon() == 0


# --------------------------------------------------------- wrapper units


class _FakeEngine:
    """Minimal engine stand-in: counts steps, reports canned health."""

    def __init__(self):
        self.steps = 0
        self.tag = "fake"

    def step(self):
        self.steps += 1
        return ["tok"]

    def health(self):
        return {"alive": True, "has_work": True, "queued": 1,
                "progress": self.steps, "free_frac": 0.5, "exhausted": False}

    def arena_stats(self):
        return {"free_frac": 0.5}


def test_crash_raises_before_touching_engine():
    inner = _FakeEngine()
    rep = FaultyReplica(inner, FaultPlan((FaultEvent(1, "crash", 2),)))
    assert rep.step() == ["tok"]                # tick 0: clean
    with pytest.raises(ReplicaFault):
        rep.step()                              # tick 1: crash window
    with pytest.raises(ReplicaFault):
        rep.health()                            # tick 2: still crashing
    assert inner.steps == 1, "crash must fail-stop, not fail-corrupt"
    assert rep.step() == ["tok"]                # tick 3: recovered
    assert rep.faults_injected["crash"] == 2


def test_stall_noops_and_exhaust_masks_pressure():
    inner = _FakeEngine()
    rep = FaultyReplica(inner, FaultPlan((FaultEvent(0, "stall", 1),
                                          FaultEvent(1, "exhaust", 1))))
    assert rep.step() == []                     # stalled: no inner work
    assert inner.steps == 0
    h = rep.health()                            # tick 1: exhaust window
    assert h["exhausted"] and h["free_frac"] == 0.0
    assert rep.arena_stats()["free_frac"] == 0.5  # window passed (peek)
    assert rep.step() == ["tok"]


def test_wrapper_forwards_everything_else():
    inner = _FakeEngine()
    rep = FaultyReplica(inner, FaultPlan())
    assert rep.tag == "fake"
    assert rep.arena_stats() == {"free_frac": 0.5}
    for _ in range(5):
        rep.step()
    assert inner.steps == 5 and rep.clock == 5


# ----------------------------------------- deterministic crash regression


def test_crash_auto_drains_and_recovers_with_parity(model, donor, reference):
    """Replica 0 crashes hard mid-trace: the monitor drains it through the
    snapshot path, its work migrates, it re-admits after the fault window,
    and every token stream matches the fault-free run bit-for-bit."""
    plan = FaultPlan((FaultEvent(3, "crash", 4),))
    router = _router(model, donor, 2, plans=[plan, None])
    router.reset()
    for r in _trace():
        router.add_request(r)
    _run_to_completion(router)
    res = router.results()
    assert {rid: list(rec["tokens"]) for rid, rec in res.items()} == reference
    stats = router.stats()
    assert stats["auto_drains"] >= 1, "crash never tripped the monitor"
    assert stats["recoveries"] >= 1, "replica never re-admitted"
    assert stats["draining"] == [], "recovered replica still out of service"
    assert stats["dense_pages_leaked"] == 0
    assert stats["timeouts"] == 0 and stats["shed"] == 0


def test_exhaust_fault_trips_pressure_probe(model, donor, reference):
    """A sustained exhaustion report (with queued work) is a probe failure
    chain ending in auto-drain; service continues on the peer."""
    plan = FaultPlan((FaultEvent(2, "exhaust", 10),))
    router = _router(model, donor, 2, plans=[plan, None])
    router.reset()
    for r in _trace():
        router.add_request(r)
    _run_to_completion(router)
    res = router.results()
    assert {rid: list(rec["tokens"]) for rid, rec in res.items()} == reference
    assert router.stats()["dense_pages_leaked"] == 0


# ----------------------------------------------------- chaos (hypothesis)


@hypothesis.given(seed=st.integers(0, 2 ** 31 - 1))
@hypothesis.settings(max_examples=10, deadline=None)
def test_chaos_any_fault_schedule_exact_once_and_parity(model, donor,
                                                        reference, seed):
    """THE acceptance property: any seeded fault schedule over the mixed
    trace — every request finishes exactly once, greedy and seeded streams
    match the fault-free run bit-for-bit (deadlines off, so no timeout
    shedding by construction), and the allocator invariants hold on every
    live replica after recovery."""
    plans = [FaultPlan.random(seed, horizon=24, n_events=3),
             FaultPlan.random(seed + 1, horizon=24, n_events=2)]
    router = _router(model, donor, 2, plans=plans)
    router.reset()
    for r in _trace():
        router.add_request(r)
    _run_to_completion(router)

    events = router.pending_outputs()
    seen: dict[int, list] = {}
    finished: dict[int, int] = {}
    for ev in events:
        if ev.token >= 0:
            seen.setdefault(ev.rid, []).append(ev.index)
        if ev.finished:
            finished[ev.rid] = finished.get(ev.rid, 0) + 1
    res = router.results()
    assert set(res) == set(reference), "lost or phantom request records"
    for rid, toks in reference.items():
        assert list(res[rid]["tokens"]) == toks, (
            f"rid {rid} diverged under fault schedule seed={seed}")
        assert sorted(seen.get(rid, [])) == list(range(len(toks)))
        assert finished.get(rid, 0) == 1, f"rid {rid} finished twice/never"
    for eng in router.engines:
        if eng._st is not None:
            _check_alloc(eng.engine if isinstance(eng, FaultyReplica)
                         else eng)
    agg = router.stats()
    assert agg["dense_pages_leaked"] == 0 and agg["cpq_pages_leaked"] == 0
    assert agg["timeouts"] == 0 and agg["shed"] == 0


def test_chaos_single_replica_parks_and_recovers(model, donor, reference):
    """Worst case: ONE replica, crash window long enough to auto-drain the
    whole fleet. Arrivals park in the router backlog (no raise — the old
    behavior), place on recovery, and parity still holds."""
    plan = FaultPlan((FaultEvent(2, "crash", 3),))
    router = _router(model, donor, 1, plans=[plan])
    router.reset()
    for r in _trace():
        router.add_request(r)   # must never raise, even while down
    _run_to_completion(router)
    res = router.results()
    assert {rid: list(rec["tokens"]) for rid, rec in res.items()} == reference
    stats = router.stats()
    assert stats["auto_drains"] >= 1 and stats["recoveries"] >= 1
    assert stats["backlog"] == 0 and stats["dense_pages_leaked"] == 0
