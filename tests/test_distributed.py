"""Distributed machinery: spec resolution, cache spec trees, HLO analysis,
flash-decoding combine, ring overlap, GPipe (multi-device parts run in
subprocesses so in-process tests keep the single real CPU device)."""
import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.common.param import ParamDef, spec_tree
from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed import hlo_analysis
from repro.distributed.rules import act_rules, batch_axes, param_rules
from repro.distributed.sharding import resolve


def test_spec_tree_divisibility_filter():
    defs = {
        "ok": ParamDef((64, 32), jnp.float32, ("embed", "heads")),
        "bad_heads": ParamDef((4, 4, 8, 8), jnp.float32, (None, "heads", None, None)),
    }
    specs = spec_tree(defs, param_rules(False), {"data": 16, "model": 16})
    assert specs["ok"] == P("data", "model")
    assert specs["bad_heads"] == P(None, None, None, None)


def test_rules_resolve_dedup():
    rules = act_rules(True)
    spec = resolve(rules, ("act_batch", None, "act_heads"))
    assert spec == P(("pod", "data"), None, "model")


def test_batch_axes_divisibility():
    ms = {"pod": 2, "data": 16, "model": 16}
    assert batch_axes(True, 256, ms) == ("pod", "data")
    assert batch_axes(False, 1, {"data": 16, "model": 16}) == ()
    assert batch_axes(True, 2, ms) == ("pod",)


def test_cache_spec_trees_match_cache_structure():
    """Spec tree structure == eval_shape(init_caches) structure, all modes."""
    from functools import partial

    from repro.distributed.cache_specs import cache_pspecs
    from repro.models import model as M

    for arch in ("qwen3-4b", "deepseek-v2-lite-16b", "jamba-1.5-large-398b",
                 "xlstm-125m", "llama-3.2-vision-11b"):
        cfg = get_config(arch)
        for mode in ("dense", "decomposed", "cpq", "retrieval"):
            c = cfg.with_attention(mode)
            caches = jax.eval_shape(partial(M.init_caches, c, c.attention, 4, 64))
            specs = cache_pspecs(c, c.attention, "data", None)
            s1 = jax.tree.structure(caches)
            s2 = jax.tree.structure(specs, is_leaf=lambda x: isinstance(x, P))
            assert s1 == s2, (arch, mode)


def test_hlo_analysis_matmul_and_scan():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y

    c = jax.jit(f).lower(jax.ShapeDtypeStruct((128, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    a = hlo_analysis.analyze(c.as_text())
    expect = 5 * 2 * 128 * 64 * 64
    np.testing.assert_allclose(a.flops, expect, rtol=0.01)
    assert 5 in hlo_analysis.while_trip_counts(c.as_text())


def test_hlo_analysis_collectives(run8):
    out = run8("""
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distributed.hlo_analysis import analyze
mesh = jax.make_mesh((8,), ('d',))
def h(x, w):
    def body(c, _): return c @ w, None
    y, _ = jax.lax.scan(body, x, None, length=3)
    return jnp.sum(y)
fn = jax.jit(h, in_shardings=(NamedSharding(mesh, P(None, 'd')),
                              NamedSharding(mesh, P('d', None))))
c = fn.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32),
             jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
a = analyze(c.as_text())
assert a.collective_total > 0, a.collectives
assert abs(a.flops - 3 * 2 * 64 * 64 * 64 / 8) / a.flops < 0.05
print('collectives ok', a.collectives)
""")
    assert "collectives ok" in out


def test_flash_decoding_and_ring(run8):
    out = run8("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.collectives import flash_decoding_attention, ring_decomposed_scores
from repro.core.attention import dense_attention
mesh = jax.make_mesh((8,), ('s',))
key = jax.random.PRNGKey(0)
B,H,KV,Dh,N = 2,8,4,32,128
ks = jax.random.split(key,4)
q = jax.random.normal(ks[0],(B,1,H,Dh)); k = jax.random.normal(ks[1],(B,N,KV,Dh)); v = jax.random.normal(ks[2],(B,N,KV,Dh))
ln = jnp.asarray(100, jnp.int32)
out = flash_decoding_attention(mesh, 's')(q, k, v, ln, 0.125)
ref = dense_attention(q, k, v, 0.125, causal=False, kv_length=ln)
assert np.abs(np.asarray(out-ref)).max() < 1e-5
r = jax.random.normal(ks[3],(B,16,64)); x = jax.random.normal(ks[0],(B,N,64))
s1 = ring_decomposed_scores(mesh, 's')(r, x)
s2 = jnp.einsum('bhm,bnm->bhn', r, x)
assert np.abs(np.asarray(s1-s2)).max() < 2e-4
print('dist ok')
""")
    assert "dist ok" in out


def test_gpipe(run8):
    out = run8("""
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import gpipe_forward
mesh = jax.make_mesh((4,), ('pod',))
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (8, 16, 16)) / 4.0
x = jax.random.normal(key, (6, 2, 16))
blk = lambda p, h: jnp.tanh(h @ p)
out = gpipe_forward(mesh, 'pod', blk)(w, x)
ref = x
for i in range(8): ref = blk(w[i], ref)
assert np.abs(np.asarray(out-ref)).max() < 1e-6
print('gpipe ok')
""")
    assert "gpipe ok" in out


def test_bubble_fraction():
    from repro.distributed.pipeline import bubble_fraction

    assert bubble_fraction(1, 4) == 0.75
    assert bubble_fraction(32, 2) < 0.04


def test_dryrun_records_complete():
    """The 40-cell x 2-mesh dry-run artifacts exist and are green
    (deliverable e) — regenerate with launch/dryrun.py --all --both-meshes."""
    import json
    from pathlib import Path

    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    if not d.exists():
        import pytest
        pytest.skip("dry-run artifacts not generated yet")
    recs = [json.loads(p.read_text()) for p in d.glob("*.json")]
    ok = [r for r in recs if not r.get("skipped")]
    meshes = {r["mesh"] for r in ok}
    assert {"16x16", "pod2x16x16"} <= meshes
    archs = {r["arch"] for r in ok}
    assert len(archs) >= 10
    for r in ok:
        assert r["flops_per_device"] and r["flops_per_device"] > 0, r["arch"]
