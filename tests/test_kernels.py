"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import CPQCfg
from repro.core import cpq as C
from repro.core import retrieval_attention as R

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("T,S,H,KV,D,causal,bq,bk,dtype", [
    (128, 128, 4, 2, 64, True, 64, 64, jnp.float32),
    (256, 256, 8, 8, 128, True, 128, 128, jnp.float32),
    (100, 100, 4, 1, 32, False, 64, 64, jnp.float32),
    (192, 192, 6, 3, 64, True, 128, 64, jnp.float32),
    (128, 128, 4, 4, 64, True, 64, 64, jnp.bfloat16),
])
def test_flash_attention_kernel(T, S, H, KV, D, causal, bq, bk, dtype):
    from repro.kernels.flash_attn.ops import flash_attention_tpu
    from repro.kernels.flash_attn.ref import flash_attention_ref

    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (2, T, H, D), dtype)
    k = jax.random.normal(ks[1], (2, S, KV, D), dtype)
    v = jax.random.normal(ks[2], (2, S, KV, D), dtype)
    out = flash_attention_tpu(q, k, v, D**-0.5, causal, bq, bk)
    ref = flash_attention_ref(q, k, v, D**-0.5, causal)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("H,Dm,N,Rr,bn,dtype", [
    (8, 128, 256, 0, 64, jnp.float32),
    (8, 128, 300, 16, 128, jnp.float32),
    (16, 64, 512, 32, 256, jnp.float32),
    (4, 256, 128, 0, 128, jnp.bfloat16),
])
def test_decomposed_kernel(H, Dm, N, Rr, bn, dtype):
    from repro.kernels.decomposed_attn.kernel import decomposed_decode_fwd
    from repro.kernels.decomposed_attn.ref import decomposed_decode_ref

    ks = jax.random.split(KEY, 4)
    r = jax.random.normal(ks[0], (2, H, Dm), dtype)
    qr = jax.random.normal(ks[1], (2, H, Rr), dtype)
    x = jax.random.normal(ks[2], (2, N, Dm), dtype)
    kr = jax.random.normal(ks[3], (2, N, Rr), dtype)
    ln = jnp.asarray(N - 9, jnp.int32)
    out = decomposed_decode_fwd(r, qr, x, kr, ln, scale=0.1, block_n=bn)
    ref = decomposed_decode_ref(r, qr, x, kr, ln, 0.1)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_decomposed_op_end_to_end(rng):
    from repro.core.decomposed_attention import decomposed_attention
    from repro.kernels.decomposed_attn.ops import decomposed_decode_tpu

    B, H, KV, Dn, Dv, Dm, N = 2, 8, 4, 32, 32, 128, 192
    ks = jax.random.split(rng, 4)
    qn = jax.random.normal(ks[0], (B, 1, H, Dn))
    xc = jax.random.normal(ks[1], (B, N, Dm))
    wk = jax.random.normal(ks[2], (Dm, KV, Dn)) / np.sqrt(Dm)
    wv = jax.random.normal(ks[3], (Dm, KV, Dv)) / np.sqrt(Dm)
    ln = jnp.asarray(N, jnp.int32)
    o1 = decomposed_decode_tpu(qn, None, xc, None, wk, wv, ln, 0.125, block_n=64)
    o2 = decomposed_attention(qn, jnp.zeros((B, 1, H, 0)), xc,
                              jnp.zeros((B, N, KV, 0)), wk, wv, ln, 0.125)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("bits,KV,G,Dh,N,bn", [
    (8, 4, 2, 32, 128, 32),
    (4, 2, 4, 64, 96, 48),
    (8, 8, 1, 128, 256, 128),
])
def test_cpq_dequant_kernel(bits, KV, G, Dh, N, bn):
    from repro.kernels.cpq_dequant_attn.kernel import cpq_decode_fwd
    from repro.kernels.cpq_dequant_attn.ref import cpq_decode_ref

    cfg = CPQCfg(prune_ratio=0.3, bits=bits, max_levels=4)
    ks = jax.random.split(KEY, 3)
    S0 = N - 16
    kx = jax.random.normal(ks[0], (2, S0, KV, Dh))
    vx = jax.random.normal(ks[1], (2, S0, KV, Dh))
    tk = C.cpq_compress_prefill(kx, cfg, N)
    tv = C.cpq_compress_prefill(vx, cfg, N)
    tk = C.cpq_append_decode(tk, 6 * jnp.ones((2, 1, KV, Dh)),
                             jnp.asarray(S0, jnp.int32), cfg)
    tv = C.cpq_append_decode(tv, -6 * jnp.ones((2, 1, KV, Dh)),
                             jnp.asarray(S0, jnp.int32), cfg)
    q = jax.random.normal(ks[2], (2, KV, G, Dh))
    ln = jnp.asarray(S0 + 1, jnp.int32)
    o1 = cpq_decode_fwd(q, tk.codes, tv.codes, tk.scale, tk.zero, tv.scale,
                        tv.zero, tk.level, tv.level, ln, scale=0.17, block_n=bn)
    o2 = cpq_decode_ref(q, tk.codes, tv.codes, tk.scale, tk.zero, tv.scale,
                        tv.zero, tk.level, tv.level, ln, 0.17)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


@pytest.mark.parametrize("KV,G,Dp,N,bn", [(4, 2, 32, 128, 32), (2, 8, 64, 96, 96)])
def test_proxy_scores_kernel(KV, G, Dp, N, bn):
    from repro.kernels.topk_retrieval.kernel import proxy_scores_fwd
    from repro.kernels.topk_retrieval.ref import proxy_scores_ref

    ks = jax.random.split(KEY, 2)
    kx = jax.random.normal(ks[0], (2, N, KV, Dp))
    codes, psc, pz = R.fit_proxy(kx, 8)
    qf = jax.random.normal(ks[1], (2, KV, G, Dp))
    qs = qf * psc[:, :, None, :]
    qz = jnp.einsum("bkgd,bkd->bkg", qf, pz)[..., None]
    ln = jnp.asarray(N - 5, jnp.int32)
    s1 = proxy_scores_fwd(qs, qz, codes, ln, block_n=bn)
    s2 = proxy_scores_ref(qs, qz, codes, ln)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-3)


def test_retrieval_decode_op(rng):
    """Kernel-based T3 decode == jnp retrieval path (no calibration)."""
    from repro.configs.base import RetrievalCfg
    from repro.core import kv_cache as kvc
    from repro.core.attention import init_cache, prefill_into_cache
    from repro.configs.base import AttentionRuntime
    from repro.kernels.topk_retrieval.ops import retrieval_decode_tpu

    B, H, KV, Dh, N = 2, 8, 4, 32, 96
    rcfg = RetrievalCfg(top_k=N, recent_window=4)
    rt = AttentionRuntime(mode="retrieval", retrieval=rcfg)
    ks = jax.random.split(rng, 3)
    k = jax.random.normal(ks[0], (B, N, KV, Dh))
    v = jax.random.normal(ks[1], (B, N, KV, Dh))
    q = jax.random.normal(ks[2], (B, 1, H, Dh))
    cache = init_cache(rt, batch=B, n_max=N, kv=KV, dh=Dh, d_model=0,
                       rope_dims=0, dtype=jnp.float32)
    cache = prefill_into_cache(rt, cache, k=k, v=v, x=None, k_rope=None,
                               length=jnp.asarray(N, jnp.int32))
    out = retrieval_decode_tpu(q, cache, rcfg, Dh**-0.5)
    from repro.core.attention import dense_attention
    ref = dense_attention(q, k, v, Dh**-0.5, causal=False,
                          kv_length=jnp.asarray(N, jnp.int32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
