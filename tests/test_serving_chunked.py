"""Chunked paged prefill tests (the PR-3 tentpole).

Covers: token-exact greedy parity of chunked vs one-shot admission at f32
(dense / decomposed / MLA / retrieval / tiered; CPQ single-chunk), the
fused Q-chunk>1 paged prefill kernels vs their jnp oracles, split-invariance
of page contents under arbitrary (prompt length, chunk size, page size)
splits (hypothesis), the no-scratch-cache guarantee on the admission path,
and the decode-interleaving property (running rows keep emitting while a
long prompt streams in)."""
import dataclasses

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.configs.base import MLACfg, ModelConfig
from repro.core import attention as core_attn
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.scheduler import Request

# pure-MLA stack (dense MLPs): the MLA chunked-parity target. The published
# MLA arch (deepseek-v2-lite) pairs MLA with capacity-factor MoE, whose drop
# pattern depends on the token GROUP — chunking the group changes routing, so
# MoE stacks keep one-shot admission (asserted below) and MLA parity is
# tested on this synthetic stack.
MLA_DENSE = ModelConfig(
    name="mla-dense-test", family="dense", d_model=32, num_heads=4,
    num_kv_heads=4, head_dim=8, d_ff=64, vocab_size=256,
    block_pattern=(("mla", "dense"),), num_blocks=2,
    mla=MLACfg(kv_lora_rank=16, qk_nope_head_dim=8, qk_rope_head_dim=4,
               v_head_dim=8),
    dtype="float32")

_PROMPTS = (5, 12, 3, 21)  # spans 1..3 chunks at chunk=8


def _mk(arch=None, mode=None):
    cfg = MLA_DENSE if arch == "mla-dense" else smoke_config(ARCHS[arch])
    cfg = dataclasses.replace(cfg, dtype="float32")
    if mode:
        cfg = cfg.with_attention(mode)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(cfg, sizes=_PROMPTS, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
            for s in sizes]


def _serve(cfg, params, prompts, *, prefill_chunk, fused=False, bucket=4,
           max_new=6, **kw):
    base = dict(num_slots=3, page_size=4, num_pages=65, max_blocks_per_slot=8,
                prefill_bucket=bucket, prefill_chunk=prefill_chunk,
                use_paged_kernels=fused)
    base.update(kw)
    serving = ServingCfg(**base)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)],
        GenerationConfig(max_new_tokens=max_new))
    return {i: res[i]["tokens"] for i in res}, stats, eng


# ------------------------------------------- chunked vs one-shot parity


@pytest.mark.parametrize("arch,mode", [
    ("qwen1.5-0.5b", None),            # dense K/V pages
    ("qwen1.5-0.5b", "decomposed"),    # T1 X pages (decoupled rope)
    ("opt-6.7b", "decomposed"),        # T1, absolute positions (exact T1)
    ("qwen1.5-0.5b", "retrieval"),     # T3: raw K/V pages + proxy codes
    ("mla-dense", None),               # MLA latent pages, absorbed chunks
])
def test_chunked_equals_oneshot(arch, mode):
    """ACCEPTANCE: chunked admission (prompts streamed into arena pages in
    page-aligned chunks, interleaved with decode) produces token-exact
    greedy output vs the one-shot admission oracle at f32 — on BOTH the jnp
    gather path and the fused Q-chunk>1 paged kernels."""
    cfg, params = _mk(arch, mode)
    prompts = _prompts(cfg)
    one, _, e0 = _serve(cfg, params, prompts, prefill_chunk=0)
    chg, sg, e1 = _serve(cfg, params, prompts, prefill_chunk=8)
    chf, sf, _ = _serve(cfg, params, prompts, prefill_chunk=8, fused=True)
    assert e1.chunked and not e0.chunked
    assert sg["prefill_chunks"] >= sum(-(-s // 8) for s in _PROMPTS[:1])
    for i in one:
        np.testing.assert_array_equal(one[i], chg[i])
        np.testing.assert_array_equal(one[i], chf[i])
    assert sg["dense_pages_leaked"] == 0 and sf["dense_pages_leaked"] == 0
    assert sg["prefill_write_bytes"] > 0  # energy story: writes accounted


def test_chunked_cpq_single_chunk_exact_and_multi_chunk_consistent():
    """CPQ tiers: a single-chunk admission is token-exact vs the unbucketed
    one-shot oracle (same level-0 fit over the valid tokens, raw within-chunk
    attention). Multi-chunk admissions compress incrementally and read their
    own codes across chunk boundaries — exactly what decode reads — so fused
    and gather agree token-exact at f32 and reruns are deterministic."""
    cfg, params = _mk("qwen1.5-0.5b", "cpq")
    short = _prompts(cfg, sizes=(5, 7, 3, 8))     # all fit one chunk of 8
    one, _, _ = _serve(cfg, params, short, prefill_chunk=0, bucket=1)
    chg, _, _ = _serve(cfg, params, short, prefill_chunk=8)
    chf, _, _ = _serve(cfg, params, short, prefill_chunk=8, fused=True)
    for i in one:
        np.testing.assert_array_equal(one[i], chg[i])
        np.testing.assert_array_equal(one[i], chf[i])

    multi = _prompts(cfg, sizes=(5, 12, 21), seed=1)
    mg, sg, _ = _serve(cfg, params, multi, prefill_chunk=8)
    mf, _, _ = _serve(cfg, params, multi, prefill_chunk=8, fused=True)
    mg2, _, _ = _serve(cfg, params, multi, prefill_chunk=8)
    for i in mg:
        np.testing.assert_array_equal(mg[i], mf[i])   # fused == gather
        np.testing.assert_array_equal(mg[i], mg2[i])  # deterministic
        assert (mg[i] >= 0).all() and (mg[i] < cfg.vocab_size).all()
    assert sg["dense_pages_leaked"] == 0


def test_chunked_decomposed_cpq_and_mla_cpq_valid():
    """T1+T2 and the CPQ latent tier (no fused kernel — gather like their
    decode): multi-chunk admissions stay valid, deterministic, leak-free."""
    for arch, mode in (("qwen1.5-0.5b", "decomposed_cpq"), ("mla-dense", "cpq")):
        cfg, params = _mk(arch, mode)
        prompts = _prompts(cfg, sizes=(5, 12, 21), seed=2)
        a, sa, eng = _serve(cfg, params, prompts, prefill_chunk=8)
        b, _, _ = _serve(cfg, params, prompts, prefill_chunk=8)
        assert eng.chunked
        for i in a:
            np.testing.assert_array_equal(a[i], b[i])
            assert len(a[i]) == 6
            assert (a[i] >= 0).all() and (a[i] < cfg.vocab_size).all()
        assert sa["dense_pages_leaked"] == 0


def test_chunked_tiered_matches_oneshot_and_escalates():
    """Tiered engine: chunked admission through the dense arm is exact vs
    one-shot; mid-request watermark escalation (dense -> T2) composes with
    chunked admission; both arenas end leak-free."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _prompts(cfg, sizes=(8, 10, 6, 7, 9), seed=3)
    kw = dict(num_pages=13, escalated_pages=33, enable_escalation=True,
              low_watermark=0.5, critical_watermark=0.25)
    tg, sg, _ = _serve(cfg, params, prompts, prefill_chunk=8, max_new=10, **kw)
    tf, sf, _ = _serve(cfg, params, prompts, prefill_chunk=8, max_new=10,
                       fused=True, **kw)
    assert sg["escalations"] >= 1 and sf["escalations"] >= 1
    for i in tg:
        np.testing.assert_array_equal(tg[i], tf[i])
    assert sg["dense_pages_leaked"] == 0 and sg["cpq_pages_leaked"] == 0


def test_group_routed_and_recurrent_archs_fall_back_to_oneshot():
    """Capacity-factor MoE routes per token GROUP (chunking changes drops)
    and recurrent state cannot be cut at page boundaries: both keep the
    exact one-shot admission even when prefill_chunk is set."""
    for arch in ("deepseek-v2-lite-16b", "xlstm-125m"):
        cfg, params = _mk(arch)
        prompts = _prompts(cfg, sizes=(5, 9), seed=4)
        one, _, e0 = _serve(cfg, params, prompts, prefill_chunk=0, max_new=4)
        fb, sfb, e1 = _serve(cfg, params, prompts, prefill_chunk=16, max_new=4)
        assert not e1.chunked and sfb["prefill_chunks"] == 0
        for i in one:
            np.testing.assert_array_equal(one[i], fb[i])


# ----------------------------------------------- no-scratch-cache guarantee


def test_chunked_admission_allocates_no_scratch_cache(monkeypatch):
    """ACCEPTANCE: the default (chunked) admission path never allocates a
    contiguous scratch prefill cache — M.init_caches is only reachable from
    the one-shot oracle path."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _prompts(cfg)
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        num_slots=3, page_size=4, num_pages=65, max_blocks_per_slot=8,
        prefill_bucket=4, prefill_chunk=8))
    assert eng.chunked

    def boom(*a, **k):
        raise AssertionError("contiguous scratch prefill cache allocated "
                             "on the chunked admission path")

    monkeypatch.setattr(M, "init_caches", boom)
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=4)
         for i, p in enumerate(prompts)],
        GenerationConfig(max_new_tokens=4))
    assert len(res) == len(prompts) and stats["prefill_chunks"] > 0


# -------------------------------------------------- interleaving / latency


def test_long_prompt_no_longer_stalls_running_rows():
    """The head-of-line property the tentpole exists for: while a long
    prompt streams in chunk by chunk, an already-running row keeps emitting
    a token EVERY tick (max inter-token gap 1); under one-shot admission the
    same workload stalls it for the whole monolithic prefill."""
    cfg, params = _mk("qwen1.5-0.5b")
    rng = np.random.default_rng(7)
    short = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
    long = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    reqs = lambda: [Request(rid=0, prompt=short, max_new_tokens=16, arrival=0.0),  # noqa: E731
                    Request(rid=1, prompt=long, max_new_tokens=4, arrival=2.0)]
    kw = dict(num_slots=2, page_size=4, num_pages=65, max_blocks_per_slot=16)
    gen = GenerationConfig(max_new_tokens=16)

    eng_c = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        prefill_bucket=8, prefill_chunk=8, **kw))
    res_c, _ = eng_c.serve(reqs(), gen)
    gaps_c = np.diff(res_c[0]["token_steps"])
    assert gaps_c.max() == 1, gaps_c                 # never stalled

    eng_o = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        prefill_bucket=8, prefill_chunk=0, **kw))
    res_o, _ = eng_o.serve(reqs(), gen)
    gaps_o = np.diff(res_o[0]["token_steps"])
    assert gaps_o.max() >= -(-len(long) // 8)        # monolithic stall
    # and the long prompt's first token is not delayed by chunking
    assert res_c[1]["first_token_step"] <= res_o[1]["first_token_step"] + 1


# --------------------------------------- split-invariance (property tests)


def check_chunk_split_invariance(seed, S, chunk, page_size):
    """Writing a prompt through ANY (chunk size, page size) split leaves
    identical page contents (every page, null page excluded) and identical
    lengths as the unsplit reference write."""
    rng = np.random.default_rng(seed)
    feat = 3
    nb = -(-S // page_size)
    num_pages = nb + 2
    vals = jnp.asarray(rng.normal(size=(S, feat)).astype(np.float32))
    block_row = jnp.asarray(np.arange(1, nb + 1, dtype=np.int32))

    def write_chunked(C):
        pages = jnp.zeros((num_pages, page_size, feat))
        off = 0
        while off < S:
            valid = min(C, S - off)
            buf = jnp.zeros((C, feat)).at[:valid].set(vals[off:off + valid])
            pages = pgc.write_chunk_pages(pages, block_row,
                                          jnp.asarray(off, jnp.int32),
                                          jnp.asarray(valid, jnp.int32), buf)
            off += valid
        return np.asarray(pages)

    ref = np.asarray(pgc.write_prompt_pages(
        jnp.zeros((num_pages, page_size, feat)), block_row, vals))
    got = write_chunked(chunk)
    np.testing.assert_array_equal(got[1:], ref[1:])  # all non-null pages
    logical = pgc.gather_pages(jnp.asarray(got), block_row[None])[0]
    np.testing.assert_array_equal(np.asarray(logical[:S]), np.asarray(vals))


@pytest.mark.parametrize("seed,S,chunk,page_size", [
    (0, 12, 4, 4), (1, 21, 8, 4), (2, 5, 8, 2), (3, 16, 16, 8), (4, 7, 2, 2),
])
def test_chunk_split_invariance_deterministic(seed, S, chunk, page_size):
    check_chunk_split_invariance(seed, S, chunk, page_size)


@hypothesis.given(seed=st.integers(0, 2 ** 16), S=st.integers(1, 48),
                  chunk=st.integers(1, 24), page_size=st.integers(1, 8))
@hypothesis.settings(max_examples=40, deadline=None)
def test_chunk_split_invariance_property(seed, S, chunk, page_size):
    check_chunk_split_invariance(seed, S, chunk, page_size)


def test_engine_chunked_pages_match_oneshot_pack():
    """Model-level: streaming a prompt through prefill_chunk_rows leaves the
    SAME dense K/V page contents (on valid positions) and lengths as the
    one-shot prefill + pack path, for every split of the same prompt."""
    cfg, params = _mk("qwen1.5-0.5b")
    rt = cfg.attention
    rng = np.random.default_rng(5)
    S = 13
    prompt = rng.integers(0, cfg.vocab_size, S).astype(np.int32)
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=17,
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=8)
    nb_needed = -(-S // 4)
    block_row = np.zeros((8,), np.int32)
    block_row[:nb_needed] = np.arange(1, nb_needed + 1)

    # one-shot: contiguous prefill packed into the pages
    caches1 = M.init_paged_caches(cfg, rt, serving, False)
    ctg = M.init_caches(cfg, rt, 1, 16)
    padded = np.concatenate([prompt, np.full((3,), prompt[-1], np.int32)])
    from functools import partial
    _, ctg = jax.jit(partial(M.prefill, cfg, rt))(
        params, {"tokens": jnp.asarray(padded[None])}, ctg,
        jnp.asarray(S - 1, jnp.int32))
    caches1 = jax.jit(partial(M.pack_prefill_caches, cfg, rt))(
        caches1, ctg, jnp.asarray(block_row), jnp.asarray(0, jnp.int32))

    def run_chunked(C):
        caches = M.init_paged_caches(cfg, rt, serving, False)
        off = 0
        while off < S:
            valid = min(C, S - off)
            ch = prompt[off:off + valid]
            if valid < C:
                ch = np.concatenate([ch, np.full((C - valid,), ch[-1], np.int32)])
            fn = partial(M.prefill_chunk_rows, cfg, rt, 0, off == 0)
            _, caches = jax.jit(fn)(
                params, jnp.asarray(ch[None]), jnp.asarray(0, jnp.int32),
                jnp.asarray(block_row), jnp.asarray(off, jnp.int32),
                jnp.asarray(valid, jnp.int32), caches)
            off += valid
        return caches

    def all_dense_k(caches):
        out = []
        for c in jax.tree.leaves(caches, is_leaf=lambda x: isinstance(
                x, pgc.PagedDenseKVCache)):
            if isinstance(c, pgc.PagedDenseKVCache):
                k = c.k  # (P, page, KV, Dh) or stacked (nb, P, page, KV, Dh)
                ks = k[None] if k.ndim == 4 else k
                for j in range(ks.shape[0]):
                    out.append(np.asarray(pgc.gather_pages(
                        ks[j], jnp.asarray(block_row[None])))[0, :S])
        assert out, "no dense paged caches found"
        return out

    ref = all_dense_k(caches1)
    for C in (4, 8, 12):
        got = all_dense_k(run_chunked(C))
        assert len(got) == len(ref)
        for a, b in zip(got, ref):
            np.testing.assert_allclose(a, b, rtol=0, atol=0)


# -------------------------------------------------- kernel-level oracles


def _rand_paged_dense(rng, P, page, KV, Dh, Dv):
    k = jnp.asarray(rng.normal(size=(P, page, KV, Dh)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(P, page, KV, Dv)).astype(np.float32))
    return k, v


@pytest.mark.parametrize("seed,offset,valid", [
    (0, 0, 8), (1, 8, 8), (2, 8, 3), (3, 4, 1), (4, 12, 5)])
def test_paged_flash_prefill_kernel_vs_oracle(seed, offset, valid):
    """Q-chunk>1 paged flash prefill == dense attention over the gathered
    logical view with (q_offset, kv_length) masking, on permuted pages."""
    from repro.kernels.flash_attn.ops import paged_flash_prefill_tpu

    rng = np.random.default_rng(seed)
    page, KV, Dh, Dv, C, H = 4, 2, 8, 8, 8, 4
    nb = 8
    P = nb + 2
    k, v = _rand_paged_dense(rng, P, page, KV, Dh, Dv)
    block_row = jnp.asarray(rng.permutation(np.arange(1, nb + 1)
                                            ).astype(np.int32))
    q = jnp.asarray(rng.normal(size=(1, C, H, Dh)).astype(np.float32))
    out = paged_flash_prefill_tpu(q, k, v, block_row,
                                  jnp.asarray(offset, jnp.int32),
                                  jnp.asarray(valid, jnp.int32), 0.35)
    ref = core_attn.dense_attention(
        q, pgc.gather_pages(k, block_row[None]),
        pgc.gather_pages(v, block_row[None]), 0.35, causal=True,
        q_offset=jnp.asarray(offset, jnp.int32),
        kv_length=jnp.asarray(offset + valid, jnp.int32))
    np.testing.assert_allclose(np.asarray(out)[0, :valid],
                               np.asarray(ref)[0, :valid], atol=2e-5)


@pytest.mark.parametrize("seed,offset,valid,kv_r", [
    (0, 0, 8, 1), (1, 8, 4, 1), (2, 4, 8, 2), (3, 12, 2, 2)])
def test_paged_decomposed_prefill_kernel_vs_oracle(seed, offset, valid, kv_r):
    """Q-chunk>1 paged decomposed prefill == decomposed_attention over the
    gathered X view with causal query positions (shared and per-kv rope)."""
    from repro.core.decomposed_attention import decomposed_attention
    from repro.kernels.decomposed_attn.ops import paged_decomposed_prefill_tpu

    rng = np.random.default_rng(seed)
    page, Dm, C, H, Dn, Dv, Rr = 4, 16, 8, 4, 8, 8, 4
    nb = 8
    P = nb + 2
    x = jnp.asarray(rng.normal(size=(P, page, Dm)).astype(np.float32))
    kr = jnp.asarray(rng.normal(size=(P, page, kv_r, Rr)).astype(np.float32))
    block_row = jnp.asarray(rng.permutation(np.arange(1, nb + 1)
                                            ).astype(np.int32))
    q_nope = jnp.asarray(rng.normal(size=(1, C, H, Dn)).astype(np.float32))
    q_rope = jnp.asarray(rng.normal(size=(1, C, H, Rr)).astype(np.float32))
    w_k = jnp.asarray(rng.normal(size=(Dm, H, Dn)).astype(np.float32))
    w_v = jnp.asarray(rng.normal(size=(Dm, H, Dv)).astype(np.float32))
    out = paged_decomposed_prefill_tpu(
        q_nope, q_rope, x, kr, block_row, jnp.asarray(offset, jnp.int32),
        jnp.asarray(valid, jnp.int32), w_k, w_v, 0.3)
    ref = decomposed_attention(
        q_nope, q_rope, pgc.gather_pages(x, block_row[None]),
        pgc.gather_pages(kr, block_row[None]), w_k, w_v,
        jnp.asarray(offset + valid, jnp.int32), 0.3,
        query_positions=offset + jnp.arange(C, dtype=jnp.int32))
    np.testing.assert_allclose(np.asarray(out)[0, :valid],
                               np.asarray(ref)[0, :valid], atol=2e-4)


@pytest.mark.parametrize("seed,offset,valid", [(0, 0, 8), (1, 8, 4), (2, 12, 8)])
def test_paged_cpq_prefill_kernel_vs_oracle(seed, offset, valid):
    """Q-chunk>1 paged CPQ prefill kernel == the jnp gather oracle
    (dequantized earlier pages + raw causal chunk tail)."""
    from repro.configs.base import CPQCfg
    from repro.core import cpq as cpq_lib
    from repro.kernels.cpq_dequant_attn.ops import paged_cpq_prefill_tpu

    rng = np.random.default_rng(seed)
    cfgq = CPQCfg(max_levels=3)
    page, KV, Dh, C, H = 4, 2, 8, 8, 4
    nb = 8
    P = nb + 2
    num_slots = 2

    kt = pgc._init_paged_cpq_tensor(P, page, num_slots, KV, Dh, cfgq)
    vt = pgc._init_paged_cpq_tensor(P, page, num_slots, KV, Dh, cfgq)

    def fill(t, seed2):
        r2 = np.random.default_rng(seed2)
        return t._replace(
            codes=jnp.asarray(r2.integers(-128, 127, size=t.codes.shape,
                                          dtype=np.int64).astype(np.int8)),
            level=jnp.asarray(r2.integers(0, cfgq.max_levels,
                                          size=t.level.shape).astype(np.int32)),
            scale=jnp.asarray(np.abs(r2.normal(size=t.scale.shape)
                                     ).astype(np.float32) + 0.05),
            zero=jnp.asarray(r2.normal(size=t.zero.shape).astype(np.float32)))

    kt, vt = fill(kt, seed + 10), fill(vt, seed + 20)
    block_row = jnp.asarray(rng.permutation(np.arange(1, nb + 1)
                                            ).astype(np.int32))
    slot = jnp.asarray(1, jnp.int32)
    q = jnp.asarray(rng.normal(size=(1, C, H, Dh)).astype(np.float32))
    k_raw = jnp.asarray(rng.normal(size=(1, C, KV, Dh)).astype(np.float32))
    v_raw = jnp.asarray(rng.normal(size=(1, C, KV, Dh)).astype(np.float32))

    out = paged_cpq_prefill_tpu(q, kt, vt, k_raw, v_raw, slot, block_row,
                                jnp.asarray(offset, jnp.int32),
                                jnp.asarray(valid, jnp.int32), 0.3)
    ref = pgc.cpq_chunk_prefill_attention(
        q, kt, vt, block_row, slot, k_raw, v_raw,
        jnp.asarray(offset, jnp.int32), jnp.asarray(valid, jnp.int32), 0.3)
    np.testing.assert_allclose(np.asarray(out)[0, :valid],
                               np.asarray(ref)[0, :valid], atol=3e-5)
