"""Per-arch REDUCED-config smoke tests (deliverable f): one forward/train
step on CPU asserting output shapes + no NaNs, plus prefill/decode
consistency for every assigned architecture family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED, smoke_config
from repro.models import model as M

B, S = 2, 24


def _batch(cfg, key, seq=S):
    batch = {}
    if cfg.input_kind == "audio_frames":
        batch["frames"] = 0.3 * jax.random.normal(key, (B, seq, cfg.d_model))
        batch["labels"] = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (B, seq), 0, cfg.vocab_size)
        batch["tokens"], batch["labels"] = toks, toks
    if cfg.input_kind == "text+patches":
        batch["patches"] = jax.random.normal(key, (B, cfg.num_patch_tokens,
                                                   cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", sorted(ASSIGNED) + ["opt-6.7b"])
def test_smoke_forward_and_train_step(arch):
    cfg = smoke_config(ARCHS[arch])
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, aux = M.forward_train(cfg, params, batch, remat=False)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits, np.float32))), arch
    # one real train step (grads + update)
    from repro.optim import adamw
    from repro.train.step import TrainStepCfg, make_train_step

    opt = adamw(1e-3)
    step = make_train_step(cfg, opt, TrainStepCfg(microbatches=1, remat=True))
    p2, _, metrics = step(params, opt.init(params), jnp.asarray(0), batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # parameters actually changed
    delta = jax.tree.reduce(
        lambda a, l: a + float(jnp.sum(jnp.abs(l[0].astype(jnp.float32)
                                               - l[1].astype(jnp.float32)))),
        jax.tree.map(lambda a, b: (a, b), params, p2), 0.0,
        is_leaf=lambda x: isinstance(x, tuple))
    assert delta > 0, arch


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
def test_smoke_prefill_decode_consistency_f32(arch):
    """decode(t=S) logits == teacher-forced logits[S] in f32.

    MoE capacity is raised so routing drops (which legitimately differ
    between teacher-forced and incremental execution) don't mask the
    numerical comparison."""
    cfg = dataclasses.replace(smoke_config(ARCHS[arch]), dtype="float32")
    if cfg.moe is not None:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key, seq=S + 1)
    batch.pop("labels")
    logits, _ = M.forward_train(cfg, params, batch, remat=False)
    rt = cfg.attention
    caches = M.init_caches(cfg, rt, B, S + 4)
    pf = {k: (v[:, :S] if k in ("tokens", "frames") else v)
          for k, v in batch.items()}
    lg, caches = M.prefill(cfg, rt, params, pf, caches)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(logits[:, S - 1]),
                               atol=2e-4)
    if "tokens" in batch:
        tok = batch["tokens"][:, S:S + 1]
        lg2, _ = M.decode_step(cfg, rt, params, tok, jnp.asarray(S, jnp.int32),
                               caches)
        np.testing.assert_allclose(np.asarray(lg2), np.asarray(logits[:, S]),
                                   atol=2e-4)


@pytest.mark.parametrize("mode", ["decomposed", "cpq", "retrieval",
                                  "decomposed_cpq"])
def test_smoke_paper_modes_decode(mode):
    """Every paper technique decodes on the representative MHA arch."""
    cfg = dataclasses.replace(smoke_config(ARCHS["musicgen-large"]),
                              dtype="float32").with_attention(mode)
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    batch = {"frames": 0.3 * jax.random.normal(key, (B, S, cfg.d_model))}
    rt = cfg.attention
    caches = M.init_caches(cfg, rt, B, S + 4)
    lg, caches = M.prefill(cfg, rt, params, batch, caches)
    tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    lg2, _ = M.decode_step(cfg, rt, params, tok, jnp.asarray(S, jnp.int32), caches)
    assert lg2.shape == (B, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(lg2)))


def test_decomposed_mode_matches_dense_on_norope():
    """On an absolute-position arch the T1 decode path is EXACT vs dense, and
    the T1+T2 composition (8-bit, no prune) stays greedy-equivalent."""
    from repro.configs.base import CPQCfg

    base = dataclasses.replace(smoke_config(ARCHS["musicgen-large"]),
                               dtype="float32")
    key = jax.random.PRNGKey(3)
    params = M.init_params(base, key)
    batch = {"frames": 0.3 * jax.random.normal(key, (B, S, base.d_model))}
    outs = {}
    for mode in ("dense", "decomposed", "decomposed_cpq"):
        cfg = (base.with_attention(mode, cpq=CPQCfg(prune_ratio=0.0, bits=8))
               if mode == "decomposed_cpq" else base.with_attention(mode))
        rt = cfg.attention
        caches = M.init_caches(cfg, rt, B, S + 4)
        lg, caches = M.prefill(cfg, rt, params, batch, caches)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        lg2, _ = M.decode_step(cfg, rt, params, tok, jnp.asarray(S, jnp.int32),
                               caches)
        outs[mode] = np.asarray(lg2)
    np.testing.assert_allclose(outs["dense"], outs["decomposed"], atol=3e-4)
    # 8-bit quantized X cache: small logit error, same greedy decisions
    assert np.abs(outs["decomposed_cpq"] - outs["dense"]).max() < 0.05
    assert (outs["decomposed_cpq"].argmax(-1) == outs["dense"].argmax(-1)).all()
