"""Optional-hypothesis shim.

``hypothesis`` is a test-extra dependency (see pyproject.toml
``[project.optional-dependencies] test``), not a runtime one. Importing it
unconditionally made the whole suite fail at collection on environments
without it. Property-based test modules import the library through this shim
instead: when hypothesis is absent, ``@hypothesis.given(...)`` turns the test
into a cleanly skipped stub (the same outcome as ``pytest.importorskip``, but
scoped to the property tests so the deterministic tests in the same module
still run).
"""
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the extra
    HAVE_HYPOTHESIS = False

    class _GivenShim:
        """Stands in for the ``hypothesis`` module: ``given`` swallows the
        test body and emits a skip stub; every other decorator is identity."""

        def given(self, *_a, **_k):
            def deco(f):
                @pytest.mark.skip(reason="hypothesis not installed "
                                         "(pip install '.[test]')")
                def _skipped():
                    pass

                _skipped.__name__ = getattr(f, "__name__", "property_test")
                return _skipped

            return deco

        def settings(self, *_a, **_k):
            return lambda f: f

        def assume(self, *_a, **_k):  # never reached from a skipped stub
            return True

    class _StShim:
        """Strategy factories only feed ``given``; return inert placeholders."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    hypothesis = _GivenShim()
    st = _StShim()
