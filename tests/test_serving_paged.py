"""Paged KV-cache arena tests: allocator/defrag invariants, page-plumbing
round trips, paged-vs-contiguous decode equivalence across cache modes."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.configs.base import CPQCfg, RetrievalCfg
from repro.core import kv_cache as kvc
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.engine import ContinuousServeEngine, GenerationConfig, ServeEngine
from repro.serving.scheduler import Request


# ---------------------------------------------------------------- allocator


def test_page_allocator_invariants():
    a = pgc.PageAllocator(9)  # pages 1..8 allocatable
    assert a.num_free == 8 and a.num_used == 0
    p1 = a.alloc(3)
    assert len(set(p1)) == 3 and pgc.NULL_PAGE not in p1
    assert a.num_used == 3 and abs(a.utilization - 3 / 8) < 1e-9
    with pytest.raises(pgc.PageAllocator.OutOfPages):
        a.alloc(6)
    a.free(p1[:2])
    assert a.num_free == 7
    with pytest.raises(AssertionError):  # double free
        a.free([p1[0]])
    with pytest.raises(AssertionError):  # null page is never owned
        a.free([pgc.NULL_PAGE])


def test_pages_needed():
    assert pgc.pages_needed(0, 4) == 0
    assert pgc.pages_needed(1, 4) == 1
    assert pgc.pages_needed(4, 4) == 1
    assert pgc.pages_needed(5, 4) == 2


def test_defrag_compacts_and_preserves_views():
    rng = np.random.default_rng(0)
    num_pages, page, kv, dh = 17, 4, 2, 3
    pages = jnp.asarray(rng.normal(size=(num_pages, page, kv, dh)).astype(np.float32))
    # two slots with scattered pages
    bt = np.zeros((2, 4), np.int32)
    bt[0, :3] = [9, 2, 14]
    bt[1, :2] = [7, 11]
    before = np.asarray(pgc.gather_pages(pages, jnp.asarray(bt)))
    perm, new_bt, free = pgc.defrag_plan(bt, num_pages)
    new_pages = jnp.take(pages, jnp.asarray(perm), axis=0)
    after = np.asarray(pgc.gather_pages(new_pages, jnp.asarray(new_bt)))
    np.testing.assert_array_equal(before, after)
    # compaction: mapped pages occupy the lowest non-null ids
    mapped = sorted(p for p in new_bt.flatten() if p != pgc.NULL_PAGE)
    assert mapped == list(range(1, 6))
    assert set(free) == set(range(6, num_pages))


# ------------------------------------------------------------ page plumbing


def test_prompt_and_token_writes_roundtrip():
    page, max_blocks = 4, 4
    pages = jnp.zeros((9, page, 3))
    block_row = jnp.asarray([2, 5, 0, 0], jnp.int32)  # 2 pages mapped
    vals = jnp.arange(6 * 3, dtype=jnp.float32).reshape(6, 3)
    pages = pgc.write_prompt_pages(pages, block_row, vals)
    bt = jnp.asarray([[2, 5, 0, 0]], jnp.int32)
    logical = pgc.gather_pages(pages, bt)[0]
    np.testing.assert_array_equal(np.asarray(logical[:6]), np.asarray(vals))

    # append one token at position 6 (same page as slots 4..7)
    rows_active = jnp.asarray([True])
    tok = jnp.full((1, 3), 7.0)
    pages = pgc.write_token_pages(pages, bt, jnp.asarray([6]), rows_active, tok)
    logical = pgc.gather_pages(pages, bt)[0]
    np.testing.assert_array_equal(np.asarray(logical[6]), np.asarray(tok[0]))
    # inactive rows write the null page, never their mapped pages (the
    # logical view beyond the mapped blocks reads the null page and is
    # masked by lengths downstream, so only the first 8 slots matter)
    pages2 = pgc.write_token_pages(pages, bt, jnp.asarray([7]), jnp.asarray([False]),
                                   jnp.full((1, 3), -1.0))
    np.testing.assert_array_equal(np.asarray(pgc.gather_pages(pages2, bt)[0][:8]),
                                  np.asarray(logical[:8]))


def test_prompt_write_past_capacity_hits_null_page():
    """Bucket padding beyond max_blocks*page must land on the null page, not
    wrap around onto the slot's last mapped page (regression)."""
    page = 4
    pages = jnp.zeros((5, page, 2))
    block_row = jnp.asarray([1, 2], jnp.int32)        # capacity 8 tokens
    vals = jnp.ones((12, 2))                          # 4 tokens past capacity
    pages = pgc.write_prompt_pages(pages, block_row, vals)
    logical = pgc.gather_pages(pages, block_row[None])[0]
    np.testing.assert_array_equal(np.asarray(logical[:8]), np.ones((8, 2)))
    # overflow went to page 0, mapped pages untouched beyond their 8 slots
    assert np.asarray(pages[0]).sum() > 0
    np.testing.assert_array_equal(np.asarray(pages[3]), np.zeros((page, 2)))


# ------------------------------------------------- decode-path equivalence


def _mk(arch="qwen1.5-0.5b", mode=None):
    cfg = smoke_config(ARCHS[arch])
    if mode:
        cfg = cfg.with_attention(mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _static_refs(cfg, params, prompts, gen):
    eng = ServeEngine(cfg, params, max_len=64)
    return [eng.generate({"tokens": jnp.asarray(p[None])}, gen)[0][0] for p in prompts]


_PROMPT_LENS = (5, 12, 3, 9)


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
            for s in _PROMPT_LENS]


def test_paged_dense_greedy_equals_contiguous():
    """The acceptance-criterion equivalence: mixed prompt lengths, greedy,
    paged continuous decode == contiguous dense decode, token for token."""
    cfg, params = _mk()
    gen = GenerationConfig(max_new_tokens=6)
    prompts = _prompts(cfg)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=41,
                         max_blocks_per_slot=8, prefill_bucket=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)
    assert stats["dense_pages_leaked"] == 0


@pytest.mark.parametrize("arch,mode", [
    ("opt-6.7b", "decomposed"),        # absolute positions: T1 exact
    ("qwen1.5-0.5b", "decomposed"),    # rope: decoupled T1
    ("qwen1.5-0.5b", "retrieval"),     # T3
    ("deepseek-v2-lite-16b", "decomposed"),  # MLA latent cache
    ("jamba-1.5-large-398b", None),    # hybrid: paged attn + slot SSM state
    ("xlstm-125m", None),              # pure recurrent (exact prefill path)
])
def test_paged_modes_match_contiguous(arch, mode):
    cfg, params = _mk(arch, mode)
    gen = GenerationConfig(max_new_tokens=5)
    prompts = _prompts(cfg, seed=1)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=65,
                         max_blocks_per_slot=8, prefill_bucket=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, _ = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)


@pytest.mark.parametrize("mode", ["cpq", "decomposed_cpq"])
def test_paged_cpq_modes_match_with_unbucketed_prefill(mode):
    """CPQ prefill statistics are fitted over the (possibly padded) prompt, so
    exact equality with the contiguous path needs prefill_bucket=1 (no
    padding). Bucketed admission stays VALID, just not bit-identical."""
    cfg, params = _mk(mode=mode)
    gen = GenerationConfig(max_new_tokens=5)
    prompts = _prompts(cfg, seed=2)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=65,
                         max_blocks_per_slot=8, prefill_bucket=1)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, _ = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)


# ----------------------------------------------------------------- traffic


def test_bytes_per_token_every_container():
    """Satellite: every cache container reports traffic through ONE API —
    including the CPQ modes that used to raise TypeError."""
    cpq = CPQCfg()
    dense = kvc.init_dense(1, 8, 2, 4)
    x = kvc.init_x(1, 8, 16, 2, 4)
    cq = kvc.init_cpq(1, 8, 2, 4, cpq)
    ret = kvc.init_retrieval(1, 8, 2, 4, RetrievalCfg())
    cqx = kvc.init_cpq_x(1, 8, 16, 2, 4, cpq)
    vals = {c.__class__.__name__: kvc.bytes_per_token(c, cpq)
            for c in (dense, x, cq, ret, cqx)}
    assert all(v > 0 for v in vals.values()), vals
    assert vals["CPQKVCache"] < vals["DenseKVCache"]   # T2 compresses
    assert vals["CPQXCache"] < vals["XCache"]          # T1+T2 < T1

    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9)
    paged = [
        pgc.init_paged_dense(9, 4, 2, 4),
        pgc.init_paged_x(9, 4, 16, 2, 4),
        pgc.init_paged_cpq(9, 4, 2, 2, 4, cpq),
        pgc.init_paged_retrieval(9, 4, 2, 2, 4, RetrievalCfg()),
        pgc.init_paged_cpq_x(9, 4, 2, 16, 2, 4, cpq),
    ]
    for contiguous, p in zip((dense, x, cq, ret, cqx), paged):
        bp = pgc.bytes_per_token(p, serving.page_size, cpq)
        bc = kvc.bytes_per_token(contiguous, cpq)
        # paged = payload + amortized block-table entry
        assert abs(bp - (bc + 4.0 / serving.page_size)) < 1e-6
        assert pgc.arena_bytes(p) > 0
