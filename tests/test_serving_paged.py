"""Paged KV-cache arena tests: allocator/defrag invariants (deterministic +
property-based), page-plumbing round trips, paged-vs-contiguous decode
equivalence across cache modes, and fused paged-kernel engine parity."""
from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.configs.base import CPQCfg, RetrievalCfg
from repro.core import kv_cache as kvc
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.engine import ContinuousServeEngine, GenerationConfig, ServeEngine
from repro.serving.scheduler import Request


# ---------------------------------------------------------------- allocator


def test_page_allocator_invariants():
    a = pgc.PageAllocator(9)  # pages 1..8 allocatable
    assert a.num_free == 8 and a.num_used == 0
    p1 = a.alloc(3)
    assert len(set(p1)) == 3 and pgc.NULL_PAGE not in p1
    assert a.num_used == 3 and abs(a.utilization - 3 / 8) < 1e-9
    with pytest.raises(pgc.PageAllocator.OutOfPages):
        a.alloc(6)
    a.free(p1[:2])
    assert a.num_free == 7
    with pytest.raises(pgc.PageAllocator.DoubleFree):  # double free RAISES
        a.free([p1[0]])
    with pytest.raises(pgc.PageAllocator.DoubleFree):  # null page never owned
        a.free([pgc.NULL_PAGE])


def test_pages_needed():
    assert pgc.pages_needed(0, 4) == 0
    assert pgc.pages_needed(1, 4) == 1
    assert pgc.pages_needed(4, 4) == 1
    assert pgc.pages_needed(5, 4) == 2


def test_defrag_compacts_and_preserves_views():
    rng = np.random.default_rng(0)
    num_pages, page, kv, dh = 17, 4, 2, 3
    pages = jnp.asarray(rng.normal(size=(num_pages, page, kv, dh)).astype(np.float32))
    # two slots with scattered pages
    bt = np.zeros((2, 4), np.int32)
    bt[0, :3] = [9, 2, 14]
    bt[1, :2] = [7, 11]
    before = np.asarray(pgc.gather_pages(pages, jnp.asarray(bt)))
    perm, new_bt, free = pgc.defrag_plan(bt, num_pages)
    new_pages = jnp.take(pages, jnp.asarray(perm), axis=0)
    after = np.asarray(pgc.gather_pages(new_pages, jnp.asarray(new_bt)))
    np.testing.assert_array_equal(before, after)
    # compaction: mapped pages occupy the lowest non-null ids
    mapped = sorted(p for p in new_bt.flatten() if p != pgc.NULL_PAGE)
    assert mapped == list(range(1, 6))
    assert set(free) == set(range(6, num_pages))


def check_allocator_cycle(seed, num_pages, n_ops):
    """Model-based allocator check: random alloc/free/preempt-style cycles
    never double-allocate, never hand out the null page, never leak, and
    raise OutOfPages exactly when the demand exceeds the free count."""
    rng = np.random.default_rng(seed)
    a = pgc.PageAllocator(num_pages)
    outstanding: list[list[int]] = []   # "requests" holding page lists
    ever_allocated = set()
    for _ in range(n_ops):
        assert a.num_free + a.num_used == num_pages - 1     # conservation
        op = rng.random()
        if op < 0.55:                                       # alloc a request
            n = int(rng.integers(1, max(num_pages // 3, 2)))
            if n > a.num_free:
                with pytest.raises(pgc.PageAllocator.OutOfPages):
                    a.alloc(n)
                continue
            pages = a.alloc(n)
            assert len(pages) == n == len(set(pages))       # no dup in grant
            assert pgc.NULL_PAGE not in pages
            held = {p for req in outstanding for p in req}
            assert not held & set(pages)                    # no double alloc
            assert all(0 < p < num_pages for p in pages)
            ever_allocated.update(pages)
            outstanding.append(pages)
        elif outstanding:                                   # retire/preempt
            req = outstanding.pop(int(rng.integers(0, len(outstanding))))
            a.free(req)
    for req in outstanding:                                 # drain: leak-free
        a.free(req)
    assert a.num_used == 0 and a.num_free == num_pages - 1


@pytest.mark.parametrize("seed", range(4))
def test_allocator_cycles_deterministic(seed):
    check_allocator_cycle(seed, num_pages=17, n_ops=120)


@hypothesis.given(seed=st.integers(0, 2 ** 16), num_pages=st.integers(2, 33))
@hypothesis.settings(max_examples=25, deadline=None)
def test_allocator_cycles_property(seed, num_pages):
    check_allocator_cycle(seed, num_pages, n_ops=60)


def check_defrag_roundtrip(seed, num_pages, n_slots, max_blocks, page, feat):
    """defrag_plan followed by the page moves preserves every live token
    (gathered logical contents identical), compacts mapped pages onto the
    lowest ids, and rebuilds a consistent free list."""
    rng = np.random.default_rng(seed)
    pages = jnp.asarray(rng.normal(size=(num_pages, page, feat)).astype(np.float32))
    avail = rng.permutation(np.arange(1, num_pages)).tolist()
    bt = np.zeros((n_slots, max_blocks), np.int32)
    for s in range(n_slots):
        for j in range(int(rng.integers(0, max_blocks + 1))):
            if not avail:
                break
            bt[s, j] = avail.pop()
    before = np.asarray(pgc.gather_pages(pages, jnp.asarray(bt)))
    perm, new_bt, free = pgc.defrag_plan(bt, num_pages)
    moved = jnp.take(pages, jnp.asarray(perm), axis=0)
    after = np.asarray(pgc.gather_pages(moved, jnp.asarray(new_bt)))
    np.testing.assert_array_equal(before, after)            # live tokens kept
    mapped = sorted({int(p) for p in new_bt.flatten() if p != pgc.NULL_PAGE})
    assert mapped == list(range(1, len(mapped) + 1))        # compacted
    assert set(free) == set(range(num_pages)) - {pgc.NULL_PAGE} - set(mapped)
    assert len(perm) == num_pages and sorted(perm) == list(range(num_pages))


@pytest.mark.parametrize("seed", range(4))
def test_defrag_roundtrip_deterministic(seed):
    check_defrag_roundtrip(seed, num_pages=19, n_slots=3, max_blocks=4,
                           page=4, feat=3)


@hypothesis.given(seed=st.integers(0, 2 ** 16), num_pages=st.integers(2, 25),
                  n_slots=st.integers(1, 4))
@hypothesis.settings(max_examples=25, deadline=None)
def test_defrag_roundtrip_property(seed, num_pages, n_slots):
    check_defrag_roundtrip(seed, num_pages, n_slots, max_blocks=3, page=2,
                           feat=2)


# ------------------------------------------------------------ page plumbing


def test_prompt_and_token_writes_roundtrip():
    page, max_blocks = 4, 4
    pages = jnp.zeros((9, page, 3))
    block_row = jnp.asarray([2, 5, 0, 0], jnp.int32)  # 2 pages mapped
    vals = jnp.arange(6 * 3, dtype=jnp.float32).reshape(6, 3)
    pages = pgc.write_prompt_pages(pages, block_row, vals)
    bt = jnp.asarray([[2, 5, 0, 0]], jnp.int32)
    logical = pgc.gather_pages(pages, bt)[0]
    np.testing.assert_array_equal(np.asarray(logical[:6]), np.asarray(vals))

    # append one token at position 6 (same page as slots 4..7)
    rows_active = jnp.asarray([True])
    tok = jnp.full((1, 3), 7.0)
    pages = pgc.write_token_pages(pages, bt, jnp.asarray([6]), rows_active, tok)
    logical = pgc.gather_pages(pages, bt)[0]
    np.testing.assert_array_equal(np.asarray(logical[6]), np.asarray(tok[0]))
    # inactive rows write the null page, never their mapped pages (the
    # logical view beyond the mapped blocks reads the null page and is
    # masked by lengths downstream, so only the first 8 slots matter)
    pages2 = pgc.write_token_pages(pages, bt, jnp.asarray([7]), jnp.asarray([False]),
                                   jnp.full((1, 3), -1.0))
    np.testing.assert_array_equal(np.asarray(pgc.gather_pages(pages2, bt)[0][:8]),
                                  np.asarray(logical[:8]))


def test_prompt_write_past_capacity_hits_null_page():
    """Bucket padding beyond max_blocks*page must land on the null page, not
    wrap around onto the slot's last mapped page (regression)."""
    page = 4
    pages = jnp.zeros((5, page, 2))
    block_row = jnp.asarray([1, 2], jnp.int32)        # capacity 8 tokens
    vals = jnp.ones((12, 2))                          # 4 tokens past capacity
    pages = pgc.write_prompt_pages(pages, block_row, vals)
    logical = pgc.gather_pages(pages, block_row[None])[0]
    np.testing.assert_array_equal(np.asarray(logical[:8]), np.ones((8, 2)))
    # overflow went to page 0, mapped pages untouched beyond their 8 slots
    assert np.asarray(pages[0]).sum() > 0
    np.testing.assert_array_equal(np.asarray(pages[3]), np.zeros((page, 2)))


# ------------------------------------------------- decode-path equivalence


def _mk(arch="qwen1.5-0.5b", mode=None):
    cfg = smoke_config(ARCHS[arch])
    if mode:
        cfg = cfg.with_attention(mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _static_refs(cfg, params, prompts, gen):
    eng = ServeEngine(cfg, params, max_len=64)
    return [eng.generate({"tokens": jnp.asarray(p[None])}, gen)[0][0] for p in prompts]


_PROMPT_LENS = (5, 12, 3, 9)


def _prompts(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=s).astype(np.int32)
            for s in _PROMPT_LENS]


def test_paged_dense_greedy_equals_contiguous():
    """The PR-1 equivalence: mixed prompt lengths, greedy, paged continuous
    decode == contiguous dense decode, token for token. Pinned to the jnp
    gather path (``use_paged_kernels=False``), which shares every op with the
    static engine — construction-exact at any dtype. Fused-kernel parity is
    covered by test_paged_kernel_engine_parity (f32) below."""
    cfg, params = _mk()
    gen = GenerationConfig(max_new_tokens=6)
    prompts = _prompts(cfg)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=41,
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=0,  # one-shot oracle: shares static ops
                         use_paged_kernels=False)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=6) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)
    assert stats["dense_pages_leaked"] == 0


@pytest.mark.parametrize("arch,mode", [
    ("opt-6.7b", "decomposed"),        # absolute positions: T1 exact
    ("qwen1.5-0.5b", "decomposed"),    # rope: decoupled T1
    ("qwen1.5-0.5b", "retrieval"),     # T3
    ("deepseek-v2-lite-16b", "decomposed"),  # MLA latent cache
    ("jamba-1.5-large-398b", None),    # hybrid: paged attn + slot SSM state
    ("xlstm-125m", None),              # pure recurrent (exact prefill path)
])
def test_paged_modes_match_contiguous(arch, mode):
    cfg, params = _mk(arch, mode)
    gen = GenerationConfig(max_new_tokens=5)
    prompts = _prompts(cfg, seed=1)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=65,
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=0,  # one-shot oracle: shares static ops
                         use_paged_kernels=False)  # gather path == static ops
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, _ = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)


@pytest.mark.parametrize("mode", ["cpq", "decomposed_cpq"])
def test_paged_cpq_modes_match_with_unbucketed_prefill(mode):
    """CPQ prefill statistics are fitted over the (possibly padded) prompt, so
    exact equality with the contiguous path needs prefill_bucket=1 (no
    padding). Bucketed admission stays VALID, just not bit-identical."""
    cfg, params = _mk(mode=mode)
    gen = GenerationConfig(max_new_tokens=5)
    prompts = _prompts(cfg, seed=2)
    refs = _static_refs(cfg, params, prompts, gen)
    serving = ServingCfg(num_slots=4, page_size=4, num_pages=65,
                         max_blocks_per_slot=8, prefill_bucket=1,
                         prefill_chunk=0,  # one-shot oracle: shares static ops
                         use_paged_kernels=False)  # gather path == static ops
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, _ = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)],
        gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)


# -------------------------------------------- fused paged-kernel parity


def _serve_tokens(cfg, params, prompts, *, use_paged_kernels, tiered=False,
                  max_new=8):
    kw = dict(num_slots=3, page_size=4, num_pages=65, max_blocks_per_slot=8,
              prefill_bucket=4, use_paged_kernels=use_paged_kernels)
    if tiered:
        kw.update(num_pages=13, escalated_pages=33, enable_escalation=True,
                  low_watermark=0.5, critical_watermark=0.25)
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(**kw))
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)],
        GenerationConfig(max_new_tokens=max_new))
    return {i: res[i]["tokens"] for i in res}, stats


@pytest.mark.parametrize("arch,mode,tiered", [
    ("qwen1.5-0.5b", None, False),           # dense -> paged flash kernel
    ("qwen1.5-0.5b", "cpq", False),          # T2 -> paged CPQ-dequant kernel
    ("qwen1.5-0.5b", "decomposed", False),   # T1 -> paged decomposed kernel
    ("deepseek-v2-lite-16b", None, False),   # MLA latent -> paged decomposed
    ("qwen1.5-0.5b", None, True),            # tiered dense+CPQ dispatch
])
def test_paged_kernel_engine_parity(arch, mode, tiered):
    """ACCEPTANCE: the fused paged kernels (dense flash, CPQ-dequant, X/MLA
    decomposed — and the tiered dispatch over the first two) produce
    token-exact greedy output vs the PR-1 gather-based decode on the
    continuous engine. Run at f32 so both paths agree to reduction-order
    epsilon; the jnp gather oracle's bf16 rounding points are an XLA-fusion
    artifact no kernel can reproduce bit-for-bit at bf16."""
    import dataclasses

    cfg = smoke_config(ARCHS[arch])
    cfg = dataclasses.replace(cfg, dtype="float32")
    if mode:
        cfg = cfg.with_attention(mode)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = _prompts(cfg, seed=3) + _prompts(cfg, seed=4)
    fused, fstats = _serve_tokens(cfg, params, prompts,
                                  use_paged_kernels=True, tiered=tiered)
    gather, _ = _serve_tokens(cfg, params, prompts,
                              use_paged_kernels=False, tiered=tiered)
    assert set(fused) == set(gather) == set(range(len(prompts)))
    for i in fused:
        np.testing.assert_array_equal(fused[i], gather[i])
    if tiered:
        assert fstats["escalations"] >= 1  # the tiered dispatch really ran
    assert fstats["dense_pages_leaked"] == 0


def test_paged_kernel_bf16_decode_is_valid():
    """At the default bf16 the fused kernels are not bit-identical to the
    gather oracle (different rounding points), but decode must stay finite,
    in-vocab, and leak-free across all slots and steps."""
    cfg, params = _mk()  # bf16 smoke model, fused kernels on by default
    prompts = _prompts(cfg, seed=5)
    toks, stats = _serve_tokens(cfg, params, prompts, use_paged_kernels=True)
    assert set(toks) == set(range(len(prompts)))
    for i in toks:
        assert len(toks[i]) == 8
        assert (toks[i] >= 0).all() and (toks[i] < cfg.vocab_size).all()
    assert stats["dense_pages_leaked"] == 0


# ----------------------------------------------------------------- traffic


def test_bytes_per_token_every_container():
    """Satellite: every cache container reports traffic through ONE API —
    including the CPQ modes that used to raise TypeError."""
    cpq = CPQCfg()
    dense = kvc.init_dense(1, 8, 2, 4)
    x = kvc.init_x(1, 8, 16, 2, 4)
    cq = kvc.init_cpq(1, 8, 2, 4, cpq)
    ret = kvc.init_retrieval(1, 8, 2, 4, RetrievalCfg())
    cqx = kvc.init_cpq_x(1, 8, 16, 2, 4, cpq)
    vals = {c.__class__.__name__: kvc.bytes_per_token(c, cpq)
            for c in (dense, x, cq, ret, cqx)}
    assert all(v > 0 for v in vals.values()), vals
    assert vals["CPQKVCache"] < vals["DenseKVCache"]   # T2 compresses
    assert vals["CPQXCache"] < vals["XCache"]          # T1+T2 < T1

    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9)
    paged = [
        pgc.init_paged_dense(9, 4, 2, 4),
        pgc.init_paged_x(9, 4, 16, 2, 4),
        pgc.init_paged_cpq(9, 4, 2, 2, 4, cpq),
        pgc.init_paged_retrieval(9, 4, 2, 2, 4, RetrievalCfg()),
        pgc.init_paged_cpq_x(9, 4, 2, 16, 2, 4, cpq),
    ]
    for contiguous, p in zip((dense, x, cq, ret, cqx), paged):
        bp = pgc.bytes_per_token(p, serving.page_size, cpq)
        bc = kvc.bytes_per_token(contiguous, cpq)
        # paged = payload + amortized block-table entry
        assert abs(bp - (bc + 4.0 / serving.page_size)) < 1e-6
        assert pgc.arena_bytes(p) > 0
