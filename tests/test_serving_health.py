"""Health-monitor / rebalance / graceful-degradation suite: the probe state
machine (suspect -> down -> backoff recovery -> readmit), the satellite
regression that zero healthy replicas PARKS instead of raising, the
migrate-without-drain primitive (mid-decode, mid-PREFILL, and double
A->B->C migration — all token-exact), deadline-aware timeouts, and the
bounded-backlog shed policy."""
import math

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.health import HealthMonitor
from repro.serving.request import (BATCH, INTERACTIVE, SamplingParams,
                                   ServeRequest)
from repro.serving.router import ReplicaRouter
from repro.serving.scheduler import SchedulerConfigError


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


SERVING = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4)
FT = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4,
                probe_interval=2, probe_failures=2, probe_backoff=2,
                auto_drain=True)


@pytest.fixture(scope="module")
def donor(model):
    cfg, params = model
    return ContinuousServeEngine(cfg, params, serving=SERVING)


def _router(model, donor, n, serving=SERVING, plans=None, placement="rr"):
    cfg, params = model
    r = ReplicaRouter(cfg, params, num_replicas=n, serving=serving,
                      placement=placement, fault_plans=plans)
    for eng in r.engines:
        eng.adopt_compiled(donor)
    return r


def _run(router, cap=600):
    for _ in range(cap):
        if not router.has_unfinished():
            return
        router.step()
    raise AssertionError("router did not finish")


# ----------------------------------------------- probe state machine units


class _ScriptedEngine:
    """health() replays a script of dict responses / exceptions."""

    def __init__(self, script):
        self.script = list(script)

    def health(self):
        item = self.script.pop(0) if self.script else _OK
        if isinstance(item, Exception):
            raise item
        return item


_OK = {"alive": True, "has_work": False, "queued": 0, "progress": 0,
       "free_frac": 1.0, "exhausted": False}
_BUSY = dict(_OK, has_work=True, queued=1, progress=5)


class _FakeRouter:
    def __init__(self, scripts):
        self.engines = [_ScriptedEngine(s) for s in scripts]
        self._manual_drained = set()
        self.drained = []
        self.readmitted = []

    def _auto_drain(self, i):
        self.drained.append(i)

    def readmit(self, i):
        self.readmitted.append(i)


def test_monitor_drains_after_threshold_and_readmits_on_recovery():
    boom = RuntimeError("dead")
    r = _FakeRouter([[boom, boom, _OK]])
    mon = HealthMonitor(r, interval=1, fail_threshold=2, backoff=2,
                        auto_drain=True)
    mon.tick(0)
    assert mon.state(0) == "suspect" and r.drained == []
    mon.tick(1)
    assert mon.state(0) == "down" and r.drained == [0]
    assert mon.replicas[0].next_probe == 1 + 2  # backoff, not interval
    mon.tick(2)                                 # not due yet
    assert r.readmitted == []
    mon.tick(3)                                 # recovery probe succeeds
    assert mon.state(0) == "healthy" and r.readmitted == [0]
    assert mon.stats() == {"auto_drains": 1, "recoveries": 1, "down": 0}


def test_monitor_backoff_doubles_and_caps():
    r = _FakeRouter([[RuntimeError(i) for i in range(10)]])
    mon = HealthMonitor(r, interval=1, fail_threshold=1, backoff=2,
                        auto_drain=True)
    mon.tick(0)
    assert mon.state(0) == "down"
    gaps = []
    now = mon.replicas[0].next_probe
    for _ in range(5):
        mon.tick(now)
        nxt = mon.replicas[0].next_probe
        gaps.append(nxt - now)
        now = nxt
    assert gaps == [4, 8, 16, 16, 16], "expected doubling capped at 8x base"


def test_monitor_progress_stall_detection():
    stuck = dict(_BUSY)                          # same progress twice
    r = _FakeRouter([[_BUSY, stuck, stuck]])
    mon = HealthMonitor(r, interval=1, fail_threshold=3, backoff=2)
    mon.tick(0)                                  # baseline: records progress
    assert mon.state(0) == "healthy"
    mon.tick(1)
    assert mon.state(0) == "suspect", "no progress with work = failure"
    assert "no progress" in mon.replicas[0].last_error


def test_monitor_pressure_check_needs_queued_work():
    empty_full = dict(_OK, free_frac=0.0)        # exhausted but no queue
    queued_full = dict(_BUSY, free_frac=0.0)
    r = _FakeRouter([[empty_full, queued_full]])
    mon = HealthMonitor(r, interval=1, fail_threshold=3, exhaust_frac=0.0)
    mon.tick(0)
    assert mon.state(0) == "healthy", "exhaustion without demand is fine"
    mon.tick(1)
    assert mon.state(0) == "suspect"
    assert "exhausted" in mon.replicas[0].last_error


def test_monitor_skips_manually_drained():
    r = _FakeRouter([[RuntimeError("x")] * 5])
    r._manual_drained.add(0)
    mon = HealthMonitor(r, interval=1, fail_threshold=1, auto_drain=True)
    for t in range(4):
        mon.tick(t)
    assert mon.state(0) == "healthy" and r.drained == []


# ------------------------------------- satellite: park instead of raise


def test_zero_healthy_replicas_parks_then_places(model, donor):
    """The old crash: every replica draining -> add_request raised
    RuntimeError. Now the request parks in the backlog and places on the
    first recovery."""
    plan = FaultPlan((FaultEvent(1, "crash", 3),))
    router = _router(model, donor, 1, serving=FT, plans=[plan])
    router.reset()
    rid0 = router.add_request(ServeRequest(
        prompt=np.arange(1, 7), sampling=SamplingParams(max_tokens=4)))
    # step until the monitor auto-drains the only replica
    for _ in range(30):
        router.step()
        if router.healthy() == []:
            break
    assert router.healthy() == [], "fault never tripped auto-drain"
    rid1 = router.add_request(ServeRequest(      # old behavior: raised here
        prompt=np.arange(1, 7), sampling=SamplingParams(max_tokens=4)))
    assert router.stats()["backlog"] >= 1
    _run(router)
    res = router.results()
    assert set(res) >= {rid0, rid1}
    assert res[rid1]["finish_reason"] == "max_tokens"
    assert list(res[rid0]["tokens"]) == list(res[rid1]["tokens"]), (
        "same prompt, same greedy stream — recovery changed tokens")
    assert router.stats()["backlog"] == 0


def test_manual_drain_still_guards_last_replica(model, donor):
    router = _router(model, donor, 2, serving=FT)
    router.reset()
    router.drain(1)
    with pytest.raises(SchedulerConfigError):
        router.drain(0)
    # ...but the forced (auto-drain) path may take the last one down
    assert router.drain(0, force=True) == 0
    assert router.healthy() == []


# ------------------------------------------------------ stats satellite


def test_stats_expose_health_and_robustness_counters(model, donor):
    router = _router(model, donor, 2, serving=FT)
    router.reset()
    router.add_request(ServeRequest(prompt=np.arange(1, 6),
                                    sampling=SamplingParams(max_tokens=3)))
    _run(router)
    stats = router.stats()
    for key in ("timeouts", "shed", "rebalanced", "auto_drains",
                "recoveries", "backlog", "backlog_timeouts", "down"):
        assert key in stats, f"missing router stat {key}"
    for row in stats["per_replica"]:
        assert row["health"] == "healthy"
        assert row["consecutive_failures"] == 0
        assert row["auto_drained"] is False
        assert "probe_failures" in row and "timeouts" in row


# ------------------------------------------------ rebalance (no drain)


def _ref_tokens(model, donor, reqs):
    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    res, _ = eng.serve(reqs)
    return {rid: list(rec["tokens"]) for rid, rec in res.items()}


def test_rebalance_mid_decode_greedy_parity(model, donor):
    reqs = [ServeRequest(prompt=np.arange(1, 8),
                         sampling=SamplingParams(max_tokens=8), rid=i)
            for i in range(3)]
    ref = _ref_tokens(model, donor, reqs)
    router = _router(model, donor, 2, placement="rr")
    router.reset()
    for r in reqs:
        router.add_request(r)
    for _ in range(6):
        router.step()                            # rid 0 is decoding on 0
    src = router.replica_of(0)
    dst = 1 - src
    assert router.rebalance(0, dst) is True
    assert router.replica_of(0) == dst
    assert router.healthy() == [0, 1], "rebalance must not drain anyone"
    _run(router)
    res = router.results()
    for rid in ref:
        assert list(res[rid]["tokens"]) == ref[rid]
    stats = router.stats()
    assert stats["rebalanced"] == 1 and stats["dense_pages_leaked"] == 0


def test_rebalance_prefilling_row_token_exact(model, donor):
    """Satellite: migrating a row that is still MID-CHUNK (prefilling state)
    replays its snapshot token-exact — the chunked-prefill offset restarts
    from the context, not from the partial arena write."""
    long_prompt = np.arange(1, 25)               # 24 tokens = 6 chunks of 4
    reqs = [ServeRequest(prompt=long_prompt,
                         sampling=SamplingParams(max_tokens=6), rid=0)]
    ref = _ref_tokens(model, donor, reqs)
    router = _router(model, donor, 2, placement="rr")
    router.reset()
    router.add_request(reqs[0])
    src = router.replica_of(0)
    router.step()                                # 1 chunk in: prefilling
    eng = router.engines[src]
    row = [r for r in eng._st.sched.occupied() if r.rid == 0]
    assert row and row[0].state == "prefilling", "row should be mid-prefill"
    assert router.rebalance(0, 1 - src) is True
    _run(router)
    res = router.results()
    assert list(res[0]["tokens"]) == ref[0]
    assert res[0]["finish_reason"] == "max_tokens"
    assert router.stats()["dense_pages_leaked"] == 0


def test_double_migration_seeded_parity(model, donor):
    """Satellite: A -> B -> C — two consecutive migrations of a SEEDED
    request keep the sampled stream bit-exact (draws are fold_in(seed, i),
    a function of the request alone)."""
    sp = SamplingParams(temperature=0.9, top_k=12, seed=31, max_tokens=10)
    reqs = [ServeRequest(prompt=np.arange(1, 9), sampling=sp, rid=0)]
    ref = _ref_tokens(model, donor, reqs)
    router = _router(model, donor, 3, placement="rr")
    router.reset()
    router.add_request(reqs[0])
    a = router.replica_of(0)
    for _ in range(4):
        router.step()
    b = (a + 1) % 3
    assert router.rebalance(0, b) is True        # A -> B mid-stream
    for _ in range(3):
        router.step()
    c = (b + 1) % 3
    assert router.rebalance(0, c) is True        # B -> C mid-stream
    assert router.replica_of(0) == c
    _run(router)
    res = router.results()
    assert list(res[0]["tokens"]) == ref[0], "seeded stream diverged"
    assert res[0]["preemptions"] >= 1
    assert router.stats()["rebalanced"] == 2


def test_rebalance_guards(model, donor):
    router = _router(model, donor, 2)
    router.reset()
    rid = router.add_request(ServeRequest(
        prompt=np.arange(1, 5), sampling=SamplingParams(max_tokens=2)))
    src = router.replica_of(rid)
    assert router.rebalance(rid, src) is False   # already there
    assert router.rebalance(999, 1 - src) is False
    with pytest.raises(SchedulerConfigError):
        router.rebalance(rid, 7)
    _run(router)
    assert router.rebalance(rid, 1 - src) is False  # finished


# --------------------------------------------------- deadlines / shedding


def test_explicit_deadline_times_out(model, donor):
    """A blown SamplingParams.deadline retires with finish_reason 'timeout'
    at a tick boundary: counted, pages freed, finish-only event emitted."""
    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    eng.reset()
    events = []
    eng.add_request(ServeRequest(
        prompt=np.arange(1, 6),
        sampling=SamplingParams(max_tokens=25, deadline=4.0)),
        stream=events.append)
    while eng.has_unfinished():
        eng.step()
    res = eng.results()[0]
    assert res["finish_reason"] == "timeout"
    assert len(res["tokens"]) < 25
    fin = [e for e in events if e.finished]
    assert len(fin) == 1 and fin[0].finish_reason == "timeout"
    assert fin[0].token == -1 and fin[0].index == len(res["tokens"])
    st = eng.stats()
    assert st["timeouts"] == 1
    assert st["dense_pages_leaked"] == 0, "timeout leaked arena pages"


def test_deadline_scale_derives_slo_budgets(model, donor):
    """deadline_scale turns finite SloClass targets into enforced budgets;
    BATCH (infinite targets) never times out."""
    cfg, params = model
    tight = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                       max_blocks_per_slot=8, prefill_bucket=4,
                       prefill_chunk=4, deadline_scale=0.25)
    eng = ContinuousServeEngine(cfg, params, serving=tight)
    eng.adopt_compiled(donor)
    eng.reset()
    r_int = eng.add_request(ServeRequest(
        prompt=np.arange(1, 10), slo=INTERACTIVE,
        sampling=SamplingParams(max_tokens=20)))
    r_bat = eng.add_request(ServeRequest(
        prompt=np.arange(1, 10), slo=BATCH,
        sampling=SamplingParams(max_tokens=4)))
    while eng.has_unfinished():
        eng.step()
    res = eng.results()
    assert res[r_int]["finish_reason"] == "timeout", (
        "0.25x-scaled INTERACTIVE budget should be unmeetable")
    assert res[r_bat]["finish_reason"] == "max_tokens", (
        "BATCH has no finite targets, hence no derived deadline")
    assert eng.stats()["timeouts"] == 1


def test_deadlines_off_by_default(model, donor):
    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    eng.reset()
    rid = eng.add_request(ServeRequest(
        prompt=np.arange(1, 10), slo=INTERACTIVE,
        sampling=SamplingParams(max_tokens=6)))
    while eng.has_unfinished():
        eng.step()
    assert eng.results()[rid]["finish_reason"] == "max_tokens"
    assert eng.stats()["timeouts"] == 0
    assert not eng._st.has_deadlines


def test_bounded_backlog_sheds_batch_class(model, donor):
    """With every replica down and the backlog full, deadline-free
    batch-class arrivals shed (counted, finished 'shed', never raised);
    non-batch arrivals keep parking."""
    shed_cfg = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                          max_blocks_per_slot=8, prefill_bucket=4,
                          prefill_chunk=4, max_backlog=1, auto_drain=True,
                          probe_interval=2, probe_failures=2,
                          probe_backoff=2)
    router = _router(model, donor, 1, serving=shed_cfg)
    router.reset()
    router._auto_drain(0)                        # monitor path, forced
    assert router.healthy() == []
    sp = SamplingParams(max_tokens=3)
    r0 = router.add_request(ServeRequest(prompt=np.arange(1, 5),
                                         slo=BATCH, sampling=sp))
    r1 = router.add_request(ServeRequest(prompt=np.arange(1, 5),
                                         slo=BATCH, sampling=sp))
    r2 = router.add_request(ServeRequest(prompt=np.arange(1, 5),
                                         slo=INTERACTIVE, sampling=sp))
    stats = router.stats()
    assert stats["shed"] == 1 and stats["backlog"] == 2
    res = router.results()
    assert res[r1]["finish_reason"] == "shed" and len(res[r1]["tokens"]) == 0
    assert r0 not in res and r2 not in res, "parked work is not finished"
    router.readmit(0)
    _run(router)
    res = router.results()
    assert res[r0]["finish_reason"] == "max_tokens"
    assert res[r2]["finish_reason"] == "max_tokens"


def test_parked_requests_can_time_out(model, donor):
    """A parked request past its deadline finishes 'timeout' from the
    backlog — counted separately (backlog_timeouts) from engine timeouts."""
    router = _router(model, donor, 1, serving=FT)
    router.reset()
    router._auto_drain(0)
    rid = router.add_request(ServeRequest(
        prompt=np.arange(1, 5),
        sampling=SamplingParams(max_tokens=4, deadline=2.0)))
    for _ in range(4):                           # router clock passes 2.0
        router.step()
    res = router.results()
    assert res[rid]["finish_reason"] == "timeout"
    stats = router.stats()
    assert stats["backlog_timeouts"] == 1 and stats["backlog"] == 0
    assert stats["timeouts"] == 1, "backlog timeouts fold into the total"
