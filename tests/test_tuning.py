"""Auto-tuner suite (ROADMAP item 5): dominance / non-dominated sort /
hypervolume units, the repair contract (every mutated / crossed / repaired
genome materializes into a VALID ``ServingCfg`` inside the knob space and
under the fixed arena byte budget), same-seed search determinism and
checkpoint-resume bit-identity on a cheap synthetic objective,
``ServingCfg.validate`` clear-error units (including at engine
construction), and the ``from_preset`` round trip against the committed
presets JSON."""
import json
import os
import sys

import numpy as np
import pytest

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import SchedulerConfigError
from repro.tuning import (DEFAULT_GENOME, EvalRecord, KnobSpace, ParetoSearch,
                          dominates, hypervolume, load_presets, materialize,
                          non_dominated_sort, pareto_front, select_presets)
from repro.tuning.evolution import make_space_from_signature
from repro.tuning.space import space_for_trace

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------- frontier

def test_dominates():
    assert dominates((1, 1), (2, 2))
    assert dominates((1, 2), (1, 3))          # tie on one axis, better other
    assert not dominates((1, 2), (1, 2))      # equal: no strict improvement
    assert not dominates((1, 3), (3, 1))      # incomparable
    assert not dominates((2, 2), (1, 1))


def test_pareto_front_known():
    pts = [(1, 5), (2, 2), (5, 1), (3, 3), (2, 2), (6, 6)]
    front = pareto_front(pts)
    assert front == [0, 1, 2, 4]              # (3,3) dominated by (2,2); dup kept
    fronts = non_dominated_sort(pts)
    assert fronts[0] == [0, 1, 2, 4]
    assert fronts[1] == [3]
    assert fronts[2] == [5]
    assert sorted(i for f in fronts for i in f) == list(range(len(pts)))


def test_hypervolume_known_values():
    # single point: a box
    assert hypervolume([(1.0, 1.0)], (3.0, 3.0)) == pytest.approx(4.0)
    # two staircase points: union of boxes, overlap counted once
    assert hypervolume([(1.0, 2.0), (2.0, 1.0)], (3.0, 3.0)) \
        == pytest.approx(2 + 2 - 1)
    # dominated and out-of-reference points contribute nothing
    assert hypervolume([(1.0, 2.0), (2.0, 1.0), (2.5, 2.5), (5.0, 0.0)],
                       (3.0, 3.0)) == pytest.approx(3.0)
    assert hypervolume([], (3.0, 3.0)) == 0.0
    # 3d box
    assert hypervolume([(0.0, 0.0, 0.0)], (2.0, 2.0, 2.0)) \
        == pytest.approx(8.0)


# ------------------------------------------------------------------- space

def _space():
    return KnobSpace(max_len=48)


def test_default_genome_matches_hand_tuned_equal_arena():
    import dataclasses

    from repro.serving.trace import equal_arena_serving
    sp = _space()
    got = sp.to_serving(sp.default_genome())
    want = equal_arena_serving(4, 48, 8, prefill_chunk=16)
    # escalated_pages is budget-derived by the tuner but left at the class
    # default by the hand-tuned foil; escalation is OFF in both, so the
    # field is inert — everything else must match exactly
    assert got == dataclasses.replace(
        want, escalated_pages=got.escalated_pages)


def test_proposals_stay_in_space_after_repair():
    sp = _space()
    rng = np.random.default_rng(7)
    budget_bytes = sp.budget_tokens
    for _ in range(200):
        a, b = sp.sample(rng), sp.sample(rng)
        for g in (a, sp.mutate(a, rng, 0.35), sp.crossover(a, b, rng)):
            for knob in sp.knobs:
                assert g[knob.name] in knob.choices, (knob.name, g)
            s = sp.to_serving(g)          # .validate() chained inside
            assert s.prefill_chunk % s.page_size == 0
            assert s.critical_watermark <= s.low_watermark <= 1.0
            assert s.low_watermark <= s.high_watermark <= 1.0
            # equal-arena contract: capacity never exceeds the byte budget
            # by more than one page of rounding slack
            assert (s.num_pages - 1) * s.page_size <= budget_bytes


def test_repair_fixes_out_of_space_genomes():
    sp = _space()
    g = sp.validate_and_repair({"num_slots": 5, "page_size": 9,
                                "policy": "lifo",
                                "low_watermark": 0.05,
                                "critical_watermark": 0.9,
                                "high_watermark": 0.0})
    for knob in sp.knobs:
        assert g[knob.name] in knob.choices
    assert g["critical_watermark"] <= g["low_watermark"] \
        <= g["high_watermark"]
    sp.to_serving(g)
    # missing knobs fill from the default genome
    assert sp.validate_and_repair({}) == sp.default_genome()


def test_mutation_always_moves():
    sp = _space()
    rng = np.random.default_rng(0)
    for _ in range(50):
        g = sp.sample(rng)
        assert sp.mutate(g, rng, 0.0) != g     # p=0 still forces one move


# ---------------------------------------------------------------- evolution

def _synthetic_evaluate(space):
    """Cheap deterministic stand-in: objectives derived from the genome."""
    def ev(g):
        s = space.to_serving(g)
        obj = (-float(s.num_slots * (1 + 0.3 * s.spec_len)),
               float(s.prefill_chunk + 10 * (s.policy == "fifo")),
               float(s.page_size + s.num_slots))
        return obj, {"num_slots": s.num_slots}
    return ev


def test_same_seed_reproduces_search():
    sp = _space()
    runs = []
    for _ in range(2):
        se = ParetoSearch(sp, _synthetic_evaluate(sp), seed=3, mu=4, lam=4)
        front = se.run(20)
        runs.append(([sp.genome_key(r.genome) for r in se.records],
                     [r.objectives for r in front]))
    assert runs[0] == runs[1]
    se2 = ParetoSearch(sp, _synthetic_evaluate(sp), seed=4, mu=4, lam=4)
    se2.run(20)
    assert [sp.genome_key(r.genome) for r in se2.records] != runs[0][0]


def test_record_zero_is_hand_tuned_default():
    sp = _space()
    se = ParetoSearch(sp, _synthetic_evaluate(sp), seed=0)
    se.run(1)
    assert se.baseline().genome == sp.default_genome()
    assert sp.default_genome() == dict(DEFAULT_GENOME)


def test_frontier_is_non_dominated_and_covers_baseline():
    sp = _space()
    se = ParetoSearch(sp, _synthetic_evaluate(sp), seed=0, mu=4, lam=4)
    front = se.run(24)
    objs = [r.objectives for r in front]
    assert len(pareto_front(objs)) == len(objs)
    base = se.baseline().objectives
    presets = select_presets(sp, front)
    for axis, name in enumerate(("throughput", "latency", "energy")):
        assert presets[name].objectives[axis] <= base[axis]
    assert se.frontier_hypervolume() > 0


def test_checkpoint_resume_bit_identical(tmp_path):
    sp = _space()
    ck = str(tmp_path / "ck.json")
    a = ParetoSearch(sp, _synthetic_evaluate(sp), seed=5, mu=4, lam=4,
                     checkpoint=ck)
    a.run(6)
    assert os.path.exists(ck)
    # fresh process stand-in: new search object resumes from the file
    b = ParetoSearch(sp, _synthetic_evaluate(sp), seed=5, mu=4, lam=4,
                     checkpoint=ck)
    assert len(b.records) == 6
    front_b = b.run(18)
    straight = ParetoSearch(sp, _synthetic_evaluate(sp), seed=5, mu=4, lam=4)
    front_s = straight.run(18)
    assert [sp.genome_key(r.genome) for r in b.records] == \
        [sp.genome_key(r.genome) for r in straight.records]
    assert [r.objectives for r in front_b] == [r.objectives for r in front_s]


def test_checkpoint_param_mismatch_rejected(tmp_path):
    sp = _space()
    ck = str(tmp_path / "ck.json")
    ParetoSearch(sp, _synthetic_evaluate(sp), seed=5, checkpoint=ck).run(3)
    with pytest.raises(ValueError, match="seed"):
        ParetoSearch(sp, _synthetic_evaluate(sp), seed=6, checkpoint=ck)
    with pytest.raises(ValueError, match="knob space"):
        ParetoSearch(KnobSpace(max_len=64), _synthetic_evaluate(sp), seed=5,
                     checkpoint=ck)


def test_space_signature_round_trip(tmp_path):
    sp = _space()
    ck = str(tmp_path / "ck.json")
    ParetoSearch(sp, _synthetic_evaluate(sp), seed=1, checkpoint=ck).run(2)
    with open(ck) as f:
        sig = json.load(f)["space"]
    sp2 = make_space_from_signature(sig)
    assert sp2.genome_key(sp2.default_genome()) == \
        sp.genome_key(sp.default_genome())
    assert sp2.to_serving(sp2.default_genome()) == \
        sp.to_serving(sp.default_genome())


def test_memo_hits_advance_budget_on_tiny_space():
    # a space smaller than the budget must terminate, re-using evaluations
    sp = KnobSpace(max_len=48, knobs=(
        KnobSpace(max_len=48).knobs[0],))  # num_slots only: 4 genomes
    calls = {"n": 0}

    def ev(g):
        calls["n"] += 1
        return (float(g["num_slots"]),), {}

    se = ParetoSearch(sp, ev, seed=0, mu=2, lam=2)
    se.run(12)
    assert len(se.records) == 12
    assert calls["n"] <= 4


# ------------------------------------------------------- ServingCfg.validate

def test_validate_clear_errors():
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServingCfg(page_size=8, prefill_chunk=12)
    with pytest.raises(ValueError, match="high_watermark"):
        ServingCfg(low_watermark=0.6, high_watermark=0.4)
    with pytest.raises(ValueError, match="critical_watermark"):
        ServingCfg(critical_watermark=0.5, low_watermark=0.25)
    with pytest.raises(ValueError, match="policy"):
        ServingCfg(policy="lifo")
    with pytest.raises(ValueError, match="spec_len"):
        ServingCfg(spec_len=-1)
    with pytest.raises(ValueError, match="num_pages"):
        ServingCfg(num_pages=1)
    # strict-only gate: speculation needs chunked admission
    cfg = ServingCfg(spec_len=2, prefill_chunk=0)     # constructs fine
    with pytest.raises(ValueError, match="spec_len"):
        cfg.validate()
    ok = ServingCfg()
    assert ok.validate() is ok                 # chainable: returns self


def test_engine_construction_raises_scheduler_config_error():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    from repro.serving.engine import ContinuousServeEngine
    bad = ServingCfg(spec_len=2, prefill_chunk=0)
    with pytest.raises(SchedulerConfigError, match="spec_len"):
        ContinuousServeEngine(cfg, params, serving=bad)


# ----------------------------------------------------------------- presets

def test_committed_presets_load_and_validate():
    path = ServingCfg.preset_path()
    assert os.path.exists(path), "run launch/tune.py to regenerate"
    doc = load_presets(path)
    assert doc["version"] == 1
    names = ServingCfg.list_presets()
    for req in ("latency", "throughput", "energy", "default"):
        assert req in names
    for name in names:
        s = ServingCfg.from_preset(name)       # .validate() inside
        assert isinstance(s, ServingCfg)
        assert s.prefill_chunk % s.page_size == 0
    # frontier in the committed doc really is non-dominated
    objs = [tuple(p["objectives"][n] for n in doc["objective_names"])
            for p in doc["frontier"]]
    assert len(pareto_front(objs)) == len(objs)
    # per-axis winners are no worse than the hand-tuned default
    base = doc["presets"]["default"]["objectives"]
    for name in ("throughput", "latency", "energy"):
        assert doc["presets"][name]["objectives"][name] <= base[name]


def test_from_preset_overrides_and_unknown():
    s = ServingCfg.from_preset("latency", num_slots=2, num_pages=9,
                               max_blocks_per_slot=2)
    assert s.num_slots == 2 and s.num_pages == 9
    with pytest.raises(ValueError, match="latency"):
        ServingCfg.from_preset("no-such-preset")


def test_materialize_document_shape(tmp_path):
    sp = _space()
    se = ParetoSearch(sp, _synthetic_evaluate(sp), seed=0, mu=4, lam=4)
    se.run(16)
    doc = materialize(se, trace={"kind": "synthetic"})
    assert set(doc["presets"]) == {"throughput", "latency", "energy",
                                   "default"}
    for p in doc["presets"].values():
        ServingCfg(**p["serving"])            # serving dict round-trips
    assert doc["seed"] == 0 and doc["budget"] == 16
    assert doc["hypervolume"] == se.frontier_hypervolume()
    # wall-time free: a rerun materializes the identical document
    se2 = ParetoSearch(sp, _synthetic_evaluate(sp), seed=0, mu=4, lam=4)
    se2.run(16)
    assert materialize(se2, trace={"kind": "synthetic"}) == doc


# ------------------------------------------------- trace extraction (sat 1)

def test_run_trace_importable_and_bench_back_compat():
    from repro.serving.trace import (class_tails, equal_arena_serving,
                                     make_slo_workload, run_trace)
    from benchmarks.bench_serving import run_continuous
    assert run_continuous is run_trace
    work, slos = make_slo_workload(0, 8, 64, 2.0)
    assert len(work) == 8 and len(slos) == 8
    assert {s.name for s in slos} <= {"interactive", "batch"}
    assert equal_arena_serving(4, 48, 8).num_pages == \
        4 * ((48 + 7) // 8) + 1


def test_space_for_trace_covers_workload():
    from repro.serving.trace import make_workload
    work = make_workload(0, 6, 64, 2.0)
    sp = space_for_trace(work)
    assert sp.max_len >= max(len(w.prompt) + w.target for w in work)
