"""T2 CPQ + HQE property tests (paper §IV invariants)."""
from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CPQCfg
from repro.core import cpq as C


@hypothesis.given(
    bits=st.sampled_from([4, 8]),
    prune=st.floats(0.0, 0.7),
    seed=st.integers(0, 2**16),
)
@hypothesis.settings(max_examples=15, deadline=None)
def test_roundtrip_error_bound(bits, prune, seed):
    """Kept elements reconstruct within scale/2; pruned dequant to EXACTLY 0;
    keep fraction ~ 1 - prune_ratio."""
    cfg = CPQCfg(prune_ratio=prune, bits=bits)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 64, 4, 8))
    t = C.cpq_compress_prefill(x, cfg, 64)
    d = {k: float(v) for k, v in C.cpq_roundtrip_error(x, t).items()}
    bound = float(np.asarray(t.scale[:, 0]).max()) / 2 * 1.02 + 1e-6
    assert d["max_err_kept"] <= bound
    assert d["pruned_exact_zero"] == 0.0
    assert abs(d["keep_frac"] - (1 - prune)) < 0.15


def test_hqe_token_quantized_once():
    """Appending new tokens never rewrites earlier codes or level-0 params
    (the paper's 'each token is quantized once' guarantee)."""
    cfg = CPQCfg(prune_ratio=0.3, bits=8, max_levels=4)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 32, 4, 8))
    t = C.cpq_compress_prefill(x, cfg, 64)
    codes0 = np.asarray(t.codes[:, :32]).copy()
    scale0 = np.asarray(t.scale[:, 0]).copy()
    for i in range(8):
        tok = (3.0 + i) * jax.random.normal(jax.random.fold_in(key, i), (2, 1, 4, 8))
        t = C.cpq_append_decode(t, tok, jnp.asarray(32 + i, jnp.int32), cfg)
    assert np.array_equal(np.asarray(t.codes[:, :32]), codes0)
    assert np.array_equal(np.asarray(t.scale[:, 0]), scale0)


def test_hqe_levels_monotone_and_capped():
    cfg = CPQCfg(prune_ratio=0.0, bits=8, max_levels=3)
    key = jax.random.PRNGKey(1)
    x = 0.1 * jax.random.normal(key, (1, 16, 2, 4))
    t = C.cpq_compress_prefill(x, cfg, 64)
    prev = np.asarray(t.num_levels).copy()
    for i in range(6):
        tok = (5.0 * (i + 1)) * jnp.ones((1, 1, 2, 4))
        t = C.cpq_append_decode(t, tok, jnp.asarray(16 + i, jnp.int32), cfg)
        cur = np.asarray(t.num_levels)
        assert np.all(cur >= prev)
        prev = cur
    assert np.asarray(t.num_levels).max() <= cfg.max_levels


def test_hqe_range_extension_covers_outlier():
    """A spawned level's range includes the outlier (near-exact recon)."""
    cfg = CPQCfg(prune_ratio=0.0, bits=8, max_levels=4)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(2), (1, 16, 2, 4))
    t = C.cpq_compress_prefill(x, cfg, 32)
    t = C.cpq_append_decode(t, 9.0 * jnp.ones((1, 1, 2, 4)),
                            jnp.asarray(16, jnp.int32), cfg)
    xh = C.cpq_dequant(t, jnp.float32)
    assert float(jnp.abs(xh[:, 16] - 9.0).max()) < 0.05


def test_in_range_token_reuses_level():
    cfg = CPQCfg(prune_ratio=0.0, bits=8, max_levels=4)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 2, 4))
    t = C.cpq_compress_prefill(x, cfg, 64)
    lv0 = np.asarray(t.num_levels).copy()
    t = C.cpq_append_decode(t, 0.1 * jnp.ones((1, 1, 2, 4)),
                            jnp.asarray(32, jnp.int32), cfg)
    assert np.array_equal(np.asarray(t.num_levels), lv0)


def test_traffic_model_orders():
    """CPQ bytes/token < dense bf16 bytes/token for sane configs, and 4-bit
    beats 8-bit."""
    from repro.core.cpq import cpq_bytes_per_token, dense_bytes_per_token

    h, d = 8, 128
    dense = dense_bytes_per_token(h, d)
    b8 = cpq_bytes_per_token(CPQCfg(prune_ratio=0.4, bits=8), h, d)
    b4 = cpq_bytes_per_token(CPQCfg(prune_ratio=0.4, bits=4), h, d)
    assert b4 < b8 < dense
    assert dense / b4 > 4  # the headline compression regime
