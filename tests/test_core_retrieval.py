"""T3 retrieval attention properties (paper §V)."""
from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RetrievalCfg
from repro.core import retrieval_attention as R
from repro.core.attention import dense_attention


def _setup(seed, B=2, N=96, H=8, KV=4, Dh=16):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh))
    k = jax.random.normal(ks[1], (B, N, KV, Dh))
    v = jax.random.normal(ks[2], (B, N, KV, Dh))
    return q, k, v


def test_full_topk_equals_dense():
    q, k, v = _setup(0)
    N = k.shape[1]
    codes, ps, pz = R.fit_proxy(k, 8)
    cfg = RetrievalCfg(top_k=N, recent_window=4)
    length = jnp.asarray(N, jnp.int32)
    out = R.retrieval_attention(q, k, v, codes, ps, pz, length, cfg, 0.25)
    ref = dense_attention(q, k, v, 0.25, causal=False, kv_length=length)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)


@hypothesis.given(seed=st.integers(0, 2**16))
@hypothesis.settings(max_examples=10, deadline=None)
def test_error_decreases_with_k(seed):
    q, k, v = _setup(seed)
    N = k.shape[1]
    codes, ps, pz = R.fit_proxy(k, 8)
    length = jnp.asarray(N, jnp.int32)
    ref = dense_attention(q, k, v, 0.25, causal=False, kv_length=length)
    errs = []
    for topk in (8, 32, N):
        cfg = RetrievalCfg(top_k=topk, recent_window=4)
        out = R.retrieval_attention(q, k, v, codes, ps, pz, length, cfg, 0.25)
        errs.append(float(jnp.abs(out - ref).max()))
    assert errs[2] <= errs[0] + 1e-5
    assert errs[2] < 1e-4


def test_proxy_recall():
    """int8 proxy top-k recalls >= 90% of exact top-k keys."""
    q, k, v = _setup(3, N=128)
    codes, ps, pz = R.fit_proxy(k, 8)
    sp = R.proxy_scores(q, codes, ps, pz)          # (B,1,H,N)
    B, _, H, N = sp.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, 1, KV, g, -1)
    se = jnp.einsum("btkgd,bnkd->btkgn", qg, k).reshape(B, 1, H, N)
    K = 16
    _, ip = jax.lax.top_k(sp, K)
    _, ie = jax.lax.top_k(se.astype(jnp.float32), K)
    recall = np.mean([
        len(set(np.asarray(ip)[b, 0, h]) & set(np.asarray(ie)[b, 0, h])) / K
        for b in range(B) for h in range(H)])
    assert recall >= 0.9, recall


def test_recent_window_always_selected():
    q, k, v = _setup(4)
    N = k.shape[1]
    codes, ps, pz = R.fit_proxy(k, 8)
    cfg = RetrievalCfg(top_k=16, recent_window=8)
    sp = R.proxy_scores(q, codes, ps, pz)
    idx = R.select_topk(sp, jnp.asarray(N, jnp.int32), cfg)
    sel = np.asarray(idx)
    for t in range(N - 8, N):
        assert np.all((sel == t).any(axis=-1)), f"recent token {t} not selected"


def test_calibration_bounded():
    """Calibrated outputs never exceed the uncalibrated magnitude (the mass
    fraction multiplier is in [0, 1])."""
    q, k, v = _setup(5)
    N = k.shape[1]
    codes, ps, pz = R.fit_proxy(k, 8)
    cfg = RetrievalCfg(top_k=16, recent_window=4)
    length = jnp.asarray(N, jnp.int32)
    cal = R.retrieval_attention(q, k, v, codes, ps, pz, length, cfg, 0.25,
                                calibrate=True)
    raw = R.retrieval_attention(q, k, v, codes, ps, pz, length, cfg, 0.25,
                                calibrate=False)
    assert float(jnp.max(jnp.abs(cal))) <= float(jnp.max(jnp.abs(raw))) * 1.01
