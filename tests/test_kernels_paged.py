"""Paged Pallas decode kernels vs oracles (interpret=True).

Property tests (via the optional-hypothesis shim) and deterministic seed
sweeps share the same checkers, so the invariants are exercised even where
hypothesis is not installed. Each checker builds a physical page pool with:

  * a POISONED null page (page 0 filled with huge garbage — the layout
    convention says its contents must never reach an output),
  * PERMUTED physical page order (block tables need not be contiguous or
    sorted),
  * RAGGED per-row lengths including empty (length-0) rows and partial last
    pages,

and asserts the fused kernel matches the oracle computed straight from
``(pages, block_table, lengths)`` to fp tolerance, that outputs are invariant
under a physical-page relabeling, and that greedy argmax matches exactly
whenever the oracle's top-2 gap is resolvable (near-ties are skipped — they
are decided by reduction-order epsilon in any implementation).
"""
from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import CPQCfg
from repro.core import cpq as C

ARGMAX_GAP = 1e-4  # top-2 oracle gap below which greedy ties are ignored


def _pool_layout(rng, B, nb, page):
    """Random paged layout: per-row lengths (0..capacity), pages assigned in
    PERMUTED physical order, unmapped entries left at the null page 0."""
    num_pages = 1 + B * nb + int(rng.integers(0, 4))  # spare pages stay stale
    lengths = np.array([int(rng.integers(0, nb * page + 1)) for _ in range(B)],
                       np.int32)
    if B > 1 and rng.random() < 0.5:
        lengths[int(rng.integers(0, B))] = 0          # force an empty row
    perm = rng.permutation(np.arange(1, num_pages)).tolist()
    bt = np.zeros((B, nb), np.int32)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // page)):
            bt[b, j] = perm.pop()
    return num_pages, lengths, bt


def _relabel(pools, bt, num_pages, rng):
    """Apply a random physical-page relabeling (defrag analogue): outputs
    must be bitwise invariant."""
    perm = np.concatenate([[0], rng.permutation(np.arange(1, num_pages))])
    inv = np.argsort(perm)
    return [np.asarray(p)[perm] for p in pools], inv[bt].astype(np.int32)


def _argmax_where_resolvable(out, ref):
    out, ref = np.asarray(out, np.float32), np.asarray(ref, np.float32)
    flat_o = out.reshape(-1, out.shape[-1])
    flat_r = ref.reshape(-1, ref.shape[-1])
    top2 = np.sort(flat_r, axis=-1)
    resolvable = (top2[:, -1] - top2[:, -2]) > ARGMAX_GAP
    np.testing.assert_array_equal(flat_o.argmax(-1)[resolvable],
                                  flat_r.argmax(-1)[resolvable])


# ------------------------------------------------------------- dense / flash


def check_paged_flash(seed, page, nb, B, KV, g, Dh, dtype=jnp.float32):
    from repro.kernels.flash_attn.ops import paged_flash_decode_tpu
    from repro.kernels.flash_attn.ref import paged_flash_decode_ref

    rng = np.random.default_rng(seed)
    num_pages, lengths, bt = _pool_layout(rng, B, nb, page)
    kp = rng.normal(size=(num_pages, page, KV, Dh)).astype(np.float32)
    vp = rng.normal(size=(num_pages, page, KV, Dh)).astype(np.float32)
    kp[0] = vp[0] = 1e3                               # poison the null page
    q = rng.normal(size=(B, 1, KV * g, Dh)).astype(np.float32)
    args = (jnp.asarray(q, dtype), jnp.asarray(kp, dtype),
            jnp.asarray(vp, dtype), jnp.asarray(bt), jnp.asarray(lengths))
    out = paged_flash_decode_tpu(*args, Dh ** -0.5)
    ref = paged_flash_decode_ref(*args, Dh ** -0.5)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
    _argmax_where_resolvable(out, ref)

    (kp2, vp2), bt2 = _relabel([kp, vp], bt, num_pages, rng)
    out2 = paged_flash_decode_tpu(jnp.asarray(q, dtype), jnp.asarray(kp2, dtype),
                                  jnp.asarray(vp2, dtype), jnp.asarray(bt2),
                                  jnp.asarray(lengths), Dh ** -0.5)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(out2, np.float32))


@pytest.mark.parametrize("seed,page,nb,B,KV,g,Dh,dtype", [
    (0, 4, 4, 3, 2, 2, 16, jnp.float32),
    (1, 1, 3, 2, 1, 4, 8, jnp.float32),   # page_size 1: one token per page
    (2, 8, 2, 2, 4, 1, 32, jnp.float32),
    (3, 5, 4, 4, 2, 3, 16, jnp.float32),  # odd page size, partial last pages
    (4, 4, 1, 1, 1, 1, 8, jnp.float32),   # single block
    (5, 4, 3, 2, 2, 2, 16, jnp.bfloat16),  # the engine's default cache dtype
])
def test_paged_flash_sweep(seed, page, nb, B, KV, g, Dh, dtype):
    check_paged_flash(seed, page, nb, B, KV, g, Dh, dtype)


@hypothesis.given(
    seed=st.integers(0, 2 ** 16),
    page=st.integers(1, 8),
    nb=st.integers(1, 4),
    B=st.integers(1, 3),
    KV=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_paged_flash_property(seed, page, nb, B, KV, g):
    check_paged_flash(seed, page, nb, B, KV, g, Dh=16)


# ------------------------------------------------------------------ T2 / CPQ


def check_paged_cpq(seed, page, nb, B, KV, g, Dh, bits):
    from repro.kernels.cpq_dequant_attn.kernel import paged_cpq_decode_fwd
    from repro.kernels.cpq_dequant_attn.ref import paged_cpq_decode_ref

    rng = np.random.default_rng(seed)
    cfg = CPQCfg(prune_ratio=0.3, bits=bits, max_levels=4)
    num_pages, lengths, bt = _pool_layout(rng, B, nb, page)
    cap = nb * page
    # per-row CPQ compression (the real serving construction), then scatter
    # codes/levels into the permuted physical pool
    S = max(int(lengths.max()), 1)
    kx = jnp.asarray(rng.normal(size=(B, S, KV, Dh)).astype(np.float32))
    vx = jnp.asarray(rng.normal(size=(B, S, KV, Dh)).astype(np.float32))
    tk = C.cpq_compress_prefill(kx, cfg, cap)
    tv = C.cpq_compress_prefill(vx, cfg, cap)
    ck = rng.integers(-128, 128, size=(num_pages, page, KV, Dh)).astype(np.int8)
    cv = rng.integers(-128, 128, size=(num_pages, page, KV, Dh)).astype(np.int8)
    lk = rng.integers(0, 4, size=(num_pages, page, KV)).astype(np.int32)
    lv = rng.integers(0, 4, size=(num_pages, page, KV)).astype(np.int32)
    for b in range(B):
        for j in range(-(-int(lengths[b]) // page)):
            sl = slice(j * page, (j + 1) * page)
            ck[bt[b, j]] = np.asarray(tk.codes)[b, sl]
            cv[bt[b, j]] = np.asarray(tv.codes)[b, sl]
            lk[bt[b, j]] = np.asarray(tk.level)[b, sl]
            lv[bt[b, j]] = np.asarray(tv.level)[b, sl]
    q = rng.normal(size=(B, KV, g, Dh)).astype(np.float32)
    args = (jnp.asarray(q), jnp.asarray(ck), jnp.asarray(cv),
            tk.scale, tk.zero, tv.scale, tv.zero,
            jnp.asarray(lk), jnp.asarray(lv), jnp.asarray(bt),
            jnp.asarray(lengths))
    out = paged_cpq_decode_fwd(*args, scale=0.17, interpret=True)
    ref = paged_cpq_decode_ref(*args, 0.17)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
    _argmax_where_resolvable(out, ref)

    (ck2, cv2, lk2, lv2), bt2 = _relabel([ck, cv, lk, lv], bt, num_pages, rng)
    out2 = paged_cpq_decode_fwd(
        jnp.asarray(q), jnp.asarray(ck2), jnp.asarray(cv2),
        tk.scale, tk.zero, tv.scale, tv.zero,
        jnp.asarray(lk2), jnp.asarray(lv2), jnp.asarray(bt2),
        jnp.asarray(lengths), scale=0.17, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))


@pytest.mark.parametrize("seed,page,nb,B,KV,g,Dh,bits", [
    (0, 4, 4, 2, 2, 2, 16, 8),
    (1, 2, 3, 3, 1, 4, 8, 4),
    (2, 8, 2, 2, 4, 1, 32, 8),
    (3, 3, 4, 2, 2, 1, 16, 4),  # odd page size
])
def test_paged_cpq_sweep(seed, page, nb, B, KV, g, Dh, bits):
    check_paged_cpq(seed, page, nb, B, KV, g, Dh, bits)


@hypothesis.given(
    seed=st.integers(0, 2 ** 16),
    page=st.integers(1, 8),
    nb=st.integers(1, 4),
    B=st.integers(1, 3),
    bits=st.sampled_from([4, 8]),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_paged_cpq_property(seed, page, nb, B, bits):
    check_paged_cpq(seed, page, nb, B, KV=2, g=2, Dh=16, bits=bits)


# ---------------------------------------------------------- T1 / decomposed


def check_paged_decomposed(seed, page, nb, B, H, Dm, kv_r, Rr,
                           dtype=jnp.float32):
    from repro.kernels.decomposed_attn.kernel import paged_decomposed_decode_fwd
    from repro.kernels.decomposed_attn.ref import paged_decomposed_decode_ref

    rng = np.random.default_rng(seed)
    num_pages, lengths, bt = _pool_layout(rng, B, nb, page)
    xp = rng.normal(size=(num_pages, page, Dm)).astype(np.float32)
    krp = rng.normal(size=(num_pages, page, kv_r, max(Rr, 1))).astype(np.float32)
    xp[0] = krp[0] = 1e3                              # poison the null page
    r = rng.normal(size=(B, H, Dm)).astype(np.float32)
    qr = rng.normal(size=(B, H, Rr)).astype(np.float32)
    args = (jnp.asarray(r, dtype), jnp.asarray(qr, dtype),
            jnp.asarray(xp, dtype), jnp.asarray(krp[..., :Rr], dtype),
            jnp.asarray(bt), jnp.asarray(lengths))
    out = paged_decomposed_decode_fwd(*args, scale=0.2, interpret=True)
    ref = paged_decomposed_decode_ref(*args, 0.2)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)
    _argmax_where_resolvable(out, ref)

    (xp2, krp2), bt2 = _relabel([xp, krp], bt, num_pages, rng)
    out2 = paged_decomposed_decode_fwd(
        jnp.asarray(r, dtype), jnp.asarray(qr, dtype), jnp.asarray(xp2, dtype),
        jnp.asarray(krp2[..., :Rr], dtype), jnp.asarray(bt2),
        jnp.asarray(lengths), scale=0.2, interpret=True)
    np.testing.assert_array_equal(np.asarray(out, np.float32),
                                  np.asarray(out2, np.float32))


@pytest.mark.parametrize("seed,page,nb,B,H,Dm,kv_r,Rr,dtype", [
    (0, 4, 4, 2, 4, 16, 1, 8, jnp.float32),   # MLA layout: shared rope head
    (1, 4, 3, 3, 4, 16, 2, 8, jnp.float32),   # per-kv-head rope (decoupled T1)
    (2, 2, 4, 2, 8, 32, 4, 4, jnp.float32),
    (3, 8, 2, 2, 4, 16, 1, 0, jnp.float32),   # absolute positions: no rope
    (4, 5, 3, 1, 2, 8, 2, 8, jnp.float32),    # odd page size
    (5, 4, 3, 2, 4, 16, 1, 8, jnp.bfloat16),  # engine's default cache dtype
])
def test_paged_decomposed_sweep(seed, page, nb, B, H, Dm, kv_r, Rr, dtype):
    check_paged_decomposed(seed, page, nb, B, H, Dm, kv_r, Rr, dtype)


@hypothesis.given(
    seed=st.integers(0, 2 ** 16),
    page=st.integers(1, 8),
    nb=st.integers(1, 4),
    B=st.integers(1, 3),
    kv_r=st.sampled_from([1, 2, 4]),
    Rr=st.sampled_from([0, 8]),
)
@hypothesis.settings(max_examples=10, deadline=None)
def test_paged_decomposed_property(seed, page, nb, B, kv_r, Rr):
    check_paged_decomposed(seed, page, nb, B, H=4, Dm=16, kv_r=kv_r, Rr=Rr)


# ------------------------------------------------- engine-level greedy parity


def test_paged_kernels_greedy_exact_vs_gather_f32():
    """Property satellite's exactness anchor at the kernel level: one decode
    step through the fused dense kernel and through the gather path on the
    SAME paged cache state agree on greedy argmax for every resolvable row
    (f32; both are reduction-order-epsilon realizations of the same math)."""
    from repro.core import attention as core_attn
    from repro.kernels.flash_attn.ops import paged_flash_decode_tpu
    from repro.serving import paged_cache as pgc

    rng = np.random.default_rng(9)
    B, KV, g, Dh, page, nb = 3, 2, 2, 16, 4, 4
    num_pages, lengths, bt = _pool_layout(rng, B, nb, page)
    kp = jnp.asarray(rng.normal(size=(num_pages, page, KV, Dh)).astype(np.float32))
    vp = jnp.asarray(rng.normal(size=(num_pages, page, KV, Dh)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(B, 1, KV * g, Dh)).astype(np.float32))
    out_k = paged_flash_decode_tpu(q, kp, vp, jnp.asarray(bt),
                                   jnp.asarray(lengths), Dh ** -0.5)
    out_g = core_attn.dense_attention(
        q, pgc.gather_pages(kp, jnp.asarray(bt)),
        pgc.gather_pages(vp, jnp.asarray(bt)), Dh ** -0.5,
        causal=False, kv_length=jnp.asarray(lengths))
    live = lengths > 0
    np.testing.assert_allclose(np.asarray(out_k)[live], np.asarray(out_g)[live],
                               atol=2e-5)
    _argmax_where_resolvable(np.asarray(out_k)[live], np.asarray(out_g)[live])
