"""Multi-replica router suite: single-replica token parity vs the bare
engine, placement-policy unit decisions, session affinity, drain/re-queue
(greedy AND seeded-sampling token parity after migration), the engine-level
drain snapshot, stats aggregation, and the hypothesis property that ANY
interleaving of add / step / drain delivers every request's output exactly
once — no lost rids, no duplicated (rid, index) events — with the allocator
invariants green on every replica."""
import numpy as np
import pytest

import jax

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.paged_cache import NULL_PAGE
from repro.serving.policies import (LeastLoadedPlacement, ReplicaView,
                                    RoundRobinPlacement, SloPressurePlacement,
                                    make_placement)
from repro.serving.request import (BATCH, INTERACTIVE, SamplingParams,
                                   ServeRequest)
from repro.serving.router import ReplicaRouter
from repro.serving.scheduler import Request, SchedulerConfigError


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


SERVING = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4)


@pytest.fixture(scope="module")
def donor(model):
    """One engine donates its jit wrappers to every router in the module —
    the whole suite compiles each step function once."""
    cfg, params = model
    return ContinuousServeEngine(cfg, params, serving=SERVING)


def _router(model, donor, n, placement="rr"):
    cfg, params = model
    r = ReplicaRouter(cfg, params, num_replicas=n, serving=SERVING,
                      placement=placement)
    for eng in r.engines:
        eng.adopt_compiled(donor)
    return r


def _reqs(n=6, seed=0, max_tokens=6, sampled=False, session=None):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        sp = (SamplingParams(temperature=0.8, top_k=10, seed=7 + i,
                             max_tokens=max_tokens) if sampled
              else SamplingParams(max_tokens=max_tokens))
        out.append(ServeRequest(
            prompt=rng.integers(1, 200, size=int(rng.integers(3, 10))),
            sampling=sp, slo=INTERACTIVE if i % 2 else BATCH,
            arrival=float(i // 2),
            session_id=None if session is None else session(i)))
    return out


def _check_alloc(eng):
    """No leaked / double-owned pages on a live replica."""
    sched = eng._st.sched
    owned = [p for r in sched.occupied() if r.tier == 0 for p in r.pages]
    assert len(set(owned)) == len(owned), "double-owned page"
    assert NULL_PAGE not in owned
    assert sched.dense_alloc.num_used == len(owned), "leaked/phantom pages"


# ------------------------------------------------------------ parity (N=1)


def test_single_replica_matches_bare_engine(model, donor):
    cfg, params = model
    reqs = _reqs()
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    res_e, stats_e = eng.serve(reqs)
    router = _router(model, donor, 1)
    res_r, stats_r = router.serve(reqs)
    assert set(res_r) == set(res_e)
    for rid in res_e:
        assert list(res_r[rid]["tokens"]) == list(res_e[rid]["tokens"])
        assert res_r[rid]["finish_reason"] == res_e[rid]["finish_reason"]
    assert stats_r["generated_tokens"] == stats_e["generated_tokens"]
    assert stats_r["decode_steps_max"] == stats_e["decode_steps"]


# --------------------------------------------------- placement policy units


def _views(*pairs):
    return [ReplicaView(index=i, outstanding_tokens=o, free_frac=f)
            for i, (o, f) in enumerate(pairs)]


def test_round_robin_cycles_over_views():
    p = RoundRobinPlacement()
    views = _views((0, 1.0), (0, 1.0), (0, 1.0))
    picks = [p.select(views, None) for _ in range(6)]
    assert picks == [0, 1, 2, 0, 1, 2]
    # the cursor keeps cycling over whatever views remain after a drain
    assert p.select(views[:2], None) in (0, 1)


def test_least_loaded_picks_min_outstanding():
    p = LeastLoadedPlacement()
    assert p.select(_views((30, 0.9), (10, 0.1), (20, 0.5)), None) == 1
    # deterministic tie-break on index
    assert p.select(_views((10, 0.2), (10, 0.8)), None) == 0


def test_slo_placement_splits_classes():
    p = SloPressurePlacement()
    views = _views((40, 0.8), (5, 0.2))
    hot = Request(rid=0, prompt=np.ones(4, np.int32), max_new_tokens=4,
                  slo=INTERACTIVE)
    cold = Request(rid=1, prompt=np.ones(4, np.int32), max_new_tokens=4,
                   slo=BATCH)
    # latency-bound class -> freest arena even if busier; deadline-free
    # batch balances by outstanding tokens instead
    assert p.select(views, hot) == 0
    assert p.select(views, cold) == 1


def test_make_placement_rejects_unknown():
    assert make_placement("rr").name == "rr"
    with pytest.raises(ValueError):
        make_placement("nope")


# ---------------------------------------------------------- session affinity


def test_session_affinity_pins_follow_up_turns(model, donor):
    router = _router(model, donor, 2, placement="rr")
    router.reset()
    sid = lambda i: "chat" if i % 2 == 0 else None  # noqa: E731
    rids = [router.add_request(r) for r in _reqs(6, session=sid)]
    pinned = {router.replica_of(rids[i]) for i in (0, 2, 4)}
    assert len(pinned) == 1, "session requests spread over replicas"
    free = [router.replica_of(rids[i]) for i in (1, 3, 5)]
    assert len(set(free)) == 2, "round-robin stopped spreading the rest"
    while router.has_unfinished():
        router.step()
    assert len(router.results()) == 6


def test_session_remaps_after_drain(model, donor):
    router = _router(model, donor, 2, placement="rr")
    router.reset()
    rid0 = router.add_request(ServeRequest(
        prompt=np.arange(1, 6), session_id="s0",
        sampling=SamplingParams(max_tokens=4)))
    home = router.replica_of(rid0)
    router.drain(home)
    rid1 = router.add_request(ServeRequest(
        prompt=np.arange(1, 6), session_id="s0",
        sampling=SamplingParams(max_tokens=4)))
    assert router.replica_of(rid0) == router.replica_of(rid1) != home
    while router.has_unfinished():
        router.step()
    assert set(router.results()) == {rid0, rid1}


# ------------------------------------------------------------ drain/re-queue


def test_drain_migrates_and_finishes_greedy_parity(model, donor):
    cfg, params = model
    reqs = _reqs(6)
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    ref, _ = eng.serve(reqs)

    router = _router(model, donor, 2, placement="load")
    router.reset()
    rids = [router.add_request(r) for r in reqs]
    for _ in range(4):
        router.step()
    moved = router.drain(0)
    assert moved > 0
    done_at_drain = set(router.results())
    assert all(router.replica_of(rid) == 1 for rid in rids
               if rid not in done_at_drain), "incomplete request not moved"
    while router.has_unfinished():
        router.step()
    res = router.results()
    assert set(res) == set(ref)
    for rid in ref:
        assert list(res[rid]["tokens"]) == list(ref[rid]["tokens"])
    stats = router.stats()
    assert stats["migrated_requests"] == moved
    assert stats["draining"] == [0]
    assert stats["dense_pages_leaked"] == 0


def test_drain_seeded_sampling_token_parity(model, donor):
    """The acceptance contract: a drained request replays prompt +
    generated-so-far elsewhere and its remaining SAMPLED stream reproduces
    token-for-token (fold_in(seed, token_index) is request-local)."""
    cfg, params = model
    reqs = _reqs(6, sampled=True, max_tokens=8)
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    ref, _ = eng.serve(reqs)

    router = _router(model, donor, 2, placement="load")
    router.reset()
    for r in reqs:
        router.add_request(r)
    for _ in range(5):
        router.step()
    router.drain(1)
    while router.has_unfinished():
        router.step()
    res = router.results()
    assert set(res) == set(ref)
    for rid in ref:
        assert list(res[rid]["tokens"]) == list(ref[rid]["tokens"]), (
            f"rid {rid} diverged after drain/migration")


def test_drain_guards(model, donor):
    router = _router(model, donor, 2)
    router.reset()
    router.drain(1)
    assert router.drain(1) == 0          # idempotent
    with pytest.raises(SchedulerConfigError):
        router.drain(0)                  # last healthy replica
    with pytest.raises(SchedulerConfigError):
        router.drain(7)                  # no such replica
    router.reset()                       # drained replicas rejoin
    assert router.healthy() == [0, 1]


def test_engine_drain_snapshot(model, donor):
    """Engine-level drain: pages freed, generated-so-far preserved, and the
    snapshot completes on a DIFFERENT engine with greedy parity."""
    cfg, params = model
    reqs = _reqs(4)
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.adopt_compiled(donor)
    ref, _ = eng.serve(reqs)

    eng.reset()
    for r in reqs:
        eng.add_request(r)
    for _ in range(4):
        eng.step()
    done = dict(eng.results())
    moved = eng.drain()
    assert eng._st.sched.dense_alloc.num_used == 0, "drain leaked pages"
    assert not eng.has_unfinished()
    assert {r.rid for r in moved} | set(done) == set(ref)
    assert any(r.num_generated > 0 for r in moved), (
        "expected at least one mid-flight request in the snapshot")

    other = ContinuousServeEngine(cfg, params, serving=SERVING)
    other.adopt_compiled(donor)
    other.reset()
    for r in moved:
        other.add_request(r)
    while other.has_unfinished():
        other.step()
    for rid, rec in other.results().items():
        assert list(rec["tokens"]) == list(ref[rid]["tokens"])


# ------------------------------------------------------------------- stats


def test_stats_aggregation(model, donor):
    router = _router(model, donor, 2, placement="load")
    res, stats = router.serve(_reqs(6))
    assert stats["replicas"] == 2 and stats["placement"] == "load"
    assert len(stats["per_replica"]) == 2
    assert (sum(p["generated_tokens"] for p in stats["per_replica"])
            == stats["generated_tokens"] == sum(len(r["tokens"])
                                                for r in res.values()))
    assert stats["decode_steps_max"] == max(
        p["decode_steps"] for p in stats["per_replica"])
    assert stats["tokens_per_step"] == pytest.approx(
        stats["generated_tokens"] / stats["decode_steps_max"])
    assert stats["dense_pages_leaked"] == 0
    assert all(eng.outstanding_tokens() == 0 for eng in router.engines)


def test_rid_collision_rejected_across_replicas(model, donor):
    router = _router(model, donor, 2)
    router.reset()
    router.add_request(ServeRequest(prompt=np.arange(1, 5), rid=3,
                                    sampling=SamplingParams(max_tokens=4)))
    with pytest.raises(SchedulerConfigError):
        router.add_request(ServeRequest(prompt=np.arange(1, 5), rid=3,
                                        sampling=SamplingParams(max_tokens=4)))


# ----------------------------------------------- exactly-once (hypothesis)


@hypothesis.given(
    seed=st.integers(0, 2 ** 31 - 1),
    ops=st.lists(st.sampled_from(["add", "add", "step", "step", "drain0",
                                  "drain1"]), min_size=4, max_size=14),
    placement=st.sampled_from(["rr", "load", "slo"]))
@hypothesis.settings(max_examples=15, deadline=None)
def test_any_interleaving_delivers_exactly_once(model, donor, seed, ops,
                                                placement):
    """ANY interleaving of add / step / drain / re-queue delivers every
    request's output stream exactly once — each (rid, index) event appears
    once, indices are gapless, exactly one finished event per rid, results
    hold every submitted rid — and no replica leaks pages."""
    router = _router(model, donor, 2, placement=placement)
    router.reset()
    rng = np.random.default_rng(seed)
    submitted = []
    for op in ops:
        if op == "add":
            sid = f"s{rng.integers(3)}" if rng.random() < 0.4 else None
            sp = (SamplingParams(temperature=0.7, top_k=8,
                                 seed=int(rng.integers(99)),
                                 max_tokens=int(rng.integers(1, 5)))
                  if rng.random() < 0.5
                  else SamplingParams(max_tokens=int(rng.integers(1, 5))))
            submitted.append(router.add_request(ServeRequest(
                prompt=rng.integers(1, 200, size=int(rng.integers(2, 7))),
                sampling=sp, session_id=sid)))
        elif op == "step":
            router.step()
        else:
            target = int(op[-1])
            if target in router.healthy() and len(router.healthy()) > 1:
                router.drain(target)
    while router.has_unfinished():
        router.step()

    events = router.pending_outputs()
    seen: dict[int, list] = {}
    finished: dict[int, int] = {}
    for ev in events:
        seen.setdefault(ev.rid, []).append(ev.index)
        if ev.finished:
            finished[ev.rid] = finished.get(ev.rid, 0) + 1
    res = router.results()
    assert set(res) == set(submitted), "lost or phantom request records"
    for rid in submitted:
        n = len(res[rid]["tokens"])
        assert sorted(seen.get(rid, [])) == list(range(n)), (
            f"rid {rid}: events {sorted(seen.get(rid, []))} != 0..{n - 1}")
        assert finished.get(rid, 0) == 1, f"rid {rid} finished twice/never"
    for i, eng in enumerate(router.engines):
        if eng._st is not None:
            _check_alloc(eng)
    agg = router.stats()
    assert agg["dense_pages_leaked"] == 0
    assert agg["cpq_pages_leaked"] == 0
