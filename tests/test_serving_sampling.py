"""Per-request sampling suite: the vectorized per-row sampler vs a
single-row reference categorical sampler, greedy-row token-exactness inside
mixed greedy+sampled batches, seeded reproducibility independent of slot
placement, stop-token retirement (pages freed like EOS), and the streaming
RequestOutput event contract."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import (ContinuousServeEngine, GenerationConfig,
                                  sample_token_rows)
from repro.serving.request import RequestOutput, SamplingParams, ServeRequest
from repro.serving.scheduler import Request


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


SERVING = ServingCfg(num_slots=3, page_size=4, num_pages=65,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4)


# ------------------------------------------------------------- sampler unit


def _reference_sample(logits_row: np.ndarray, sp: SamplingParams,
                      index: int) -> int:
    """Independent single-row reference: numpy top-k / nucleus filtering +
    the documented key derivation fold_in(PRNGKey(seed), index) feeding
    jax.random.categorical."""
    if sp.temperature <= 0.0:
        return int(np.argmax(logits_row))
    l = logits_row.astype(np.float64) / sp.temperature
    if sp.top_k > 0:
        kth = np.sort(l)[::-1][min(sp.top_k, len(l)) - 1]
        l = np.where(l < kth, -1e30, l)
    if sp.top_p < 1.0:
        desc = np.sort(l)[::-1]
        probs = np.exp(desc - desc.max())
        probs /= probs.sum()
        cum = np.cumsum(probs)
        j = min(int(np.sum(cum < sp.top_p)), len(l) - 1)
        l = np.where(l < desc[j], -1e30, l)
    key = jax.random.fold_in(jax.random.PRNGKey(sp.seed), index)
    return int(jax.random.categorical(key, jnp.asarray(l, jnp.float32)))


def test_sampler_matches_reference_per_row():
    """Each row of one vectorized sample_token_rows call reproduces the
    reference sampler run on that row alone — per-row params, keys, and
    filters never leak across rows."""
    rng = np.random.default_rng(0)
    B, V = 6, 64
    logits = rng.normal(size=(B, V)).astype(np.float32) * 3.0
    sps = [SamplingParams(temperature=0.0),
           SamplingParams(temperature=1.0, seed=1),
           SamplingParams(temperature=0.7, top_k=5, seed=2),
           SamplingParams(temperature=1.3, top_p=0.8, seed=3),
           SamplingParams(temperature=0.5, top_k=9, top_p=0.6, seed=4),
           SamplingParams(temperature=2.0, top_k=1, seed=5)]  # top_k=1: argmax
    indices = np.array([0, 0, 3, 7, 1, 2], np.int32)
    got = np.asarray(sample_token_rows(
        jnp.asarray(logits),
        jnp.asarray([s.temperature for s in sps], jnp.float32),
        jnp.asarray([s.top_k for s in sps], jnp.int32),
        jnp.asarray([s.top_p for s in sps], jnp.float32),
        jnp.asarray([s.seed for s in sps], jnp.int32),
        jnp.asarray(indices)))
    want = [_reference_sample(logits[b], sps[b], int(indices[b]))
            for b in range(B)]
    np.testing.assert_array_equal(got, np.asarray(want, np.int32))
    # top_k=1 must equal argmax regardless of temperature/key
    assert got[5] == int(np.argmax(logits[5]))


def test_sampler_greedy_rows_are_argmax_rows():
    """temp <= 0 rows are plain argmax over the raw logits — identical no
    matter what sampling parameters the OTHER rows carry."""
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(4, 32)).astype(np.float32)

    def run(temps):
        return np.asarray(sample_token_rows(
            jnp.asarray(logits), jnp.asarray(temps, jnp.float32),
            jnp.asarray([0, 50, 3, 0], jnp.int32),
            jnp.asarray([1.0, 0.7, 0.9, 1.0], jnp.float32),
            jnp.asarray([0, 1, 2, 3], jnp.int32),
            jnp.asarray([0, 5, 2, 9], jnp.int32)))

    mixed = run([0.0, 1.1, 0.8, 0.0])
    all_greedy = run([0.0, 0.0, 0.0, 0.0])
    argmax = np.argmax(logits, axis=-1)
    np.testing.assert_array_equal(all_greedy, argmax)
    np.testing.assert_array_equal(mixed[[0, 3]], argmax[[0, 3]])


# -------------------------------------------------- engine-level sampling


def test_mixed_batch_leaves_greedy_rows_token_exact(model):
    """Greedy requests co-resident with sampled ones generate EXACTLY the
    tokens of an all-greedy legacy serve: per-row sampling never perturbs
    another row's stream."""
    cfg, params = model
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (5, 9, 7, 4, 8)]

    def legacy():
        return [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]

    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    ref, rstats = eng.serve(legacy(), GenerationConfig(max_new_tokens=6))

    mixed = [ServeRequest(prompt=p, rid=i, sampling=SamplingParams(
        temperature=0.9 if i % 2 else 0.0, top_k=12, top_p=0.9,
        max_tokens=6, seed=100 + i)) for i, p in enumerate(prompts)]
    res, stats = eng.serve(mixed, GenerationConfig(max_new_tokens=6))
    for i in range(len(prompts)):
        if i % 2 == 0:   # greedy rows: token-exact vs the legacy engine
            np.testing.assert_array_equal(res[i]["tokens"], ref[i]["tokens"])
        else:            # sampled rows: valid, full-length streams
            t = res[i]["tokens"]
            assert len(t) == 6 and (t >= 0).all() and (t < cfg.vocab_size).all()
    assert stats["dense_pages_leaked"] == 0


def test_seeded_sampling_reproducible_and_slot_invariant(model):
    """Same (prompt, seed) => same tokens, whether the request runs alone or
    shares the machine with other traffic (the fold_in(seed, index) keys
    depend on the request alone); a different seed diverges."""
    cfg, params = model
    rng = np.random.default_rng(11)
    p = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    sp = SamplingParams(temperature=0.8, top_k=0, top_p=1.0, max_tokens=8,
                        seed=42)
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)

    res, _ = eng.serve([ServeRequest(prompt=p, rid=0, sampling=sp)],
                       GenerationConfig())
    alone = res[0]["tokens"]
    others = [ServeRequest(prompt=rng.integers(0, cfg.vocab_size, 7), rid=i,
                           sampling=SamplingParams(max_tokens=8))
              for i in (1, 2)]
    res2, _ = eng.serve([ServeRequest(prompt=p, rid=0, sampling=sp)] + others,
                        GenerationConfig())
    np.testing.assert_array_equal(alone, res2[0]["tokens"])
    res3, _ = eng.serve([ServeRequest(
        prompt=p, rid=0, sampling=SamplingParams(
            temperature=0.8, max_tokens=8, seed=43))], GenerationConfig())
    assert not np.array_equal(alone, res3[0]["tokens"])


def test_stop_token_retires_and_frees_pages_like_eos(model):
    """stop_token_ids retire the request mid-stream exactly like EOS: the
    stream ends AT the stop token, reason "stop", pages return to the pool
    and the vacated slot admits queued work."""
    cfg, params = model
    rng = np.random.default_rng(5)
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=65,
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    prompts = [rng.integers(0, cfg.vocab_size, s).astype(np.int32)
               for s in (6, 9, 5, 11)]

    # probe greedily for a token emitted mid-stream, then replay with it as
    # a per-request stop token — deterministic early retirement
    probe, _ = eng.serve([ServeRequest(prompt=p, rid=i,
                                       sampling=SamplingParams(max_tokens=16))
                          for i, p in enumerate(prompts)], GenerationConfig())
    stop = -1
    for i in probe:
        mid = probe[i]["tokens"][1:-1]
        if len(mid):
            stop = int(mid[0])
            break
    assert stop >= 0
    res, stats = eng.serve(
        [ServeRequest(prompt=p, rid=i, sampling=SamplingParams(
            max_tokens=16, stop_token_ids=(stop,)))
         for i, p in enumerate(prompts)], GenerationConfig())
    stopped = [i for i in res if res[i]["finish_reason"] == "stop"]
    assert stopped, "probe token never re-emitted; premise broken"
    for i in stopped:
        t = res[i]["tokens"]
        assert t[-1] == stop and (t[:-1] != stop).all()
        assert len(t) < 16                     # retired early
    assert stats["generated_tokens"] == sum(len(res[i]["tokens"]) for i in res)
    assert stats["dense_pages_leaked"] == 0
    assert stats["retired"] == len(prompts)    # every slot vacated properly


# ---------------------------------------------------- streaming event API


def test_step_api_streams_request_outputs(model):
    """add_request()/step(): every generated token arrives exactly once as a
    RequestOutput (stream callback AND step() return AND pending_outputs
    buffer agree), indices are per-request contiguous, and the final event
    carries finished=True with the reason."""
    cfg, params = model
    rng = np.random.default_rng(9)
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.reset()
    seen: list[RequestOutput] = []
    eng.add_request(ServeRequest(prompt=rng.integers(0, cfg.vocab_size, 5),
                                 rid=0, sampling=SamplingParams(max_tokens=5)),
                    stream=seen.append)
    eng.add_request(ServeRequest(prompt=rng.integers(0, cfg.vocab_size, 8),
                                 rid=1, sampling=SamplingParams(max_tokens=3)))
    stepped: list[RequestOutput] = []
    while eng.has_unfinished():
        stepped += eng.step()
    buffered = eng.pending_outputs()
    assert eng.pending_outputs() == []          # drained
    assert stepped == buffered
    assert [e for e in stepped if e.rid == 0] == seen
    res = eng.results()
    for rid, n in ((0, 5), (1, 3)):
        evs = [e for e in stepped if e.rid == rid]
        assert [e.index for e in evs] == list(range(n))
        assert [e.token for e in evs] == list(res[rid]["tokens"])
        assert [e.step for e in evs] == list(res[rid]["token_steps"])
        assert evs[-1].finished and evs[-1].finish_reason == "max_tokens"
        assert all(not e.finished for e in evs[:-1])
    # serve() on the same engine afterwards resets the session cleanly
    res2, _ = eng.serve([Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2)],
        GenerationConfig(max_new_tokens=2))
    assert len(res2[0]["tokens"]) == 2


def test_sampled_parity_under_model_sharding():
    """Mixed greedy+sampled serving over mesh=(dp=1, model=2) is token-exact
    vs the single-device engine at f32: the per-row sampling parameter
    arrays cross the shard_map REPLICATED and the sampler consumes the
    already-concatenated logits, so every device draws the same token."""
    from conftest import run_with_devices

    out = run_with_devices("""
import dataclasses
import numpy as np
import jax
from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.request import SamplingParams, ServeRequest
from repro.launch.mesh import make_serve_mesh

cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]), dtype="float32")
params = M.init_params(cfg, jax.random.PRNGKey(0))
serving = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4)

def reqs():
    rng = np.random.default_rng(0)
    return [ServeRequest(prompt=rng.integers(0, cfg.vocab_size, s), rid=i,
                         sampling=SamplingParams(
                             temperature=0.9 if i % 2 else 0.0, top_k=16,
                             top_p=0.9, max_tokens=6, seed=50 + i))
            for i, s in enumerate([5, 9, 3, 7])]

r0, _ = ContinuousServeEngine(cfg, params, serving=serving).serve(
    reqs(), GenerationConfig())
r1, s1 = ContinuousServeEngine(cfg, params, serving=serving,
                               mesh=make_serve_mesh(1, 2)).serve(
    reqs(), GenerationConfig())
assert s1["model_shards"] == 2
for rid in r0:
    assert np.array_equal(r0[rid]["tokens"], r1[rid]["tokens"]), (
        rid, r0[rid]["tokens"], r1[rid]["tokens"])
print("SAMPLED-PARITY-OK")
""")
    assert "SAMPLED-PARITY-OK" in out


def test_sampled_rows_survive_preemption_exactly(model):
    """Recompute preemption replays the context AND the sample stream: a
    sampled request preempted mid-flight finishes with the same tokens as
    an uncontended run (keys are fold_in(seed, index) — replay-stable)."""
    cfg, params = model
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(3)]
    sps = [SamplingParams(temperature=0.8, top_k=10, max_tokens=12,
                          seed=7 + i) for i in range(3)]
    roomy = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        num_slots=3, page_size=4, num_pages=65, max_blocks_per_slot=8,
        prefill_bucket=4, prefill_chunk=4))
    ref, _ = roomy.serve([ServeRequest(prompt=p, rid=i, sampling=sp)
                          for i, (p, sp) in enumerate(zip(prompts, sps))],
                         GenerationConfig())
    tight = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        num_slots=3, page_size=4, num_pages=10, max_blocks_per_slot=8,
        prefill_bucket=4, prefill_chunk=4))
    res, stats = tight.serve([ServeRequest(prompt=p, rid=i, sampling=sp)
                              for i, (p, sp) in enumerate(zip(prompts, sps))],
                             GenerationConfig())
    assert stats["preemptions"] >= 1
    for i in range(3):
        np.testing.assert_array_equal(res[i]["tokens"], ref[i]["tokens"])
    assert stats["dense_pages_leaked"] == 0
