"""Recurrent mixers: chunked/parallel forms vs sequential decode oracles."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.param import init_tree
from repro.configs import ARCHS, smoke_config
from repro.models import mamba as ML
from repro.models import xlstm as XL

KEY = jax.random.PRNGKey(0)


def test_mamba_forward_vs_decode_chain():
    cfg = dataclasses.replace(smoke_config(ARCHS["jamba-1.5-large-398b"]),
                              dtype="float32")
    p = init_tree(ML.mamba_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 37, cfg.d_model))
    y_full, st_full = ML.mamba_forward(cfg, p, x)
    st = ML.init_mamba_state(cfg, 2)
    outs = []
    for t in range(37):
        y, st = ML.mamba_decode(cfg, p, x[:, t:t + 1], st)
        outs.append(y)
    y_seq = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_seq), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_full.h), np.asarray(st.h), atol=2e-5)


def test_mamba_prefill_state_continuation():
    """forward(x) == forward(x1) then forward(x2, state) — streaming prefill."""
    cfg = dataclasses.replace(smoke_config(ARCHS["jamba-1.5-large-398b"]),
                              dtype="float32")
    p = init_tree(ML.mamba_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 40, cfg.d_model))
    y_full, st_full = ML.mamba_forward(cfg, p, x)
    y1, st1 = ML.mamba_forward(cfg, p, x[:, :17])
    y2, st2 = ML.mamba_forward(cfg, p, x[:, 17:], st1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate([y1, y2], 1)), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_full.h), np.asarray(st2.h), atol=2e-5)


def test_mlstm_chunkwise_vs_sequential():
    cfg = dataclasses.replace(smoke_config(ARCHS["xlstm-125m"]), dtype="float32")
    p = init_tree(XL.mlstm_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 40, cfg.d_model))
    y_chunk, st_chunk = XL.mlstm_forward(cfg, p, x)
    y_seq, st_seq = XL.mlstm_seq_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq), atol=3e-4)
    np.testing.assert_allclose(np.asarray(st_chunk.C), np.asarray(st_seq.C),
                               atol=3e-4)


def test_slstm_forward_vs_decode_chain():
    cfg = dataclasses.replace(smoke_config(ARCHS["xlstm-125m"]), dtype="float32")
    p = init_tree(XL.slstm_defs(cfg), KEY)
    x = jax.random.normal(KEY, (2, 21, cfg.d_model))
    y_full, st_full = XL.slstm_forward(cfg, p, x)
    st = XL.init_slstm_state(cfg, 2)
    outs = []
    for t in range(21):
        y, st = XL.slstm_decode(cfg, p, x[:, t:t + 1], st)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(jnp.concatenate(outs, 1)), atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_full.c), np.asarray(st.c), atol=2e-5)
