"""End-to-end behaviour tests for the paper's system: train loop learns,
checkpoints resume bit-exactly, the serving engine generates under every
paper mode, and the flash custom-VJP is gradient-correct."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_training_reduces_loss():
    """Few dozen steps on the structured synthetic stream must cut loss —
    end-to-end: data -> model -> loss -> grads -> adamw."""
    from repro.launch.train import main

    with tempfile.TemporaryDirectory() as d:
        loss = main(["--arch", "qwen1.5-0.5b", "--smoke", "--steps", "60",
                     "--batch", "8", "--seq", "64", "--lr", "3e-3",
                     "--log-every", "30", "--ckpt-dir", d])
    assert loss < 5.2, loss  # ln(256)=5.55 start; structure is learnable


def test_training_resume_bit_exact():
    """Stop at 20, resume to 30 == straight run to 30 (same data, same rng)."""
    from repro.launch.train import main

    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        args = ["--arch", "qwen1.5-0.5b", "--smoke", "--batch", "4",
                "--seq", "32", "--lr", "1e-3", "--log-every", "100"]
        main(args + ["--steps", "20", "--ckpt-dir", d1, "--ckpt-every", "100"])
        l_resumed = main(args + ["--steps", "30", "--ckpt-dir", d1,
                                 "--ckpt-every", "100"])
        l_straight = main(args + ["--steps", "30", "--ckpt-dir", d2,
                                  "--ckpt-every", "100"])
    np.testing.assert_allclose(l_resumed, l_straight, rtol=1e-5)


@pytest.mark.parametrize("mode", ["dense", "decomposed", "cpq", "retrieval"])
def test_serve_engine_modes(mode):
    from repro.launch.serve import main

    out = main(["--arch", "musicgen-large", "--smoke", "--mode", mode,
                "--batch", "2", "--prompt", "24", "--new", "6"])
    assert out.shape == (2, 6)
    assert out.min() >= 0


def test_serve_sampling_reproducible():
    from repro.configs import ARCHS, smoke_config
    from repro.models import model as M
    from repro.serving import GenerationConfig, ServeEngine

    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    eng = ServeEngine(cfg, params, max_len=32)
    gen = GenerationConfig(max_new_tokens=8, temperature=0.8, seed=5)
    o1, _ = eng.generate(batch, gen)
    o2, _ = eng.generate(batch, gen)
    assert np.array_equal(o1, o2)


def test_flash_vjp_grad_correct(rng):
    """Flash custom-VJP gradients == dense-attention autodiff gradients."""
    from repro.core.attention import dense_attention
    from repro.core.flash_ref import flash_attention

    B, T, S, H, KV, Dh = 2, 64, 64, 4, 2, 16
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, T, H, Dh))
    k = jax.random.normal(ks[1], (B, S, KV, Dh))
    v = jax.random.normal(ks[2], (B, S, KV, Dh))
    w = jnp.cos(jnp.arange(Dh))

    def f_dense(q, k, v):
        return jnp.sum(dense_attention(q, k, v, 0.25, causal=True) * w)

    def f_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, 0.25, True, 0, 32) * w)

    g1 = jax.grad(f_dense, (0, 1, 2))(q, k, v)
    g2 = jax.grad(f_flash, (0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-5)


def test_submatrix_pipeline_model():
    """Paper Fig. 3: sub-matrix pipelining beats layer-level, speedup -> 2x
    for balanced stages, utilization strictly improves."""
    from repro.core.submatrix_pipeline import (
        StageCost, layer_level_latency, speedup, submatrix_latency, utilization)

    c = StageCost(1.0, 1.0)
    for n in (2, 8, 64):
        assert submatrix_latency(n, c) < layer_level_latency(n, c)
        u_layer = utilization(n, c, layer_level_latency(n, c))
        u_sub = utilization(n, c, submatrix_latency(n, c))
        assert u_sub > u_layer
    assert speedup(256, c) > 1.9  # asymptotically 2x for balanced stages


def test_train_step_microbatch_equivalence():
    """k microbatches == single batch gradients (linearity), f32."""
    import dataclasses

    from repro.configs import ARCHS, smoke_config
    from repro.models import model as M
    from repro.optim import adamw
    from repro.train.step import TrainStepCfg, make_train_step

    cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]), dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    opt = adamw(1e-3)
    outs = {}
    for k in (1, 2):
        step = make_train_step(cfg, opt, TrainStepCfg(microbatches=k, remat=False))
        p2, _, m = step(params, opt.init(params), jnp.asarray(0), batch)
        outs[k] = (jax.tree.leaves(p2)[0], float(m["loss"]))
    np.testing.assert_allclose(np.asarray(outs[1][0]), np.asarray(outs[2][0]),
                               atol=1e-5)
    np.testing.assert_allclose(outs[1][1], outs[2][1], rtol=1e-5)
