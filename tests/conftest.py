"""Shared test fixtures. NOTE: no XLA_FLAGS here — unit/smoke tests must see
the real single CPU device (the 512-device override belongs ONLY to
launch/dryrun.py). Multi-device tests spawn subprocesses."""
import os
import subprocess
import sys

import jax
import pytest

try:  # seed-pinned hypothesis profiles: reproducible CI runs (optional dep)
    from hypothesis import settings as _hyp_settings

    _hyp_settings.register_profile("ci", derandomize=True, deadline=None,
                                   max_examples=25, print_blob=True)
    _hyp_settings.register_profile("dev", deadline=None)
    # CI runs replay a fixed example set (reproducible); local runs keep
    # exploring fresh examples unless a profile is pinned explicitly
    _hyp_settings.load_profile(os.environ.get(
        "HYPOTHESIS_PROFILE", "ci" if os.environ.get("CI") else "dev"))
except ImportError:  # pragma: no cover - property tests skip via the shim
    pass


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture
def run8():
    """Run a code snippet in a subprocess with 8 virtual host devices."""
    return lambda code, n=8: run_with_devices(code, n)


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    """Run ``code`` in a subprocess with n virtual host devices; returns
    stdout. Raises on nonzero exit."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout
