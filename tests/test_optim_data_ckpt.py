"""Optimizers, data pipeline determinism, checkpoint manager."""
import tempfile

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject test extra
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, smoke_config
from repro.configs.base import ShapeCfg
from repro.data import DataConfig, SyntheticLMData
from repro.optim import adafactor, adamw, apply_updates, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8


def _quadratic_losses(opt, steps=60):
    target = jnp.asarray([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    losses = []

    def loss_fn(p):
        return jnp.sum((p["w"] - target) ** 2)

    for s in range(steps):
        loss, g = jax.value_and_grad(loss_fn)(params)
        u, state = opt.update(g, state, params, jnp.asarray(s))
        params = apply_updates(params, u)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _quadratic_losses(adamw(5e-2, weight_decay=0.0))
    assert losses[-1] < losses[0] * 0.01


def test_adafactor_converges():
    losses = _quadratic_losses(adafactor(5e-1))
    assert losses[-1] < losses[0] * 0.05


def test_grad_clip():
    from repro.optim.optimizers import clip_by_global_norm

    g = {"a": jnp.full((4,), 100.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) > 100
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    np.testing.assert_allclose(norm, 1.0, rtol=1e-5)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, 10, 100, min_ratio=0.1)
    assert float(lr(jnp.asarray(0))) < 2e-4
    np.testing.assert_allclose(float(lr(jnp.asarray(10))), 1e-3, rtol=0.1)
    assert float(lr(jnp.asarray(99))) < 2.1e-4


@hypothesis.given(seed=st.integers(0, 1000))
@hypothesis.settings(max_examples=10, deadline=None)
def test_int8_compression_bounded_error(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * 3.0
    codes, scale = compress_int8(x)
    xh = decompress_int8(codes, scale)
    assert float(jnp.abs(xh - x).max()) <= float(scale) / 2 + 1e-6


def test_compressed_psum_error_feedback(run8):
    """EF accumulates: mean of compressed psums over steps converges to the
    true mean (bias-free) — run on an 8-device mesh in a subprocess."""
    out = run8("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.optim.compression import compressed_psum
mesh = jax.make_mesh((8,), ('pod',))
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (8, 128))  # row i = device i's gradient
true_mean = jnp.mean(x, 0)
def body(xl, err):
    m, e = compressed_psum(xl[0], err[0], 'pod')
    return m[None], e[None]
f = shard_map(body, mesh=mesh, in_specs=(P('pod'), P('pod')), out_specs=(P('pod'), P('pod')))
err = jnp.zeros_like(x)
acc = jnp.zeros((128,))
for step in range(20):
    m, err = f(x, err)
    acc = acc + m[0]
drift = float(jnp.abs(acc/20 - true_mean).max())
one = float(jnp.abs(m[0] - true_mean).max())
print('drift', drift, 'one', one)
assert drift < one * 0.5 + 1e-5, (drift, one)
""")
    assert "drift" in out


def test_data_determinism_and_seek():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    shape = ShapeCfg("t", 32, 4, "train")
    d1 = SyntheticLMData(cfg, shape, DataConfig(seed=7))
    d2 = SyntheticLMData(cfg, shape, DataConfig(seed=7))
    b5a, b5b = d1.batch(5), d2.batch(5)
    assert np.array_equal(b5a["tokens"], b5b["tokens"])
    it = d1.iter_from(5)
    assert np.array_equal(next(it)["tokens"], b5a["tokens"])
    assert not np.array_equal(d1.batch(6)["tokens"], b5a["tokens"])


def test_data_has_learnable_structure():
    """bigram successor shows up >> chance."""
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    shape = ShapeCfg("t", 256, 8, "train")
    d = SyntheticLMData(cfg, shape, DataConfig(seed=0))
    t = d.batch(0)["tokens"]
    succ = d._succ
    hit = np.mean(t[:, 1:] == succ[t[:, :-1]])
    assert hit > 0.3, hit


def test_checkpoint_roundtrip_async_gc():
    tree = {"a": jnp.arange(12, dtype=jnp.bfloat16).reshape(3, 4),
            "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.int32)]}
    with tempfile.TemporaryDirectory() as d:
        m = CheckpointManager(d, keep=2)
        m.save(3, tree)
        m.save_async(7, tree)
        m.wait()
        out = m.restore(7, tree)
        assert jax.tree.all(jax.tree.map(
            lambda a, b: np.array_equal(np.asarray(a), np.asarray(b)), tree, out))
        assert out["a"].dtype == jnp.bfloat16
        m.save(9, tree)
        m.save(11, tree)
        assert m.all_steps() == [9, 11]


def test_checkpoint_elastic_reshard(run8):
    """Save sharded on a (2, 4) mesh, restore onto (8,) — mesh-shape change."""
    out = run8("""
import jax, jax.numpy as jnp, numpy as np, tempfile
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager
m1 = jax.make_mesh((2, 4), ('a', 'b'))
m2 = jax.make_mesh((8,), ('c',))
x = jnp.arange(64.0).reshape(8, 8)
xs = jax.device_put(x, NamedSharding(m1, P('a', 'b')))
with tempfile.TemporaryDirectory() as d:
    ck = CheckpointManager(d)
    ck.save(1, {'x': xs})
    out = ck.restore(1, {'x': x}, {'x': NamedSharding(m2, P('c', None))})
    assert np.array_equal(np.asarray(out['x']), np.asarray(x))
    assert len(out['x'].sharding.device_set) == 8
print('elastic ok')
""")
    assert "elastic ok" in out
