"""Mesh-native paged serving: sharded-vs-single-device greedy parity (every
tier, gather AND fused kernels), spec-tree structure, the sharded-arena
allocation/defrag logical-contents property, mesh validation guards, and the
public allocator-stats / defrag engine surface.

Multi-device tests run in subprocesses with 8 emulated host devices
(conftest.run_with_devices) so the in-process suite keeps the single real
CPU device; ``mesh=None`` bit-identity is what every OTHER serving suite
already pins (they run unmodified on the unsharded path)."""
import dataclasses

import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, ServingCfg, get_config, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.scheduler import Request

from conftest import run_with_devices

# ------------------------------------------------------------ spec structure


@pytest.mark.parametrize("arch,mode,tiered", [
    ("qwen1.5-0.5b", "dense", False),
    ("qwen1.5-0.5b", "decomposed", False),
    ("qwen1.5-0.5b", "cpq", False),
    ("qwen1.5-0.5b", "retrieval", False),
    ("qwen1.5-0.5b", "decomposed_cpq", False),
    ("qwen1.5-0.5b", "dense", True),
    ("deepseek-v2-lite-16b", "decomposed", False),
    ("jamba-1.5-large-398b", "dense", False),
])
def test_paged_spec_tree_matches_cache_structure(arch, mode, tiered):
    """paged_cache_pspecs mirrors init_paged_caches exactly (same pytree),
    so device placement and shard_map specs can never misalign."""
    from functools import partial

    from repro.distributed.cache_specs import paged_cache_pspecs

    cfg = smoke_config(get_config(arch)).with_attention(mode)
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         max_blocks_per_slot=4)
    caches = jax.eval_shape(
        partial(M.init_paged_caches, cfg, cfg.attention, serving, tiered))
    specs = paged_cache_pspecs(cfg, cfg.attention, serving, tiered)
    assert jax.tree.structure(caches) == jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, P))


def test_serve_paged_rules_shard_head_and_latent_axes():
    from repro.distributed.cache_specs import paged_layer_cache_specs

    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         max_blocks_per_slot=4)
    dense = paged_layer_cache_specs(cfg, cfg.attention, ("attn", "dense"),
                                    serving)
    assert dense.k == P(None, None, "model", None)
    x = paged_layer_cache_specs(cfg, cfg.with_attention("decomposed").attention,
                                ("attn", "dense"), serving)
    assert x.x == P(None, None, "model")          # latent feature axis
    assert x.k_rope == P(None, None, "model", None)
    mamba = paged_layer_cache_specs(
        smoke_config(get_config("jamba-1.5-large-398b")), cfg.attention,
        ("mamba", "dense"), serving)
    assert all(sp == P() for sp in jax.tree.leaves(
        mamba, is_leaf=lambda s: isinstance(s, P)))


# --------------------------------------------------- engine stats / defrag


@pytest.fixture(scope="module")
def model_f32():
    cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]),
                              dtype="float32")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _reqs(cfg, sizes, max_new=6, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    max_new_tokens=max_new)
            for i, s in enumerate(sizes)]


def test_engine_surfaces_allocator_stats(model_f32):
    """The small-fix satellite: utilization + defrag counts are public serve
    stats (bench_serving / the sharded watermark read these, not private
    allocator state)."""
    cfg, params = model_f32
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=17,
                         max_blocks_per_slot=4, prefill_bucket=4,
                         prefill_chunk=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    _, stats = eng.serve(_reqs(cfg, [5, 3, 6, 4]), GenerationConfig(max_new_tokens=5))
    for key in ("dense_arena_utilization", "dense_pages_used",
                "dense_pages_free", "defrags", "model_shards",
                "arena_bytes_total", "arena_bytes_per_device",
                "interconnect_bytes_per_token"):
        assert key in stats, key
    assert stats["model_shards"] == 1
    assert stats["arena_bytes_per_device"] == stats["arena_bytes_total"]
    assert stats["interconnect_bytes"] == 0.0   # no mesh, no concat traffic
    assert stats["dense_arena_utilization"] == 0.0  # all pages freed at end


def test_defrag_policy_preserves_outputs_and_counts(model_f32):
    """defrag_every compacts the base arena mid-serve: greedy outputs are
    unchanged and the compaction count surfaces in stats."""
    cfg, params = model_f32
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=17,
                         max_blocks_per_slot=4, prefill_bucket=4,
                         prefill_chunk=4)
    gen = GenerationConfig(max_new_tokens=6)
    base_eng = ContinuousServeEngine(cfg, params, serving=serving)
    base, bstats = base_eng.serve(_reqs(cfg, [5, 3, 7, 4, 6]), gen)
    frag_eng = ContinuousServeEngine(
        cfg, params, serving=dataclasses.replace(serving, defrag_every=1))
    frag, fstats = frag_eng.serve(_reqs(cfg, [5, 3, 7, 4, 6]), gen)
    assert bstats["defrags"] == 0 and fstats["defrags"] > 0
    for rid in base:
        np.testing.assert_array_equal(base[rid]["tokens"], frag[rid]["tokens"])


def test_scheduler_plan_defrag_remaps_pages_and_free_list():
    from repro.serving.paged_cache import NULL_PAGE
    from repro.serving.scheduler import Scheduler

    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         max_blocks_per_slot=4)
    sched = Scheduler(serving)
    reqs = _reqs(smoke_config(ARCHS["qwen1.5-0.5b"]), [8, 8])
    for r in reqs:
        sched.submit(r)
    a = sched.admit_next(now=0, step=0)
    b = sched.admit_next(now=0, step=0)
    sched.finish_prefill(a), sched.finish_prefill(b)
    sched.retire(a, 1, "eos")      # leaves b's pages fragmented (high ids)
    perm = sched.plan_defrag()
    assert perm is not None and sched.stats["defrags"] == 1
    assert sorted(b.pages) == [1, 2]       # compacted onto the lowest ids
    assert set(sched.block_tables[b.slot]) - {NULL_PAGE} == set(b.pages)
    free = sched.dense_alloc
    assert free.num_free == serving.num_pages - 1 - len(b.pages)
    assert sched.plan_defrag() is None     # already compact


# ------------------------------------------------------------ mesh validation


def test_mesh_validation_rejects_nondividing_heads():
    run_with_devices("""
import jax
from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine
from repro.serving.scheduler import SchedulerConfigError
from repro.launch.mesh import make_serve_mesh

cfg = smoke_config(ARCHS["qwen1.5-0.5b"])  # 4 query / 4 kv heads
params = M.init_params(cfg, jax.random.PRNGKey(0))
try:
    ContinuousServeEngine(cfg, params, serving=ServingCfg(),
                          mesh=make_serve_mesh(1, 8))
except SchedulerConfigError as e:
    assert "num_heads" in str(e) or "num_kv_heads" in str(e)
    print("REJECTED-OK")
else:
    raise AssertionError("8-way model sharding of 4 heads was accepted")
""")


# ------------------------------------- sharded-vs-single-device greedy parity

_PARITY_CODE = """
import dataclasses
import numpy as np
import jax
from repro.configs import ARCHS, ServingCfg, get_config, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.scheduler import Request
from repro.launch.mesh import make_serve_mesh

arch, mode, tiered = {arch!r}, {mode!r}, {tiered}
cfg = smoke_config(get_config(arch))
cfg = dataclasses.replace(cfg, dtype="float32")
if mode is not None:
    cfg = cfg.with_attention(mode)
params = M.init_params(cfg, jax.random.PRNGKey(0))
serving = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4,
                     enable_escalation=tiered,
                     low_watermark=0.6 if tiered else 0.25,
                     critical_watermark=0.3 if tiered else 0.10)
gen = GenerationConfig(max_new_tokens=6)

def serve(mesh, fused):
    rt = dataclasses.replace(cfg.attention, paged_kernels=fused)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=s)
                    .astype(np.int32), max_new_tokens=6)
            for i, s in enumerate([5, 9, 3, 7])]
    eng = ContinuousServeEngine(cfg, params, rt=rt, serving=serving, mesh=mesh)
    return eng.serve(reqs, gen)

mesh = make_serve_mesh(1, 2)
for fused in (True, False):
    r0, s0 = serve(None, fused)
    r1, s1 = serve(mesh, fused)
    for rid in r0:
        assert np.array_equal(r0[rid]["tokens"], r1[rid]["tokens"]), (
            mode, fused, rid, r0[rid]["tokens"], r1[rid]["tokens"])
        assert r0[rid]["finish_reason"] == r1[rid]["finish_reason"]
    assert s1["model_shards"] == 2
    assert s1["dense_pages_leaked"] == 0
    assert s1["arena_bytes_per_device"] < s1["arena_bytes_total"]
    assert s1["interconnect_bytes"] > 0
    if tiered:
        assert s0["escalations"] == s1["escalations"]
print("PARITY-OK", s1["arena_bytes_per_device"], "/", s1["arena_bytes_total"])
"""


@pytest.mark.parametrize("arch,mode,tiered", [
    ("qwen1.5-0.5b", "dense", False),
    ("qwen1.5-0.5b", "cpq", False),
    ("qwen1.5-0.5b", "decomposed", False),
    ("deepseek-v2-lite-16b", None, False),   # MLA latent (one-shot: MoE)
    ("qwen1.5-0.5b", "dense", True),         # tiered dense+CPQ watermark
], ids=["dense", "cpq", "decomposed", "mla", "tiered"])
def test_sharded_engine_greedy_parity(arch, mode, tiered):
    """mesh=(dp=1, model=2): token-exact greedy parity vs the single-device
    engine at f32, fused AND gather kernel paths; per-device arena bytes
    shrink and only per-head partials cross the interconnect."""
    out = run_with_devices(_PARITY_CODE.format(arch=arch, mode=mode,
                                               tiered=tiered))
    assert "PARITY-OK" in out


# --------------------------- sharded arena alloc/defrag logical invariance

_ARENA_PROPERTY_CODE = """
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.serving import paged_cache as pgc

def scenario(seed, num_pages, n_slots, mp):
    \"\"\"Replay one random alloc/write/retire/defrag history against a
    replicated arena and a model-sharded one: logical contents (the
    gathered per-slot views) must match exactly for any mesh shape.\"\"\"
    page, kv, dh, max_blocks = 2, 8, 4, 4
    mesh = jax.make_mesh((1, mp), ("data", "model"))
    sh = NamedSharding(mesh, P(None, None, "model", None))
    rng = np.random.default_rng(seed)
    ref = jnp.zeros((num_pages, page, kv, dh), jnp.float32)
    shd = jax.device_put(ref, sh)
    alloc = pgc.PageAllocator(num_pages)
    tables = np.zeros((n_slots, max_blocks), np.int32)
    owned = {}
    for step in range(20):
        op = rng.integers(0, 3)
        if op == 0:  # admit a prompt into a free slot
            slot = next((s for s in range(n_slots) if s not in owned), None)
            n_tok = int(rng.integers(1, page * max_blocks + 1))
            need = pgc.pages_needed(n_tok, page)
            if slot is None or not alloc.can_alloc(need):
                continue
            pages = alloc.alloc(need)
            owned[slot] = pages
            tables[slot, :] = pgc.NULL_PAGE
            tables[slot, :need] = pages
            val = jnp.asarray(rng.normal(size=(n_tok, kv, dh)), jnp.float32)
            row = jnp.asarray(tables[slot])
            ref = pgc.write_prompt_pages(ref, row, val)
            shd = pgc.write_prompt_pages(shd, row, val)
        elif op == 1:  # retire a slot
            if not owned:
                continue
            slot = int(rng.choice(list(owned)))
            alloc.free(owned.pop(slot))
            tables[slot, :] = pgc.NULL_PAGE
        else:  # defrag: relabel mapped pages onto the lowest ids
            perm, new_bt, free = pgc.defrag_plan(tables, num_pages)
            remap = {int(o): n for n, o in enumerate(perm)}
            tables[:] = new_bt
            owned = {s: [remap[p] for p in ps] for s, ps in owned.items()}
            alloc.reset_free(free)
            pj = jnp.asarray(perm)
            ref = jnp.take(ref, pj, axis=0)
            shd = jnp.take(shd, pj, axis=0)
    bt = jnp.asarray(tables)
    np.testing.assert_array_equal(
        np.asarray(pgc.gather_pages(ref, bt)),
        np.asarray(pgc.gather_pages(shd, bt)))

try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None, derandomize=True)
    @given(seed=st.integers(0, 2**31 - 1), num_pages=st.integers(4, 24),
           n_slots=st.integers(1, 4), mp=st.sampled_from([2, 4, 8]))
    def prop(seed, num_pages, n_slots, mp):
        scenario(seed, num_pages, n_slots, mp)

    prop()
    print("PROPERTY-OK hypothesis")
except ImportError:
    for seed in range(8):           # deterministic fallback sweep
        for mp in (2, 4, 8):
            scenario(seed, 4 + 3 * seed, 1 + seed % 4, mp)
    print("PROPERTY-OK deterministic")
"""


def test_sharded_arena_alloc_defrag_logical_invariance():
    """Any alloc/write/retire/defrag history leaves a model-sharded arena
    with logical contents identical to the replicated arena, for any mesh
    shape (hypothesis when installed; seed-pinned ci profile in CI)."""
    out = run_with_devices(_ARENA_PROPERTY_CODE)
    assert "PROPERTY-OK" in out
