"""Continuous-batching engine + scheduler behaviour tests: page-leak
invariants, admission/retirement/resume correctness, preemption recompute,
watermark tier escalation, and the throughput acceptance bar vs the static
engine."""
import os
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig, ServeEngine
from repro.serving.scheduler import Request, Scheduler, SchedulerConfigError

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _reqs(cfg, sizes, max_new, seed=0, arrivals=None):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=s).astype(np.int32),
                    max_new_tokens=max_new,
                    arrival=0.0 if arrivals is None else arrivals[i])
            for i, s in enumerate(sizes)]


# ----------------------------------------------------------- scheduler unit


def test_scheduler_admission_and_leak_free():
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         max_blocks_per_slot=4)
    sched = Scheduler(serving)
    reqs = [Request(rid=i, prompt=np.arange(6, dtype=np.int32), max_new_tokens=4)
            for i in range(3)]
    for r in reqs:
        sched.submit(r)
    a = sched.admit_next(now=0, step=0)
    b = sched.admit_next(now=0, step=0)
    assert a is reqs[0] and b is reqs[1]
    assert sched.admit_next(now=0, step=0) is None  # no free slot
    # admission enters the prefilling window: pages owned, nothing valid yet
    assert a.state == "prefilling" and a.prefill_target == 6
    assert sched.lengths[a.slot] == 0 and len(a.pages) == 2
    sched.note_chunk(a, 4)
    assert sched.lengths[a.slot] == 4 and a.state == "prefilling"
    sched.finish_prefill(a)
    sched.finish_prefill(b)
    assert a.state == "running" and sched.lengths[a.slot] == 6
    # block table maps exactly the prompt's pages; rest is null
    assert (sched.block_tables[a.slot, :2] > 0).all()
    assert (sched.block_tables[a.slot, 2:] == 0).all()
    a_slot = a.slot
    sched.retire(a, step=1, reason="eos")
    assert sched.slots[a_slot] is None and sched.lengths[a_slot] == 0
    c = sched.admit_next(now=0, step=1)          # vacated slot is refilled
    assert c is reqs[2] and c.slot == a_slot
    sched.retire(b, step=2, reason="eos")
    sched.retire(c, step=2, reason="eos")
    assert sched.dense_alloc.num_used == 0       # every page returned
    assert sched.stats["admitted"] == 3 and sched.stats["retired"] == 3


def test_scheduler_rejects_oversized_request():
    serving = ServingCfg(num_slots=1, page_size=4, num_pages=9,
                         max_blocks_per_slot=2)  # max_len = 8
    sched = Scheduler(serving)
    with pytest.raises(SchedulerConfigError):
        sched.submit(Request(rid=0, prompt=np.arange(6, dtype=np.int32),
                             max_new_tokens=4))


def test_scheduler_growth_and_ceiling():
    serving = ServingCfg(num_slots=1, page_size=2, num_pages=9,
                         max_blocks_per_slot=3)
    sched = Scheduler(serving)
    r = Request(rid=0, prompt=np.arange(3, dtype=np.int32), max_new_tokens=3)
    sched.submit(r)
    sched.admit_next(now=0, step=0)
    sched.finish_prefill(r)
    assert len(r.pages) == 2                      # ceil(3/2)
    assert sched.ensure_writable(r)               # position 3: page already mapped
    r.length = 4
    assert sched.ensure_writable(r)               # position 4: grows a 3rd page
    assert len(r.pages) == 3
    r.length = 6
    assert not sched.ensure_writable(r)           # context ceiling (3 blocks)


def test_admission_at_exact_pool_exhaustion():
    """A prompt whose page demand EQUALS the free-page count admits (no
    off-by-one slack required); the next request waits until a retirement
    frees pages, then takes the vacated capacity."""
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=5,  # 4 usable
                         max_blocks_per_slot=4)
    sched = Scheduler(serving)
    a = Request(rid=0, prompt=np.arange(16, dtype=np.int32), max_new_tokens=0)
    b = Request(rid=1, prompt=np.arange(4, dtype=np.int32), max_new_tokens=0)
    sched.submit(a)
    sched.submit(b)
    got = sched.admit_next(now=0, step=0)
    assert got is a and sched.dense_alloc.num_free == 0   # exact fit admitted
    assert sched.admit_next(now=0, step=0) is None        # b must wait
    assert b.state == "queued"
    sched.retire(a, step=1, reason="eos")
    got = sched.admit_next(now=0, step=1)
    assert got is b and len(b.pages) == 1
    sched.retire(b, step=2, reason="eos")
    assert sched.dense_alloc.num_used == 0


def test_preemption_picks_newest_same_arena_row():
    """The preemption victim is the YOUNGEST running request (latest
    admitted), never the grower itself — LIFO recompute keeps the oldest
    request's progress."""
    serving = ServingCfg(num_slots=3, page_size=2, num_pages=9,
                         max_blocks_per_slot=4)
    sched = Scheduler(serving)
    reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                    max_new_tokens=2) for i in range(3)]
    for r in reqs:
        sched.submit(r)
    for step, r in enumerate(reqs):
        assert sched.admit_next(now=step, step=step) is r  # staggered ages
    victim = sched.preemption_victim(exclude=reqs[0])
    assert victim is reqs[2]                               # newest row
    victim = sched.preemption_victim(exclude=reqs[2])      # newest excluded
    assert victim is reqs[1]
    sched.preempt(reqs[2])
    assert reqs[2].state == "queued" and reqs[2].pages == []
    assert sched.queue[0] is reqs[2]                       # requeued at front
    # engine-level: under page starvation the OLDER request keeps its slot
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(
        num_slots=2, page_size=4, num_pages=7, max_blocks_per_slot=8,
        prefill_bucket=4))
    rng = np.random.default_rng(11)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
                    max_new_tokens=10) for i in range(2)]
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=10))
    assert stats["preemptions"] >= 1
    assert res[1]["preemptions"] >= 1 and res[0]["preemptions"] == 0
    assert all(len(res[i]["tokens"]) == 10 for i in res)
    assert stats["dense_pages_leaked"] == 0


def test_escalation_then_continued_decode_is_correct(model):
    """Watermark escalation mid-request must not corrupt the survivor: the
    escalated request keeps decoding AFTER the dense -> T2 migration (its
    done_step postdates escalation), finishes its full budget with in-vocab
    tokens, and both arenas end leak-free. A re-run of the same workload is
    bit-identical (escalation is deterministic, no hidden state)."""
    cfg, params = model
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         escalated_pages=33, max_blocks_per_slot=8,
                         prefill_bucket=4, low_watermark=0.75,
                         critical_watermark=0.5, enable_escalation=True)
    eng = ContinuousServeEngine(cfg, params, serving=serving)

    def fresh():
        rng = np.random.default_rng(13)
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, 7
                                                   ).astype(np.int32),
                        max_new_tokens=12) for i in range(2)]

    res, stats = eng.serve(fresh(), GenerationConfig(max_new_tokens=12))
    assert stats["escalations"] >= 1
    esc = [i for i in res if res[i]["escalated"]]
    assert esc
    for i in esc:
        t = res[i]["tokens"]
        assert len(t) == 12 and res[i]["finish_reason"] == "max_tokens"
        assert (t >= 0).all() and (t < cfg.vocab_size).all()
        # decode continued after the escalation step (which can only happen
        # once decoding is underway, i.e. after admission)
        assert res[i]["done_step"] > res[i]["admitted_step"] + 1
    assert stats["dense_pages_leaked"] == 0 and stats["cpq_pages_leaked"] == 0
    res2, stats2 = eng.serve(fresh(), GenerationConfig(max_new_tokens=12))
    for i in res:
        np.testing.assert_array_equal(res[i]["tokens"], res2[i]["tokens"])
    assert stats2["escalations"] == stats["escalations"]


# ------------------------------------------------------------- engine runs


def test_continuous_no_leak_and_all_finish(model):
    cfg, params = model
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=33,
                         max_blocks_per_slot=8, prefill_bucket=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    reqs = _reqs(cfg, sizes=(5, 11, 7, 3, 9, 6), max_new=7)
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=7))
    assert set(res) == set(range(6))
    assert all(r["finish_reason"] == "max_tokens" for r in res.values())
    assert all(len(r["tokens"]) == 7 for r in res.values())
    assert stats["dense_pages_leaked"] == 0 and stats["cpq_pages_leaked"] == 0
    assert stats["admitted"] >= 6 and stats["retired"] == 6


def test_admitted_request_resumes_at_correct_position(model):
    """A request admitted into a vacated slot must decode exactly as if it had
    the machine to itself (same greedy tokens, position continuity)."""
    cfg, params = model
    gen = GenerationConfig(max_new_tokens=6)
    sizes = (5, 9, 12, 3, 8, 6)
    reqs = _reqs(cfg, sizes, max_new=6, arrivals=[0, 0, 1, 2, 3, 8])
    static = ServeEngine(cfg, params, max_len=64)
    refs = []
    for r in reqs:
        out, _ = static.generate({"tokens": jnp.asarray(r.prompt[None])}, gen)
        refs.append(out[0])
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=0,  # one-shot oracle: shares static ops
                         use_paged_kernels=False)  # gather path == static ops
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(reqs, gen)
    for i, ref in enumerate(refs):
        np.testing.assert_array_equal(res[i]["tokens"], ref)
    # later arrivals really were admitted later (slot reuse, not parallel)
    admits = sorted(res[i]["admitted_step"] for i in res)
    assert admits[-1] > admits[0]
    assert stats["dense_pages_leaked"] == 0


def test_preemption_recompute_is_exact(model):
    """Out-of-pages preemption requeues and re-prefills prompt+generated; the
    final greedy tokens must equal an unconstrained run's."""
    cfg, params = model
    gen = GenerationConfig(max_new_tokens=12)
    reqs_small = _reqs(cfg, sizes=(8, 8, 8), max_new=12, seed=3)
    refs = {}
    static = ServeEngine(cfg, params, max_len=64)
    for r in reqs_small:
        refs[r.rid] = static.generate({"tokens": jnp.asarray(r.prompt[None])}, gen)[0][0]
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=10,  # too small
                         max_blocks_per_slot=8, prefill_bucket=4,
                         prefill_chunk=0,  # one-shot oracle: shares static ops
                         use_paged_kernels=False)  # gather path == static ops
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(reqs_small, gen)
    assert stats["preemptions"] >= 1
    for rid, ref in refs.items():
        np.testing.assert_array_equal(res[rid]["tokens"], ref)
    assert stats["dense_pages_leaked"] == 0


def test_tier_escalation_under_pressure(model):
    """Watermark policy: under critical memory pressure a running dense
    request is escalated to the T2 CPQ arena and still produces valid output;
    both arenas end leak-free."""
    cfg, params = model
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=13,
                         escalated_pages=33, max_blocks_per_slot=8,
                         prefill_bucket=4, low_watermark=0.5,
                         critical_watermark=0.25, enable_escalation=True)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    assert eng.tiered
    reqs = _reqs(cfg, sizes=(8, 10, 6, 7, 9), max_new=10, seed=2)
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=10))
    assert stats["escalations"] >= 1
    assert any(res[i]["escalated"] for i in res)
    for i in res:
        t = res[i]["tokens"]
        assert res[i]["finish_reason"] in ("max_tokens", "eos")
        assert len(t) == 10
        assert (t >= 0).all() and (t < cfg.vocab_size).all()
    assert stats["dense_pages_leaked"] == 0 and stats["cpq_pages_leaked"] == 0


def test_eos_retirement_vacates_and_admits(model):
    """Per-row EOS retirement frees the slot for the queue (the continuous
    engine's reason to exist); stats count only live tokens."""
    cfg, params = model
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=65,
                         max_blocks_per_slot=32, prefill_bucket=4)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    reqs = _reqs(cfg, sizes=(6, 9, 5, 11, 7, 8), max_new=24, seed=5)

    # probe greedily for a token the model actually emits mid-stream, then
    # replay with that token as EOS — deterministic early retirement
    probe, _ = eng.serve(reqs, GenerationConfig(max_new_tokens=24))
    eos = -1
    for i in probe:
        mid = probe[i]["tokens"][1:-1]
        if len(mid):
            eos = int(mid[0])
            break
    assert eos >= 0
    for r in reqs:  # reset scheduler-owned request state for the replay
        r.generated, r.state, r.length = [], "queued", 0
        r.admitted_step = r.first_token_step = r.done_step = -1
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=24, eos_id=eos))
    assert set(res) == set(range(6))
    eos_finishers = [i for i in res if res[i]["finish_reason"] == "eos"]
    assert eos_finishers, "probe token never re-emitted; premise broken"
    for i in eos_finishers:
        t = res[i]["tokens"]
        assert t[-1] == eos and (t[:-1] != eos).all()  # stops AT the first EOS
        assert len(t) < 24                             # retired early
    assert stats["generated_tokens"] == sum(len(res[i]["tokens"]) for i in res)
    assert stats["dense_pages_leaked"] == 0


def test_static_engine_eos_masking(model):
    """Satellite: static engine masks post-EOS samples to eos_id and reports
    only live tokens."""
    cfg, params = model
    eng = ServeEngine(cfg, params, max_len=64)
    rng = np.random.default_rng(7)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 8)))}
    out, stats = eng.generate(batch, GenerationConfig(max_new_tokens=32, eos_id=0))
    for row in out:
        hits = np.flatnonzero(row == 0)
        if hits.size and hits[0] < len(row) - 1:
            assert (row[hits[0]:] == 0).all()  # everything after EOS is eos_id
    live = sum((np.flatnonzero(r == 0)[0] + 1) if (r == 0).any() else len(r)
               for r in out)
    assert stats["generated_tokens"] == live


def test_throughput_vs_static_acceptance():
    """Acceptance bar: >= 1.5x token throughput over the static engine on a
    mixed-length Poisson workload at equal arena bytes."""
    from benchmarks.bench_serving import compare

    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    st, ct = compare(cfg, params, rate=1.0, n_requests=12, num_slots=4)
    ratio = ct["tokens_per_step"] / st["tokens_per_step"]
    assert ratio >= 1.5, (st, ct)
    assert ct["arena_utilization"] > st["arena_utilization"]
    assert ct["latency_mean"] < st["latency_mean"]
