"""Scheduler-policy suite: admission ordering (priority / aging / TTFT-slack
EDF), victim selection, the de-escalation (T2 -> dense recovery) regression,
engine-level policy behaviour on contended traces, and the hypothesis
property that ANY interleaving of policy decisions (admit / preempt /
escalate / de-escalate / retire) preserves the allocator invariants — no
leaked and no double-owned pages, in either arena."""
import numpy as np
import pytest

import jax

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.paged_cache import NULL_PAGE, pages_needed
from repro.serving.policies import (FifoPolicy, PriorityPolicy, SloAwarePolicy,
                                    make_policy)
from repro.serving.request import SamplingParams, ServeRequest, SloClass
from repro.serving.scheduler import Request, Scheduler


@pytest.fixture(scope="module")
def model():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _req(rid, plen=4, max_new=4, arrival=0.0, prio=None, ttft=None):
    slo = None
    if prio is not None or ttft is not None:
        slo = SloClass(f"c{prio}", priority=prio or 0,
                       ttft_target=float("inf") if ttft is None else ttft)
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32) % 7,
                   max_new_tokens=max_new, arrival=arrival, slo=slo)


SERVING = ServingCfg(num_slots=2, page_size=4, num_pages=17,
                     max_blocks_per_slot=4)


# -------------------------------------------------------- admission ordering


def test_fifo_policy_is_head_only():
    """FIFO never bypasses the head: an arrived later request does not admit
    while the (unarrived or unfitting) head blocks."""
    sched = Scheduler(SERVING, policy=FifoPolicy())
    a, b = _req(0, arrival=5.0), _req(1, arrival=0.0)
    sched.submit(a)
    sched.submit(b)
    assert sched.admit_next(now=0, step=0) is None      # head not arrived
    got = sched.admit_next(now=5, step=5)
    assert got is a                                      # head first


def test_priority_policy_jumps_queue_and_ages():
    pol = PriorityPolicy(aging_ticks=10)
    sched = Scheduler(SERVING, policy=pol)
    lo, hi = _req(0, prio=0), _req(1, prio=2)
    sched.submit(lo)
    sched.submit(hi)
    assert sched.admit_next(now=0, step=0) is hi         # class order
    # aging: a level-0 request that waited 2*aging_ticks outranks a fresh
    # level-1 arrival
    sched2 = Scheduler(SERVING, policy=pol)
    old = _req(0, prio=0, arrival=0.0)
    fresh = _req(1, prio=1, arrival=20.0)
    sched2.submit(old)
    sched2.submit(fresh)
    assert pol.effective_priority(old, 20.0) == 2.0
    assert sched2.admit_next(now=20, step=20) is old


def test_slo_policy_admits_least_slack_first():
    pol = SloAwarePolicy()
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=17,
                         max_blocks_per_slot=4)
    sched = Scheduler(serving, policy=pol)
    patient = _req(0, plen=4, ttft=100.0)
    urgent = _req(1, plen=4, ttft=3.0)
    nodeadline = _req(2, plen=4, ttft=float("inf"))      # inf target: last
    for r in (patient, urgent, nodeadline):
        sched.submit(r)
    assert sched.admit_next(now=0, step=0) is urgent
    assert sched.admit_next(now=0, step=0) is patient
    assert sched.admit_next(now=0, step=0) is nodeadline


def test_priority_preemption_and_escalation_pick_low_class():
    pol = PriorityPolicy()
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=17,
                         max_blocks_per_slot=4)
    sched = Scheduler(serving, policy=pol)
    reqs = [_req(0, prio=2), _req(1, prio=0), _req(2, prio=1)]
    for r in reqs:
        sched.submit(r)
    for s in range(3):
        sched.admit_next(now=s, step=s)
    # victim: lowest class, NOT the newest (rid 2 admitted last)
    assert sched.preemption_victim(exclude=reqs[0]) is reqs[1]


# --------------------------------------------------- engine-level behaviour


def test_policy_string_and_object_select_the_same_policy(model):
    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(policy="slo"))
    assert eng.make_policy().name == "slo"
    eng = ContinuousServeEngine(cfg, params, policy=PriorityPolicy())
    assert eng.make_policy().name == "priority"
    with pytest.raises(ValueError):
        make_policy("round-robin")


def test_priority_improves_high_class_ttft(model):
    """Contended single-slot trace: batch jobs arrive first, an interactive
    request second — priority admits it decades earlier than FIFO, and the
    greedy tokens of every request are policy-invariant (scheduling changes
    WHEN a request runs, never WHAT it generates)."""
    cfg, params = model
    serving = ServingCfg(num_slots=1, page_size=4, num_pages=17,
                         max_blocks_per_slot=4, prefill_bucket=4,
                         prefill_chunk=4)

    def trace():
        rng = np.random.default_rng(3)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                        max_new_tokens=8,
                        slo=SloClass("batch", priority=0)) for i in range(3)]
        reqs.append(Request(
            rid=9, prompt=rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
            max_new_tokens=3, arrival=1.0,
            slo=SloClass("interactive", priority=2, ttft_target=8.0)))
        return reqs

    outs = {}
    for name in ("fifo", "priority"):
        eng = ContinuousServeEngine(cfg, params, serving=serving, policy=name)
        res, stats = eng.serve(trace(), GenerationConfig())
        assert stats["policy"] == name
        assert stats["dense_pages_leaked"] == 0
        outs[name] = res
    f, p = outs["fifo"], outs["priority"]
    assert (p[9]["first_token_step"] - 1.0) < (f[9]["first_token_step"] - 1.0)
    for rid in f:
        np.testing.assert_array_equal(f[rid]["tokens"], p[rid]["tokens"])


def test_deescalation_restores_dense_tier(model):
    """The ROADMAP de-escalation item: once memory pressure clears (free
    fraction above the high watermark), the policy re-admits an escalated
    T2 row to the dense tier via chunked re-admission. The recovered
    request finishes its full budget, both arenas end leak-free, and a
    replay is bit-identical (recovery is deterministic recompute)."""
    cfg, params = model
    serving = ServingCfg(num_slots=3, page_size=4, num_pages=13,
                         escalated_pages=33, max_blocks_per_slot=8,
                         prefill_bucket=4, low_watermark=0.5,
                         critical_watermark=0.25, high_watermark=0.55,
                         enable_escalation=True)
    eng = ContinuousServeEngine(cfg, params, serving=serving,
                                policy=SloAwarePolicy())
    assert eng.tiered

    def fresh():
        rng = np.random.default_rng(2)
        sizes, targets = (8, 10, 6, 7, 9), (6, 16, 6, 6, 6)
        return [Request(rid=i,
                        prompt=rng.integers(0, cfg.vocab_size, s).astype(np.int32),
                        max_new_tokens=t)
                for i, (s, t) in enumerate(zip(sizes, targets))]

    res, stats = eng.serve(fresh(), GenerationConfig(max_new_tokens=16))
    assert stats["escalations"] >= 1
    assert stats["deescalations"] >= 1
    recovered = [i for i in res if res[i]["deescalations"] > 0]
    assert recovered
    for i in recovered:
        # escalated, then recovered, then FINISHED its whole budget dense
        assert res[i]["escalated"]
        assert res[i]["finish_reason"] == "max_tokens"
        assert len(res[i]["tokens"]) == 16
        t = res[i]["tokens"]
        assert (t >= 0).all() and (t < cfg.vocab_size).all()
    assert stats["dense_pages_leaked"] == 0 and stats["cpq_pages_leaked"] == 0
    res2, stats2 = eng.serve(fresh(), GenerationConfig(max_new_tokens=16))
    for i in res:
        np.testing.assert_array_equal(res[i]["tokens"], res2[i]["tokens"])
    assert stats2["deescalations"] == stats["deescalations"]


def test_deescalation_of_sole_occupant_readmits_not_drops(model):
    """Regression: de-escalating the ONLY occupied slot vacates the machine
    mid-tick, AFTER the admission phase ran — the end-of-tick
    empty-machine branch must recognize the requeued row as placeable and
    let the next tick re-admit it, NOT drop it as 'unschedulable' with a
    truncated stream (the bug: finish_reason='unschedulable' at 18/20
    tokens on this exact trace)."""
    cfg, params = model
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                         escalated_pages=33, max_blocks_per_slot=8,
                         prefill_bucket=4, prefill_chunk=4,
                         low_watermark=0.5, critical_watermark=0.25,
                         high_watermark=0.6, enable_escalation=True)
    eng = ContinuousServeEngine(cfg, params, serving=serving,
                                policy=SloAwarePolicy())
    rng = np.random.default_rng(4)
    reqs = [Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=4),   # retires early
            Request(rid=1, prompt=rng.integers(0, cfg.vocab_size, 8)
                    .astype(np.int32), max_new_tokens=20)]  # recovers alone
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=20))
    assert stats["deescalations"] >= 1
    assert res[1]["deescalations"] >= 1
    assert res[1]["finish_reason"] == "max_tokens"
    assert len(res[1]["tokens"]) == 20          # nothing truncated
    assert stats["dense_pages_leaked"] == 0 and stats["cpq_pages_leaked"] == 0


def test_fifo_deescalation_is_opt_in():
    """high_watermark alone never triggers recovery under the default
    policy; FifoPolicy(deescalate=True) opts in."""
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=9,
                        escalated_pages=17, max_blocks_per_slot=4,
                        low_watermark=0.5, critical_watermark=0.25,
                        high_watermark=0.6, enable_escalation=True)
    sched = Scheduler(serving, tiered=True, policy=FifoPolicy())
    r = _req(0, plen=4)
    sched.submit(r)
    sched.admit_next(now=0, step=0)
    sched.finish_prefill(r)
    dense_row, _ = sched.apply_escalation(r)
    assert r.tier == 1 and sched.free_frac() > serving.high_watermark
    assert sched.deescalation_candidate() is None         # default: off
    sched.policy = FifoPolicy(deescalate=True)
    assert sched.deescalation_candidate() is r
    sched.deescalate(r)
    assert r.state == "queued" and r.tier == 0 and r.deescalations == 1
    assert sched.cpq_alloc.num_used == 0                  # CPQ pages freed
    assert sched.stats["deescalations"] == 1


def test_add_request_rejects_duplicate_rid(model):
    """rid keys results and scheduler bookkeeping — a collision must raise
    instead of silently clobbering another request's record."""
    from repro.serving.request import SamplingParams, ServeRequest
    from repro.serving.scheduler import SchedulerConfigError

    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.reset()
    eng.add_request(ServeRequest(prompt=np.arange(4) % 7, rid=5,
                                 sampling=SamplingParams(max_tokens=2)))
    with pytest.raises(SchedulerConfigError):
        eng.add_request(ServeRequest(prompt=np.arange(4) % 7, rid=5,
                                     sampling=SamplingParams(max_tokens=2)))
    # auto-assigned rids steer around the taken id
    rid = eng.add_request(ServeRequest(prompt=np.arange(4) % 7,
                                       sampling=SamplingParams(max_tokens=2)))
    assert rid == 6


def test_idle_clock_jumps_over_unarrived_fifo_head(model):
    """An arrived request blocked behind an unarrived no-bypass FIFO head
    must not degrade the idle fast-forward into one-tick spins: the clock
    jumps straight to the blocking head's arrival."""
    from repro.serving.request import SamplingParams, ServeRequest

    cfg, params = model
    eng = ContinuousServeEngine(cfg, params, serving=SERVING)
    eng.reset()
    eng.add_request(ServeRequest(prompt=np.arange(4) % 7, rid=0, arrival=500.0,
                                 sampling=SamplingParams(max_tokens=2)))
    eng.add_request(ServeRequest(prompt=np.arange(4) % 7, rid=1, arrival=0.0,
                                 sampling=SamplingParams(max_tokens=2)))
    for _ in range(4):   # a few idle ticks must reach the head's arrival
        eng.step()
        if eng._st.step >= 500:
            break
    assert eng._st.step >= 500
    while eng.has_unfinished():
        eng.step()
    res = eng.results()
    assert len(res[0]["tokens"]) == 2 and len(res[1]["tokens"]) == 2


def test_high_watermark_validation():
    # ServingCfg.validate() runs from __post_init__: inconsistent knobs
    # raise ValueError with the knob names spelled out
    with pytest.raises(ValueError, match="high_watermark"):
        ServingCfg(low_watermark=0.6, high_watermark=0.4)
    with pytest.raises(ValueError, match="policy"):
        ServingCfg(policy="lifo")


# ------------------------------- allocator invariants under policy decisions


def _check_invariants(sched: Scheduler, serving: ServingCfg, tiered: bool):
    """No leaked, no double-owned pages; block tables mirror ownership."""
    for tier, alloc in ((0, sched.dense_alloc), (1, sched.cpq_alloc)):
        if alloc is None:
            continue
        owned = [p for r in sched.occupied() if r.tier == tier
                 for p in r.pages]
        assert len(set(owned)) == len(owned), "double-owned page"
        assert NULL_PAGE not in owned
        assert alloc.num_used == len(owned), "leaked/phantom pages"
        assert alloc.num_used + alloc.num_free == alloc.num_pages - 1
    for slot, r in enumerate(sched.slots):
        for tier, tables in ((0, sched.block_tables),
                             (1, sched.alt_block_tables)):
            if tables is None:
                continue
            mapped = set(int(p) for p in tables[slot]) - {NULL_PAGE}
            if r is None or r.tier != tier:
                assert not mapped, "stale block-table row"
            else:
                assert mapped == set(r.pages)


@hypothesis.given(seed=st.integers(0, 2 ** 31 - 1),
                  policy=st.sampled_from(["fifo", "priority", "slo"]),
                  tiered=st.booleans(),
                  num_pages=st.integers(4, 17))
@hypothesis.settings(max_examples=40, deadline=None)
def test_policy_interleaving_preserves_allocator_invariants(
        seed, policy, tiered, num_pages):
    """Drive a Scheduler through a random interleaving of the full decision
    vocabulary — admit / chunk / finish / grow / preempt / escalate /
    de-escalate / retire, as chosen by a random policy — and assert after
    every step that no page is leaked or double-owned and every block table
    mirrors ownership exactly. At the end, retire everything: both arenas
    must drain to zero used pages."""
    rng = np.random.default_rng(seed)
    serving = ServingCfg(num_slots=3, page_size=2, num_pages=num_pages,
                         escalated_pages=9, max_blocks_per_slot=4,
                         low_watermark=0.5, critical_watermark=0.25,
                         high_watermark=0.6)
    pol = make_policy(policy)
    pol.deescalate = True
    sched = Scheduler(serving, tiered=tiered, policy=pol)
    next_rid = 0
    clock = 0
    for _ in range(60):
        op = rng.integers(0, 6)
        clock += 1
        if op == 0 and len(sched.queue) < 4:             # submit
            # prompt + budget stays within max_len (= 8 here)
            sched.submit(Request(
                rid=next_rid, prompt=rng.integers(0, 7, rng.integers(1, 5))
                .astype(np.int32), max_new_tokens=4,
                slo=SloClass("x", priority=int(rng.integers(0, 3)),
                             ttft_target=float(rng.integers(1, 50)))))
            next_rid += 1
        elif op == 1:                                    # admit (policy)
            r = sched.admit_next(now=clock, step=clock)
            if r is not None and rng.random() < 0.7:
                sched.finish_prefill(r)
        elif op == 2:                                    # chunk progress
            pre = sched.prefilling()
            if pre:
                sched.note_chunk(pre[0], 2)
                if pre[0].length >= pre[0].prefill_target:
                    sched.finish_prefill(pre[0])
        elif op == 3:                                    # grow / preempt
            for r in list(sched.running()):
                if r.state != "running":
                    continue
                r.length += 1
                sched.lengths[r.slot] = r.length
                while not sched.ensure_writable(r):
                    if (r.length // serving.page_size
                            >= serving.max_blocks_per_slot):
                        sched.retire(r, clock, "length_cap")
                        break
                    v = sched.preemption_victim(exclude=r)
                    if v is None:
                        sched.retire(r, clock, "oom")
                        break
                    sched.preempt(v)
        elif op == 4 and tiered:                         # escalate / recover
            cand = sched.escalation_candidate()
            if cand is not None:
                sched.apply_escalation(cand)
            elif (cand := sched.deescalation_candidate()) is not None:
                sched.deescalate(cand)
        else:                                            # retire someone
            occ = sched.occupied()
            if occ:
                sched.retire(occ[int(rng.integers(len(occ)))], clock, "eos")
        _check_invariants(sched, serving, tiered)
    for r in list(sched.occupied()):
        sched.retire(r, clock, "eos")
    _check_invariants(sched, serving, tiered)
    assert sched.dense_alloc.num_used == 0
    if sched.cpq_alloc is not None:
        assert sched.cpq_alloc.num_used == 0
