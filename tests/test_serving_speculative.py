"""Speculative decoding over paged arenas (the PR-9 tentpole).

Covers: prompt-lookup drafting unit semantics; the draft-extended
refcount-ownership property suite — for ANY interleaving of
begin/commit/abort draft with admit / chunk / decode-grow / COW / preempt /
escalate / retire / defrag, refcount == owner count (drafts COUNT as
owners: one per aliased page, one per scratch page) and free-list
membership <=> refcount 0 (hypothesis); the token-parity acceptance
matrix — greedy streams bit-identical speculative on-vs-off across
dense / T1 / MLA / tiered on both the gather and fused paged-kernel paths
and under a 2-way model mesh, seeded sampling replay-stable across
recompute preemption and A->B engine migration with speculation on; the
defrag-locality regression (shared pages compact to the lowest physical
ids and prefix-index entries stay exact across compaction); and the
defrag-vs-open-draft deferral."""
import dataclasses

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject
import numpy as np
import pytest

import jax

from conftest import run_with_devices
from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.configs.base import MLACfg, ModelConfig
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.paged_cache import NULL_PAGE, PageAllocator, defrag_plan
from repro.serving.request import SamplingParams, ServeRequest
from repro.serving.scheduler import Request, Scheduler
from repro.serving.speculative import propose_ngram

# pure-MLA stack with dense MLPs (same rationale as test_serving_prefix:
# MoE drop patterns are group-dependent, so MLA parity runs on this stack)
MLA_DENSE = ModelConfig(
    name="mla-dense-test", family="dense", d_model=32, num_heads=4,
    num_kv_heads=4, head_dim=8, d_ff=64, vocab_size=256,
    block_pattern=(("mla", "dense"),), num_blocks=2,
    mla=MLACfg(kv_lora_rank=16, qk_nope_head_dim=8, qk_rope_head_dim=4,
               v_head_dim=8),
    dtype="float32")


def _mk(arch=None, mode=None):
    cfg = MLA_DENSE if arch == "mla-dense" else smoke_config(ARCHS[arch])
    cfg = dataclasses.replace(cfg, dtype="float32")
    if mode:
        cfg = cfg.with_attention(mode)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _loopy_prompts(cfg, n=3, motif=6, reps=3, seed=0):
    """Self-similar prompts (tiled motif + unique tail): the structure
    prompt-lookup drafting actually fires on."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        m = rng.integers(1, cfg.vocab_size, size=motif).astype(np.int32)
        out.append(np.concatenate(
            [np.tile(m, reps),
             rng.integers(1, cfg.vocab_size, size=2).astype(np.int32)]))
    return out


def _serve(cfg, params, prompts, *, spec, fused=False, max_new=12, **kw):
    base = dict(num_slots=3, page_size=4, num_pages=65,
                max_blocks_per_slot=12, prefill_bucket=4, prefill_chunk=4,
                spec_len=spec, use_paged_kernels=fused)
    base.update(kw)
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(**base))
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)],
        GenerationConfig(max_new_tokens=max_new))
    return {i: res[i]["tokens"] for i in res}, stats, eng


# --------------------------------------------------- prompt-lookup drafting


def test_propose_ngram_longest_suffix_latest_occurrence():
    """The longest recurring suffix n-gram wins; among equal-length matches
    the LATEST occurrence wins; the draft is the <= k tokens that followed."""
    #                 0  1  2  3  4  5  6  7  8
    ctx = np.array([5, 6, 7, 9, 5, 6, 7, 2, 5, 6, 7], np.int32)
    # suffix (5,6,7) recurs at 0 and 4; latest (4) wins -> draft starts at 7
    np.testing.assert_array_equal(propose_ngram(ctx, 3, 2), [2, 5])
    np.testing.assert_array_equal(propose_ngram(ctx, 3, 8), [2, 5, 6, 7])
    # max_ngram=1: suffix (7,) recurs latest at 6 -> followed by 2, 5, ...
    np.testing.assert_array_equal(propose_ngram(ctx, 1, 2), [2, 5])


def test_propose_ngram_falls_back_and_bounds():
    ctx = np.array([1, 2, 3, 4], np.int32)
    assert len(propose_ngram(ctx, 3, 4)) == 0         # nothing recurs
    assert len(propose_ngram(ctx, 3, 0)) == 0         # k = 0
    assert len(propose_ngram(np.array([7], np.int32), 3, 4)) == 0
    # suffix ngram shorter than max_ngram still matches (falls to n=1)
    ctx = np.array([9, 1, 9], np.int32)
    np.testing.assert_array_equal(propose_ngram(ctx, 3, 2), [1, 9])
    # the window at the suffix's own position is excluded: no self-match
    assert len(propose_ngram(np.array([3, 4], np.int32), 1, 2)) == 0


# ------------------------- draft-extended refcount-ownership property suite


def _check_refcounts(sched: Scheduler, tiered: bool):
    """THE invariant, draft-aware: refcount(p) == block-table owners PLUS
    one per reference an open draft holds (every aliased page, every
    scratch page); free-list membership <=> refcount 0; the weak index
    never points at an unowned page; drafts never appear in block tables."""
    alloc = sched.dense_alloc
    owners: dict[int, int] = {}
    for r in sched.occupied():
        if r.tier == 0:
            for p in r.pages:
                owners[int(p)] = owners.get(int(p), 0) + 1
        if r.draft is not None:
            assert r.tier == 0 and r.state == "running"
            for p in r.draft.aliased + r.draft.scratch:
                owners[int(p)] = owners.get(int(p), 0) + 1
    in_free = set(alloc._free)
    for p in range(1, alloc.num_pages):
        assert alloc.refcount(p) == owners.get(p, 0), f"page {p}"
        assert (alloc.refcount(p) == 0) == (p in in_free), f"page {p}"
    assert alloc.refcount(NULL_PAGE) == 0 and NULL_PAGE not in in_free
    for slot, r in enumerate(sched.slots):
        row = [int(p) for p in sched.block_tables[slot]]
        if r is None or r.tier != 0:
            assert set(row) == {NULL_PAGE}, "stale block-table row"
        else:
            n = len(r.pages)
            assert row[:n] == [int(p) for p in r.pages]
            assert set(row[n:]) <= {NULL_PAGE}
            if r.draft is not None:  # scratch is invisible to the tables
                assert not (set(row) & set(map(int, r.draft.scratch)))
    if sched.prefix_index is not None:
        for p in sched.prefix_index.registered_pages():
            assert alloc.refcount(p) >= 1, f"index dangles on page {p}"
    if tiered:
        cpq_owned = [int(p) for r in sched.occupied() if r.tier == 1
                     for p in r.pages]
        assert len(set(cpq_owned)) == len(cpq_owned)
        for p in range(1, sched.cpq_alloc.num_pages):
            assert sched.cpq_alloc.refcount(p) == int(p in cpq_owned)


def _grow_one(sched, serving, r, rng, clock):
    """Engine-faithful decode growth for one running row."""
    while True:
        try:
            if sched.cow_plan(r) is None:
                break
        except PageAllocator.OutOfPages:
            v = sched.preemption_victim(exclude=r)
            if v is None:
                sched.retire(r, clock, "oom")
                return
            sched.preempt(v)
    while not sched.ensure_writable(r):
        if r.length // serving.page_size >= serving.max_blocks_per_slot:
            sched.retire(r, clock, "length_cap")
            return
        v = sched.preemption_victim(exclude=r)
        if v is None:
            sched.retire(r, clock, "oom")
            return
        sched.preempt(v)
    r.generated.append(int(rng.integers(1, 7)))
    r.length += 1
    sched.lengths[r.slot] = r.length
    sched.register_prefix(r)


@hypothesis.given(seed=st.integers(0, 2 ** 31 - 1),
                  tiered=st.booleans(),
                  num_pages=st.integers(6, 17),
                  share=st.booleans())
@hypothesis.settings(max_examples=40, deadline=None)
def test_refcount_invariant_with_draft_ops(seed, tiered, num_pages, share):
    """ACCEPTANCE: the PR-7 interleaving suite extended with the draft
    lifecycle — begin_draft / commit_draft(+emit growth) / abort_draft
    interleaved with admit / chunk / grow / COW / preempt / escalate /
    retire / defrag — asserting the draft-aware refcount invariant after
    EVERY op. Drafts deliberately stay OPEN across foreign ops (the engine
    closes them within a tick; the scheduler must tolerate anything):
    preempt/retire/escalate of a drafted row abort via the hooks, and
    defrag defers while any draft is open. At the end everything retires
    and both arenas drain to zero."""
    rng = np.random.default_rng(seed)
    serving = ServingCfg(num_slots=3, page_size=2, num_pages=num_pages,
                         escalated_pages=9, max_blocks_per_slot=4,
                         low_watermark=0.5, critical_watermark=0.25,
                         high_watermark=0.6, enable_escalation=tiered,
                         prefill_chunk=2, share_prefix=share, spec_len=2)
    sched = Scheduler(serving, tiered=tiered, share_prefix=share)
    templates = [rng.integers(1, 7, 3).astype(np.int32) for _ in range(2)]
    next_rid = 0
    clock = 0

    def drafted():
        return [r for r in sched.occupied() if r.draft is not None]

    for _ in range(90):
        op = rng.integers(0, 9)
        clock += 1
        if op == 0 and len(sched.queue) < 4:                 # submit
            t = templates[int(rng.integers(2))]
            keep = int(rng.integers(1, len(t) + 1))
            prompt = np.concatenate(
                [t[:keep], rng.integers(1, 7, rng.integers(1, 3))
                 .astype(np.int32)])
            sched.submit(Request(rid=next_rid, prompt=prompt,
                                 max_new_tokens=3))
            next_rid += 1
        elif op == 1:                                        # admit
            sched.admit_next(now=clock, step=clock)
        elif op == 2:                                        # chunk progress
            pre = sched.prefilling()
            if pre:
                r = pre[0]
                try:
                    while sched.cow_plan(r) is not None:
                        pass
                except PageAllocator.OutOfPages:
                    sched.preempt(r)
                else:
                    sched.note_chunk(r, serving.page_size)
                    sched.register_prefix(r)
                    if r.length >= r.prefill_target:
                        sched.finish_prefill(r)
        elif op == 3:                                        # decode growth
            for r in list(sched.running()):
                if r.state == "running" and r.draft is None:
                    _grow_one(sched, serving, r, rng, clock)
        elif op == 4 and tiered:                             # escalate/recover
            cand = sched.escalation_candidate()
            if cand is not None:
                sched.apply_escalation(cand)     # aborts any open draft
            elif (cand := sched.deescalation_candidate()) is not None:
                sched.deescalate(cand)
        elif op == 5:                                        # defrag
            if drafted():
                assert sched.plan_defrag() is None, (
                    "defrag must defer while a draft holds scratch pages")
            else:
                sched.plan_defrag()
        elif op == 6:                                        # open a draft
            cands = [r for r in sched.running()
                     if r.state == "running" and r.tier == 0
                     and r.draft is None
                     and r.max_new_tokens - r.num_generated >= 2]
            if cands:
                r = cands[int(rng.integers(len(cands)))]
                cap = (serving.max_blocks_per_slot * serving.page_size
                       - 1 - r.length)
                budget = r.max_new_tokens - r.num_generated
                k = min(int(rng.integers(1, serving.spec_len + 1)),
                        budget - 1, cap)
                if k >= 1:
                    d = sched.begin_draft(r, k)
                    if d is not None:
                        d.tokens = [1] * k
        elif op == 7:                                        # close a draft
            ds = drafted()
            if ds:
                r = ds[int(rng.integers(len(ds)))]
                k = len(r.draft.tokens)
                if rng.random() < 0.3:
                    sched.abort_draft(r)
                else:
                    # engine-faithful commit: n_accept tokens emit with
                    # growth, retiring at the budget exactly like
                    # _emit_token does
                    n_accept = int(rng.integers(1, k + 2))
                    sched.commit_draft(r, n_accept)
                    for _ in range(n_accept):
                        if r.state != "running":
                            break
                        r.generated.append(int(rng.integers(1, 7)))
                        r.length += 1
                        sched.lengths[r.slot] = r.length
                        sched.register_prefix(r)
                        if r.num_generated >= r.max_new_tokens:
                            sched.retire(r, clock, "max_tokens")
        else:                                                # retire/preempt
            occ = sched.occupied()
            if occ:
                victim = occ[int(rng.integers(len(occ)))]
                if rng.random() < 0.5:
                    sched.retire(victim, clock, "eos")
                else:
                    sched.preempt(victim)
        _check_refcounts(sched, tiered)
    for r in list(sched.occupied()):
        sched.retire(r, clock, "eos")
    _check_refcounts(sched, tiered)
    assert sched.dense_alloc.num_used == 0
    if sched.cpq_alloc is not None:
        assert sched.cpq_alloc.num_used == 0
    if sched.prefix_index is not None:
        assert len(sched.prefix_index) == 0


def test_draft_lifecycle_unit():
    """Deterministic draft bookkeeping: begin increfs every mapped page and
    allocates scratch for exactly the blocks the candidates cover; a
    partial frontier names copy_src; commit adopts in block order and
    releases every alias; abort releases everything and leaves the row's
    arena untouched."""
    serving = ServingCfg(num_slots=2, page_size=4, num_pages=17,
                         max_blocks_per_slot=4, prefill_chunk=4)
    sched = Scheduler(serving)
    r = Request(rid=0, prompt=np.arange(1, 7, dtype=np.int32),
                max_new_tokens=8)
    sched.submit(r)
    sched.admit_next(now=0, step=0)
    sched.note_chunk(r, 6)
    sched.finish_prefill(r)                       # length 6: partial page 1
    assert sched.ensure_writable(r)
    pages0 = [int(p) for p in r.pages]
    d = sched.begin_draft(r, 3)                   # positions 6..9 -> blocks 1,2
    assert d is not None
    assert d.copy_src == pages0[1] and d.blocks == [1, 2]
    assert len(d.scratch) == 2 and len(d.aliased) == len(pages0)
    for p in pages0:
        assert sched.dense_alloc.refcount(p) == 2     # owner + draft alias
    row = sched.draft_block_row(r)
    assert list(row[:3]) == [pages0[0], d.scratch[0], d.scratch[1]]
    # commit 2 tokens: scratch block 1 replaces the frontier (old page
    # freed), block 2's scratch is surplus (position 7 is the last valid)
    sched.commit_draft(r, 2)
    assert r.draft is None
    assert int(r.pages[1]) == d.scratch[0]
    assert sched.dense_alloc.refcount(pages0[1]) == 0
    assert sched.dense_alloc.refcount(d.scratch[1]) == 0
    for _ in range(2):
        r.generated.append(1)
        r.length += 1
        sched.lengths[r.slot] = r.length
    # abort leaves the arena exactly as it was
    before = [int(p) for p in r.pages]
    d2 = sched.begin_draft(r, 2)
    assert d2 is not None
    sched.abort_draft(r)
    assert [int(p) for p in r.pages] == before
    for p in before:
        assert sched.dense_alloc.refcount(p) == 1
    sched.retire(r, 0, "eos")
    assert sched.dense_alloc.num_used == 0


def test_begin_draft_refuses_block_ceiling_and_pressure():
    serving = ServingCfg(num_slots=1, page_size=2, num_pages=5,
                         max_blocks_per_slot=2, prefill_chunk=2)
    sched = Scheduler(serving)
    r = Request(rid=0, prompt=np.array([1, 2], np.int32), max_new_tokens=2)
    sched.submit(r)
    sched.admit_next(now=0, step=0)
    sched.note_chunk(r, 2)
    sched.finish_prefill(r)                      # length 2 of max 4
    assert sched.begin_draft(r, 2) is None       # (2+2)//2 = block 2: ceiling
    d = sched.begin_draft(r, 1)                  # fits in block 1
    assert d is not None
    sched.abort_draft(r)
    sched.retire(r, 0, "eos")
    assert sched.dense_alloc.num_used == 0


# ------------------------------------------------ token-parity acceptance


@pytest.mark.parametrize("arch,mode,fused", [
    ("qwen1.5-0.5b", None, False),           # dense K/V, gather
    ("qwen1.5-0.5b", None, True),            # dense K/V, fused kernels
    ("qwen1.5-0.5b", "decomposed", False),   # T1 X pages, gather
    ("qwen1.5-0.5b", "decomposed", True),    # T1 X pages, fused
    ("mla-dense", None, False),              # MLA latent pages, gather
    ("mla-dense", None, True),               # MLA latent pages, fused
])
def test_speculative_greedy_parity(arch, mode, fused):
    """ACCEPTANCE: greedy output with speculation ON is bit-identical to
    OFF across the tier modes on both paged-attention paths — while
    verification actually runs (spec_steps > 0) and nothing leaks."""
    cfg, params = _mk(arch, mode)
    prompts = _loopy_prompts(cfg)
    on_t, on_s, eng = _serve(cfg, params, prompts, spec=3, fused=fused)
    off_t, off_s, _ = _serve(cfg, params, prompts, spec=0, fused=fused)
    assert eng.spec_on
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["spec_steps"] > 0
    assert on_s["dense_pages_leaked"] == 0
    assert off_s["spec_steps"] == 0 and not off_s["spec_on"]


def test_speculative_accepts_on_loopy_trace():
    """On the self-similar trace with a long budget, drafts are ACCEPTED
    (not merely scored): accepted tokens raise tokens-per-invocation above
    the 1/step decode bound for the same total stream."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _loopy_prompts(cfg, n=1, seed=2)
    on_t, on_s, _ = _serve(cfg, params, prompts, spec=4, max_new=24)
    off_t, off_s, _ = _serve(cfg, params, prompts, spec=0, max_new=24)
    np.testing.assert_array_equal(on_t[0], off_t[0])
    assert on_s["spec_accepted"] > 0
    assert on_s["decode_steps"] < off_s["decode_steps"]


def test_speculative_seeded_sampling_parity():
    """Seeded non-greedy streams are ALSO bit-identical on vs off: a
    committed token is always the request's own fold_in(seed, index) draw —
    speculation changes when tokens land, never which. At temperature 0.9
    the sampled continuations rarely recur, so any single workload may
    never draft; two workload seeds together always do."""
    cfg, params = _mk("qwen1.5-0.5b")
    total_spec = 0
    for wseed in (4, 5):
        prompts = _loopy_prompts(cfg, seed=wseed)
        sps = [SamplingParams(temperature=0.9, seed=10 + i, max_tokens=10)
               for i in range(len(prompts))]

        def run(spec):
            sv = ServingCfg(num_slots=3, page_size=4, num_pages=65,
                            max_blocks_per_slot=12, prefill_bucket=4,
                            prefill_chunk=4, spec_len=spec,
                            use_paged_kernels=False)
            eng = ContinuousServeEngine(cfg, params, serving=sv)
            res, stats = eng.serve(
                [ServeRequest(prompt=p, rid=i, sampling=sps[i])
                 for i, p in enumerate(prompts)],
                GenerationConfig(max_new_tokens=10))
            return {i: res[i]["tokens"] for i in res}, stats

        on_t, on_s = run(3)
        off_t, _ = run(0)
        for i in off_t:
            np.testing.assert_array_equal(on_t[i], off_t[i])
        assert on_s["dense_pages_leaked"] == 0
        total_spec += on_s["spec_steps"]
    assert total_spec > 0


def test_speculative_tiered_dense_arm_parity():
    """Tiered engine with dormant watermarks: tier-0 rows speculate, the
    streams match spec-off bit-exactly, and both arenas drain."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _loopy_prompts(cfg, seed=5)
    kw = dict(num_pages=65, escalated_pages=33, enable_escalation=True,
              low_watermark=0.0, critical_watermark=0.0, max_new=8)
    on_t, on_s, eng = _serve(cfg, params, prompts, spec=3, **kw)
    off_t, off_s, _ = _serve(cfg, params, prompts, spec=0, **kw)
    assert eng.tiered and eng.spec_on
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["spec_steps"] > 0 and on_s["escalations"] == 0
    assert on_s["dense_pages_leaked"] == 0
    assert on_s["cpq_pages_leaked"] == 0


def test_speculative_with_prefix_sharing_parity():
    """Speculation composes with prefix sharing + COW: shared-prefix
    admissions mount pages that drafts then alias; streams still match the
    both-off run bit-exactly and nothing leaks or dangles."""
    cfg, params = _mk("qwen1.5-0.5b")
    rng = np.random.default_rng(4)
    # a LOOPY shared system prefix: tiled motif, so prompt lookup fires
    sys_p = np.tile(rng.integers(1, cfg.vocab_size, size=4).astype(np.int32),
                    4)
    # 5 prompts over 3 slots: later admissions mount the indexed prefix
    prompts = [np.concatenate([sys_p,
                               rng.integers(1, cfg.vocab_size, size=t)
                               .astype(np.int32)]) for t in (5, 9, 3, 14, 7)]
    both_t, both_s, _ = _serve(cfg, params, prompts, spec=3,
                               share_prefix=True)
    off_t, off_s, _ = _serve(cfg, params, prompts, spec=0,
                             share_prefix=False)
    for i in off_t:
        np.testing.assert_array_equal(both_t[i], off_t[i])
    assert both_s["prefix_hits"] > 0 and both_s["spec_steps"] > 0
    assert both_s["dense_pages_leaked"] == 0


def test_preemption_replay_with_spec_is_exact():
    """A tiny arena forces recompute preemptions WHILE rows speculate:
    victims' drafts abort via the release hook, replays re-draw the same
    fold_in(seed, index) streams, and the final outputs equal the spec-off
    run bit-exactly."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _loopy_prompts(cfg, n=4, motif=4, reps=2, seed=7)
    kw = dict(num_slots=3, num_pages=14, max_blocks_per_slot=8, max_new=12)
    on_t, on_s, _ = _serve(cfg, params, prompts, spec=3, **kw)
    off_t, off_s, _ = _serve(cfg, params, prompts, spec=0, **kw)
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["preemptions"] > 0            # pressure actually bit
    assert on_s["spec_steps"] > 0
    assert on_s["dense_pages_leaked"] == 0
    assert off_s["dense_pages_leaked"] == 0


def test_migration_replay_with_spec_is_exact():
    """drain_request mid-stream from engine A and replay on engine B, BOTH
    speculating, seeded sampling: the reassembled stream equals an
    uninterrupted spec-OFF run — speculative state is fully tick-local
    (drafts never outlive a step), so migration needs no draft handoff."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompt = _loopy_prompts(cfg, n=1, motif=4, reps=4, seed=11)[0]
    sp = SamplingParams(temperature=0.3, seed=21, max_tokens=16)
    sv = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                    max_blocks_per_slot=12, prefill_bucket=4, prefill_chunk=4,
                    use_paged_kernels=False)

    def engine(spec):
        return ContinuousServeEngine(cfg, params, serving=dataclasses.replace(
            sv, spec_len=spec))

    ref = engine(0)
    res, _ = ref.serve([ServeRequest(prompt=prompt, rid=0, sampling=sp)],
                       GenerationConfig(max_new_tokens=16))
    want = res[0]["tokens"]

    a = engine(3)
    a.reset(GenerationConfig(max_new_tokens=16))
    a.add_request(ServeRequest(prompt=prompt, rid=0, sampling=sp))
    for _ in range(12):                       # decode (and speculate) a while
        a.step()
    assert a._st.sched.stats["spec_steps"] > 0
    req = a.drain_request(0)
    assert req is not None and 0 < req.num_generated < 16   # mid-stream
    assert a._st.sched.dense_alloc.num_used == 0

    b = engine(3)
    b.reset(GenerationConfig(max_new_tokens=16))
    b.add_request(req)
    while b.has_unfinished():
        b.step()
    np.testing.assert_array_equal(b.results()[0]["tokens"], want)
    assert b.stats()["dense_pages_leaked"] == 0


_MESH_SPEC_CODE = """
import dataclasses
import numpy as np
import jax
from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.scheduler import Request
from repro.launch.mesh import make_serve_mesh

cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]), dtype="float32")
params = M.init_params(cfg, jax.random.PRNGKey(0))
serving = ServingCfg(num_slots=2, page_size=4, num_pages=33,
                     max_blocks_per_slot=8, prefill_bucket=4, prefill_chunk=4,
                     use_paged_kernels=False)
rng = np.random.default_rng(0)
m = rng.integers(1, cfg.vocab_size, size=6).astype(np.int32)
prompts = [np.concatenate([np.tile(m, 3),
                           rng.integers(1, cfg.vocab_size, size=2)
                           .astype(np.int32)]) for _ in range(2)]
gen = GenerationConfig(max_new_tokens=10)

def serve(mesh, spec):
    sv = dataclasses.replace(serving, spec_len=spec)
    eng = ContinuousServeEngine(cfg, params, serving=sv, mesh=mesh)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=10)
            for i, p in enumerate(prompts)]
    return eng.serve(reqs, gen)

mesh = make_serve_mesh(1, 2)
r_off, _ = serve(None, 0)
r_on, s_on = serve(None, 3)
m_on, ms_on = serve(mesh, 3)
for rid in r_off:
    assert np.array_equal(r_off[rid]["tokens"], r_on[rid]["tokens"])
    assert np.array_equal(r_off[rid]["tokens"], m_on[rid]["tokens"]), (
        rid, r_off[rid]["tokens"], m_on[rid]["tokens"])
assert ms_on["spec_steps"] > 0 and ms_on["model_shards"] == 2
assert ms_on["dense_pages_leaked"] == 0
print("MESH-SPEC-OK", ms_on["spec_steps"])
"""


def test_sharded_speculative_greedy_parity():
    """mesh=(dp=1, model=2): speculative decoding under the model mesh is
    token-exact vs both the unsharded spec-on and the spec-off engine —
    the verify chunk routes through the same shard_map'd chunk attend."""
    out = run_with_devices(_MESH_SPEC_CODE, 2)
    assert "MESH-SPEC-OK" in out


# --------------------------------------------------- eligibility gating


def test_spec_opt_out_and_budget_gate():
    """Per-request SamplingParams(speculate=False) opts a row out; a
    1-token budget never drafts (nothing to accept). Outputs unchanged."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _loopy_prompts(cfg, n=2, seed=9)
    sv = ServingCfg(num_slots=2, page_size=4, num_pages=65,
                    max_blocks_per_slot=12, prefill_bucket=4,
                    prefill_chunk=4, spec_len=3)
    eng = ContinuousServeEngine(cfg, params, serving=sv)
    res, stats = eng.serve(
        [ServeRequest(prompt=prompts[0], rid=0,
                      sampling=SamplingParams(max_tokens=12,
                                              speculate=False)),
         ServeRequest(prompt=prompts[1], rid=1,
                      sampling=SamplingParams(max_tokens=1))],
        GenerationConfig(max_new_tokens=12))
    assert eng.spec_on and stats["spec_steps"] == 0
    assert len(res[0]["tokens"]) == 12 and len(res[1]["tokens"]) == 1


def test_spec_gated_off_for_side_state_tiers():
    """CPQ-mode pages read through per-slot side state: the engine gate
    keeps speculation off exactly like prefix sharing."""
    cfg, params = _mk("qwen1.5-0.5b", "cpq")
    prompts = _loopy_prompts(cfg, n=2, seed=1)
    toks, stats, eng = _serve(cfg, params, prompts, spec=3, max_new=6)
    assert not eng.spec_on
    assert stats["spec_steps"] == 0 and not stats["spec_on"]
    for i in toks:
        assert len(toks[i]) == 6


# ------------------------------------ defrag locality regression (ROADMAP 2)


def test_defrag_plan_orders_shared_pages_first():
    """Shared (refcount > 1) pages compact to the LOWEST physical ids —
    stably, keeping first-encounter order within each class — so the pages
    every sharer re-reads cluster in one hot region."""
    bt = np.full((3, 4), NULL_PAGE, np.int64)
    bt[0, :3] = [9, 4, 7]
    bt[1, :3] = [9, 4, 2]        # 9 and 4 are shared
    bt[2, :2] = [5, 7]           # 7 shared too
    perm, new_bt, free = defrag_plan(bt, 12, shared={9, 4, 7})
    # shared first in first-encounter order, then private
    assert list(perm[1:7]) == [9, 4, 7, 2, 5] + [p for p in range(12)
                                                 if p not in (0, 9, 4, 7, 2, 5)][:1]
    assert list(new_bt[0][:3]) == [1, 2, 3]
    assert list(new_bt[1][:3]) == [1, 2, 4]
    assert list(new_bt[2][:2]) == [5, 3]
    # without the hint the order is purely first-encounter
    perm0, _, _ = defrag_plan(bt, 12)
    assert list(perm0[1:6]) == [9, 4, 7, 2, 5]
    # free list unchanged by the partition (same page count)
    assert free == list(range(11, 5, -1))


def test_defrag_keeps_prefix_index_exact():
    """End-to-end compaction regression: retire-churn fragments a sharing
    scheduler, plan_defrag relabels with shared pages first, and the
    prefix index still resolves the template to EXACTLY the pages the
    surviving owner's block table maps (ids renamed, content keys
    untouched) — a follow-up admission keeps mounting them."""
    serving = ServingCfg(num_slots=3, page_size=2, num_pages=33,
                         max_blocks_per_slot=8, prefill_chunk=2,
                         share_prefix=True)
    sched = Scheduler(serving, share_prefix=True)
    template = np.arange(1, 9, dtype=np.int32)          # 4 full pages

    def admit(rid, prompt):
        r = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=2)
        sched.submit(r)
        sched.admit_next(now=0, step=0)
        while r.length < r.prefill_target:
            while sched.cow_plan(r) is not None:
                pass
            sched.note_chunk(r, serving.page_size)
            sched.register_prefix(r)
        sched.finish_prefill(r)
        return r

    # filler occupies the LOW physical ids; the shared template lands high
    x = admit(9, np.full(8, 30, np.int32))
    a = admit(0, np.concatenate([template, [10, 10]]))
    b = admit(1, np.concatenate([template, [11, 11]]))   # mounts a's prefix
    assert sched.stats["prefix_hits"] >= 1
    sched.retire(x, 0, "eos")                            # holes at the bottom
    perm = sched.plan_defrag()
    assert perm is not None
    _check_refcounts(sched, tiered=False)
    # shared pages (template, refs 2) now sit on the lowest ids
    shared_ids = sorted(p for p in range(1, serving.num_pages)
                        if sched.dense_alloc.refcount(p) > 1)
    private_ids = [p for p in range(1, serving.num_pages)
                   if sched.dense_alloc.refcount(p) == 1]
    assert shared_ids and max(shared_ids) < min(private_ids)
    # the index resolves the template to exactly the owner's mapped pages
    pages, shared_tokens = sched.prefix_index.match(
        np.concatenate([template, [1, 2]]))
    assert shared_tokens == len(template)
    assert pages == [int(p) for p in a.pages[:len(pages)]]
    assert pages == shared_ids[:len(pages)]
    # and a follow-up admission still mounts them (no stale ids anywhere)
    d = admit(3, np.concatenate([template, [12, 12]]))
    assert [int(p) for p in d.pages[:4]] == pages
    for r in list(sched.occupied()):
        sched.retire(r, 0, "eos")
    assert sched.dense_alloc.num_used == 0
    assert len(sched.prefix_index) == 0


def test_defrag_defers_while_draft_open():
    serving = ServingCfg(num_slots=2, page_size=2, num_pages=9,
                         max_blocks_per_slot=4, prefill_chunk=2, spec_len=2)
    sched = Scheduler(serving)

    def admit(rid, prompt):
        r = Request(rid=rid, prompt=np.asarray(prompt, np.int32),
                    max_new_tokens=4)
        sched.submit(r)
        sched.admit_next(now=0, step=0)
        sched.note_chunk(r, len(prompt))
        sched.finish_prefill(r)
        return r

    filler = admit(0, [5, 5, 5])              # pins the low physical ids
    r = admit(1, [1, 2, 3])
    sched.retire(filler, 0, "eos")            # holes below r's pages
    assert sched.begin_draft(r, 2) is not None
    assert sched.plan_defrag() is None        # scratch invisible to tables
    sched.abort_draft(r)
    assert sched.plan_defrag() is not None    # same arena compacts now
    sched.retire(r, 0, "eos")
    assert sched.dense_alloc.num_used == 0
