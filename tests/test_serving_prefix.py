"""Prefix sharing + refcounted copy-on-write pages (the PR-7 tentpole).

Covers: the refcount-ownership property suite — for ANY interleaving of
admit(shared) / chunk / decode-grow / COW / preempt / escalate /
de-escalate / retire / defrag, every page's refcount equals the number of
block-table entries referencing it and free-list membership <=> refcount 0
(hypothesis); the token-parity acceptance matrix — greedy AND seeded
sampling are bit-identical with sharing on vs off across dense / T1 / MLA /
tiered on both the gather and fused paged-kernel paths, including COW at a
mid-page divergence and preemption-replay while holding shared pages; the
double-free regression (DoubleFree RAISES — an ``assert`` vanishes under
``-O``); and the defrag relabeling guarantee (refcount multiset preserved,
free list == zero-refcount pages, index ids renamed)."""
import dataclasses

from _hypothesis_compat import hypothesis, st  # optional dep; see pyproject
import numpy as np
import pytest

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.configs.base import MLACfg, ModelConfig
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.paged_cache import NULL_PAGE, PageAllocator, defrag_plan
from repro.serving.prefix_index import PrefixIndex
from repro.serving.request import SamplingParams, ServeRequest
from repro.serving.scheduler import Request, Scheduler

# pure-MLA stack with dense MLPs (same rationale as test_serving_chunked:
# MoE drop patterns are group-dependent, so MLA parity runs on this stack)
MLA_DENSE = ModelConfig(
    name="mla-dense-test", family="dense", d_model=32, num_heads=4,
    num_kv_heads=4, head_dim=8, d_ff=64, vocab_size=256,
    block_pattern=(("mla", "dense"),), num_blocks=2,
    mla=MLACfg(kv_lora_rank=16, qk_nope_head_dim=8, qk_rope_head_dim=4,
               v_head_dim=8),
    dtype="float32")


def _mk(arch=None, mode=None):
    cfg = MLA_DENSE if arch == "mla-dense" else smoke_config(ARCHS[arch])
    cfg = dataclasses.replace(cfg, dtype="float32")
    if mode:
        cfg = cfg.with_attention(mode)
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _shared_prompts(cfg, tails=(5, 9, 3, 14, 7), prefix=24, seed=0):
    """Prompts opening with a common ``prefix``-token system prompt."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(1, cfg.vocab_size, size=prefix).astype(np.int32)
    return [np.concatenate([sys_p,
                            rng.integers(1, cfg.vocab_size, size=t)
                            .astype(np.int32)]) for t in tails]


def _serve(cfg, params, prompts, *, share, fused=False, max_new=6, **kw):
    base = dict(num_slots=3, page_size=4, num_pages=65,
                max_blocks_per_slot=12, prefill_bucket=4, prefill_chunk=4,
                share_prefix=share, use_paged_kernels=fused)
    base.update(kw)
    eng = ContinuousServeEngine(cfg, params, serving=ServingCfg(**base))
    res, stats = eng.serve(
        [Request(rid=i, prompt=p, max_new_tokens=max_new)
         for i, p in enumerate(prompts)],
        GenerationConfig(max_new_tokens=max_new))
    return {i: res[i]["tokens"] for i in res}, stats, eng


# ------------------------------------------------- double-free regression


def test_double_free_raises_not_asserts():
    """Releasing a page more often than it was referenced RAISES DoubleFree
    (the old ``assert`` vanishes under ``python -O`` and silently corrupts
    the free list: the page double-allocates as live KV later)."""
    alloc = PageAllocator(9)
    (p,) = alloc.alloc(1)
    assert alloc.free([p]) == [p]
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.free([p])
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.free([NULL_PAGE])
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.incref(p)              # unowned page cannot gain an owner
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.incref(NULL_PAGE)
    # the failed frees must not have touched the free list
    assert alloc.num_free == 8 and alloc.num_used == 0


def test_refcount_release_order():
    """A shared page leaves the free list once and returns once: only the
    LAST decref releases it, and ``free`` reports exactly that."""
    alloc = PageAllocator(5)
    (p,) = alloc.alloc(1)
    alloc.incref(p)
    alloc.incref(p)
    assert alloc.refcount(p) == 3
    assert alloc.free([p]) == []
    assert alloc.free([p]) == []
    assert p not in alloc._free
    assert alloc.free([p]) == [p]
    assert alloc.refcount(p) == 0 and p in alloc._free
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.free([p])


# ------------------------------------------------ defrag keeps refcounts


def test_relabel_preserves_refcount_multiset():
    """Defrag on a SHARED arena: the permutation carries each page's
    refcount to its new id (a shared page moves once, every owner's table
    entry is rewritten), and the rebuilt free list is exactly the zero-
    refcount pages. Dropping a count or mislabeling the free list raises."""
    alloc = PageAllocator(9)
    a, b, c = alloc.alloc(3)
    alloc.incref(b)                  # b is shared by two owners
    bt = np.full((2, 4), NULL_PAGE, np.int64)
    bt[0, :2] = [a, b]
    bt[1, :2] = [b, c]               # b appears in BOTH rows
    perm, new_bt, free = defrag_plan(bt, alloc.num_pages)
    before = sorted(alloc._refs)
    alloc.relabel(perm, free)
    assert sorted(alloc._refs) == before
    assert {p for p in range(1, 9) if alloc.refcount(p) == 0} == set(free)
    # b moved ONCE: the deduped plan maps 3 distinct used pages
    used = set(int(p) for p in new_bt.ravel()) - {NULL_PAGE}
    assert len(used) == 3
    with pytest.raises(PageAllocator.DoubleFree):
        alloc.relabel(list(range(9)), [])          # free list went missing
    bad = PageAllocator(5)
    bad.alloc(2)
    bad.incref(1)                    # refs: page1=2, page2=1
    with pytest.raises(PageAllocator.DoubleFree):
        # duplicates page 2's refcount and drops page 1's ({2,1} -> {1,1})
        bad.relabel([0, 2, 2, 3, 4], [3, 4])


def test_prefix_index_match_insert_forget():
    """Index unit semantics: full-page chain match capped at len(ctx)-1,
    ONE partial (mid-page) child continuation, watermark-honest insert
    (foreign dedup does NOT advance), forget-on-release self-healing, and
    relabel renaming physical ids under content-stable keys."""
    idx = PrefixIndex(page_size=4)
    ctx = np.arange(100, 112, dtype=np.int32)      # 3 full pages
    assert idx.insert(ctx, [5, 6, 7], 0, 3) == 3
    pages, shared = idx.match(np.concatenate([ctx, [1, 2]]))
    assert (pages, shared) == ([5, 6, 7], 12)
    # cap: an exact-context lookup must leave >= 1 token to prefill — the
    # last page is mounted via the PARTIAL continuation (11 of 12 tokens)
    pages, shared = idx.match(ctx)
    assert (pages, shared) == ([5, 6, 7], 11)
    # mid-page divergence: 2 full pages + 2 tokens into the third
    probe = np.concatenate([ctx[:10], [9, 9, 9]]).astype(np.int32)
    pages, shared = idx.match(probe)
    assert (pages, shared) == ([5, 6, 7], 10)
    # foreign dedup: a second owner of the same content does not advance
    assert idx.insert(ctx, [8, 9, 10], 0, 3) == 0
    # ... until the incumbent dies; then the retry heals the chain
    for p in (5, 6, 7):
        assert idx.forget(p)
    assert len(idx) == 0
    assert idx.insert(ctx, [8, 9, 10], 0, 3) == 3
    assert idx.match(np.concatenate([ctx, [1]]))[0] == [8, 9, 10]
    # relabel: physical renames, content keys untouched
    idx.relabel({8: 1, 9: 2, 10: 3})
    assert idx.match(np.concatenate([ctx, [1]]))[0] == [1, 2, 3]
    assert not idx.forget(77)                      # unknown page: no-op


# ---------------------------- refcount-ownership property suite (tentpole)


def _check_refcounts(sched: Scheduler, tiered: bool):
    """THE invariant: refcount(p) == number of block-table entries mapping
    p; free-list membership <=> refcount 0; the weak index never points at
    an unowned page; the CPQ arena stays exclusively owned."""
    alloc = sched.dense_alloc
    owners: dict[int, int] = {}
    for r in sched.occupied():
        if r.tier == 0:
            for p in r.pages:
                owners[int(p)] = owners.get(int(p), 0) + 1
    in_free = set(alloc._free)
    for p in range(1, alloc.num_pages):
        assert alloc.refcount(p) == owners.get(p, 0), f"page {p}"
        assert (alloc.refcount(p) == 0) == (p in in_free), f"page {p}"
    assert alloc.refcount(NULL_PAGE) == 0 and NULL_PAGE not in in_free
    for slot, r in enumerate(sched.slots):
        row = [int(p) for p in sched.block_tables[slot]]
        if r is None or r.tier != 0:
            assert set(row) == {NULL_PAGE}, "stale block-table row"
        else:
            n = len(r.pages)
            assert row[:n] == [int(p) for p in r.pages]
            assert set(row[n:]) <= {NULL_PAGE}
    if sched.prefix_index is not None:
        for p in sched.prefix_index.registered_pages():
            assert alloc.refcount(p) >= 1, f"index dangles on page {p}"
    if tiered:
        cpq_owned = [int(p) for r in sched.occupied() if r.tier == 1
                     for p in r.pages]
        assert len(set(cpq_owned)) == len(cpq_owned)
        for p in range(1, sched.cpq_alloc.num_pages):
            assert sched.cpq_alloc.refcount(p) == int(p in cpq_owned)


def _grow_one(sched, serving, r, rng, clock):
    """Engine-faithful decode growth for one running row: COW-guard the
    write target, map the next page, append the 'generated' token."""
    while True:
        try:
            if sched.cow_plan(r) is None:
                break
        except PageAllocator.OutOfPages:
            v = sched.preemption_victim(exclude=r)
            if v is None:
                sched.retire(r, clock, "oom")
                return
            sched.preempt(v)
    while not sched.ensure_writable(r):
        if r.length // serving.page_size >= serving.max_blocks_per_slot:
            sched.retire(r, clock, "length_cap")
            return
        v = sched.preemption_victim(exclude=r)
        if v is None:
            sched.retire(r, clock, "oom")
            return
        sched.preempt(v)
    r.generated.append(int(rng.integers(1, 7)))
    r.length += 1
    sched.lengths[r.slot] = r.length
    sched.register_prefix(r)


@hypothesis.given(seed=st.integers(0, 2 ** 31 - 1),
                  tiered=st.booleans(),
                  num_pages=st.integers(5, 17),
                  share=st.booleans())
@hypothesis.settings(max_examples=40, deadline=None)
def test_refcount_invariant_any_interleaving(seed, tiered, num_pages, share):
    """ACCEPTANCE: drive a Scheduler through a random interleaving of the
    FULL lifecycle vocabulary — admit (with prefix sharing live), chunk
    progress (+ eager registration), decode growth, COW splits, recompute
    preemption, escalation, de-escalation, retirement, defrag — drawing
    prompts from a tiny template pool so shared admissions actually happen,
    and assert the refcount-ownership invariant after EVERY op. At the end
    everything retires: both arenas drain to zero and the index empties."""
    rng = np.random.default_rng(seed)
    serving = ServingCfg(num_slots=3, page_size=2, num_pages=num_pages,
                         escalated_pages=9, max_blocks_per_slot=4,
                         low_watermark=0.5, critical_watermark=0.25,
                         high_watermark=0.6, enable_escalation=tiered,
                         prefill_chunk=2, share_prefix=share)
    sched = Scheduler(serving, tiered=tiered, share_prefix=share)
    # two prefix templates of 2 full pages each: collisions are the point
    # (template 4 + tail <= 2 + budget 2 == max_len 8)
    templates = [rng.integers(1, 7, 4).astype(np.int32) for _ in range(2)]
    next_rid = 0
    clock = 0
    for _ in range(80):
        op = rng.integers(0, 7)
        clock += 1
        if op == 0 and len(sched.queue) < 4:                 # submit
            t = templates[int(rng.integers(2))]
            keep = int(rng.integers(1, len(t) + 1))
            prompt = np.concatenate(
                [t[:keep], rng.integers(1, 7, rng.integers(1, 3))
                 .astype(np.int32)])
            sched.submit(Request(rid=next_rid, prompt=prompt,
                                 max_new_tokens=2))
            next_rid += 1
        elif op == 1:                                        # admit
            sched.admit_next(now=clock, step=clock)
        elif op == 2:                                        # chunk progress
            pre = sched.prefilling()
            if pre:
                r = pre[0]
                try:
                    while sched.cow_plan(r) is not None:
                        pass                                  # split applied
                except PageAllocator.OutOfPages:
                    sched.preempt(r)
                else:
                    sched.note_chunk(r, serving.page_size)
                    sched.register_prefix(r)
                    if r.length >= r.prefill_target:
                        sched.finish_prefill(r)
        elif op == 3:                                        # decode growth
            for r in list(sched.running()):
                if r.state == "running":
                    _grow_one(sched, serving, r, rng, clock)
        elif op == 4 and tiered:                             # escalate/recover
            cand = sched.escalation_candidate()
            if cand is not None:
                sched.apply_escalation(cand)
            elif (cand := sched.deescalation_candidate()) is not None:
                sched.deescalate(cand)
        elif op == 5:                                        # defrag
            sched.plan_defrag()
        else:                                                # retire/preempt
            occ = sched.occupied()
            if occ:
                victim = occ[int(rng.integers(len(occ)))]
                if rng.random() < 0.5:
                    sched.retire(victim, clock, "eos")
                else:
                    sched.preempt(victim)
        _check_refcounts(sched, tiered)
    for r in list(sched.occupied()):
        sched.retire(r, clock, "eos")
    _check_refcounts(sched, tiered)
    assert sched.dense_alloc.num_used == 0
    if sched.cpq_alloc is not None:
        assert sched.cpq_alloc.num_used == 0
    if sched.prefix_index is not None:
        assert len(sched.prefix_index) == 0


# ------------------------------------------------ token-parity acceptance


@pytest.mark.parametrize("arch,mode,fused", [
    ("qwen1.5-0.5b", None, False),           # dense K/V, gather
    ("qwen1.5-0.5b", None, True),            # dense K/V, fused kernels
    ("qwen1.5-0.5b", "decomposed", False),   # T1 X pages, gather
    ("qwen1.5-0.5b", "decomposed", True),    # T1 X pages, fused
    ("mla-dense", None, False),              # MLA latent pages, gather
    ("mla-dense", None, True),               # MLA latent pages, fused
])
def test_sharing_greedy_parity(arch, mode, fused):
    """ACCEPTANCE: greedy output with prefix sharing ON is bit-identical to
    OFF across the tier modes on both paged-attention paths — while sharing
    actually fires (hits > 0) and strictly reduces prefill arena writes."""
    cfg, params = _mk(arch, mode)
    prompts = _shared_prompts(cfg)
    on_t, on_s, eng = _serve(cfg, params, prompts, share=True, fused=fused)
    off_t, off_s, _ = _serve(cfg, params, prompts, share=False, fused=fused)
    assert eng.share_prefix
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["prefix_hits"] > 0
    assert on_s["shared_prefix_tokens"] > 0
    assert on_s["prefill_write_bytes"] < off_s["prefill_write_bytes"]
    assert on_s["dense_pages_leaked"] == 0
    assert off_s["prefix_hits"] == 0 and not off_s["prefix_sharing"]


def test_sharing_seeded_sampling_parity():
    """Seeded non-greedy sampling is ALSO bit-identical on vs off: sharing
    changes which physical pages serve a prefix, never the logits or the
    per-request sampling streams."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _shared_prompts(cfg, seed=3)
    sps = [SamplingParams(temperature=0.9, seed=10 + i, max_tokens=6)
           for i in range(len(prompts))]

    def run(share):
        sv = ServingCfg(num_slots=3, page_size=4, num_pages=65,
                        max_blocks_per_slot=12, prefill_bucket=4,
                        prefill_chunk=4, share_prefix=share,
                        use_paged_kernels=False)
        eng = ContinuousServeEngine(cfg, params, serving=sv)
        res, stats = eng.serve(
            [ServeRequest(prompt=p, rid=i, sampling=sps[i])
             for i, p in enumerate(prompts)],
            GenerationConfig(max_new_tokens=6))
        return {i: res[i]["tokens"] for i in res}, stats

    on_t, on_s = run(True)
    off_t, _ = run(False)
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["prefix_hits"] > 0 and on_s["dense_pages_leaked"] == 0


def test_cow_at_mid_page_divergence_is_exact():
    """A late arrival diverging MID-page mounts the divergence page shared
    and splits it on its first tail write (copy-on-write). The split is
    invisible token-wise: both requests match the sharing-off run."""
    cfg, params = _mk("qwen1.5-0.5b")
    rng = np.random.default_rng(1)
    sys_p = rng.integers(1, cfg.vocab_size, size=24).astype(np.int32)
    pa = np.concatenate([sys_p,
                         rng.integers(1, cfg.vocab_size, size=8)
                         .astype(np.int32)])
    pb = np.concatenate([sys_p[:22],
                         rng.integers(1, cfg.vocab_size, size=6)
                         .astype(np.int32)])  # diverges 2 tokens into page 6

    def run(share):
        sv = ServingCfg(num_slots=2, page_size=4, num_pages=65,
                        max_blocks_per_slot=12, prefill_bucket=4,
                        prefill_chunk=4, share_prefix=share,
                        use_paged_kernels=False)
        eng = ContinuousServeEngine(cfg, params, serving=sv)
        eng.reset(GenerationConfig(max_new_tokens=16))
        eng.add_request(Request(rid=0, prompt=pa, max_new_tokens=16))
        for _ in range(12):     # A's 8 prompt pages land and register
            eng.step()
        eng.add_request(Request(rid=1, prompt=pb, max_new_tokens=8))
        while eng.has_unfinished():
            eng.step()
        toks = {r: np.asarray(v["tokens"])
                for r, v in eng._st.results.items()}
        return toks, eng.stats()

    on_t, on_s = run(True)
    off_t, off_s = run(False)
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["cow_copies"] >= 1            # the mid-page split happened
    assert on_s["shared_prefix_tokens"] == 22  # 5 full pages + 2 mid-page
    assert on_s["dense_pages_leaked"] == 0
    assert off_s["cow_copies"] == 0


def test_preemption_replay_with_shared_pages_is_exact():
    """A tiny arena forces recompute preemptions WHILE rows hold shared
    pages: victims decref (never free-under-sharer), replays re-match the
    index, and the final streams still equal the sharing-off run."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _shared_prompts(cfg, tails=(4, 6, 2, 5), prefix=12, seed=7)
    kw = dict(num_slots=3, num_pages=14, max_blocks_per_slot=8, max_new=12)
    on_t, on_s, _ = _serve(cfg, params, prompts, share=True, **kw)
    off_t, off_s, _ = _serve(cfg, params, prompts, share=False, **kw)
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["preemptions"] > 0            # pressure actually bit
    assert on_s["prefix_hits"] > 0
    assert on_s["dense_pages_leaked"] == 0
    assert off_s["dense_pages_leaked"] == 0


def test_tiered_sharing_dense_arm_only_is_exact():
    """Tiered engine: the dense arm shares (CPQ pages read through per-slot
    side state and never do). Part 1 pins the watermarks to zero so
    escalation stays dormant: greedy streams must be bit-identical sharing
    on vs off. Part 2 turns pressure back on: escalation re-encodes a row
    lossily at whatever length it reached, and sharing CHANGES the pressure
    schedule — so the exactness oracle there is fused-vs-gather at the SAME
    sharing config, plus leak-free arenas."""
    cfg, params = _mk("qwen1.5-0.5b")
    prompts = _shared_prompts(cfg, tails=(8, 10, 6, 7), prefix=12, seed=5)
    kw = dict(num_pages=33, escalated_pages=33, enable_escalation=True,
              low_watermark=0.0, critical_watermark=0.0,
              max_blocks_per_slot=8, max_new=8)
    on_t, on_s, eng = _serve(cfg, params, prompts, share=True, **kw)
    off_t, off_s, _ = _serve(cfg, params, prompts, share=False, **kw)
    assert eng.tiered and eng.share_prefix
    for i in off_t:
        np.testing.assert_array_equal(on_t[i], off_t[i])
    assert on_s["prefix_hits"] > 0 and on_s["escalations"] == 0
    assert on_s["dense_pages_leaked"] == 0
    assert on_s["cpq_pages_leaked"] == 0
    # part 2: escalation under pressure composes with sharing
    kw2 = dict(num_pages=13, escalated_pages=33, enable_escalation=True,
               low_watermark=0.5, critical_watermark=0.25,
               max_blocks_per_slot=8, max_new=8)
    g_t, g_s, _ = _serve(cfg, params, prompts, share=True, **kw2)
    f_t, f_s, _ = _serve(cfg, params, prompts, share=True, fused=True, **kw2)
    for i in g_t:
        np.testing.assert_array_equal(g_t[i], f_t[i])
    assert g_s["escalations"] > 0 and f_s["escalations"] > 0
    assert g_s["dense_pages_leaked"] == 0
    assert g_s["cpq_pages_leaked"] == 0


def test_escalation_skips_rows_at_the_block_ceiling():
    """Regression (found by the interleaving suite): a running row at
    exactly ``max_len`` needs max_blocks+1 compressed blocks — volunteering
    it overflowed the alt block-table row. It must be skipped (it is one
    growth step from the length-cap retire); shorter rows still escalate."""
    serving = ServingCfg(num_slots=2, page_size=2, num_pages=9,
                         escalated_pages=17, max_blocks_per_slot=4,
                         low_watermark=1.0, critical_watermark=1.0,
                         enable_escalation=True)
    sched = Scheduler(serving, tiered=True)
    r = Request(rid=0, prompt=(np.arange(6, dtype=np.int32) % 5) + 1,
                max_new_tokens=2)
    sched.submit(r)
    sched.admit_next(now=0, step=0)
    sched.note_chunk(r, 6)
    sched.finish_prefill(r)
    while r.length < serving.max_len:
        assert sched.ensure_writable(r)
        r.generated.append(1)
        r.length += 1
        sched.lengths[r.slot] = r.length
    assert sched.escalation_candidate() is None   # at the ceiling: skip
    r.length -= 1                                  # one block of headroom
    sched.lengths[r.slot] = r.length
    assert sched.escalation_candidate() is r
    sched.apply_escalation(r)                      # and it lands cleanly
    assert r.tier == 1
    sched.retire(r, 1, "eos")
    assert sched.dense_alloc.num_used == 0
    assert sched.cpq_alloc.num_used == 0


def test_cpq_and_retrieval_modes_never_share():
    """Sharing is gated OFF for side-state tiers: a CPQ engine with
    share_prefix=True must not build an index (its pages are only readable
    through per-request HQE state — sharing them would break parity)."""
    cfg, params = _mk("qwen1.5-0.5b", "cpq")
    prompts = _shared_prompts(cfg, tails=(5, 3), prefix=8, seed=2)
    toks, stats, eng = _serve(cfg, params, prompts, share=True)
    assert not eng.share_prefix
    assert stats["prefix_hits"] == 0 and not stats["prefix_sharing"]
    for i in toks:
        assert len(toks[i]) == 6
