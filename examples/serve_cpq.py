"""Serve a small model with batched requests under T2 CPQ cache compression,
and print the paper's traffic story: bytes/token per cache mode.

  PYTHONPATH=src python examples/serve_cpq.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.configs.base import CPQCfg
from repro.core.cpq import cpq_bytes_per_token, dense_bytes_per_token
from repro.models import model as M
from repro.serving import GenerationConfig, ServeEngine


def main():
    cfg = smoke_config(ARCHS["qwen3-4b"])
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (4, 48), 0, cfg.vocab_size)}

    full = ARCHS["qwen3-4b"]
    dense_b = 2 * dense_bytes_per_token(full.num_kv_heads, full.head_dim)  # K+V
    print("qwen3-4b decode cache traffic per token per layer (K+V):")
    print(f"  dense bf16      : {dense_b:8.1f} B")
    for bits in (8, 4):
        for prune in (0.0, 0.4):
            b = cpq_bytes_per_token(CPQCfg(prune_ratio=prune, bits=bits),
                                    full.num_kv_heads, full.head_dim) * 2
            print(f"  CPQ {bits}b prune={prune:.1f}: {b:8.1f} B "
                  f"({dense_b / b:.1f}x smaller)")

    for mode in ("dense", "cpq"):
        eng = ServeEngine(cfg.with_attention(mode), params, max_len=96)
        out, stats = eng.generate(batch, GenerationConfig(max_new_tokens=12,
                                                          temperature=0.7, seed=1))
        print(f"[serve_cpq] mode={mode}: generated {out.shape}, "
              f"first row {out[0][:8].tolist()}")


if __name__ == "__main__":
    main()
