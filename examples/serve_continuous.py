"""Continuous-batching serving demo: mixed-length Poisson traffic through the
paged-arena engine, next to the static batch engine, plus the watermark
tier-escalation path under a deliberately tiny dense arena.

  PYTHONPATH=src python examples/serve_continuous.py
"""
import numpy as np

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving import ContinuousServeEngine, GenerationConfig, Request
from repro.serving.paged_cache import pages_needed


def main():
    cfg = smoke_config(ARCHS["qwen3-4b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    # mixed prompts + heavy-tailed targets, Poisson arrivals (decode-step units)
    reqs, t = [], 0.0
    for i in range(10):
        t += rng.exponential(2.0)
        tgt = int(rng.integers(24, 48)) if rng.random() < 0.3 else int(rng.integers(3, 10))
        reqs.append(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                size=int(rng.integers(4, 20))).astype(np.int32),
            max_new_tokens=tgt, arrival=t))

    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs)
    serving = ServingCfg(num_slots=4, page_size=8,
                         num_pages=4 * pages_needed(max_len, 8) + 1,
                         max_blocks_per_slot=pages_needed(max_len, 8),
                         prefill_bucket=8)
    eng = ContinuousServeEngine(cfg, params, serving=serving)
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=max_len))

    print(f"[continuous] {stats['generated_tokens']} tokens in "
          f"{stats['decode_steps']} decode steps "
          f"({stats['tokens_per_step']:.2f} tok/step, "
          f"slot util {stats['slot_utilization']:.2f}, "
          f"arena util mean {stats['arena_utilization_mean']:.2f})")
    print(f"[continuous] chunked prefill: {stats['prefill_chunks']} chunks "
          f"streamed into arena pages ({stats['prefill_tokens']} prompt "
          f"tokens, {stats['prefill_write_bytes'] / 1e3:.1f} KB arena writes)")
    for i in sorted(res):
        r = res[i]
        print(f"  req {i}: arrival {r['arrival']:5.1f} admitted {r['admitted_step']:3d} "
              f"done {r['done_step']:3d} ({len(r['tokens'])} tokens, "
              f"{r['finish_reason']})")

    # memory-pressure story: tiny dense arena + CPQ escalation arena
    pressured = ServingCfg(num_slots=4, page_size=8, num_pages=17,
                           escalated_pages=65, max_blocks_per_slot=8,
                           low_watermark=0.5, critical_watermark=0.25,
                           enable_escalation=True, prefill_bucket=8)
    eng2 = ContinuousServeEngine(cfg, params, serving=pressured)
    reqs2 = [Request(rid=100 + i,
                     prompt=rng.integers(0, cfg.vocab_size, size=12).astype(np.int32),
                     max_new_tokens=16) for i in range(6)]
    res2, stats2 = eng2.serve(reqs2, GenerationConfig(max_new_tokens=16))
    print(f"[escalation] escalations={stats2['escalations']} "
          f"preemptions={stats2['preemptions']} "
          f"(dense arena {pressured.num_pages - 1} pages, "
          f"CPQ arena {pressured.escalated_pages - 1} pages)")
    esc = [i for i in res2 if res2[i]["escalated"]]
    print(f"  escalated requests {esc} still finished: "
          f"{[res2[i]['finish_reason'] for i in esc]}")


if __name__ == "__main__":
    main()
