"""Quickstart: build a tiny model, train a few steps, generate with every
paper technique (T1 decomposed X-cache, T2 CPQ, T3 retrieval).

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import ARCHS, smoke_config
from repro.configs.base import ShapeCfg
from repro.data import DataConfig, SyntheticLMData
from repro.models import model as M
from repro.optim import adamw
from repro.serving import GenerationConfig, ServeEngine
from repro.train.step import TrainStepCfg, make_train_step


def main():
    # the paper-representative arch (MHA -> T1 halves decode cache traffic)
    cfg = smoke_config(ARCHS["musicgen-large"])
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)

    # --- train a few steps on the synthetic stream
    shape = ShapeCfg("quick", 64, 4, "train")
    data = SyntheticLMData(cfg, shape, DataConfig(seed=0))
    opt = adamw(1e-3)
    step = jax.jit(make_train_step(cfg, opt, TrainStepCfg()), donate_argnums=(0, 1))
    opt_state = opt.init(params)
    for i in range(10):
        batch = jax.tree.map(jnp.asarray, data.batch(i))
        params, opt_state, metrics = step(params, opt_state,
                                          jnp.asarray(i, jnp.int32), batch)
    print(f"[quickstart] loss after 10 steps: {float(metrics['loss']):.3f}")

    # --- generate under each attention mode
    prompt = {"frames": jnp.asarray(data.batch(99)["frames"][:, :32])}
    for mode in ("dense", "decomposed", "cpq", "retrieval"):
        eng = ServeEngine(cfg.with_attention(mode), params, max_len=64)
        out, stats = eng.generate(prompt, GenerationConfig(max_new_tokens=8))
        print(f"[quickstart] mode={mode:10s} tokens={out[0].tolist()}")


if __name__ == "__main__":
    main()
