"""T3 long-context decode: attention as nearest-neighbor retrieval.

Builds a multi-thousand-token cache on a small model and decodes with the
proxy->top-k->re-score pipeline, comparing outputs and traffic vs dense.

  PYTHONPATH=src python examples/longcontext_retrieval.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke_config
from repro.configs.base import AttentionRuntime, RetrievalCfg
from repro.models import model as M

N_CTX = 4096


def main():
    cfg = dataclasses.replace(smoke_config(ARCHS["qwen1.5-0.5b"]),
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (1, N_CTX), 0, cfg.vocab_size)

    outs = {}
    for mode, rt in {
        "dense": AttentionRuntime("dense"),
        "retrieval": AttentionRuntime(
            "retrieval", retrieval=RetrievalCfg(top_k=256, recent_window=64)),
    }.items():
        c = dataclasses.replace(cfg, attention=rt)
        caches = M.init_caches(c, rt, 1, N_CTX + 8)
        t0 = time.time()
        lg, caches = jax.jit(lambda p, b, ch: M.prefill(c, rt, p, b, ch))(
            params, {"tokens": toks}, caches)
        tok = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
        lg2, _ = jax.jit(lambda p, t, pos, ch: M.decode_step(c, rt, p, t, pos, ch))(
            params, tok, jnp.asarray(N_CTX, jnp.int32), caches)
        outs[mode] = np.asarray(lg2)
        print(f"[longctx] mode={mode:9s} decode logit top5 "
              f"{np.argsort(-outs[mode][0])[:5].tolist()}  ({time.time()-t0:.1f}s)")

    top5_d = set(np.argsort(-outs["dense"][0])[:5].tolist())
    top5_r = set(np.argsort(-outs["retrieval"][0])[:5].tolist())
    kv_b = 2 * cfg.num_kv_heads * cfg.head_dim * 2
    pr_b = cfg.num_kv_heads * cfg.head_dim
    k_sel = 256 / N_CTX
    # NOTE: at RANDOM init attention is diffuse (top-256 of 4096 holds only a
    # small softmax-mass fraction), so exact agreement is not expected — on
    # trained models attention is peaked and T3 recovers dense outputs (see
    # tests/test_core_retrieval.py and benchmarks/bench_retrieval.py).
    print(f"[longctx] top-5 overlap (random-init model): {len(top5_d & top5_r)}/5")
    print(f"[longctx] similarity+V traffic: dense {N_CTX * kv_b / 1e6:.2f} MB/layer "
          f"-> retrieval {(N_CTX * pr_b + 256 * kv_b) / 1e6:.2f} MB/layer "
          f"(top-k fraction {k_sel:.3f})")


if __name__ == "__main__":
    main()
