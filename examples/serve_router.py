"""Multi-replica router demo: two data-parallel engine replicas behind
ReplicaRouter — SLO-aware placement of mixed INTERACTIVE/BATCH traffic,
session affinity pinning a multi-turn conversation to its replica, and a
mid-run drain that migrates in-flight requests to the surviving replica
with token-for-token replay.

  PYTHONPATH=src python examples/serve_router.py
"""
import numpy as np

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving import (BATCH, INTERACTIVE, ReplicaRouter, SamplingParams,
                           ServeRequest)
from repro.serving.paged_cache import pages_needed


def main():
    cfg = smoke_config(ARCHS["qwen3-4b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = 64
    serving = ServingCfg(num_slots=2, page_size=8,
                         num_pages=2 * pages_needed(max_len, 8) + 1,
                         max_blocks_per_slot=pages_needed(max_len, 8),
                         prefill_bucket=8, prefill_chunk=8)

    # two replicas, each with its own scheduler + arenas; replica 0 compiles
    # the step functions, replica 1 adopts them
    router = ReplicaRouter(cfg, params, num_replicas=2, serving=serving,
                           placement="slo")
    router.reset()

    # ---- mixed traffic: slo placement splits the classes -----------------
    rids = {}
    for i in range(3):  # batch jobs balance by outstanding tokens
        rids[f"batch{i}"] = router.add_request(ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, 12),
            sampling=SamplingParams(max_tokens=16), slo=BATCH))
    for i in range(2):  # interactive goes to the freest arena
        rids[f"chat{i}"] = router.add_request(ServeRequest(
            prompt=rng.integers(0, cfg.vocab_size, 6),
            sampling=SamplingParams(temperature=0.8, top_k=40, seed=11 + i,
                                    max_tokens=6),
            slo=INTERACTIVE, session_id=f"user{i}"))
    for name, rid in rids.items():
        print(f"[place] {name:7s} rid={rid} -> replica "
              f"{router.replica_of(rid)}")

    # ---- session affinity: the follow-up turn lands on the same replica --
    follow = router.add_request(ServeRequest(
        prompt=rng.integers(0, cfg.vocab_size, 6),
        sampling=SamplingParams(temperature=0.8, top_k=40, seed=99,
                                max_tokens=6),
        slo=INTERACTIVE, session_id="user0"))
    print(f"[affinity] user0 follow-up rid={follow} -> replica "
          f"{router.replica_of(follow)} (same as rid={rids['chat0']})")

    # ---- run a few lockstep ticks, then drain replica 0 mid-flight -------
    for _ in range(4):
        router.step()
    victim = 0
    moved = router.drain(victim)
    print(f"[drain] replica {victim} drained mid-run: {moved} in-flight "
          f"requests migrated (recompute replay; seeded streams reproduce "
          f"token-for-token), sessions remapped")

    while router.has_unfinished():
        router.step()

    res = router.results()
    stats = router.stats()
    print(f"[done] {len(res)}/{len(rids) + 1} requests finished; aggregate "
          f"{stats['tokens_per_step']:.2f} tok/step over "
          f"{stats['decode_steps_max']} lockstep ticks; "
          f"migrated={stats['migrated_requests']}, "
          f"leaked_pages={stats['dense_pages_leaked']}")
    for p in stats["per_replica"]:
        tag = " (drained)" if p["draining"] else ""
        print(f"  replica {p['replica']}{tag}: "
              f"{p['generated_tokens'] or 0} tokens @ "
              f"{(p['tokens_per_step'] or 0):.2f}/step")
    print(f"[check] user0 turns ran on one replica, outputs exactly once, "
          f"chat0 tokens: {res[rids['chat0']]['tokens'].tolist()}")


if __name__ == "__main__":
    main()
