"""End-to-end driver (deliverable b): train a ~140M-parameter dense decoder
for a few hundred steps on the synthetic pipeline, with checkpointing and
resume. Loss drops well below the unigram entropy — full substrate exercised
(data -> scan-of-blocks model -> flash attention -> remat -> adamw ->
async checkpoints).

  PYTHONPATH=src python examples/train_100m.py            # ~300 steps
  PYTHONPATH=src python examples/train_100m.py --steps 50 # quicker check
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig


def model_100m() -> ModelConfig:
    return ModelConfig(
        name="repro-140m",
        family="dense",
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=32768,
        block_pattern=(("attn", "dense"),),
        num_blocks=12,
        mlp_act="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_140m_ckpt")
    args = ap.parse_args()

    import repro.configs as C
    from repro.launch import train as T

    cfg = model_100m()
    from repro.common.param import count_params
    from repro.models.model import model_defs
    n = count_params(model_defs(cfg))
    print(f"[train_100m] params: {n/1e6:.1f}M")

    # register so launch.train can find it
    C.ARCHS[cfg.name] = cfg
    T.main(["--arch", cfg.name, "--steps", str(args.steps),
            "--batch", str(args.batch), "--seq", str(args.seq),
            "--lr", "3e-3", "--warmup", "30", "--log-every", "20",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100"])


if __name__ == "__main__":
    main()
