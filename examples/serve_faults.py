"""Fault-tolerant serving demo: a deterministic crash window on one of two
router replicas — the HealthMonitor counts the step() faults, auto-drains
the replica (its in-flight requests migrate by recompute replay), probes it
on exponential backoff, and re-admits it once the window passes. Token
streams are bit-identical to a fault-free run of the same trace. A second
pass shows deadline-aware shedding: with ``deadline_scale`` set, a request
whose SLO-derived tick budget blows finishes with reason ``timeout``
instead of occupying a slot forever.

  PYTHONPATH=src python examples/serve_faults.py
"""
import numpy as np

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving import (BATCH, INTERACTIVE, FaultEvent, FaultPlan,
                           ReplicaRouter, SamplingParams, ServeRequest)
from repro.serving.paged_cache import pages_needed


def make_serving(**kw):
    max_len = 48
    return ServingCfg(num_slots=2, page_size=8,
                      num_pages=2 * pages_needed(max_len, 8) + 1,
                      max_blocks_per_slot=pages_needed(max_len, 8),
                      prefill_bucket=8, prefill_chunk=8, **kw)


def trace(rng, n=5):
    return [ServeRequest(
        rid=i, prompt=rng.integers(1, 1000, size=int(rng.integers(4, 10))),
        sampling=(SamplingParams(temperature=0.8, top_k=20, seed=7 + i,
                                 max_tokens=8) if i % 2
                  else SamplingParams(max_tokens=8)),
        slo=INTERACTIVE if i % 2 else BATCH) for i in range(n)]


def run(router, reqs):
    router.reset()
    for r in reqs:
        router.add_request(r)
    ticks = 0
    while router.has_unfinished():
        router.step()
        ticks += 1
    return router.results(), router.stats(), ticks


def main():
    cfg = smoke_config(ARCHS["qwen1.5-0.5b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    # ---- fault-free reference -------------------------------------------
    serving = make_serving(probe_interval=2, probe_failures=2,
                           probe_backoff=2, auto_drain=True)
    router = ReplicaRouter(cfg, params, num_replicas=2, serving=serving,
                           placement="load")
    ref, _, ref_ticks = run(router, trace(np.random.default_rng(0)))
    print(f"[ref] fault-free: {len(ref)} requests in {ref_ticks} ticks")

    # ---- same trace, crash window on replica 0 --------------------------
    # two step() faults in a row hit probe_failures=2: the monitor drains
    # replica 0 (snapshots migrate to replica 1), probes it on backoff, and
    # re-admits it once the window closes
    plan = FaultPlan((FaultEvent(tick=3, kind="crash", duration=4),))
    faulty = ReplicaRouter(cfg, params, num_replicas=2, serving=serving,
                           placement="load", fault_plans=[plan, None])
    for eng in faulty.engines:
        eng.adopt_compiled(router.engines[0])
    res, stats, ticks = run(faulty, trace(np.random.default_rng(0)))
    print(f"[crash] replica 0 down ticks [3,7): auto_drains="
          f"{stats['auto_drains']} recoveries={stats['recoveries']} "
          f"migrated={stats['migrated_requests']} "
          f"(+{ticks - ref_ticks} ticks vs fault-free)")
    for p in stats["per_replica"]:
        print(f"  replica {p['replica']}: health={p['health']} "
              f"probe_failures={p['probe_failures']}")
    match = all(list(res[r]["tokens"]) == list(ref[r]["tokens"]) for r in ref)
    print(f"[parity] greedy AND seeded streams bit-identical across the "
          f"crash: {match}")
    assert match and stats["dense_pages_leaked"] == 0

    # ---- deadline-aware shedding ----------------------------------------
    # scale * (ttft_target + max_tokens * itl_target) ticks of budget; the
    # INTERACTIVE class's tight targets blow first and finish as 'timeout'
    tight = ReplicaRouter(cfg, params, num_replicas=2,
                          serving=make_serving(deadline_scale=0.25),
                          placement="load")
    for eng in tight.engines:
        eng.adopt_compiled(router.engines[0])
    res, stats, _ = run(tight, trace(np.random.default_rng(0)))
    reasons = {r: res[r]["finish_reason"] for r in sorted(res)}
    print(f"[deadlines] scale=0.25 finish reasons: {reasons} "
          f"(timeouts={stats['timeouts']})")


if __name__ == "__main__":
    main()
