"""Request-centric serving demo: per-request sampling + SLO classes streamed
through the add_request()/step() interface, then the three scheduler
policies (fifo / priority / slo) side by side on the same contended trace.

  PYTHONPATH=src python examples/serve_requests.py
"""
import numpy as np

import jax

from repro.configs import ARCHS, ServingCfg, smoke_config
from repro.models import model as M
from repro.serving import (BATCH, INTERACTIVE, ContinuousServeEngine,
                           SamplingParams, ServeRequest, make_policy)
from repro.serving.paged_cache import pages_needed


def main():
    cfg = smoke_config(ARCHS["qwen3-4b"])
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    max_len = 64
    serving = ServingCfg(num_slots=2, page_size=8,
                         num_pages=2 * pages_needed(max_len, 8) + 1,
                         max_blocks_per_slot=pages_needed(max_len, 8),
                         prefill_bucket=8, prefill_chunk=8)
    eng = ContinuousServeEngine(cfg, params, serving=serving)

    # ---- streaming: tokens arrive per engine tick, not at the end --------
    eng.reset()
    eng.add_request(
        ServeRequest(prompt=rng.integers(0, cfg.vocab_size, 12),
                     sampling=SamplingParams(max_tokens=8)),     # greedy
        stream=lambda out: print(f"  [stream] rid={out.rid} "
                                 f"token[{out.index}]={out.token} "
                                 f"@tick {out.step}"
                                 + (f" <{out.finish_reason}>"
                                    if out.finished else "")))
    sampled_prompt = rng.integers(0, cfg.vocab_size, 9)
    eng.add_request(  # sampled row: private seeded stream, nucleus-filtered
        ServeRequest(prompt=sampled_prompt,
                     sampling=SamplingParams(temperature=0.8, top_k=50,
                                             top_p=0.95, seed=7,
                                             max_tokens=8)))
    print("[stream] greedy rid=0 streams while sampled rid=1 decodes "
          "alongside:")
    while eng.has_unfinished():
        eng.step()
    res = eng.results()
    print(f"[stream] sampled row tokens: {res[1]['tokens'].tolist()}")

    # ---- stop tokens retire like EOS (pages freed, slot refilled) --------
    probe = int(res[1]["tokens"][2])
    eng.reset()
    rid = eng.add_request(ServeRequest(  # same prompt + seed => same stream
        prompt=sampled_prompt,
        sampling=SamplingParams(temperature=0.8, top_k=50, top_p=0.95,
                                seed=7, max_tokens=8,
                                stop_token_ids=(probe,))))
    while eng.has_unfinished():
        eng.step()
    r = eng.results()[rid]
    print(f"[stop] stop_token_ids=({probe},): finished "
          f"'{r['finish_reason']}' after {len(r['tokens'])} tokens, "
          f"{eng.stats()['dense_pages_leaked']} pages leaked")

    # ---- policies on a contended trace: batch jobs ahead of interactive --
    def trace():
        reqs = [ServeRequest(prompt=rng2.integers(0, cfg.vocab_size, 10),
                             sampling=SamplingParams(max_tokens=24),
                             slo=BATCH, rid=i) for i in range(4)]
        reqs += [ServeRequest(prompt=rng2.integers(0, cfg.vocab_size, 6),
                              sampling=SamplingParams(max_tokens=4),
                              slo=INTERACTIVE, arrival=2.0, rid=100 + i)
                 for i in range(2)]
        return reqs

    print("[policy] 4 batch jobs then 2 interactive arrivals, 2 slots:")
    for name in ("fifo", "priority", "slo"):
        rng2 = np.random.default_rng(1)
        eng_p = ContinuousServeEngine(cfg, params, serving=serving,
                                      policy=make_policy(name))
        eng_p.reset()
        for req in trace():
            eng_p.add_request(req)
        while eng_p.has_unfinished():
            eng_p.step()
        res = eng_p.results()
        hi = [res[i]["first_token_step"] - res[i]["arrival"]
              for i in res if res[i]["slo"] == "interactive"]
        ok = sum(t <= INTERACTIVE.ttft_target for t in hi)
        print(f"  {name:8s} interactive TTFT={sorted(hi)} ticks "
              f"(target {INTERACTIVE.ttft_target:.0f}: {ok}/{len(hi)} met)")


if __name__ == "__main__":
    main()
