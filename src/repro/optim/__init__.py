from repro.optim.optimizers import Optimizer, adamw, adafactor, apply_updates  # noqa: F401
from repro.optim.schedule import cosine_schedule  # noqa: F401
from repro.optim.compression import compress_int8, decompress_int8, compressed_psum  # noqa: F401
