"""Gradient compression for slow (cross-pod) links: int8 quantized all-reduce
with error feedback (EF-SGD style). Used by the multi-pod training path where
the ``pod`` axis rides DCN-class links — compressing the cross-pod gradient
all-reduce 4x is the classic distributed-optimization trick the brief asks
for. Residual quantization error is carried in an f32 error-feedback buffer
so compression introduces no bias over time.

``compressed_psum`` must run under ``shard_map`` (it uses lax.psum on int32
accumulators of the int8 codes — exact, since values fit well inside int32).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (codes, scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return codes, scale


def decompress_int8(codes: jax.Array, scale: jax.Array) -> jax.Array:
    return codes.astype(jnp.float32) * scale


def compressed_psum(x: jax.Array, err: jax.Array, axis_name: str):
    """EF-compressed mean over ``axis_name``.

    x: local f32 gradient shard; err: error-feedback buffer (same shape).
    Returns (mean_estimate f32, new_err). Exact int32 summation of int8 codes;
    scales are reconciled with a max-scale psum so all shards decode
    identically.
    """
    xf = x.astype(jnp.float32) + err
    amax = jnp.max(jnp.abs(xf))
    gmax = jax.lax.pmax(amax, axis_name)  # shared scale -> identical decode
    scale = jnp.maximum(gmax / 127.0, 1e-12)
    codes = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    new_err = xf - codes.astype(jnp.float32) * scale
    total = jax.lax.psum(codes.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * scale / n.astype(jnp.float32)
    return mean, new_err
