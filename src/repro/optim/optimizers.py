"""Optimizers (no optax in this environment — built from scratch).

* ``adamw``     — f32 moments; standard for <=20B models.
* ``adafactor`` — factored second moment for >=2D params + bf16 first moment:
  ~2.1 bytes/param of state instead of 8, which is what lets jamba-398b train
  on a single 256-chip pod (see DESIGN.md §4 memory budget).

All state tensors inherit the parameter's sharding (spec trees mirror the
param tree), so FSDP shards optimizer state for free (ZeRO-3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jax.Array], tuple[Any, Any]]
    # mirror of init for ShapeDtypeStructs
    state_like: Callable[[Any], Any]
    # (param_specs, abstract_params) -> state PartitionSpec tree
    state_specs: Callable[[Any, Any], Any]


def _global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = _global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
                        params, updates)


# ------------------------------------------------------------------- adamw


class AdamWState(NamedTuple):
    m: Any
    v: Any


def adamw(lr: Callable[[jax.Array], jax.Array] | float, b1=0.9, b2=0.95,
          eps=1e-8, weight_decay=0.1, max_grad_norm=1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        z = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))

    def state_like(params):
        z = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)  # noqa: E731
        return AdamWState(jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            m2 = b1 * m + (1 - b1) * g
            v2 = b2 * v + (1 - b2) * g * g
            mhat = m2 / (1 - b1 ** t)
            vhat = v2 / (1 - b2 ** t)
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m2, v2

        out = jax.tree.map(upd, grads, state.m, state.v, params)
        us = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        ms = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        vs = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return us, AdamWState(ms, vs)

    def state_specs(param_specs, abstract_params):
        return AdamWState(param_specs, param_specs)

    return Optimizer(init, update, state_like, state_specs)


# ---------------------------------------------------------------- adafactor


class AdafactorState(NamedTuple):
    m: Any        # bf16 first moment
    v_row: Any    # f32 factored second moment (rows)  — 2D+ params
    v_col: Any    # f32 factored second moment (cols)
    v_full: Any   # f32 full second moment — 0/1-D params


def adafactor(lr: Callable[[jax.Array], jax.Array] | float, b1=0.9, decay=0.99,
              eps=1e-30, weight_decay=0.0, max_grad_norm=1.0,
              clip_threshold=1.0) -> Optimizer:
    """Adafactor with momentum (bf16) and row/col-factored v for params with
    ndim >= 2 (factored over the last two dims)."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def _shapes(p):
        if p.ndim >= 2:
            return p.shape[:-1], p.shape[:-2] + p.shape[-1:], None
        return None, None, p.shape

    def init(params):
        def zr(p):
            r, c, f = _shapes(p)
            return (jnp.zeros(p.shape, jnp.bfloat16),
                    jnp.zeros(r, jnp.float32) if r else jnp.zeros((1,), jnp.float32),
                    jnp.zeros(c, jnp.float32) if c else jnp.zeros((1,), jnp.float32),
                    jnp.zeros(f, jnp.float32) if f else jnp.zeros((1,), jnp.float32))
        out = jax.tree.map(zr, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,  # noqa: E731
                                      is_leaf=lambda x: isinstance(x, tuple))
        return AdafactorState(pick(0), pick(1), pick(2), pick(3))

    def state_like(params):
        def zr(p):
            r, c, f = _shapes(p)
            return (jax.ShapeDtypeStruct(p.shape, jnp.bfloat16),
                    jax.ShapeDtypeStruct(r if r else (1,), jnp.float32),
                    jax.ShapeDtypeStruct(c if c else (1,), jnp.float32),
                    jax.ShapeDtypeStruct(f if f else (1,), jnp.float32))
        out = jax.tree.map(zr, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,  # noqa: E731
                                      is_leaf=lambda x: isinstance(x, tuple))
        return AdafactorState(pick(0), pick(1), pick(2), pick(3))

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, max_grad_norm)
        t = step.astype(jnp.float32) + 1.0
        beta2t = 1.0 - t ** -0.8  # Adafactor schedule, bounded by `decay`
        beta2t = jnp.minimum(beta2t, decay)
        lr_t = lr_fn(step)

        def upd(g, m, vr, vc, vf, p):
            g2 = g * g + eps
            if p.ndim >= 2:
                vr2 = beta2t * vr + (1 - beta2t) * jnp.mean(g2, axis=-1)
                vc2 = beta2t * vc + (1 - beta2t) * jnp.mean(g2, axis=-2)
                r = vr2 / jnp.maximum(jnp.mean(vr2, axis=-1, keepdims=True), eps)
                vhat = r[..., None] * vc2[..., None, :]
                vf2 = vf
            else:
                vf2 = beta2t * vf + (1 - beta2t) * g2
                vhat = vf2
                vr2, vc2 = vr, vc
            u = g / jnp.sqrt(vhat + eps)
            # update clipping (RMS(u) <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            m2 = (b1 * m.astype(jnp.float32) + (1 - b1) * u).astype(jnp.bfloat16)
            du = -lr_t * (m2.astype(jnp.float32) + weight_decay * p.astype(jnp.float32))
            return du, m2, vr2, vc2, vf2

        out = jax.tree.map(upd, grads, state.m, state.v_row, state.v_col,
                           state.v_full, params)
        pick = lambda i: jax.tree.map(lambda o: o[i], out,  # noqa: E731
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), AdafactorState(pick(1), pick(2), pick(3), pick(4))

    def state_specs(param_specs, abstract_params):
        from jax.sharding import PartitionSpec as P

        def per(spec, p):
            s = tuple(spec)
            if p.ndim >= 2:
                return (P(*s), P(*s[:-1]), P(*s[:-2], s[-1]), P(None))
            return (P(*s), P(None), P(None), P(*s))

        out = jax.tree.map(per, param_specs, abstract_params,
                           is_leaf=lambda x: isinstance(x, P))
        is4 = lambda x: isinstance(x, tuple) and len(x) == 4 and all(  # noqa: E731
            isinstance(e, P) for e in x)
        pick = lambda i: jax.tree.map(lambda o: o[i], out, is_leaf=is4)  # noqa: E731
        return AdafactorState(pick(0), pick(1), pick(2), pick(3))

    return Optimizer(init, update, state_like, state_specs)
