"""Jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro import kernels as K
from repro.kernels.flash_attn.kernel import (flash_attention_fwd,
                                             paged_flash_decode_fwd,
                                             paged_flash_prefill_fwd)


@partial(jax.jit, static_argnames=("scale", "causal", "block_q", "block_k",
                                   "interpret"))
def flash_attention_tpu(q, k, v, scale: float, causal: bool = True,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool | None = None):
    if interpret is None:
        interpret = K.INTERPRET
    return flash_attention_fwd(q, k, v, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_prefill_tpu(q, k_pages, v_pages, block_row, offset, valid,
                            scale: float, interpret: bool | None = None):
    """Chunked paged prefill for one slot: the admission chunk's C queries
    attend the slot's pages [0, offset + valid) through its block-table row
    (the chunk's K/V already live in those pages). q: (1, C, H, Dh);
    block_row: (max_blocks,) int32 (0 = null page); offset/valid: () int32.
    -> (1, C, H, Dv); rows past ``valid`` are jit-padding garbage."""
    if interpret is None:
        interpret = K.INTERPRET
    return paged_flash_prefill_fwd(q, k_pages, v_pages, block_row, offset,
                                   valid, scale=scale, interpret=interpret)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_flash_decode_tpu(q, k_pages, v_pages, block_table, lengths,
                           scale: float, interpret: bool | None = None):
    """Paged dense decode over a (P, page, KV, Dh) arena through its block
    table — no contiguous logical view. q: (B, 1, H, Dh); block_table:
    (B, max_blocks) int32 (0 = null page); lengths: (B,) int32 valid tokens
    per row. -> (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    return paged_flash_decode_fwd(q, k_pages, v_pages, block_table, lengths,
                                  scale=scale, interpret=interpret)
