"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_flash_decode_ref(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           block_table: jax.Array, lengths: jax.Array,
                           scale: float) -> jax.Array:
    """Oracle for the paged decode kernel, straight from the paged layout:
    q: (B, 1, H, Dh); k_pages/v_pages: (P, page, KV, Dh|Dv); block_table:
    (B, max_blocks) int32 (0 = null page); lengths: (B,). -> (B, 1, H, Dv).
    Positions >= lengths[b] (null pages, partial last page) are masked;
    lengths[b] == 0 rows return zeros."""
    B, _, H, Dh = q.shape
    page, KV = k_pages.shape[1], k_pages.shape[2]
    nb = block_table.shape[1]
    g = H // KV
    kl = jnp.take(k_pages, block_table, axis=0).reshape(B, nb * page, KV, Dh)
    vl = jnp.take(v_pages, block_table, axis=0).reshape(
        B, nb * page, KV, v_pages.shape[-1])
    qg = q[:, 0].reshape(B, KV, g, Dh)
    s = jnp.einsum("bkgd,bnkd->bkgn", qg.astype(jnp.float32),
                   kl.astype(jnp.float32)) * scale
    pos = jnp.arange(nb * page, dtype=jnp.int32)
    live = pos[None, :] < lengths[:, None]                      # (B, N)
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    l = jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bkgn,bnkd->bkgd", w, vl.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)
    o = jnp.where((lengths > 0)[:, None, None, None], o, 0.0)   # empty rows
    return o.reshape(B, 1, H, -1).astype(q.dtype)


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, scale: float,
                        causal: bool = True) -> jax.Array:
    """q: (B, T, H, D); k/v: (B, S, KV, D) -> (B, T, H, Dv). Exact SDA."""
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, T, KV, g, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qg, k).astype(jnp.float32) * scale
    if causal:
        pos_q = jnp.arange(T)[:, None]
        pos_k = jnp.arange(S)[None, :]
        s = jnp.where((pos_k <= pos_q)[None, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", w.astype(v.dtype), v)
    return o.reshape(B, T, H, v.shape[-1])
