"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, scale: float,
                        causal: bool = True) -> jax.Array:
    """q: (B, T, H, D); k/v: (B, S, KV, D) -> (B, T, H, Dv). Exact SDA."""
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    g = H // KV
    qg = q.reshape(B, T, KV, g, D)
    s = jnp.einsum("btkgd,bskd->btkgs", qg, k).astype(jnp.float32) * scale
    if causal:
        pos_q = jnp.arange(T)[:, None]
        pos_k = jnp.arange(S)[None, :]
        s = jnp.where((pos_k <= pos_q)[None, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("btkgs,bskd->btkgd", w.astype(v.dtype), v)
    return o.reshape(B, T, H, v.shape[-1])
