"""Flash attention forward, Pallas TPU.

Grid: (B, H, nq, nk) — nk is the innermost (sequential on-core) axis, so the
online-softmax state for one (b, h, iq) lives in VMEM scratch across the nk
sweep; the (T x S) score matrix never exists. Tiles are MXU-aligned
(block_q x head_dim and block_k x head_dim, head_dim a multiple of 128 on the
lane axis is ideal; 64 also maps cleanly on v5e).

Causal blocks that are fully masked are skipped with pl.when (no MXU work).
GQA: the kv-head index for query head h is h // (H // KV), computed in the
BlockSpec index_map so K/V tiles are fetched per kv head.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_sc, l_sc, acc_sc, *,
            scale: float, causal: bool, block_q: int, block_k: int,
            nk: int, seq_k: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q_start = iq * block_q
    k_start = ik * block_k
    # skip fully-masked causal blocks (first row of q tile vs last k row)
    live = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, :, 0, :]                      # (bq, D)
        k = k_ref[0, :, 0, :]                      # (bk, D)
        v = v_ref[0, :, 0, :]                      # (bk, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < seq_k
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, :, 0, :] = (
            acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                         nb: int):
    """One (b, kv, ib) step: the K/V tile IS physical page bt[b, ib] — the
    BlockSpec index map resolved the block table before the body ran, so the
    page was DMA'd straight from the arena into VMEM (no logical view).

    One sweep serves both attention matmuls per page (scores AND weighted-V
    accumulate while the page sits in VMEM); softmax state is carried online
    in f32 scratch across the block-table sweep."""
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # pages wholly past the row's length are unmapped (null page 0, garbage
    # contents by convention) — skip them entirely: no MXU work
    @pl.when(ib * page_size < len_ref[b])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)        # (G, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (page, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, page)
        # null-page / partial-last-page masking: position vs per-row length
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < len_ref[b], s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == nb - 1)
    def _finish():
        o_ref[0, 0] = (
            acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def _paged_prefill_kernel(bt_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                          m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                          nb: int, group: int):
    """One (kv, ib) step of the Q-chunk>1 paged prefill sweep: queries are the
    admission chunk's C tokens (flattened (C*G) rows per kv head), the K/V
    tile IS physical page bt[ib] of the slot being admitted. lens holds
    (offset, total): ``offset`` tokens preceded this chunk, ``total`` =
    offset + valid masks the chunk's jit padding. Causal masking is per query
    ROW: row r is chunk token r // G at absolute position offset + r // G."""
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # pages wholly past the row's post-chunk length are unmapped: skip
    @pl.when(ib * page_size < lens_ref[1])
    def _compute():
        q = q_ref[0].astype(jnp.float32)           # (C*G, Dh)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (page, Dh)
        v = v_ref[0, :, 0, :].astype(jnp.float32)  # (page, Dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale          # (C*G, page)
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qtok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        ok = (pos < lens_ref[1]) & (pos <= lens_ref[0] + qtok)   # valid & causal
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == nb - 1)
    def _finish():
        o_ref[0] = (
            acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def paged_flash_prefill_fwd(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                            block_row: jax.Array, offset: jax.Array,
                            valid: jax.Array, *, scale: float,
                            interpret: bool = True) -> jax.Array:
    """Chunked paged prefill attention for ONE request slot: the chunk's C
    queries attend over the slot's pages [0, offset + valid) — the chunk's own
    K/V were just written into those pages, so no contiguous scratch cache
    exists. Same scalar-prefetch construction as the decode kernel, with a
    per-query-row causal mask (query i sits at absolute position offset + i).

    q: (1, C, H, Dh); k_pages/v_pages: (P, page, KV, Dh|Dv) pools;
    block_row: (max_blocks,) int32 (0 = null page); offset/valid: () int32 —
    tokens already in the slot before this chunk / real tokens in this chunk
    (the tail up to C is jit padding whose output is garbage).
    Returns (1, C, H, Dv)."""
    _, C, H, Dh = q.shape
    page = k_pages.shape[1]
    KV = k_pages.shape[2]
    Dv = v_pages.shape[-1]
    g = H // KV
    nb = block_row.shape[0]
    # (KV, C*G, Dh), token-major rows within each kv head: row r = token r // g
    qg = q[0].reshape(C, KV, g, Dh).transpose(1, 0, 2, 3).reshape(KV, C * g, Dh)
    lens = jnp.stack([offset, offset + valid]).astype(jnp.int32)

    kern = functools.partial(_paged_prefill_kernel, scale=scale,
                             page_size=page, nb=nb, group=g)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_row, (offset, total)
            grid=(KV, nb),          # innermost axis sweeps block-table entries
            in_specs=[
                pl.BlockSpec((1, C * g, Dh), lambda kv, ib, bt, ln: (kv, 0, 0)),
                pl.BlockSpec((1, page, 1, Dh),
                             lambda kv, ib, bt, ln: (bt[ib], 0, kv, 0)),
                pl.BlockSpec((1, page, 1, Dv),
                             lambda kv, ib, bt, ln: (bt[ib], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, C * g, Dv),
                                   lambda kv, ib, bt, ln: (kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((C * g, 1), jnp.float32),
                pltpu.VMEM((C * g, 1), jnp.float32),
                pltpu.VMEM((C * g, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((KV, C * g, Dv), q.dtype),
        interpret=interpret,
    )(block_row.astype(jnp.int32), lens, qg, k_pages, v_pages)
    return out.reshape(KV, C, g, Dv).transpose(1, 0, 2, 3).reshape(1, C, H, Dv)


def paged_flash_decode_fwd(q: jax.Array, k_pages: jax.Array, v_pages: jax.Array,
                           block_table: jax.Array, lengths: jax.Array, *,
                           scale: float, interpret: bool = True) -> jax.Array:
    """Paged single-token flash decode: grid iterates block-table entries and
    DMAs each mapped page from the arena into VMEM via the BlockSpec index map
    (scalar-prefetched block table) — the contiguous logical K/V view is never
    materialized.

    q: (B, 1, H, Dh); k_pages/v_pages: (P, page, KV, Dh|Dv) physical pools;
    block_table: (B, max_blocks) int32, 0 = unmapped (null page);
    lengths: (B,) int32 valid tokens per row. Returns (B, 1, H, Dv).

    Masking convention (shared with serving/paged_cache.py): positions >=
    lengths[b] — including every slot of an unmapped/null page and the tail of
    a partial last page — contribute nothing; a row with lengths[b] == 0
    returns zeros."""
    B, _, H, Dh = q.shape
    page = k_pages.shape[1]
    KV = k_pages.shape[2]
    Dv = v_pages.shape[-1]
    g = H // KV
    nb = block_table.shape[1]
    qg = q[:, 0].reshape(B, KV, g, Dh)

    kern = functools.partial(_paged_decode_kernel, scale=scale,
                             page_size=page, nb=nb)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_table, lengths
            grid=(B, KV, nb),       # innermost axis sweeps block-table entries
            in_specs=[
                pl.BlockSpec((1, 1, g, Dh), lambda b, kv, ib, bt, ln: (b, kv, 0, 0)),
                pl.BlockSpec((1, page, 1, Dh),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv, 0)),
                pl.BlockSpec((1, page, 1, Dv),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, Dv),
                                   lambda b, kv, ib, bt, ln: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, 1), jnp.float32),
                pltpu.VMEM((g, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, g, Dv), q.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      qg, k_pages, v_pages)
    return out.reshape(B, 1, H, Dv)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        scale: float, causal: bool = True,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = True) -> jax.Array:
    """q: (B, T, H, D), k/v: (B, S, KV, D/Dv) -> (B, T, H, Dv)."""
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    g = H // KV
    bq = min(block_q, T)
    bk = min(block_k, S)
    pad_q = (-T) % bq
    pad_k = (-S) % bk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nq = (T + pad_q) // bq
    nk = (S + pad_k) // bk

    grid = (B, H, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, block_q=bq,
                          block_k=bk, nk=nk, seq_k=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, D), lambda b, h, iq, ik: (b, iq, h, 0)),
            pl.BlockSpec((1, bk, 1, D), lambda b, h, iq, ik: (b, ik, h // g, 0)),
            pl.BlockSpec((1, bk, 1, Dv), lambda b, h, iq, ik: (b, ik, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, Dv), lambda b, h, iq, ik: (b, iq, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T + pad_q, H, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
