"""Pure-jnp oracle for the proxy-scoring kernel."""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def proxy_scores_ref(qs, qz, codes, length):
    """qs: (B,KV,G,Dp); qz: (B,KV,G,1); codes: (B,N,KV,Dp) i8 -> (B,KV,G,N)."""
    c = codes.astype(jnp.float32) + 128.0
    s = jnp.einsum("bkgd,bnkd->bkgn", qs, c) + qz
    pos = jnp.arange(codes.shape[1], dtype=jnp.int32)
    return jnp.where((pos < length)[None, None, None, :], s, NEG_INF)
