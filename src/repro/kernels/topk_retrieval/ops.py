"""Public ops: proxy scoring via the kernel + full T3 retrieval decode
(kernel proxy pass -> lax.top_k -> exact gather re-score)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.configs.base import RetrievalCfg
from repro.core import retrieval_attention as ret_lib
from repro.core.kv_cache import RetrievalCache
from repro.kernels.topk_retrieval.kernel import proxy_scores_fwd


@partial(jax.jit, static_argnames=("block_n", "interpret"))
def proxy_scores_tpu(q, proxy_scale, proxy_zero, codes, length,
                     block_n: int = 1024, interpret: bool | None = None):
    """q: (B, H, Dp) pre-scaled query (incl. attention scale);
    proxy_scale/zero: (B, KV, Dp); codes: (B, N, KV, Dp) i8.
    Returns (B, H, N) f32."""
    if interpret is None:
        interpret = K.INTERPRET
    B, H, Dp = q.shape
    KV = codes.shape[2]
    g = H // KV
    qf = q.astype(jnp.float32).reshape(B, KV, g, Dp)
    qs = qf * proxy_scale[:, :, None, :]
    qz = jnp.einsum("bkgd,bkd->bkg", qf, proxy_zero)[..., None]
    s = proxy_scores_fwd(qs, qz, codes, length, block_n=block_n,
                         interpret=interpret)
    return s.reshape(B, H, codes.shape[1])


def retrieval_decode_tpu(q, cache: RetrievalCache, cfg: RetrievalCfg,
                         scale: float, interpret: bool | None = None):
    """Full T3 decode: kernel proxy sweep, then top-k + exact re-score.
    q: (B, 1, H, Dh) -> (B, 1, H, Dh)."""
    dp = cfg.proxy_dim or q.shape[-1]
    qp = (q[:, 0, :, :dp] * scale)
    sp = proxy_scores_tpu(qp, cache.proxy_scale, cache.proxy_zero,
                          cache.proxy, cache.length, interpret=interpret)
    # sp: (B, H, N) -> select_topk expects (B, T=1, H, N)
    idx = ret_lib.select_topk(sp[:, None], cache.length, cfg)
    k_sel, v_sel = ret_lib.gather_kv(cache.k, cache.v, idx)
    s = jnp.einsum("bthd,bthkd->bthk", q, k_sel).astype(jnp.float32) * scale
    ok = idx < cache.length
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bthk,bthkd->bthd", w.astype(v_sel.dtype), v_sel)
