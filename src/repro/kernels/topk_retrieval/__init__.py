from repro.kernels.topk_retrieval.ops import proxy_scores_tpu, retrieval_decode_tpu  # noqa: F401
