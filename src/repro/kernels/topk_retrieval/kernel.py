"""T3 — proxy-similarity scoring kernel (paper §V), Pallas TPU.

The CAM analogue: an associative lookup over ALL cached keys realized as an
int8-code matmul on the MXU. Per-channel affine codes give

    score ~ q . k_hat = (q * scale) . code + q . zero

The per-head query-side factors (qs = q * scale[kv(h)], qz = q . zero[kv(h)])
are precomputed outside (O(Dp) per head); the kernel does the O(N) sweep:
one int8 code block load -> one MXU matmul -> masked score block. HBM traffic
is 1 byte per (key, channel) instead of 2 (bf16), and V is not touched at all
during candidate search.

Grid: (B, KV, nn). Output: proxy scores (B, H, N) f32 for lax.top_k outside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, qs_ref, qz_ref, c_ref, o_ref, *, block_n: int):
    ib = pl.program_id(2)
    qs = qs_ref[0, 0]                                # (G, Dp)
    qz = qz_ref[0, 0]                                # (G, 1)
    c = c_ref[0, :, 0, :].astype(jnp.float32) + 128.0  # (bn, Dp)
    s = jax.lax.dot_general(qs, c, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) + qz  # (G, bn)
    pos = ib * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    o_ref[0, 0] = jnp.where(pos < len_ref[0], s, NEG_INF)


def proxy_scores_fwd(qs, qz, codes, length, *, block_n: int = 1024,
                     interpret: bool = True):
    """qs: (B, KV, G, Dp) f32 (= q * scale); qz: (B, KV, G, 1) f32
    (= q . zero); codes: (B, N, KV, Dp) i8 (stored code-128).
    Returns (B, KV, G, N) f32 masked proxy scores."""
    B, KV, G, Dp = qs.shape
    N = codes.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes = jnp.pad(codes, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=-128)
    nn = (N + pad) // bn

    out = pl.pallas_call(
        functools.partial(_kernel, block_n=bn),
        grid=(B, KV, nn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, Dp), lambda b, kv, ib: (b, kv, 0, 0)),
            pl.BlockSpec((1, 1, G, 1), lambda b, kv, ib: (b, kv, 0, 0)),
            pl.BlockSpec((1, bn, 1, Dp), lambda b, kv, ib: (b, ib, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bn), lambda b, kv, ib: (b, kv, 0, ib)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, N + pad), jnp.float32),
        interpret=interpret,
    )(length.reshape(1).astype(jnp.int32), qs, qz, codes)
    return out[..., :N]
