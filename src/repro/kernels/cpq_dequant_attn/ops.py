"""Public op: decode attention over a CPQKVCache via the fused dequant kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.core.kv_cache import CPQKVCache
from repro.kernels.cpq_dequant_attn.kernel import (cpq_decode_fwd,
                                                   paged_cpq_decode_fwd,
                                                   paged_cpq_prefill_fwd)


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def cpq_decode_tpu(q, cache: CPQKVCache, scale: float, block_n: int = 512,
                   interpret: bool | None = None):
    """q: (B, 1, H, Dh) roped query; cache: CPQKVCache. -> (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dh = q.shape
    KV = cache.k.codes.shape[2]
    g = H // KV
    qg = q[:, 0].reshape(B, KV, g, Dh)
    out = cpq_decode_fwd(
        qg, cache.k.codes, cache.v.codes,
        cache.k.scale, cache.k.zero, cache.v.scale, cache.v.zero,
        cache.k.level, cache.v.level, cache.length, scale=scale,
        block_n=block_n, interpret=interpret)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_cpq_prefill_tpu(q, kt, vt, k_raw, v_raw, slot, block_row, offset,
                          valid, scale: float, interpret: bool | None = None):
    """Chunked paged T2 prefill for one slot: the admission chunk's C queries
    attend the slot's earlier code/level pages (in-VMEM dequant) plus the
    chunk's raw roped K/V causally. q: (1, C, H, Dh) roped chunk queries;
    kt/vt: PagedCPQTensor arenas; k_raw/v_raw: (1, C, KV, Dh|Dv);
    slot/offset/valid: () int32; block_row: (max_blocks,) int32.
    -> (1, C, H, Dv); rows past ``valid`` are jit-padding garbage."""
    if interpret is None:
        interpret = K.INTERPRET
    _, C, H, Dh = q.shape
    KV = kt.codes.shape[2]
    g = H // KV
    # (1, KV, C*G, Dh), token-major rows within each kv head
    qg = q[0].reshape(C, KV, g, Dh).transpose(1, 0, 2, 3).reshape(1, KV, C * g, Dh)
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)  # noqa: E731
    out = paged_cpq_prefill_fwd(
        qg, kt.codes, vt.codes, sl(kt.scale), sl(kt.zero), sl(vt.scale),
        sl(vt.zero), kt.level, vt.level, k_raw[0], v_raw[0], block_row,
        offset, valid, scale=scale, interpret=interpret)
    Dv = out.shape[-1]
    return (out.reshape(KV, C, g, Dv).transpose(1, 0, 2, 3)
            .reshape(1, C, H, Dv).astype(q.dtype))


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_cpq_decode_tpu(q, kt, vt, block_table, lengths, scale: float,
                         interpret: bool | None = None):
    """Paged T2 decode over PagedCPQTensor arenas (serving/paged_cache.py)
    through their block table — no contiguous logical CPQ view. q: (B, 1, H,
    Dh) roped query; kt/vt: PagedCPQTensor (code/level pages + per-slot HQE
    scale/zero); block_table: (B, max_blocks) int32 (0 = null page);
    lengths: (B,) int32. -> (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dh = q.shape
    KV = kt.codes.shape[2]
    g = H // KV
    qg = q[:, 0].reshape(B, KV, g, Dh)
    out = paged_cpq_decode_fwd(
        qg, kt.codes, vt.codes, kt.scale, kt.zero, vt.scale, vt.zero,
        kt.level, vt.level, block_table, lengths, scale=scale,
        interpret=interpret)
    return out.reshape(B, 1, H, -1).astype(q.dtype)
