"""Public op: decode attention over a CPQKVCache via the fused dequant kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.core.kv_cache import CPQKVCache
from repro.kernels.cpq_dequant_attn.kernel import (cpq_decode_fwd,
                                                   paged_cpq_decode_fwd)


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def cpq_decode_tpu(q, cache: CPQKVCache, scale: float, block_n: int = 512,
                   interpret: bool | None = None):
    """q: (B, 1, H, Dh) roped query; cache: CPQKVCache. -> (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dh = q.shape
    KV = cache.k.codes.shape[2]
    g = H // KV
    qg = q[:, 0].reshape(B, KV, g, Dh)
    out = cpq_decode_fwd(
        qg, cache.k.codes, cache.v.codes,
        cache.k.scale, cache.k.zero, cache.v.scale, cache.v.zero,
        cache.k.level, cache.v.level, cache.length, scale=scale,
        block_n=block_n, interpret=interpret)
    return out.reshape(B, 1, H, -1).astype(q.dtype)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_cpq_decode_tpu(q, kt, vt, block_table, lengths, scale: float,
                         interpret: bool | None = None):
    """Paged T2 decode over PagedCPQTensor arenas (serving/paged_cache.py)
    through their block table — no contiguous logical CPQ view. q: (B, 1, H,
    Dh) roped query; kt/vt: PagedCPQTensor (code/level pages + per-slot HQE
    scale/zero); block_table: (B, max_blocks) int32 (0 = null page);
    lengths: (B,) int32. -> (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dh = q.shape
    KV = kt.codes.shape[2]
    g = H // KV
    qg = q[:, 0].reshape(B, KV, g, Dh)
    out = paged_cpq_decode_fwd(
        qg, kt.codes, vt.codes, kt.scale, kt.zero, vt.scale, vt.zero,
        kt.level, vt.level, block_table, lengths, scale=scale,
        interpret=interpret)
    return out.reshape(B, 1, H, -1).astype(q.dtype)
