"""Pure-jnp oracle: dequantize the whole CPQ arena, run dense attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _dequant_full(codes, scale, zero, level):
    """codes: (B,N,KV,D) i8; scale/zero: (B,L,KV,D); level: (B,N,KV)."""
    lvl = level[..., None]
    s = jnp.take_along_axis(scale, jnp.broadcast_to(lvl, codes.shape), axis=1)
    z = jnp.take_along_axis(zero, jnp.broadcast_to(lvl, codes.shape), axis=1)
    c = codes.astype(jnp.float32) + 128.0
    return jnp.where(c == 0.0, 0.0, (c - 1.0) * s + z)


def cpq_decode_ref(q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
                   level_k, level_v, length, scale):
    """q: (B, KV, G, Dh) -> (B, KV, G, Dv) f32."""
    k_hat = _dequant_full(codes_k, scale_k, zero_k, level_k)
    v_hat = _dequant_full(codes_v, scale_v, zero_v, level_v)
    s = jnp.einsum("bkgd,bnkd->bkgn", q.astype(jnp.float32), k_hat) * scale
    pos = jnp.arange(codes_k.shape[1], dtype=jnp.int32)
    s = jnp.where((pos < length)[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgn,bnkd->bkgd", w, v_hat)
