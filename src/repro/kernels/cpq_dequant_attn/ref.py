"""Pure-jnp oracle: dequantize the whole CPQ arena, run dense attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _dequant_full(codes, scale, zero, level):
    """codes: (B,N,KV,D) i8; scale/zero: (B,L,KV,D); level: (B,N,KV)."""
    lvl = level[..., None]
    s = jnp.take_along_axis(scale, jnp.broadcast_to(lvl, codes.shape), axis=1)
    z = jnp.take_along_axis(zero, jnp.broadcast_to(lvl, codes.shape), axis=1)
    c = codes.astype(jnp.float32) + 128.0
    return jnp.where(c == 0.0, 0.0, (c - 1.0) * s + z)


def paged_cpq_decode_ref(q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
                         level_k, level_v, block_table, lengths, scale):
    """Oracle for the paged T2 kernel, straight from the paged layout:
    q: (B, KV, G, Dh); codes_*: (P, page, KV, D*) i8 pools; level_*:
    (P, page, KV) i32 pools; scale_/zero_*: (B, L, KV, D*) per-slot HQE side
    state; block_table: (B, max_blocks) (0 = null page); lengths: (B,).
    -> (B, KV, G, Dv) f32; positions >= lengths[b] masked, empty rows zero."""
    B = q.shape[0]
    page, KV = codes_k.shape[1], codes_k.shape[2]
    nb = block_table.shape[1]
    ck = jnp.take(codes_k, block_table, axis=0).reshape(
        B, nb * page, KV, codes_k.shape[-1])
    cv = jnp.take(codes_v, block_table, axis=0).reshape(
        B, nb * page, KV, codes_v.shape[-1])
    lk = jnp.take(level_k, block_table, axis=0).reshape(B, nb * page, KV)
    lv = jnp.take(level_v, block_table, axis=0).reshape(B, nb * page, KV)
    # null-page levels may be arbitrary garbage: clamp so the gather in
    # _dequant_full stays in range (the positions are masked below anyway)
    L = scale_k.shape[1]
    lk = jnp.clip(lk, 0, L - 1)
    lv = jnp.clip(lv, 0, L - 1)
    # same bf16 rounding of dequantized tiles as the serving gather path
    k_hat = _dequant_full(ck, scale_k, zero_k, lk).astype(
        jnp.bfloat16).astype(jnp.float32)
    v_hat = _dequant_full(cv, scale_v, zero_v, lv).astype(
        jnp.bfloat16).astype(jnp.float32)
    s = jnp.einsum("bkgd,bnkd->bkgn", q.astype(jnp.float32), k_hat) * scale
    pos = jnp.arange(nb * page, dtype=jnp.int32)
    live = pos[None, :] < lengths[:, None]
    s = jnp.where(live[:, None, None, :], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    l = jnp.sum(w, axis=-1, keepdims=True)
    o = jnp.einsum("bkgn,bnkd->bkgd", w, v_hat) / jnp.maximum(l, 1e-30)
    return jnp.where((lengths > 0)[:, None, None, None], o, 0.0)


def cpq_decode_ref(q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
                   level_k, level_v, length, scale):
    """q: (B, KV, G, Dh) -> (B, KV, G, Dv) f32."""
    k_hat = _dequant_full(codes_k, scale_k, zero_k, level_k)
    v_hat = _dequant_full(codes_v, scale_v, zero_v, level_v)
    s = jnp.einsum("bkgd,bnkd->bkgn", q.astype(jnp.float32), k_hat) * scale
    pos = jnp.arange(codes_k.shape[1], dtype=jnp.int32)
    s = jnp.where((pos < length)[None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgn,bnkd->bkgd", w, v_hat)
