from repro.kernels.cpq_dequant_attn.ops import cpq_decode_tpu  # noqa: F401
