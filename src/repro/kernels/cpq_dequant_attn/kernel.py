"""T2 — decode attention directly over CPQ int8 codes (paper §IV), Pallas TPU.

The hardware DQU (dequantization unit) analogue: HBM moves only the int8/int4
codes + per-(level, channel) scale/zero + per-token HQE level; dequantization
happens in VMEM/registers inside the attention kernel, so the cache traffic
is the compressed bytes (4-8x less than bf16 K/V).

HQE level lookup is MXU-friendly: the per-token level id becomes a one-hot
(bn, L) matrix multiplied against the (L, D) scale/zero tables — no gathers.
Pruned elements (stored code 0, i.e. int8 -128) dequantize to exactly 0,
which realizes the paper's "transfer only non-zero" semantics as
zero-contribution MACs.

Grid: (B, KV, nn) — nn innermost; online softmax in VMEM scratch; one sweep
dequantizes K and V blocks and runs both attention matmuls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant(codes, lv_oh, scale_ref, zero_ref):
    """codes: (bn, D) i8 (stored = code - 128); lv_oh: (bn, L) f32."""
    s = jax.lax.dot_general(lv_oh, scale_ref[0, :, 0, :],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (bn, D)
    z = jax.lax.dot_general(lv_oh, zero_ref[0, :, 0, :],
                            (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    c = codes.astype(jnp.float32) + 128.0
    return jnp.where(c == 0.0, 0.0, (c - 1.0) * s + z)


def _kernel(len_ref, q_ref, ck_ref, cv_ref, sk_ref, zk_ref, sv_ref, zv_ref,
            lvk_ref, lvv_ref, o_ref, m_sc, l_sc, acc_sc, *, scale: float,
            block_n: int, nn: int, num_levels: int):
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    q = q_ref[0, 0]                                  # (G, Dh)
    ck = ck_ref[0, :, 0, :]                          # (bn, Dh) i8
    cv = cv_ref[0, :, 0, :]                          # (bn, Dv) i8

    def onehot(lv):                                  # (bn,) i32 -> (bn, L) f32
        return (lv[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, (lv.shape[0], num_levels), 1)).astype(jnp.float32)

    lvk_oh = onehot(lvk_ref[0, :, 0])
    lvv_oh = onehot(lvv_ref[0, :, 0])

    k_hat = _dequant(ck, lvk_oh, sk_ref, zk_ref)     # (bn, Dh) f32
    s = jax.lax.dot_general(q.astype(jnp.float32), k_hat,
                            (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale  # (G, bn)
    pos = ib * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_sc[...] = m_new
    v_hat = _dequant(cv, lvv_oh, sv_ref, zv_ref)     # (bn, Dv) f32
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p, v_hat, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ib == nn - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def _paged_kernel(bt_ref, len_ref, q_ref, ck_ref, cv_ref, sk_ref, zk_ref,
                  sv_ref, zv_ref, lvk_ref, lvv_ref, o_ref, m_sc, l_sc, acc_sc,
                  *, scale: float, page_size: int, nb: int, num_levels: int):
    """Paged T2 step: code/level tiles ARE physical page bt[b, ib] (resolved
    by the BlockSpec index maps from the scalar-prefetched block table);
    per-slot HQE scale/zero stay slot-indexed by b. Dequantization happens in
    VMEM on the page — HBM moved only the compressed bytes of mapped pages."""
    b = pl.program_id(0)
    ib = pl.program_id(2)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # unmapped (null) pages sit wholly past the row's length: skip
    @pl.when(ib * page_size < len_ref[b])
    def _compute():
        q = q_ref[0, 0]                                  # (G, Dh)
        ck = ck_ref[0, :, 0, :]                          # (page, Dh) i8
        cv = cv_ref[0, :, 0, :]                          # (page, Dv) i8

        def onehot(lv):                                  # (page,) -> (page, L)
            return (lv[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (lv.shape[0], num_levels), 1)).astype(jnp.float32)

        def dequant(codes, lv_oh, s_ref, z_ref):
            # round dequantized tiles to bf16 like the jnp gather path
            # (cpq_chunked_decode_attention) so paged-kernel decode stays
            # token-exact vs it under greedy sampling
            return _dequant(codes, lv_oh, s_ref, z_ref).astype(
                jnp.bfloat16).astype(jnp.float32)

        k_hat = dequant(ck, onehot(lvk_ref[0, :, 0]), sk_ref, zk_ref)
        s = jax.lax.dot_general(q.astype(jnp.float32), k_hat,
                                (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < len_ref[b], s, NEG_INF)      # partial last page

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        v_hat = dequant(cv, onehot(lvv_ref[0, :, 0]), sv_ref, zv_ref)
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v_hat, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == nb - 1)
    def _finish():
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(o_ref.dtype)


def _paged_prefill_kernel(bt_ref, lens_ref, q_ref, ck_ref, cv_ref, sk_ref,
                          zk_ref, sv_ref, zv_ref, lvk_ref, lvv_ref, kraw_ref,
                          vraw_ref, o_ref, m_sc, l_sc, acc_sc, *, scale: float,
                          page_size: int, nb: int, num_levels: int, group: int,
                          chunk: int):
    """One (kv, ib) step of the Q-chunk>1 paged T2 prefill sweep for the slot
    being admitted. Grid steps ib < nb dequantize the slot's EARLIER code
    pages (positions < offset — cross-chunk keys read exactly what decode
    will read); the extra final step ib == nb attends the chunk's RAW roped
    K/V tile causally, so a single-chunk admission reproduces the one-shot
    prefill's raw-attention numerics bit-for-bit. lens = (offset, valid)."""
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    def online(s, v_tile):
        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, v_tile, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # earlier-chunk pages: dequantize in VMEM, positions >= offset are dead
    # (the current chunk's keys are served raw by the final grid step)
    @pl.when((ib < nb) & (ib * page_size < lens_ref[0]))
    def _pages():
        q = q_ref[0, 0].astype(jnp.float32)              # (C*G, Dh)
        ck = ck_ref[0, :, 0, :]                          # (page, Dh) i8
        cv = cv_ref[0, :, 0, :]                          # (page, Dv) i8

        def onehot(lv):
            return (lv[:, None] == jax.lax.broadcasted_iota(
                jnp.int32, (lv.shape[0], num_levels), 1)).astype(jnp.float32)

        def dequant(codes, lv_oh, s_ref, z_ref):
            # bf16 rounding matches the jnp gather path (see _paged_kernel)
            return _dequant(codes, lv_oh, s_ref, z_ref).astype(
                jnp.bfloat16).astype(jnp.float32)

        k_hat = dequant(ck, onehot(lvk_ref[0, :, 0]), sk_ref, zk_ref)
        s = jax.lax.dot_general(q, k_hat, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < lens_ref[0], s, NEG_INF)     # earlier tokens only
        online(s, dequant(cv, onehot(lvv_ref[0, :, 0]), sv_ref, zv_ref))

    # final step: the chunk's raw roped K/V, causal within the chunk
    @pl.when(ib == nb)
    def _raw_tail():
        q = q_ref[0, 0].astype(jnp.float32)              # (C*G, Dh)
        k = kraw_ref[:, 0, :].astype(jnp.float32)        # (C, Dh)
        v = vraw_ref[:, 0, :].astype(jnp.float32)        # (C, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        col = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qtok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) // group
        ok = (col < lens_ref[1]) & (col <= qtok)         # valid & causal
        s = jnp.where(ok, s, NEG_INF)
        online(s, v)
        o_ref[0, 0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(
            o_ref.dtype)


def paged_cpq_prefill_fwd(q, codes_k, codes_v, scale_k, zero_k, scale_v,
                          zero_v, level_k, level_v, k_raw, v_raw, block_row,
                          offset, valid, *, scale: float,
                          interpret: bool = True):
    """Chunked paged T2 prefill for one slot: the admission chunk's C queries
    attend the slot's earlier code/level pages (dequantized in VMEM — HBM
    moves only compressed bytes) plus the chunk's raw roped K/V causally.
    No contiguous scratch cache and no logical CPQ view is materialized.

    q: (1, KV, C*G, Dh) token-major rows (row r = chunk token r // G);
    codes_*/level_*: (P, page, KV, D*) i8 / (P, page, KV) i32 pools;
    scale_/zero_*: (1, L, KV, D*) f32 HQE side state of THIS slot;
    k_raw/v_raw: (C, KV, Dh|Dv) the chunk's raw roped keys/values;
    block_row: (max_blocks,) int32 (0 = null page); offset/valid: () int32.
    Returns (1, KV, C*G, Dv) f32; rows past ``valid`` are jit-padding
    garbage."""
    _, KV, CG, Dh = q.shape
    C = k_raw.shape[0]
    G = CG // C
    page = codes_k.shape[1]
    Dv = codes_v.shape[-1]
    L = scale_k.shape[1]
    nb = block_row.shape[0]
    lens = jnp.stack([offset, valid]).astype(jnp.int32)

    kern = functools.partial(_paged_prefill_kernel, scale=scale,
                             page_size=page, nb=nb, num_levels=L, group=G,
                             chunk=C)
    # page index maps clamp ib to nb-1 so the extra raw-tail grid step keeps
    # well-formed (dummy) page operands
    pg = lambda ib, bt: bt[jnp.minimum(ib, nb - 1)]  # noqa: E731
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_row, (offset, valid)
            grid=(KV, nb + 1),      # block-table sweep + raw-chunk tail
            in_specs=[
                pl.BlockSpec((1, 1, CG, Dh), lambda kv, ib, bt, ln: (0, kv, 0, 0)),
                pl.BlockSpec((1, page, 1, Dh),
                             lambda kv, ib, bt, ln: (pg(ib, bt), 0, kv, 0)),
                pl.BlockSpec((1, page, 1, Dv),
                             lambda kv, ib, bt, ln: (pg(ib, bt), 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dh), lambda kv, ib, bt, ln: (0, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dh), lambda kv, ib, bt, ln: (0, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dv), lambda kv, ib, bt, ln: (0, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dv), lambda kv, ib, bt, ln: (0, 0, kv, 0)),
                pl.BlockSpec((1, page, 1),
                             lambda kv, ib, bt, ln: (pg(ib, bt), 0, kv)),
                pl.BlockSpec((1, page, 1),
                             lambda kv, ib, bt, ln: (pg(ib, bt), 0, kv)),
                pl.BlockSpec((C, 1, Dh), lambda kv, ib, bt, ln: (0, kv, 0)),
                pl.BlockSpec((C, 1, Dv), lambda kv, ib, bt, ln: (0, kv, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, CG, Dv),
                                   lambda kv, ib, bt, ln: (0, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((CG, 1), jnp.float32),
                pltpu.VMEM((CG, 1), jnp.float32),
                pltpu.VMEM((CG, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((1, KV, CG, Dv), jnp.float32),
        interpret=interpret,
    )(block_row.astype(jnp.int32), lens,
      q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
      level_k.astype(jnp.int32), level_v.astype(jnp.int32), k_raw, v_raw)


def paged_cpq_decode_fwd(q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
                         level_k, level_v, block_table, lengths, *,
                         scale: float, interpret: bool = True):
    """Paged T2 decode: the grid's innermost axis iterates block-table entries
    and each mapped code/level page is DMA'd from the arena into VMEM — no
    contiguous logical CPQ view is materialized.

    q: (B, KV, G, Dh); codes_*: (P, page, KV, D*) i8 pools; level_*:
    (P, page, KV) i32 pools; scale_/zero_*: (B, L, KV, D*) f32 per-SLOT HQE
    side state; block_table: (B, max_blocks) int32 (0 = null page);
    lengths: (B,) int32. Returns (B, KV, G, Dv) f32.

    Masking convention: positions >= lengths[b] (null pages, partial last
    page) are dead; lengths[b] == 0 rows return zeros."""
    B, KV, G, Dh = q.shape
    page = codes_k.shape[1]
    Dv = codes_v.shape[-1]
    L = scale_k.shape[1]
    nb = block_table.shape[1]

    kern = functools.partial(_paged_kernel, scale=scale, page_size=page,
                             nb=nb, num_levels=L)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_table, lengths
            grid=(B, KV, nb),
            in_specs=[
                pl.BlockSpec((1, 1, G, Dh), lambda b, kv, ib, bt, ln: (b, kv, 0, 0)),
                pl.BlockSpec((1, page, 1, Dh),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv, 0)),
                pl.BlockSpec((1, page, 1, Dv),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dh), lambda b, kv, ib, bt, ln: (b, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dh), lambda b, kv, ib, bt, ln: (b, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dv), lambda b, kv, ib, bt, ln: (b, 0, kv, 0)),
                pl.BlockSpec((1, L, 1, Dv), lambda b, kv, ib, bt, ln: (b, 0, kv, 0)),
                pl.BlockSpec((1, page, 1),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv)),
                pl.BlockSpec((1, page, 1),
                             lambda b, kv, ib, bt, ln: (bt[b, ib], 0, kv)),
            ],
            out_specs=pl.BlockSpec((1, 1, G, Dv),
                                   lambda b, kv, ib, bt, ln: (b, kv, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, 1), jnp.float32),
                pltpu.VMEM((G, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dv), jnp.float32),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
      level_k.astype(jnp.int32), level_v.astype(jnp.int32))


def cpq_decode_fwd(q, codes_k, codes_v, scale_k, zero_k, scale_v, zero_v,
                   level_k, level_v, length, *, scale: float,
                   block_n: int = 512, interpret: bool = True):
    """q: (B, KV, G, Dh); codes_*: (B, N, KV, D*) i8; scale_/zero_*:
    (B, L, KV, D*) f32; level_*: (B, N, KV) i32; length: () int32.
    Returns (B, KV, G, Dv)."""
    B, KV, G, Dh = q.shape
    N = codes_k.shape[1]
    Dv = codes_v.shape[-1]
    L = scale_k.shape[1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        codes_k = jnp.pad(codes_k, ((0, 0), (0, pad), (0, 0), (0, 0)),
                          constant_values=-128)
        codes_v = jnp.pad(codes_v, ((0, 0), (0, pad), (0, 0), (0, 0)),
                          constant_values=-128)
        level_k = jnp.pad(level_k, ((0, 0), (0, pad), (0, 0)))
        level_v = jnp.pad(level_v, ((0, 0), (0, pad), (0, 0)))
    nn = (N + pad) // bn

    kern = functools.partial(_kernel, scale=scale, block_n=bn, nn=nn,
                             num_levels=L)
    return pl.pallas_call(
        kern,
        grid=(B, KV, nn),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, G, Dh), lambda b, kv, ib: (b, kv, 0, 0)),
            pl.BlockSpec((1, bn, 1, Dh), lambda b, kv, ib: (b, ib, kv, 0)),
            pl.BlockSpec((1, bn, 1, Dv), lambda b, kv, ib: (b, ib, kv, 0)),
            pl.BlockSpec((1, L, 1, Dh), lambda b, kv, ib: (b, 0, kv, 0)),
            pl.BlockSpec((1, L, 1, Dh), lambda b, kv, ib: (b, 0, kv, 0)),
            pl.BlockSpec((1, L, 1, Dv), lambda b, kv, ib: (b, 0, kv, 0)),
            pl.BlockSpec((1, L, 1, Dv), lambda b, kv, ib: (b, 0, kv, 0)),
            pl.BlockSpec((1, bn, 1), lambda b, kv, ib: (b, ib, kv)),
            pl.BlockSpec((1, bn, 1), lambda b, kv, ib: (b, ib, kv)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dv), lambda b, kv, ib: (b, kv, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dv), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(length.reshape(1).astype(jnp.int32), q, codes_k, codes_v,
      scale_k, zero_k, scale_v, zero_v,
      level_k.astype(jnp.int32), level_v.astype(jnp.int32))
