"""Public op: full T1 decode attention via the fused kernel.

Splits the work exactly as the paper does: the two tiny dense matmuls
(R = q W_K^T, out = P W_V) run as ordinary XLA ops; the O(N) cache sweep —
both cascaded MatMuls + online softmax — is the Pallas kernel.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import kernels as K
from repro.kernels.decomposed_attn.kernel import (decomposed_decode_fwd,
                                                  paged_decomposed_decode_fwd,
                                                  paged_decomposed_prefill_fwd)


@partial(jax.jit, static_argnames=("scale", "block_n", "interpret"))
def decomposed_decode_tpu(q_nope, q_rope, x_cache, k_rope, w_k_nope, w_v,
                          length, scale: float, block_n: int = 512,
                          interpret: bool | None = None):
    """q_nope: (B,1,H,Dn); q_rope: (B,1,H,Rr); x_cache: (B,N,Dm);
    k_rope: (B,N,1,Rr) shared across heads (MLA layout) or Rr == 0;
    w_k_nope: (Dm, KV, Dn); w_v: (Dm, KV, Dv). Returns (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dn = q_nope.shape
    Dm = x_cache.shape[-1]
    KV, Dv = w_v.shape[1], w_v.shape[2]
    g = H // KV

    # R = q W_K^T  (first cascaded MatMul — tiny for decode)
    qg = q_nope[:, 0].reshape(B, KV, g, Dn)
    r = jnp.einsum("bkgd,mkd->bkgm", qg, w_k_nope).reshape(B, H, Dm)

    kr = k_rope[:, :, 0, :] if k_rope is not None and k_rope.shape[-1] > 0 \
        else jnp.zeros((B, x_cache.shape[1], 0), x_cache.dtype)
    qr = q_rope[:, 0] if q_rope is not None and q_rope.shape[-1] > 0 \
        else jnp.zeros((B, H, 0), x_cache.dtype)

    p = decomposed_decode_fwd(r.astype(x_cache.dtype), qr.astype(x_cache.dtype),
                              x_cache, kr, length, scale=scale,
                              block_n=block_n, interpret=interpret)

    # out = P W_V  (second tiny dense MatMul)
    pg = p.reshape(B, KV, g, Dm)
    out = jnp.einsum("bkgm,mkd->bkgd", pg, w_v).reshape(B, 1, H, Dv)
    return out


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decomposed_prefill_tpu(q_nope, q_rope, x_pages, kr_pages,
                                 block_row, offset, valid, w_k_nope, w_v,
                                 scale: float, interpret: bool | None = None):
    """Chunked paged T1/MLA prefill for one slot: the admission chunk's C
    queries attend the slot's X (+roped key) pages [0, offset + valid)
    through its block-table row (the chunk's X rows already live in those
    pages). q_nope: (1, C, H, Dn); q_rope: (1, C, H, Rr) or None/Rr == 0;
    block_row: (max_blocks,) int32 (0 = null page); offset/valid: () int32;
    w_k_nope: (Dm, KV, Dn); w_v: (Dm, KV, Dv). -> (1, C, H, Dv); rows past
    ``valid`` are jit-padding garbage."""
    if interpret is None:
        interpret = K.INTERPRET
    _, C, H, Dn = q_nope.shape
    Dm = x_pages.shape[-1]
    KV, Dv = w_v.shape[1], w_v.shape[2]
    g = H // KV

    # R = q W_K^T  (first cascaded MatMul — tiny for a chunk)
    qg = q_nope[0].reshape(C, KV, g, Dn)
    r = jnp.einsum("ckgd,mkd->ckgm", qg, w_k_nope).reshape(C, H, Dm)

    qr = q_rope[0] if q_rope is not None and q_rope.shape[-1] > 0 \
        else jnp.zeros((C, H, 0), x_pages.dtype)

    p = paged_decomposed_prefill_fwd(
        r.astype(x_pages.dtype), qr.astype(x_pages.dtype), x_pages, kr_pages,
        block_row, offset, valid, scale=scale, interpret=interpret)

    # out = P W_V  (second tiny dense MatMul)
    pg = p.reshape(C, KV, g, Dm)
    return jnp.einsum("ckgm,mkd->ckgd", pg, w_v).reshape(1, C, H, Dv)


@partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_decomposed_decode_tpu(q_nope, q_rope, x_pages, kr_pages,
                                block_table, lengths, w_k_nope, w_v,
                                scale: float, interpret: bool | None = None):
    """Paged T1/MLA decode over a (P, page, Dm) X arena through its block
    table — no contiguous logical view. q_nope: (B, 1, H, Dn); q_rope:
    (B, 1, H, Rr) or None/Rr == 0; kr_pages: (P, page, KV_r, Rr) with
    KV_r == 1 (MLA shared rope) or per-kv-head; w_k_nope: (Dm, KV, Dn);
    w_v: (Dm, KV, Dv); block_table: (B, max_blocks) int32 (0 = null page);
    lengths: (B,) int32. Returns (B, 1, H, Dv)."""
    if interpret is None:
        interpret = K.INTERPRET
    B, _, H, Dn = q_nope.shape
    Dm = x_pages.shape[-1]
    KV, Dv = w_v.shape[1], w_v.shape[2]
    g = H // KV

    # R = q W_K^T  (first cascaded MatMul — tiny for decode)
    qg = q_nope[:, 0].reshape(B, KV, g, Dn)
    r = jnp.einsum("bkgd,mkd->bkgm", qg, w_k_nope).reshape(B, H, Dm)

    qr = q_rope[:, 0] if q_rope is not None and q_rope.shape[-1] > 0 \
        else jnp.zeros((B, H, 0), x_pages.dtype)

    p = paged_decomposed_decode_fwd(
        r.astype(x_pages.dtype), qr.astype(x_pages.dtype), x_pages, kr_pages,
        block_table, lengths, scale=scale, interpret=interpret)

    # out = P W_V  (second tiny dense MatMul)
    pg = p.reshape(B, KV, g, Dm)
    return jnp.einsum("bkgm,mkd->bkgd", pg, w_v).reshape(B, 1, H, Dv)
