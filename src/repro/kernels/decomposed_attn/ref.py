"""Pure-jnp oracle for the decomposed-attention decode kernel: the P-stage of
core.decomposed_attention (shared-rope layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def paged_decomposed_decode_ref(r, q_rope, x_pages, kr_pages, block_table,
                                lengths, scale):
    """Oracle for the paged T1/MLA kernel, straight from the paged layout:
    r: (B, H, Dm); q_rope: (B, H, Rr) (Rr may be 0); x_pages: (P, page, Dm);
    kr_pages: (P, page, KV_r, Rr) (KV_r == 1 shared / per-kv-head);
    block_table: (B, max_blocks) (0 = null page); lengths: (B,).
    -> P: (B, H, Dm); positions >= lengths[b] masked, empty rows zero."""
    B, H, Dm = r.shape
    page = x_pages.shape[1]
    nb = block_table.shape[1]
    x = jnp.take(x_pages, block_table, axis=0).reshape(B, nb * page, Dm)
    s = jnp.einsum("bhm,bnm->bhn", r.astype(jnp.float32),
                   x.astype(jnp.float32))
    if q_rope.shape[-1] > 0:
        kv_r, Rr = kr_pages.shape[2], kr_pages.shape[3]
        g_r = H // kv_r
        kr = jnp.take(kr_pages, block_table, axis=0).reshape(
            B, nb * page, kv_r, Rr)
        qg = q_rope.reshape(B, kv_r, g_r, Rr)
        s = s + jnp.einsum("bkgr,bnkr->bkgn", qg.astype(jnp.float32),
                           kr.astype(jnp.float32)).reshape(B, H, nb * page)
    s = s * scale
    pos = jnp.arange(nb * page, dtype=jnp.int32)
    live = pos[None, :] < lengths[:, None]
    s = jnp.where(live[:, None, :], s, NEG_INF)
    w = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    l = jnp.sum(w, axis=-1, keepdims=True)
    p = jnp.einsum("bhn,bnm->bhm", w, x.astype(jnp.float32))
    p = p / jnp.maximum(l, 1e-30)
    return jnp.where((lengths > 0)[:, None, None], p,
                     0.0).astype(x_pages.dtype)


def decomposed_decode_ref(r, q_rope, x, k_rope, length, scale):
    """r: (B,H,Dm); q_rope: (B,H,Rr); x: (B,N,Dm); k_rope: (B,N,Rr);
    -> P: (B, H, Dm)."""
    s = jnp.einsum("bhm,bnm->bhn", r, x).astype(jnp.float32)
    if q_rope.shape[-1] > 0:
        s = s + jnp.einsum("bhr,bnr->bhn", q_rope, k_rope).astype(jnp.float32)
    s = s * scale
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    s = jnp.where((pos < length)[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhn,bnm->bhm", w.astype(x.dtype), x)
