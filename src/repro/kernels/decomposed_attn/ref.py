"""Pure-jnp oracle for the decomposed-attention decode kernel: the P-stage of
core.decomposed_attention (shared-rope layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decomposed_decode_ref(r, q_rope, x, k_rope, length, scale):
    """r: (B,H,Dm); q_rope: (B,H,Rr); x: (B,N,Dm); k_rope: (B,N,Rr);
    -> P: (B, H, Dm)."""
    s = jnp.einsum("bhm,bnm->bhn", r, x).astype(jnp.float32)
    if q_rope.shape[-1] > 0:
        s = s + jnp.einsum("bhr,bnr->bhn", q_rope, k_rope).astype(jnp.float32)
    s = s * scale
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    s = jnp.where((pos < length)[None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhn,bnm->bhm", w.astype(x.dtype), x)
