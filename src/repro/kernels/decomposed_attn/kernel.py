"""T1 — fused decomposed-attention decode kernel (paper §III), Pallas TPU.

Computes, for one new-token query against the X cache:

    s_b   = R X_b^T (+ q_rope k_rope_b^T)     (score stage,  MXU)
    P    += softmax-online(s_b) X_b           (value stage,  MXU)

per X block b — i.e. BOTH cascaded MatMuls of the paper's decomposition
stream through VMEM on one X read. This is the sub-matrix pipeline of
Fig. 3(b) realized as a single kernel: stage 2 consumes stage-1 tiles as
they are produced, and neither the scores nor P round-trip HBM.

R = q_nope W_K^T is computed outside (a (H, Dn) x (Dn, Dm) matmul, tiny for
one token), as is the final out = P W_V. The kernel owns the O(N) part.

Grid: (B, nn) — nn innermost; online-softmax state (m, l, P) in VMEM scratch.
The rope path covers the shared-rope layout (MLA: one k_rope per token).
``length`` arrives via scalar prefetch (SMEM) and masks unwritten slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, r_ref, qr_ref, x_ref, kr_ref, p_ref,
            m_sc, l_sc, acc_sc, *, scale: float, block_n: int, nn: int,
            rope_dims: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    r = r_ref[0]                    # (H, Dm)
    x = x_ref[0]                    # (bn, Dm)
    # --- score stage: s = R X^T (the first cascaded MatMul)
    s = jax.lax.dot_general(r, x, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (H, bn)
    if rope_dims > 0:
        qr = qr_ref[0]              # (H, Rr)
        kr = kr_ref[0]              # (bn, Rr)
        s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    s = s * scale
    pos = ib * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)          # (H, bn)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_sc[...] = m_new
    # --- value stage: P += p X (the second cascaded MatMul, same X tile)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ib == nn - 1)
    def _finish():
        p_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(p_ref.dtype)


def decomposed_decode_fwd(r: jax.Array, q_rope: jax.Array, x: jax.Array,
                          k_rope: jax.Array, length: jax.Array, *,
                          scale: float, block_n: int = 512,
                          interpret: bool = True) -> jax.Array:
    """r: (B, H, Dm); q_rope: (B, H, Rr); x: (B, N, Dm); k_rope: (B, N, Rr);
    length: () int32. Returns P: (B, H, Dm) — caller applies W_V."""
    B, H, Dm = r.shape
    N = x.shape[1]
    Rr = q_rope.shape[-1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    nn = (N + pad) // bn

    grid = (B, nn)
    kern = functools.partial(_kernel, scale=scale, block_n=bn, nn=nn,
                             rope_dims=Rr)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # length (1,)
            pl.BlockSpec((1, H, Dm), lambda b, ib: (b, 0, 0)),
            pl.BlockSpec((1, H, max(Rr, 1)), lambda b, ib: (b, 0, 0)),
            pl.BlockSpec((1, bn, Dm), lambda b, ib: (b, ib, 0)),
            pl.BlockSpec((1, bn, max(Rr, 1)), lambda b, ib: (b, ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dm), lambda b, ib: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dm), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, Dm), jnp.float32),
        ],
        interpret=interpret,
    )(length.reshape(1).astype(jnp.int32),
      r,
      q_rope if Rr else jnp.zeros((B, H, 1), r.dtype),
      x,
      k_rope if Rr else jnp.zeros((B, N + pad, 1), x.dtype))
