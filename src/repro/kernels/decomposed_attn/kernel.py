"""T1 — fused decomposed-attention decode kernel (paper §III), Pallas TPU.

Computes, for one new-token query against the X cache:

    s_b   = R X_b^T (+ q_rope k_rope_b^T)     (score stage,  MXU)
    P    += softmax-online(s_b) X_b           (value stage,  MXU)

per X block b — i.e. BOTH cascaded MatMuls of the paper's decomposition
stream through VMEM on one X read. This is the sub-matrix pipeline of
Fig. 3(b) realized as a single kernel: stage 2 consumes stage-1 tiles as
they are produced, and neither the scores nor P round-trip HBM.

R = q_nope W_K^T is computed outside (a (H, Dn) x (Dn, Dm) matmul, tiny for
one token), as is the final out = P W_V. The kernel owns the O(N) part.

Grid: (B, nn) — nn innermost; online-softmax state (m, l, P) in VMEM scratch.
The rope path covers the shared-rope layout (MLA: one k_rope per token).
``length`` arrives via scalar prefetch (SMEM) and masks unwritten slots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, r_ref, qr_ref, x_ref, kr_ref, p_ref,
            m_sc, l_sc, acc_sc, *, scale: float, block_n: int, nn: int,
            rope_dims: int):
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    r = r_ref[0]                    # (H, Dm)
    x = x_ref[0]                    # (bn, Dm)
    # --- score stage: s = R X^T (the first cascaded MatMul)
    s = jax.lax.dot_general(r, x, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (H, bn)
    if rope_dims > 0:
        qr = qr_ref[0]              # (H, Rr)
        kr = kr_ref[0]              # (bn, Rr)
        s = s + jax.lax.dot_general(qr, kr, (((1,), (1,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    s = s * scale
    pos = ib * block_n + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_sc[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)          # (H, bn)
    l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
    m_sc[...] = m_new
    # --- value stage: P += p X (the second cascaded MatMul, same X tile)
    acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
        p.astype(x.dtype), x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ib == nn - 1)
    def _finish():
        p_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(p_ref.dtype)


def _paged_kernel(bt_ref, len_ref, r_ref, qr_ref, x_ref, kr_ref, p_ref,
                  m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                  nb: int, rope_dims: int, kv_r: int):
    """One (b, ib) step over physical X page bt[b, ib] (resolved by the
    BlockSpec index maps from the scalar-prefetched block table). Both
    cascaded MatMuls of the decomposition consume the page on ONE read while
    it sits in VMEM; rope keys may be shared (kv_r == 1, MLA) or
    per-kv-head; softmax state is carried online in f32 scratch."""
    b = pl.program_id(0)
    ib = pl.program_id(1)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # unmapped (null) pages sit wholly past the row's length: skip
    @pl.when(ib * page_size < len_ref[b])
    def _compute():
        r = r_ref[0].astype(jnp.float32)           # (H, Dm)
        x = x_ref[0].astype(jnp.float32)           # (page, Dm)
        # --- score stage: s = R X^T on the in-VMEM page
        s = jax.lax.dot_general(r, x, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (H, page)
        if rope_dims > 0:
            H = r.shape[0]
            g_r = H // kv_r
            rope_rows = []
            for j in range(kv_r):       # static, tiny: per-kv-head rope slice
                qj = qr_ref[0, j * g_r:(j + 1) * g_r, :].astype(jnp.float32)
                kj = kr_ref[0, :, j, :].astype(jnp.float32)   # (page, Rr)
                rope_rows.append(jax.lax.dot_general(
                    qj, kj, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32))
            s = s + jnp.concatenate(rope_rows, axis=0)
        s = s * scale
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < len_ref[b], s, NEG_INF)        # partial last page

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        # --- value stage: P += p X, same page still in VMEM
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, x, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == nb - 1)
    def _finish():
        p_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(p_ref.dtype)


def _paged_prefill_kernel(bt_ref, lens_ref, r_ref, qr_ref, x_ref, kr_ref, p_ref,
                          m_sc, l_sc, acc_sc, *, scale: float, page_size: int,
                          nb: int, rope_dims: int, kv_r: int, chunk: int):
    """One ib step of the Q-chunk>1 paged decomposed sweep for ONE slot being
    admitted: both cascaded MatMuls consume physical X page bt[ib] on one
    read. Query rows are HEAD-MAJOR (row = h * C + i) so the per-kv-head rope
    slices stay contiguous; row r is chunk token r % C at absolute position
    lens[0] + r % C; lens[1] = offset + valid masks the chunk's jit padding."""
    ib = pl.program_id(0)

    @pl.when(ib == 0)
    def _init():
        m_sc[...] = jnp.full_like(m_sc, NEG_INF)
        l_sc[...] = jnp.zeros_like(l_sc)
        acc_sc[...] = jnp.zeros_like(acc_sc)

    # pages wholly past the slot's post-chunk length are unmapped: skip
    @pl.when(ib * page_size < lens_ref[1])
    def _compute():
        r = r_ref[0].astype(jnp.float32)           # (H*C, Dm)
        x = x_ref[0].astype(jnp.float32)           # (page, Dm)
        # --- score stage: s = R X^T on the in-VMEM page
        s = jax.lax.dot_general(r, x, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (H*C, page)
        if rope_dims > 0:
            HC = r.shape[0]
            g_r = HC // (kv_r * chunk)             # heads per kv_r, in rows of C
            rope_rows = []
            for j in range(kv_r):   # static, tiny: per-kv-head rope slice
                qj = qr_ref[0, j * g_r * chunk:(j + 1) * g_r * chunk, :].astype(
                    jnp.float32)
                kj = kr_ref[0, :, j, :].astype(jnp.float32)   # (page, Rr)
                rope_rows.append(jax.lax.dot_general(
                    qj, kj, (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32))
            s = s + jnp.concatenate(rope_rows, axis=0)
        s = s * scale
        pos = ib * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        qtok = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) % chunk
        ok = (pos < lens_ref[1]) & (pos <= lens_ref[0] + qtok)  # valid & causal
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_sc[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_sc[...] = l_sc[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        m_sc[...] = m_new
        # --- value stage: P += p X, same page still in VMEM
        acc_sc[...] = acc_sc[...] * corr + jax.lax.dot_general(
            p, x, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ib == nb - 1)
    def _finish():
        p_ref[0] = (acc_sc[...] / jnp.maximum(l_sc[...], 1e-30)).astype(p_ref.dtype)


def paged_decomposed_prefill_fwd(r: jax.Array, q_rope: jax.Array,
                                 x_pages: jax.Array, kr_pages: jax.Array,
                                 block_row: jax.Array, offset: jax.Array,
                                 valid: jax.Array, *, scale: float,
                                 interpret: bool = True) -> jax.Array:
    """Chunked paged T1/MLA prefill for one slot: the admission chunk's C
    queries sweep the slot's X (+roped key) pages [0, offset + valid) — the
    chunk's own X rows were just written into those pages, so the decomposed
    score/value stages serve intra-chunk causal attention too and no
    contiguous scratch cache exists.

    r: (C, H, Dm) = q_nope W_K^T; q_rope: (C, H, Rr) (Rr may be 0);
    x_pages: (P, page, Dm); kr_pages: (P, page, KV_r, Rr), KV_r == 1 for the
    MLA shared rope; block_row: (max_blocks,) int32 (0 = null page);
    offset/valid: () int32. Returns P: (C, H, Dm) — caller applies W_V; rows
    past ``valid`` are jit-padding garbage."""
    C, H, Dm = r.shape
    page = x_pages.shape[1]
    Rr = q_rope.shape[-1]
    kv_r = kr_pages.shape[2] if Rr else 1
    nb = block_row.shape[0]
    if not Rr:  # keep a well-formed (non-0-width) operand for the BlockSpec
        q_rope = jnp.zeros((C, H, 1), r.dtype)
        kr_pages = jnp.zeros((x_pages.shape[0], page, 1, 1), x_pages.dtype)
    Rp = q_rope.shape[-1]
    # head-major rows (h * C + i): kv_r slices contiguous, token = row % C
    r2 = r.transpose(1, 0, 2).reshape(1, H * C, Dm)
    qr2 = q_rope.transpose(1, 0, 2).reshape(1, H * C, Rp)
    lens = jnp.stack([offset, offset + valid]).astype(jnp.int32)

    kern = functools.partial(_paged_prefill_kernel, scale=scale, page_size=page,
                             nb=nb, rope_dims=Rr, kv_r=kv_r, chunk=C)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_row, (offset, total)
            grid=(nb,),             # sweeps the slot's block-table entries
            in_specs=[
                pl.BlockSpec((1, H * C, Dm), lambda ib, bt, ln: (0, 0, 0)),
                pl.BlockSpec((1, H * C, Rp), lambda ib, bt, ln: (0, 0, 0)),
                pl.BlockSpec((1, page, Dm), lambda ib, bt, ln: (bt[ib], 0, 0)),
                pl.BlockSpec((1, page, kv_r, Rp),
                             lambda ib, bt, ln: (bt[ib], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H * C, Dm), lambda ib, bt, ln: (0, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H * C, 1), jnp.float32),
                pltpu.VMEM((H * C, 1), jnp.float32),
                pltpu.VMEM((H * C, Dm), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((1, H * C, Dm), x_pages.dtype),
        interpret=interpret,
    )(block_row.astype(jnp.int32), lens, r2, qr2, x_pages, kr_pages)
    return out.reshape(H, C, Dm).transpose(1, 0, 2)


def paged_decomposed_decode_fwd(r: jax.Array, q_rope: jax.Array,
                                x_pages: jax.Array, kr_pages: jax.Array,
                                block_table: jax.Array, lengths: jax.Array, *,
                                scale: float, interpret: bool = True) -> jax.Array:
    """Paged T1/MLA decode: the grid's innermost axis iterates block-table
    entries and each mapped X (+roped key) page is DMA'd from the arena into
    VMEM — no contiguous logical X view is materialized.

    r: (B, H, Dm) = q_nope W_K^T; q_rope: (B, H, Rr) (Rr may be 0);
    x_pages: (P, page, Dm) pool; kr_pages: (P, page, KV_r, Rr) pool with
    KV_r == 1 (MLA shared rope) or per-kv-head; block_table: (B, max_blocks)
    int32 (0 = null page); lengths: (B,) int32. Returns P: (B, H, Dm) —
    caller applies W_V.

    Masking convention: positions >= lengths[b] (null pages, partial last
    page) are dead; lengths[b] == 0 rows return zeros."""
    B, H, Dm = r.shape
    page = x_pages.shape[1]
    Rr = q_rope.shape[-1]
    kv_r = kr_pages.shape[2] if Rr else 1
    nb = block_table.shape[1]
    if not Rr:  # keep a well-formed (non-0-width) operand for the BlockSpec
        q_rope = jnp.zeros((B, H, 1), r.dtype)
        kr_pages = jnp.zeros((x_pages.shape[0], page, 1, 1), x_pages.dtype)
    Rp = q_rope.shape[-1]

    kern = functools.partial(_paged_kernel, scale=scale, page_size=page,
                             nb=nb, rope_dims=Rr, kv_r=kv_r)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # block_table, lengths
            grid=(B, nb),           # innermost axis sweeps block-table entries
            in_specs=[
                pl.BlockSpec((1, H, Dm), lambda b, ib, bt, ln: (b, 0, 0)),
                pl.BlockSpec((1, H, Rp), lambda b, ib, bt, ln: (b, 0, 0)),
                pl.BlockSpec((1, page, Dm),
                             lambda b, ib, bt, ln: (bt[b, ib], 0, 0)),
                pl.BlockSpec((1, page, kv_r, Rp),
                             lambda b, ib, bt, ln: (bt[b, ib], 0, 0, 0)),
            ],
            out_specs=pl.BlockSpec((1, H, Dm), lambda b, ib, bt, ln: (b, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, 1), jnp.float32),
                pltpu.VMEM((H, Dm), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, Dm), x_pages.dtype),
        interpret=interpret,
    )(block_table.astype(jnp.int32), lengths.astype(jnp.int32),
      r, q_rope, x_pages, kr_pages)


def decomposed_decode_fwd(r: jax.Array, q_rope: jax.Array, x: jax.Array,
                          k_rope: jax.Array, length: jax.Array, *,
                          scale: float, block_n: int = 512,
                          interpret: bool = True) -> jax.Array:
    """r: (B, H, Dm); q_rope: (B, H, Rr); x: (B, N, Dm); k_rope: (B, N, Rr);
    length: () int32. Returns P: (B, H, Dm) — caller applies W_V."""
    B, H, Dm = r.shape
    N = x.shape[1]
    Rr = q_rope.shape[-1]
    bn = min(block_n, N)
    pad = (-N) % bn
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0)))
    nn = (N + pad) // bn

    grid = (B, nn)
    kern = functools.partial(_kernel, scale=scale, block_n=bn, nn=nn,
                             rope_dims=Rr)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # length (1,)
            pl.BlockSpec((1, H, Dm), lambda b, ib: (b, 0, 0)),
            pl.BlockSpec((1, H, max(Rr, 1)), lambda b, ib: (b, 0, 0)),
            pl.BlockSpec((1, bn, Dm), lambda b, ib: (b, ib, 0)),
            pl.BlockSpec((1, bn, max(Rr, 1)), lambda b, ib: (b, ib, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, Dm), lambda b, ib: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Dm), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, 1), jnp.float32),
            pltpu.VMEM((H, Dm), jnp.float32),
        ],
        interpret=interpret,
    )(length.reshape(1).astype(jnp.int32),
      r,
      q_rope if Rr else jnp.zeros((B, H, 1), r.dtype),
      x,
      k_rope if Rr else jnp.zeros((B, N + pad, 1), x.dtype))
