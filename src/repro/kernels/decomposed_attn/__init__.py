from repro.kernels.decomposed_attn.ops import decomposed_decode_tpu  # noqa: F401
