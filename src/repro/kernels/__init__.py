"""Pallas TPU kernels for the paper's compute hot spots.

  flash_attn/        baseline dense flash attention (train/prefill) +
                     ``paged_flash_decode_*``: paged single-token decode over
                     a (P, page, KV, Dh) arena
  decomposed_attn/   T1: fused two-stage (Q W_K^T) X^T decode attention —
                     the sub-matrix pipeline realized as one VMEM-resident
                     streaming kernel over the X cache +
                     ``paged_decomposed_decode_*``: same sweep over X pages
                     (covers the MLA latent cache: shared-rope kv_r == 1)
  cpq_dequant_attn/  T2: decode attention directly over int8 CPQ codes with
                     in-register HQE dequantization (HBM moves only codes) +
                     ``paged_cpq_decode_*``: code/level pages + per-slot HQE
                     side state
  topk_retrieval/    T3: int8 proxy-similarity scoring (the CAM analogue)

Each directory: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
interpret-mode switch), ref.py (pure-jnp oracle).

Paged decode entry points (serving/paged_cache.py arenas)
---------------------------------------------------------
The ``paged_*`` kernels take ``(pages, block_table, lengths)`` directly: the
block table is a scalar-prefetch operand, so each grid step's BlockSpec index
map resolves ``block_table[b, ib]`` and DMAs that PHYSICAL page from the
arena into VMEM — the contiguous logical view the jnp gather path
materializes never exists. Masking convention (shared with
serving/paged_cache.py): block-table entry 0 is the reserved null page whose
contents are garbage by design; every position >= lengths[b] — all slots of
an unmapped/null page and the tail of a partial last page — is masked to
-inf before the online softmax, pages wholly past lengths[b] are skipped
without issuing MXU work, and a row with lengths[b] == 0 returns zeros.
``ops.py`` wrappers select the engine-facing defaults; the serving dispatch
(``decode_attend_paged``) routes dense, CPQ, and X/MLA tiers through them
when ``AttentionRuntime.paged_kernels`` is set (retrieval T3 keeps the
gather for its top-k slot selection).

Paged prefill entry points (chunked admission)
----------------------------------------------
The ``paged_*_prefill_*`` variants generalize the decode kernels to
Q-chunk>1: the C queries of one admission chunk sweep ONE slot's
block-table row with an additional per-query-row causal mask (query i sits
at absolute position ``offset + i``; positions past ``offset + valid`` are
the chunk's jit padding). The chunk's own payload is written into the pages
first, so the same sweep serves intra-chunk causal attention — serving
admission never materializes a contiguous scratch cache. The CPQ variant
adds one extra grid step that attends the chunk's RAW roped K/V causally
(earlier pages are dequantized in VMEM, reading exactly what decode reads).
``chunk_attend_paged`` in serving/paged_cache.py is the dispatch.

INTERPRET
---------
Kernels TARGET TPU v5e (128-aligned MXU tiles, VMEM-resident accumulators)
and are VALIDATED with interpret=True on CPU. ``INTERPRET`` is the
package-wide default every ops.py wrapper applies when its ``interpret``
argument is None; per-call overrides win. It defaults to True (this
container is CPU-only) and can be forced either way with the
``REPRO_INTERPRET`` env var (1/0, true/false, yes/no, on/off —
anything else raises); flip it off on real TPUs. Interpret
mode checks semantics, not speed — benchmark latency bars only apply
compiled (see benchmarks/bench_serving.py).
"""
import os

_interpret_env = os.environ.get("REPRO_INTERPRET", "1").strip().lower()
if _interpret_env in ("1", "true", "yes", "on"):
    INTERPRET = True
elif _interpret_env in ("0", "false", "no", "off"):
    INTERPRET = False
else:
    raise ValueError(
        f"REPRO_INTERPRET={_interpret_env!r}: expected 1/0, true/false, "
        "yes/no, or on/off")
