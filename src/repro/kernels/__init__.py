"""Pallas TPU kernels for the paper's compute hot spots.

  flash_attn/        baseline dense flash attention (train/prefill)
  decomposed_attn/   T1: fused two-stage (Q W_K^T) X^T decode attention —
                     the sub-matrix pipeline realized as one VMEM-resident
                     streaming kernel over the X cache
  cpq_dequant_attn/  T2: decode attention directly over int8 CPQ codes with
                     in-register HQE dequantization (HBM moves only codes)
  topk_retrieval/    T3: int8 proxy-similarity scoring (the CAM analogue)

Each directory: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit wrapper,
interpret-mode switch), ref.py (pure-jnp oracle). Kernels TARGET TPU v5e
(128-aligned MXU tiles, VMEM-resident accumulators) and are VALIDATED with
interpret=True on CPU.
"""
INTERPRET = True  # this container is CPU-only; flipped off on real TPUs
