"""Logical-axis -> mesh-axis rule sets.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.

Parameters (train & serve): 2D sharding — the d_model/"embed" dim is FSDP-
sharded over ``data`` (ZeRO-3; optimizer state follows), the parallel dim
(heads / mlp / vocab / experts) is Megatron-TP-sharded over ``model``.
Parameters are replicated across ``pod`` (pure DP between pods; the cross-pod
gradient all-reduce is the compressible slow-link collective).

Activations: batch over (pod, data), feature-parallel dims over model.

Caches (decode): batch over (pod, data); the head_dim (or latent dim) over
``model`` — this keeps one-token dynamic_update_slice writes local to every
shard (each owns a feature slice of every token) while attention contractions
reduce over the sharded feature dim with a psum. long-context batch=1 shapes
additionally shard the token arena over ``data`` (see launch/input_specs).
"""
from __future__ import annotations


def param_rules(multi_pod: bool) -> dict:
    return {
        # multi-pod: FSDP spans the DCN pod axis too (hybrid sharded DP /
        # ZeRO-3 across pods) — halves per-device param/grad/optimizer memory;
        # the cross-pod gradient sync becomes reduce-scatter + all-gather.
        "embed": ("data", "pod") if multi_pod else "data",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "experts": "model",
        "expert_mlp": None,
        "layers": None,
    }


def act_rules(multi_pod: bool, seq_axis=None) -> dict:
    b = ("pod", "data") if multi_pod else ("data",)
    return {
        "act_batch": b,
        "act_seq": seq_axis,
        "act_heads": "model",
        # KV-head activations replicate: KV < mesh "model" for GQA archs and
        # resharding 8<->16 forces involuntary full remat in SPMD
        "act_kv": None,
        "act_mlp": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "act_expert_mlp": None,
    }


def serve_paged_rules(pool_axis=None) -> dict:
    """Logical-axis rules for the paged serving arenas (continuous batching).

    The physical page pool of every paged container partitions over the
    KV-HEAD axis (each device owns its head slice of every page — the
    paper's bank-parallel attention: compute runs where the KV lives and
    only per-head partials cross the interconnect). Latent pools (T1 X /
    MLA c_kv) have no head axis, so their FEATURE axis shards instead —
    storage is partitioned for HBM capacity and the serving shard_map
    all-gathers the local feature shards before the absorbed attend
    (serving/sharded.py). Page-pool and page axes stay unsharded by default;
    ``pool_axis`` ("data") opts the pool axis into capacity sharding for
    tiers served with global-semantics compute (GSPMD inserts the gathers).
    Block tables, RowState, and the slot/level axes replicate — note the
    slot-INDEXED CPQ HQE side state (scale/zero/num_levels/prune_thr) still
    shards its kv-head axis, exactly like the code pages it dequantizes.
    The CPQ-X (T1+T2 / MLA-CPQ) code pools are the exception and replicate
    entirely — see distributed.cache_specs._paged_cpq_specs."""
    return {
        "page_pool": pool_axis,   # physical page axis (P)
        "page": None,             # within-page token axis
        "kv_heads": "model",      # per-head pools: dense K/V, CPQ codes, proxy
        "head_dim": None,
        "latent": "model",        # feature axis of X / MLA latent pools
        "slots": None,            # per-slot side state (CPQ HQE, proxy calib)
        "levels": None,           # HQE level axis
    }


def batch_axes(multi_pod: bool, batch_size: int, mesh_shape: dict) -> tuple:
    """Mesh axes to shard the global batch over (drop axes that don't divide)."""
    axes = (("pod", "data") if multi_pod else ("data",))
    out = []
    n = batch_size
    for a in axes:
        k = mesh_shape[a]
        if n % k == 0 and n >= k:
            out.append(a)
            n //= k
    return tuple(out)
