"""GPipe-style pipeline parallelism over a mesh axis (shard_map + ppermute).

Scope: forward pipeline for the scanned decoder stack — the deployment case
where a deep model's layers are split across pods and DCN bandwidth makes
cross-pod FSDP gathers unattractive (serving, or as a stage within other
schedules). Training in this framework uses DP/FSDP/TP (+ the compressed
cross-pod gradient path in optim/compression.py); wiring a full backward
pipeline schedule (1F1B) is future work and noted in DESIGN.md.

Schedule: M microbatches, S stages, T = M + S - 1 ticks; at tick t stage s
works on microbatch t - s. Each tick overlaps compute with a single
ppermute hop of activations to the next stage. Bubble fraction is
(S - 1) / T — reported by ``bubble_fraction`` and benchmarked in
benchmarks/bench_pipeline.py alongside the paper's sub-matrix analysis.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe_forward(mesh: Mesh, axis: str, block_fn):
    """Build a pipelined forward over ``axis``.

    block_fn(params_block, x) -> x applies ONE block; each stage scans it
    over its local slice of the stacked block params.

    Returns fn(stacked_params, x_mb) where stacked_params leaves have leading
    dim num_blocks (sharded over ``axis``) and x_mb is (M, mb, ...) input
    microbatches (replicated). Output: (M, mb, ...) after ALL blocks.
    """
    n_stage = mesh.shape[axis]

    def stage_apply(params_loc, x):
        def body(h, p_one):
            return block_fn(p_one, h), None
        h, _ = jax.lax.scan(body, x, params_loc)
        return h

    def inner(params_loc, x_mb):
        stage = jax.lax.axis_index(axis)
        M = x_mb.shape[0]
        T = M + n_stage - 1
        fwd_perm = [(i, i + 1) for i in range(n_stage - 1)]

        def tick(carry, t):
            buf, outs = carry
            mb = t - stage
            active = (mb >= 0) & (mb < M)
            mb_c = jnp.clip(mb, 0, M - 1)
            x_in = jnp.where(stage == 0, x_mb[mb_c], buf)
            y = stage_apply(params_loc, x_in)
            y = jnp.where(active, y, jnp.zeros_like(y))
            is_last = stage == n_stage - 1
            outs = jnp.where(active & is_last, outs.at[mb_c].set(y), outs)
            buf_next = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf_next, outs), None

        # initial carries must be marked pod-varying for shard_map's vma check
        # (newer jax only; older shard_map has no vma tracking — no-op there)
        if hasattr(jax.lax, "pcast"):
            buf0 = jax.lax.pcast(jnp.zeros_like(x_mb[0]), (axis,), to="varying")
            outs0 = jax.lax.pcast(jnp.zeros_like(x_mb), (axis,), to="varying")
        else:
            buf0 = jnp.zeros_like(x_mb[0])
            outs0 = jnp.zeros_like(x_mb)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0),
                                    jnp.arange(T, dtype=jnp.int32))
        # outputs live on the last stage only (zeros elsewhere); replicate
        return jax.lax.psum(outs, axis)

    def fn(stacked_params, x_mb):
        in_specs = (jax.tree.map(lambda _: P(axis), stacked_params), P())
        return shard_map(inner, mesh=mesh, in_specs=in_specs, out_specs=P())(
            stacked_params, x_mb)

    return fn
