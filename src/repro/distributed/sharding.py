"""Logical-axis sharding context.

Model code calls ``constrain(x, *logical_axes)`` at the few places where
activation sharding matters (post-QKV, MLP hidden, logits, caches). Outside a
sharding context (CPU unit tests) this is a no-op; inside (train/serve/dryrun)
it resolves logical axis names -> mesh axes through the active rule set and
applies ``with_sharding_constraint``.

Rule sets map a logical axis name to a mesh axis, a tuple of mesh axes, or
None (replicated). Separate rule sets exist for parameters vs activations and
for train vs serve — see ``repro.distributed.rules``.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_CTX: contextvars.ContextVar[Optional[tuple[Mesh, dict]]] = contextvars.ContextVar(
    "shard_ctx", default=None
)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: dict):
    """Activate (mesh, activation-rules) for constrain() inside jit traces."""
    tok = _CTX.set((mesh, dict(rules)))
    try:
        yield
    finally:
        _CTX.reset(tok)


def resolve(rules: dict, axes: tuple) -> PartitionSpec:
    """Logical axes tuple -> PartitionSpec, dropping mesh-axis reuse."""
    used: set[str] = set()
    out = []
    for ax in axes:
        m = rules.get(ax) if ax is not None else None
        if m is None:
            out.append(None)
            continue
        ms = (m,) if isinstance(m, str) else tuple(m)
        ms = tuple(a for a in ms if a not in used)
        used.update(ms)
        out.append(ms if len(ms) > 1 else (ms[0] if ms else None))
    return PartitionSpec(*out)


def fit_spec_to_shape(spec: PartitionSpec, shape: tuple, mesh: Mesh) -> PartitionSpec:
    """Drop mesh axes that do not evenly divide the corresponding dim."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        ms = () if entry is None else ((entry,) if isinstance(entry, str) else tuple(entry))
        kept, prod = [], 1
        for a in ms:
            k = sizes.get(a, 1)
            if dim % (prod * k) == 0:
                kept.append(a)
                prod *= k
        out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    return PartitionSpec(*out)


def constrain(x: jax.Array, *axes) -> jax.Array:
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if x.ndim != len(axes):
        raise ValueError(f"rank {x.ndim} vs axes {axes}")
    spec = fit_spec_to_shape(resolve(rules, tuple(axes)), x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def active_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return ctx[0] if ctx else None
