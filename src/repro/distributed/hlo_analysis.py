"""Post-SPMD HLO analysis: trip-count-aware FLOPs / HBM-bytes / collective
traffic for the roofline.

Why not ``compiled.cost_analysis()``: XLA counts while-loop bodies ONCE, so a
scan-over-layers model (how this framework lowers every decoder stack) is
under-reported by ~num_blocks x (validated in EXPERIMENTS.md §Dry-run).

We parse the optimized HLO text instead:
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    exact for lax.scan loops;
  * FLOPs: dot (2 * result_elems * contraction_size) and convolution
    (2 * out_elems * kernel_elems / out_features). Elementwise FLOPs are
    ignored (<2% of any matmul-bearing model here);
  * HBM bytes: per top-level instruction, result + operand payloads (a
    post-fusion instruction ~ one kernel; fusion internals never touch HBM);
  * collectives: payload bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute), ``-start`` counted,
    ``-done`` skipped.

All quantities are PER DEVICE (the SPMD program is per-device).
"""
from __future__ import annotations

import json
import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "u1": 0.125,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id",
}

_ARRAY_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\{\s*$")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_WHILE_ATTR_RE = re.compile(r"condition=%?([\w\.\-]+)\s*,\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_APPLY_RE = re.compile(
    r"(?:to_apply|true_computation|false_computation)=%?([\w\.\-]+)")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([\d,]*)\}")
_FGC_RE = re.compile(r"feature_group_count=(\d+)")
_COND_CONST_RE = re.compile(r"=\s*[su]32\[\]\s*constant\((\d+)\)")


def _shape_arrays(shape_str: str) -> list[tuple[str, tuple[int, ...]]]:
    out = []
    for dt, dims in _ARRAY_RE.findall(shape_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _shape_bytes_sum(shape_str: str) -> float:
    return sum(math.prod(d) * _DTYPE_BYTES[dt] if d else _DTYPE_BYTES[dt]
               for dt, d in _shape_arrays(shape_str))


def _shape_bytes_max(shape_str: str) -> float:
    best = 0.0
    for dt, d in _shape_arrays(shape_str):
        best = max(best, (math.prod(d) if d else 1) * _DTYPE_BYTES[dt])
    return best


@dataclass
class _Instr:
    name: str
    op: str
    shape_str: str
    operands: list[str]
    attrs: str


@dataclass
class _Comp:
    name: str
    instrs: list = field(default_factory=list)
    shapes: dict = field(default_factory=dict)   # instr name -> shape_str
    consts: list = field(default_factory=list)


def _parse(text: str) -> tuple[dict[str, "_Comp"], str | None]:
    comps: dict[str, _Comp] = {}
    entry_name = None
    cur: _Comp | None = None
    depth = 0
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if not stripped.endswith("{"):
                continue
            m = _COMP_HDR_RE.match(stripped)
            if m:
                cur = _Comp(m.group(1))
                depth = 1
                if stripped.startswith("ENTRY"):
                    entry_name = cur.name
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, shape_str, op, rest = m.groups()
        # operand list = everything up to the matching close paren
        par = 0
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                par += 1
            elif ch == ")":
                if par == 0:
                    end = i
                    break
                par -= 1
        operand_str, attrs = rest[:end], rest[end + 1:]
        operands = _OPERAND_RE.findall(operand_str)
        cur.instrs.append(_Instr(name, op, shape_str, operands, attrs))
        cur.shapes[name] = shape_str
        if op == "constant":
            cm = _COND_CONST_RE.search(stripped)
            if cm:
                cur.consts.append(int(cm.group(1)))
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry_name


def _dot_flops(c: _Comp, ins: _Instr) -> float:
    res = _shape_arrays(ins.shape_str)
    if not res:
        return 0.0
    out_elems = math.prod(res[0][1]) if res[0][1] else 1
    lhs_shape = ()
    if ins.operands:
        lhs_str = c.shapes.get(ins.operands[0], "")
        arr = _shape_arrays(lhs_str)
        if arr:
            lhs_shape = arr[0][1]
    cm = _CONTRACT_RE.search(ins.attrs)
    contract = 1
    if cm and lhs_shape:
        for idx in (int(i) for i in cm.group(1).split(",") if i):
            if idx < len(lhs_shape):
                contract *= lhs_shape[idx]
    return 2.0 * out_elems * contract


def _conv_flops(c: _Comp, ins: _Instr) -> float:
    res = _shape_arrays(ins.shape_str)
    if not res or len(ins.operands) < 2:
        return 0.0
    out_elems = math.prod(res[0][1]) if res[0][1] else 1
    ker = _shape_arrays(c.shapes.get(ins.operands[1], ""))
    if not ker:
        return 0.0
    kelems = math.prod(ker[0][1]) if ker[0][1] else 1
    # approximate: per-output work = kernel elems / output features
    out_feat = ker[0][1][-1] if ker[0][1] else 1
    return 2.0 * out_elems * kelems / max(out_feat, 1)


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collectives: dict = field(default_factory=dict)

    @property
    def collective_total(self) -> float:
        return sum(self.collectives.values())

    def scaled(self, k: float) -> "HloCost":
        return HloCost(self.flops * k, self.bytes * k,
                       defaultdict(float, {kk: v * k for kk, v in self.collectives.items()}))

    def __add__(self, o: "HloCost") -> "HloCost":
        coll = defaultdict(float, self.collectives)
        for k, v in o.collectives.items():
            coll[k] += v
        return HloCost(self.flops + o.flops, self.bytes + o.bytes, coll)


def analyze(hlo_text: str) -> HloCost:
    comps, entry = _parse(hlo_text)
    if entry is None:
        for name in comps:
            if "main" in name:
                entry = name
                break
        else:
            return HloCost()
    memo: dict[str, HloCost] = {}

    def eff(name: str, stack=()) -> HloCost:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return HloCost()
        c = comps[name]
        tot = HloCost(collectives=defaultdict(float))
        for ins in c.instrs:
            base = ins.op
            is_done = base.endswith("-done")
            root = base[:-6] if base.endswith("-start") else (
                base[:-5] if is_done else base)
            if root in COLLECTIVES:
                if not is_done:
                    tot.collectives[root] += _shape_bytes_max(ins.shape_str)
                    # payload also moves through HBM
                    tot.bytes += 2 * _shape_bytes_max(ins.shape_str)
                continue
            if ins.op == "dot":
                tot.flops += _dot_flops(c, ins)
            elif ins.op == "convolution":
                tot.flops += _conv_flops(c, ins)
            if ins.op == "while":
                wm = _WHILE_ATTR_RE.search(ins.attrs)
                trip = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                elif wm and wm.group(1) in comps and comps[wm.group(1)].consts:
                    trip = max(comps[wm.group(1)].consts)
                if wm:
                    sub = eff(wm.group(2), stack + (name,)) + \
                        eff(wm.group(1), stack + (name,))
                    tot = tot + sub.scaled(trip)
                continue
            if ins.op in ("fusion", "call", "conditional", "custom-call",
                          "reduce", "map", "sort", "scatter", "select-and-scatter"):
                for rex in (_CALLS_RE, _TO_APPLY_RE):
                    for cm in rex.finditer(ins.attrs):
                        sub = eff(cm.group(1), stack + (name,))
                        # fusion/reduce bodies never touch HBM: take their
                        # FLOPs and collectives, not their bytes
                        tot.flops += sub.flops
                        for k, v in sub.collectives.items():
                            tot.collectives[k] += v
                        if ins.op in ("call", "conditional"):
                            tot.bytes += sub.bytes
            if ins.op in _SKIP_BYTES_OPS:
                continue
            b = _shape_bytes_sum(ins.shape_str)
            for opn in ins.operands:
                if opn in c.shapes:
                    b += _shape_bytes_sum(c.shapes[opn])
            tot.bytes += b
        memo[name] = HloCost(tot.flops, tot.bytes, dict(tot.collectives))
        return memo[name]

    return eff(entry)


def collective_bytes(hlo_text: str) -> dict[str, float]:
    return analyze(hlo_text).collectives


def total_collective_bytes(hlo_text: str) -> float:
    return analyze(hlo_text).collective_total


def while_trip_counts(hlo_text: str) -> list[int]:
    out = []
    for m in _TRIP_RE.finditer(hlo_text):
        out.append(int(m.group(1)))
    return out
