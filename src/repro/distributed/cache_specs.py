"""PartitionSpec trees for decode caches, mirroring models.model.init_caches.

Sharding strategy (see rules.py): batch over (pod,)data; feature dims (head
dim / latent dim / d_inner) over model so one-token cache writes stay local;
for batch==1 long-context shapes the token arena is sharded over the axes the
batch cannot use.
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.configs.base import AttentionRuntime, ModelConfig
from repro.models import transformer as tfm


def _cpq_tensor_specs(b, s):
    from repro.core.cpq import CPQTensor
    return CPQTensor(
        codes=P(b, s, None, "model"),
        scale=P(b, None, None, "model"),
        zero=P(b, None, None, "model"),
        level=P(b, s, None),
        num_levels=P(b, None),
        prune_thr=P(b, None, "model"),
    )


def layer_cache_specs(cfg: ModelConfig, rt: AttentionRuntime, kind, b, s):
    """b: mesh axes for batch (str/tuple/None); s: mesh axes for token arena."""
    from repro.core import kv_cache as kvc
    from repro.models.mamba import MambaState
    from repro.models.xlstm import MLSTMState, SLSTMState

    mixer, _ = kind
    if mixer == "xattn":
        return kvc.DenseKVCache(k=P(b, None, None, "model"),
                                v=P(b, None, None, "model"), length=P())
    if mixer == "mla":
        if rt.mode == "cpq":
            return kvc.CPQXCache(x=_cpq_tensor_specs(b, s),
                                 k_rope=P(b, s, None, None), length=P())
        return kvc.XCache(x=P(b, s, "model"), k_rope=P(b, s, None, None), length=P())
    if mixer == "attn":
        if rt.mode == "dense":
            return kvc.DenseKVCache(k=P(b, s, None, "model"),
                                    v=P(b, s, None, "model"), length=P())
        if rt.mode == "decomposed":
            return kvc.XCache(x=P(b, s, "model"), k_rope=P(b, s, None, None), length=P())
        if rt.mode == "decomposed_cpq":
            return kvc.CPQXCache(x=_cpq_tensor_specs(b, s),
                                 k_rope=P(b, s, None, None), length=P())
        if rt.mode == "cpq":
            return kvc.CPQKVCache(k=_cpq_tensor_specs(b, s),
                                  v=_cpq_tensor_specs(b, s), length=P())
        if rt.mode == "retrieval":
            return kvc.RetrievalCache(
                k=P(b, s, None, "model"), v=P(b, s, None, "model"),
                proxy=P(b, s, None, "model"),
                proxy_scale=P(b, None, "model"), proxy_zero=P(b, None, "model"),
                length=P())
        raise ValueError(rt.mode)
    if mixer == "mamba":
        return MambaState(conv=P(b, None, "model"), h=P(b, "model", None))
    if mixer == "mlstm":
        return MLSTMState(C=P(b, None, None, "model"), n=P(b, None, "model"),
                          m=P(b, None), conv=P(b, None, "model"))
    if mixer == "slstm":
        return SLSTMState(c=P(b, "model"), n=P(b, "model"),
                          h=P(b, "model"), m=P(b, "model"))
    raise ValueError(mixer)


def cache_pspecs(cfg: ModelConfig, rt: AttentionRuntime, batch_axes, seq_axes):
    """Spec tree matching models.model.init_caches output."""
    import jax

    b = batch_axes if batch_axes else None
    s = seq_axes if seq_axes else None

    prefix = [layer_cache_specs(cfg, rt, k, b, s) for k in cfg.prefix_pattern]

    def stacked(kind):
        one = layer_cache_specs(cfg, rt, kind, b, s)
        return jax.tree.map(lambda sp: P(None, *sp), one,
                            is_leaf=lambda x: isinstance(x, P))

    blocks = [stacked(k) for k in cfg.block_pattern]
    return {"prefix": prefix, "blocks": blocks}


# ------------------------------------------------------- paged serving arenas


def _paged_cpq_specs(rules: dict, latent: bool):
    """Spec tree for a serving PagedCPQTensor. ``latent`` selects the
    T1+T2 / MLA-CPQ layout (H == 1, D == d_model/L), which REPLICATES: its
    attend contracts over the feature axis, and feature-sharding would make
    GSPMD split that f32 reduction — summation order changes and greedy
    parity vs the single-device engine is no longer token-exact (observed).
    Head-axis pools are safe to shard because every contraction treats the
    kv-head axis as batch-like. Sharding the CPQ-X codes behind an exact
    psum-staged attend is an open item (ROADMAP)."""
    from repro.serving.paged_cache import PagedCPQTensor
    from repro.distributed.sharding import resolve

    if latent:
        rep = P()
        return PagedCPQTensor(codes=rep, level=rep, scale=rep, zero=rep,
                              num_levels=rep, prune_thr=rep)
    r = lambda *axes: resolve(rules, axes)  # noqa: E731
    return PagedCPQTensor(
        codes=r("page_pool", "page", "kv_heads", "head_dim"),
        level=r("page_pool", "page", "kv_heads"),
        scale=r("slots", "levels", "kv_heads", "head_dim"),
        zero=r("slots", "levels", "kv_heads", "head_dim"),
        num_levels=r("slots", "kv_heads"),
        prune_thr=r("slots", "kv_heads", "head_dim"))


def paged_container_specs(container, rules: dict | None = None):
    """PartitionSpec intent tree for a paged serving container (instance or
    eval_shape skeleton — only the container TYPES matter): per-kv-head page
    pools shard their head axis over ``model``, latent pools (T1 X / MLA
    c_kv / CPQ-X codes) shard their feature axis, the page-pool / page /
    slot axes replicate (rules from distributed.rules.serve_paged_rules).
    Specs are INTENT — callers fit them to concrete shapes with
    ``sharding.fit_spec_to_shape`` (which drops non-dividing axes, e.g.
    MLA's shared kv_r == 1 rope head). The single source of truth for BOTH
    device placement (engine) and shard_map in/out specs (serving/sharded)."""
    from repro.distributed.rules import serve_paged_rules
    from repro.distributed.sharding import resolve
    from repro.serving import paged_cache as pgc

    rules = serve_paged_rules() if rules is None else rules
    r = lambda *axes: resolve(rules, axes)  # noqa: E731
    c = container
    if isinstance(c, pgc.TieredPagedCache):
        return pgc.TieredPagedCache(dense=paged_container_specs(c.dense, rules),
                                    cpq=paged_container_specs(c.cpq, rules))
    if isinstance(c, pgc.PagedDenseKVCache):
        return pgc.PagedDenseKVCache(
            k=r("page_pool", "page", "kv_heads", "head_dim"),
            v=r("page_pool", "page", "kv_heads", "head_dim"))
    if isinstance(c, pgc.PagedXCache):
        return pgc.PagedXCache(
            x=r("page_pool", "page", "latent"),
            k_rope=r("page_pool", "page", "kv_heads", "head_dim"))
    if isinstance(c, pgc.PagedCPQKVCache):
        t = _paged_cpq_specs(rules, latent=False)
        return pgc.PagedCPQKVCache(k=t, v=t)
    if isinstance(c, pgc.PagedCPQXCache):
        return pgc.PagedCPQXCache(
            x=_paged_cpq_specs(rules, latent=True),
            k_rope=r("page_pool", "page", "kv_heads", "head_dim"))
    if isinstance(c, pgc.PagedRetrievalCache):
        return pgc.PagedRetrievalCache(
            k=r("page_pool", "page", "kv_heads", "head_dim"),
            v=r("page_pool", "page", "kv_heads", "head_dim"),
            proxy=r("page_pool", "page", "kv_heads", "head_dim"),
            proxy_scale=r("slots", "kv_heads", "head_dim"),
            proxy_zero=r("slots", "kv_heads", "head_dim"))
    raise TypeError(type(c))


def paged_layer_cache_specs(cfg: ModelConfig, rt: AttentionRuntime, kind,
                            serving, tiered: bool = False,
                            rules: dict | None = None):
    """PartitionSpec tree for ONE layer's paged serving container, mirroring
    models.transformer.layer_paged_cache_init (attention mixers get the
    ``paged_container_specs`` intent; recurrent / xattn state is slot-indexed
    and O(1)/request, so it replicates)."""
    import jax

    from repro.models import transformer as tfm
    from repro.serving import paged_cache as pgc

    skeleton = jax.eval_shape(
        lambda: tfm.layer_paged_cache_init(cfg, rt, kind, serving, tiered))
    if isinstance(skeleton, pgc.PagedCache):
        return paged_container_specs(skeleton, rules)
    return jax.tree.map(lambda _: P(), skeleton)


def paged_cache_pspecs(cfg: ModelConfig, rt: AttentionRuntime, serving,
                       tiered: bool = False, rules: dict | None = None):
    """Spec tree matching models.model.init_paged_caches output (prefix list
    + stacked blocks with a leading replicated layer axis)."""
    import jax

    prefix = [paged_layer_cache_specs(cfg, rt, k, serving, tiered, rules)
              for k in cfg.prefix_pattern]

    def stacked(kind):
        one = paged_layer_cache_specs(cfg, rt, kind, serving, tiered, rules)
        return jax.tree.map(lambda sp: P(None, *sp), one,
                            is_leaf=lambda x: isinstance(x, P))

    blocks = [stacked(k) for k in cfg.block_pattern]
    return {"prefix": prefix, "blocks": blocks}
