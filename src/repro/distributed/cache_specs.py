"""PartitionSpec trees for decode caches, mirroring models.model.init_caches.

Sharding strategy (see rules.py): batch over (pod,)data; feature dims (head
dim / latent dim / d_inner) over model so one-token cache writes stay local;
for batch==1 long-context shapes the token arena is sharded over the axes the
batch cannot use.
"""
from __future__ import annotations

from jax.sharding import PartitionSpec as P

from repro.configs.base import AttentionRuntime, ModelConfig
from repro.models import transformer as tfm


def _cpq_tensor_specs(b, s):
    from repro.core.cpq import CPQTensor
    return CPQTensor(
        codes=P(b, s, None, "model"),
        scale=P(b, None, None, "model"),
        zero=P(b, None, None, "model"),
        level=P(b, s, None),
        num_levels=P(b, None),
        prune_thr=P(b, None, "model"),
    )


def layer_cache_specs(cfg: ModelConfig, rt: AttentionRuntime, kind, b, s):
    """b: mesh axes for batch (str/tuple/None); s: mesh axes for token arena."""
    from repro.core import kv_cache as kvc
    from repro.models.mamba import MambaState
    from repro.models.xlstm import MLSTMState, SLSTMState

    mixer, _ = kind
    if mixer == "xattn":
        return kvc.DenseKVCache(k=P(b, None, None, "model"),
                                v=P(b, None, None, "model"), length=P())
    if mixer == "mla":
        if rt.mode == "cpq":
            return kvc.CPQXCache(x=_cpq_tensor_specs(b, s),
                                 k_rope=P(b, s, None, None), length=P())
        return kvc.XCache(x=P(b, s, "model"), k_rope=P(b, s, None, None), length=P())
    if mixer == "attn":
        if rt.mode == "dense":
            return kvc.DenseKVCache(k=P(b, s, None, "model"),
                                    v=P(b, s, None, "model"), length=P())
        if rt.mode == "decomposed":
            return kvc.XCache(x=P(b, s, "model"), k_rope=P(b, s, None, None), length=P())
        if rt.mode == "decomposed_cpq":
            return kvc.CPQXCache(x=_cpq_tensor_specs(b, s),
                                 k_rope=P(b, s, None, None), length=P())
        if rt.mode == "cpq":
            return kvc.CPQKVCache(k=_cpq_tensor_specs(b, s),
                                  v=_cpq_tensor_specs(b, s), length=P())
        if rt.mode == "retrieval":
            return kvc.RetrievalCache(
                k=P(b, s, None, "model"), v=P(b, s, None, "model"),
                proxy=P(b, s, None, "model"),
                proxy_scale=P(b, None, "model"), proxy_zero=P(b, None, "model"),
                length=P())
        raise ValueError(rt.mode)
    if mixer == "mamba":
        return MambaState(conv=P(b, None, "model"), h=P(b, "model", None))
    if mixer == "mlstm":
        return MLSTMState(C=P(b, None, None, "model"), n=P(b, None, "model"),
                          m=P(b, None), conv=P(b, None, "model"))
    if mixer == "slstm":
        return SLSTMState(c=P(b, "model"), n=P(b, "model"),
                          h=P(b, "model"), m=P(b, "model"))
    raise ValueError(mixer)


def cache_pspecs(cfg: ModelConfig, rt: AttentionRuntime, batch_axes, seq_axes):
    """Spec tree matching models.model.init_caches output."""
    import jax

    b = batch_axes if batch_axes else None
    s = seq_axes if seq_axes else None

    prefix = [layer_cache_specs(cfg, rt, k, b, s) for k in cfg.prefix_pattern]

    def stacked(kind):
        one = layer_cache_specs(cfg, rt, kind, b, s)
        return jax.tree.map(lambda sp: P(None, *sp), one,
                            is_leaf=lambda x: isinstance(x, P))

    blocks = [stacked(k) for k in cfg.block_pattern]
    return {"prefix": prefix, "blocks": blocks}
