"""Manual collective patterns (shard_map) for the hot distributed paths.

1. ``flash_decoding_attention`` — decode attention over a SEQUENCE-SHARDED
   cache: each shard computes (m, l, o) over its local tokens, then a single
   psum-based softmax combine merges shards. One small collective instead of
   all-gathering the cache. This is the distributed analogue of the paper's
   sub-matrix pipeline: partial attention results stream out of each memory
   shard and are merged, instead of centralizing the operand.

2. ``ring_decomposed_scores`` — T1 score stage over a sequence-sharded
   X-cache with a ppermute ring: compute on the resident block while the next
   block's owner index rotates — per-step overlap of collective and compute
   (paper Fig. 3(b) across chips).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
try:
    from jax import shard_map
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


def _local_flash(q, k, v, scale, base, length):
    """q: (B,H,Dh); k/v: (B,n,KV,Dh) local shard starting at global ``base``.
    Returns (m, l, o) partial softmax stats, f32."""
    B, H, Dh = q.shape
    KV = k.shape[2]
    g = H // KV
    qg = q.reshape(B, KV, g, Dh)
    s = jnp.einsum("bkgd,bnkd->bkgn", qg, k).astype(jnp.float32) * scale
    pos = base + jnp.arange(k.shape[1], dtype=jnp.int32)
    s = jnp.where((pos < length)[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)                      # (B,KV,g)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgn,bnkd->bkgd", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, o


def flash_decoding_attention(mesh: Mesh, seq_axis: str):
    """Returns fn(q (B,1,H,Dh), k, v (B,N,KV,Dh) seq-sharded, length) ->
    (B,1,H,Dh); softmax combine via psum over ``seq_axis``."""

    def inner(q, k, v, length, scale):
        ax = jax.lax.axis_index(seq_axis)
        n_local = k.shape[1]
        base = ax * n_local
        m, l, o = _local_flash(q[:, 0], k, v, scale, base, length)
        m_g = jax.lax.pmax(m, seq_axis)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * corr, seq_axis)
        o_g = jax.lax.psum(o * corr[..., None], seq_axis)
        out = o_g / jnp.maximum(l_g, 1e-30)[..., None]
        B, KV, g, Dh = out.shape
        return out.reshape(B, 1, KV * g, Dh).astype(q.dtype)

    def fn(q, k, v, length, scale: float):
        return shard_map(
            partial(inner, scale=scale),
            mesh=mesh,
            in_specs=(P(None, None, None, None), P(None, seq_axis, None, None),
                      P(None, seq_axis, None, None), P()),
            out_specs=P(None, None, None, None),
        )(q, k, v, length)

    return fn


def ring_decomposed_scores(mesh: Mesh, axis: str):
    """T1 score stage R X^T with HEADS sharded over ``axis`` and the X cache
    SEQUENCE-sharded over the same axis — the classic ring matmul: each shard
    computes its heads' scores against the resident X block while blocks
    rotate via ppermute, overlapping transfer with compute (the paper's
    sub-matrix pipeline across chips).

    Returns fn(r (B,H,Dm) heads-sharded, x (B,N,Dm) seq-sharded)
    -> scores (B,H,N) with H sharded over ``axis``."""
    n_dev = mesh.shape[axis]

    def inner(r, x):
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % n_dev) for i in range(n_dev)]

        def step(carry, _):
            xb, src = carry  # resident block, owner index of that block
            s = jnp.einsum("bhm,bnm->bhn", r, xb).astype(jnp.float32)
            xb = jax.lax.ppermute(xb, axis, perm)
            nxt = (src - 1) % n_dev
            return (xb, nxt), (s, src)

        (_, _), (ss, srcs) = jax.lax.scan(step, (x, idx), None, length=n_dev)
        # chunk computed at step t came from shard srcs[t]; restore global order
        order = jnp.argsort(srcs)
        ss = jnp.take(ss, order, axis=0)          # (n_dev, B, H_loc, n_local)
        return jnp.moveaxis(ss, 0, 2).reshape(r.shape[0], r.shape[1], -1)

    def fn(r, x):
        return shard_map(
            inner, mesh=mesh,
            in_specs=(P(None, axis, None), P(None, axis, None)),
            out_specs=P(None, axis, None),
        )(r, x)

    return fn
