"""Health probing and auto-drain for the multi-replica router.

``ReplicaRouter`` ticks a ``HealthMonitor`` once per ``step()`` (on the
router's own monotone clock, independent of engine work). Every
``probe_interval`` ticks the monitor runs a cheap probe against each
supervised replica — three checks, any failing marks the probe failed:

  liveness   ``engine.health()`` raises (a crashed / wedged-hard replica —
             injected ``ReplicaFault`` or any real exception)
  pressure   the replica reports an exhausted arena while holding queued
             work: explicit ``exhausted`` flag, or ``free_frac`` at/below
             ``probe_exhaust_frac`` with a non-empty queue
  progress   the replica had work at the previous probe, still has work,
             and its progress counter (engine step + admitted + retired)
             has not moved — a silent stall

State machine per replica (``ReplicaHealth.state``):

    healthy --probe fail--> suspect --fail_threshold consecutive--> down
       ^                       |                                      |
       +----probe success------+        (auto_drain: router._auto_drain)
       ^                                                              |
       +------------- recovery probe succeeds (readmit) --------------+

``down`` replicas are probed on exponential backoff (doubling from
``backoff`` up to 8x) rather than every interval; one successful recovery
probe re-admits the replica through ``router.readmit`` — it rejoins
placement and the parked backlog flushes onto it. A fault raised from
``step()`` itself (``note_fault``) counts as an immediate probe failure, so
a crashing replica needs no probe cycle to start accumulating strikes.

Manually drained replicas (caller-initiated ``router.drain``) are NOT
probed or re-admitted — the monitor only manages drains it initiated.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

BACKOFF_CAP_MULT = 8  # down-replica probe backoff doubles up to 8x base


@dataclasses.dataclass
class ReplicaHealth:
    """Per-replica probe bookkeeping (exposed via router stats)."""

    state: str = "healthy"            # healthy | suspect | down
    consecutive_failures: int = 0
    probe_failures: int = 0           # lifetime count
    probes: int = 0                   # lifetime probe count
    last_probe: int = -1              # monitor clock of last probe
    next_probe: int = 0               # earliest clock of the next probe
    backoff: int = 0                  # current down-state probe gap
    last_progress: int = -1           # progress counter at last good probe
    had_work: bool = False
    drained_at: int = -1              # monitor clock of the auto-drain
    last_error: str = ""


class HealthMonitor:
    """Probes a router's replicas and (optionally) auto-drains the sick.

    ``interval`` 0 disables periodic probing entirely — ``note_fault`` still
    records step() faults, and with ``auto_drain`` it still drains on the
    threshold (recovery probes then run on the backoff schedule, which does
    not need ``interval``)."""

    def __init__(self, router, interval: int = 4, fail_threshold: int = 3,
                 backoff: int = 4, exhaust_frac: float = 0.0,
                 auto_drain: bool = False):
        assert fail_threshold >= 1 and backoff >= 1 and interval >= 0
        self.router = router
        self.interval = interval
        self.fail_threshold = fail_threshold
        self.base_backoff = backoff
        self.exhaust_frac = exhaust_frac
        self.auto_drain = auto_drain
        self.replicas = [ReplicaHealth() for _ in router.engines]
        self.auto_drains = 0
        self.recoveries = 0

    # ------------------------------------------------------------- queries

    def state(self, i: int) -> str:
        return self.replicas[i].state

    def is_down(self, i: int) -> bool:
        return self.replicas[i].state == "down"

    def stats(self) -> dict:
        return {"auto_drains": self.auto_drains,
                "recoveries": self.recoveries,
                "down": sum(1 for r in self.replicas if r.state == "down")}

    # ------------------------------------------------------------- failures

    def note_fault(self, i: int, err: BaseException, now: int) -> None:
        """A replica's ``step()`` raised: immediate failure credit (no probe
        cycle needed for a crashing replica to hit the drain threshold)."""
        self._fail(i, f"step: {err}", now)

    def _fail(self, i: int, why: str, now: int) -> None:
        rh = self.replicas[i]
        rh.consecutive_failures += 1
        rh.probe_failures += 1
        rh.last_error = why
        if rh.state == "down":
            # still sick: back off harder (doubling, capped)
            rh.backoff = min(rh.backoff * 2,
                             self.base_backoff * BACKOFF_CAP_MULT)
            rh.next_probe = now + rh.backoff
            return
        rh.state = "suspect"
        if rh.consecutive_failures >= self.fail_threshold:
            rh.state = "down"
            rh.backoff = self.base_backoff
            rh.next_probe = now + rh.backoff
            rh.drained_at = now
            if self.auto_drain:
                self.auto_drains += 1
                self.router._auto_drain(i)

    def _recover(self, i: int, now: int) -> None:
        rh = self.replicas[i]
        was_down = rh.state == "down"
        rh.state = "healthy"
        rh.consecutive_failures = 0
        rh.backoff = 0
        rh.last_error = ""
        if was_down:
            self.recoveries += 1
            if self.auto_drain:
                self.router.readmit(i)

    # --------------------------------------------------------------- probes

    def _probe(self, i: int, now: int) -> None:
        rh = self.replicas[i]
        rh.probes += 1
        rh.last_probe = now
        eng = self.router.engines[i]
        try:
            h = eng.health()
        except BaseException as e:  # liveness: ANY raise is a failure
            self._fail(i, f"probe: {e}", now)
            return
        if h.get("exhausted") or (h["queued"] > 0
                                  and h["free_frac"] <= self.exhaust_frac):
            self._fail(i, "arena exhausted with queued work", now)
            return
        if (rh.had_work and h["has_work"]
                and h["progress"] == rh.last_progress):
            self._fail(i, "no progress since last probe", now)
            return
        rh.last_progress = h["progress"]
        rh.had_work = h["has_work"]
        self._recover(i, now)

    def tick(self, now: int) -> None:
        """Called by ``router.step()`` with the router's monitor clock.
        Probes every supervised replica that is due. Down replicas probe on
        their backoff schedule; healthy/suspect ones every ``interval``."""
        for i, rh in enumerate(self.replicas):
            if i in self.router._manual_drained:
                continue  # caller-managed: never probe or re-admit
            if rh.state == "down":
                if now >= rh.next_probe:
                    self._probe(i, now)
            elif self.interval and now >= rh.next_probe:
                # schedule first: a probe that takes the replica down
                # overwrites this with its backoff inside _fail
                rh.next_probe = now + self.interval
                self._probe(i, now)
