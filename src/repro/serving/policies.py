"""Pluggable scheduler policies: WHO gets admitted, preempted, escalated —
and, for the multi-replica router, WHERE a request is placed.

``serving/scheduler.py`` keeps the mechanisms — page allocation, slot
bookkeeping, state transitions — and delegates every *decision* to a
``SchedulerPolicy``:

  select_admission        which queued request takes the vacated slot, and
                          into which arena tier (0 = dense, 1 = T2 CPQ)
  preemption_victim       which slot holder is recomputed away when a grower
                          runs out of pages
  escalation_candidate    which running dense row is re-compressed into the
                          CPQ arena under critical memory pressure
  deescalation_candidate  which escalated (T2) row is restored to the dense
                          tier once pressure clears (chunked re-admission)

Policies see the scheduler read-only (queue, slots, allocators, watermark
fractions) and return Request objects; they never mutate scheduler state.
Three implementations:

  ``FifoPolicy``      today's behavior, decision-identical: head-of-queue
                      admission (no bypass), watermark tier assignment,
                      youngest-same-arena preemption, longest-dense
                      escalation, no de-escalation (unless opted in).
  ``PriorityPolicy``  strict ``SloClass.priority`` classes with aging: a
                      queued request gains one effective priority level per
                      ``aging_ticks`` waited, so starved low classes
                      eventually outrank fresh high ones. Preemption and
                      escalation pick low-priority victims first.
  ``SloAwarePolicy``  earliest-deadline-first admission by projected TTFT
                      slack (wait so far + the prompt's remaining chunk
                      ticks against ``SloClass.ttft_target``), low-priority
                      preemption/escalation victims, and de-escalation ON
                      by default: when the dense free-page fraction recovers
                      above ``ServingCfg.high_watermark``, the
                      highest-priority escalated row is re-admitted dense.

De-escalation (the ROADMAP's "T2 -> dense when pressure clears") is a
recompute: CPQ codes are lossy, so the dense K/V is rebuilt by chunked
re-admission of the request's ``prompt + generated`` context — the same
exact-replay path preemption uses. The candidate hook requires hysteresis
headroom (``free_frac > high_watermark >= low_watermark``) AND a full dense
fit for the row's context before volunteering it, so a de-escalated row is
never immediately re-escalated by the same watermark that moved it out.
"""
from __future__ import annotations

import dataclasses
import math
from typing import TYPE_CHECKING, Optional, Protocol, runtime_checkable

from repro.serving.paged_cache import pages_needed
from repro.serving.request import STANDARD, SloClass

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.serving.scheduler import Request, Scheduler


def slo_of(req: "Request") -> SloClass:
    """A request's service class (STANDARD when unset — legacy Requests)."""
    return req.slo if req.slo is not None else STANDARD


def derive_deadlines(sampling, slo: SloClass, arrival: float,
                     scale: float) -> tuple[float, float]:
    """(ttft_deadline, deadline) — ABSOLUTE engine ticks, ``math.inf`` = none.

    An explicit ``SamplingParams.deadline`` budget always wins for the total
    deadline (``arrival + budget``). Otherwise, with ``scale > 0`` and finite
    SloClass targets, the class targets become enforced budgets:

        ttft_deadline = arrival + scale * ttft_target
        deadline      = arrival + scale * (ttft_target
                                           + max_tokens * itl_target)

    ``scale`` is the slack multiplier (``ServingCfg.deadline_scale``): 1.0
    enforces the bare SLO targets, larger values give proportional headroom,
    0 disables class-derived deadlines entirely. Infinite targets (e.g. the
    BATCH class) never derive a deadline — batch work is shed by admission
    backpressure, not timers."""
    ttft_deadline = deadline = math.inf
    if math.isfinite(sampling.deadline):
        deadline = arrival + sampling.deadline
    elif scale > 0 and math.isfinite(slo.ttft_target) \
            and math.isfinite(slo.itl_target):
        deadline = arrival + scale * (slo.ttft_target
                                      + sampling.max_tokens * slo.itl_target)
    if scale > 0 and math.isfinite(slo.ttft_target):
        ttft_deadline = arrival + scale * slo.ttft_target
    return ttft_deadline, deadline


@runtime_checkable
class SchedulerPolicy(Protocol):
    """Decision interface consulted by ``Scheduler``. Implementations must
    be deterministic functions of scheduler state (serving is replayable)."""

    name: str

    def admission_order(self, sched: "Scheduler", now: float
                        ) -> list["Request"]:
        """Admission preference order over queued requests (the engine also
        reads this to identify the blocked candidate when an empty machine
        cannot place anyone — the unschedulable-drop path). May contain
        not-yet-arrived requests (e.g. a FIFO head); ``select_admission``
        filters those."""
        ...

    def select_admission(self, sched: "Scheduler", now: float
                         ) -> Optional[tuple["Request", int]]:
        """(request to admit, tier) — or None to leave the slot empty this
        tick. The request must be in ``sched.queue`` with
        ``arrival <= now``, and its context's pages must fit the tier's
        arena (the scheduler allocates exactly that)."""
        ...

    def preemption_victim(self, sched: "Scheduler", exclude: "Request"
                          ) -> Optional["Request"]:
        ...

    def escalation_candidate(self, sched: "Scheduler") -> Optional["Request"]:
        ...

    def deescalation_candidate(self, sched: "Scheduler") -> Optional["Request"]:
        ...


class FifoPolicy:
    """The pre-policy scheduler's decisions, verbatim. ``deescalate=True``
    opts the fifo order into the recovery hook (off by default so the
    default engine is decision-identical to before)."""

    name = "fifo"

    def __init__(self, deescalate: bool = False):
        self.deescalate = deescalate

    # -- admission --------------------------------------------------------
    def _arrived(self, sched: "Scheduler", now: float) -> list["Request"]:
        return [r for r in sched.queue if r.arrival <= now]

    def admission_order(self, sched: "Scheduler", now: float
                     ) -> list["Request"]:
        """Admission preference order over arrived requests. FIFO considers
        only the head: no bypass, so per-request latency stays fair."""
        return list(sched.queue)[:1] if self._arrived(sched, now) else []

    def _fit_tier(self, sched: "Scheduler", req: "Request"
                  ) -> Optional[int]:
        """Watermark tier assignment + arena fit (the shared mechanism all
        three policies use): below the low watermark new admissions go
        compressed; a full dense arena falls back to the CPQ arena.
        EXCEPTION: a de-escalation recovery replay (``req.recovering``) is
        pinned to the dense tier — if a racing admission consumed the dense
        headroom since the row was volunteered, it WAITS rather than paying
        a full-context recompute just to land compressed again."""
        tier = 0
        if (sched.tiered and not req.recovering
                and sched.free_frac() < sched.cfg.low_watermark):
            tier = 1
        need = pages_needed(len(req.context), sched.cfg.page_size)
        if not sched._arena(tier).can_alloc(need):
            if (tier == 0 and sched.tiered and not req.recovering
                    and sched.cpq_alloc.can_alloc(need)):
                tier = 1
            else:
                return None
        return tier

    def select_admission(self, sched, now):
        for req in self.admission_order(sched, now):
            if req.arrival > now:
                continue
            tier = self._fit_tier(sched, req)
            if tier is None:
                return None  # no bypass: the chosen request blocks the slot
            return req, tier
        return None

    # -- preemption -------------------------------------------------------
    def preemption_victim(self, sched, exclude):
        """Youngest slot holder in the SAME arena as the blocked request
        (evicting across arenas cannot unblock the grower)."""
        cands = [r for r in sched.occupied()
                 if r is not exclude and r.tier == exclude.tier]
        return max(cands, key=lambda r: r.admitted_step, default=None)

    # -- escalation -------------------------------------------------------
    @staticmethod
    def _cpq_fits(sched, r) -> bool:
        """The compressed footprint (one growth page included) must fit the
        CPQ arena AND the per-slot block ceiling — a row sitting exactly at
        ``max_len`` needs max_blocks+1 blocks and would overflow its alt
        block-table row (it is one growth step from the length-cap retire)."""
        need = pages_needed(r.length + 1, sched.cfg.page_size)
        return (need <= sched.cfg.max_blocks_per_slot
                and sched.cpq_alloc.can_alloc(need))

    def escalation_candidate(self, sched):
        """Under critical pressure: the longest running dense request whose
        compressed footprint fits the CPQ arena."""
        if sched.free_frac() >= sched.cfg.critical_watermark:
            return None
        cands = [r for r in sched.running() if r.tier == 0]
        for r in sorted(cands, key=lambda r: -r.length):
            if self._cpq_fits(sched, r):
                return r
        return None

    # -- de-escalation ----------------------------------------------------
    def _deesc_order(self, cands: list["Request"]) -> list["Request"]:
        """Recovery preference among escalated rows: shortest context first
        (cheapest recompute)."""
        return sorted(cands, key=lambda r: r.length)

    def deescalation_candidate(self, sched):
        if not self.deescalate:
            return None
        if sched.free_frac() <= sched.cfg.high_watermark:
            return None  # hysteresis: recover only with real headroom
        cands = [r for r in sched.running() if r.tier == 1]
        for r in self._deesc_order(cands):
            # the full context must fit dense NOW (re-admission is a
            # recompute; volunteering a row that cannot land thrashes)
            need = pages_needed(len(r.context) + 1, sched.cfg.page_size)
            if sched.dense_alloc.can_alloc(need):
                return r
        return None


class PriorityPolicy(FifoPolicy):
    """Strict priority classes with aging. Admission picks the highest
    effective priority — ``priority + waited // aging_ticks`` — breaking
    ties by arrival order, so high classes jump the queue but starved low
    classes climb one level per ``aging_ticks`` waited. Preemption and
    escalation spend low-priority rows first."""

    name = "priority"

    def __init__(self, aging_ticks: int = 64, deescalate: bool = False):
        super().__init__(deescalate=deescalate)
        assert aging_ticks >= 1
        self.aging_ticks = aging_ticks

    def effective_priority(self, req: "Request", now: float) -> float:
        return slo_of(req).priority + (max(0.0, now - req.arrival)
                                       // self.aging_ticks)

    def admission_order(self, sched, now):
        arrived = self._arrived(sched, now)
        order = {id(r): i for i, r in enumerate(sched.queue)}
        return sorted(arrived,
                      key=lambda r: (-self.effective_priority(r, now),
                                     r.arrival, order[id(r)]))[:1]

    def preemption_victim(self, sched, exclude):
        cands = [r for r in sched.occupied()
                 if r is not exclude and r.tier == exclude.tier]
        return max(cands,
                   key=lambda r: (-slo_of(r).priority, r.admitted_step),
                   default=None)

    def escalation_candidate(self, sched):
        if sched.free_frac() >= sched.cfg.critical_watermark:
            return None
        cands = [r for r in sched.running() if r.tier == 0]
        for r in sorted(cands, key=lambda r: (slo_of(r).priority, -r.length)):
            if self._cpq_fits(sched, r):
                return r
        return None

    def _deesc_order(self, cands):
        """Restore full-quality (dense) attention to important rows first."""
        return sorted(cands, key=lambda r: (-slo_of(r).priority, r.length))


class SloAwarePolicy(PriorityPolicy):
    """Earliest-deadline-first admission by projected TTFT slack.

    For each arrived request: ``projected_ttft = waited + remaining prefill
    chunk ticks``; slack = ``ttft_target - projected_ttft``. The request
    with the LEAST slack admits first (already-blown deadlines are the most
    negative, hence most urgent); infinite targets sort last, ordered by
    priority then arrival. Victim selection spends low-priority rows first
    (inherited), and de-escalation is ON by default — the paper's
    memory-pressure tiering run in both directions."""

    name = "slo"

    def __init__(self, aging_ticks: int = 64, deescalate: bool = True):
        super().__init__(aging_ticks=aging_ticks, deescalate=deescalate)

    def projected_ttft(self, sched: "Scheduler", req: "Request",
                       now: float) -> float:
        quantum = sched.cfg.prefill_chunk or sched.cfg.prefill_bucket
        chunks = -(-len(req.context) // quantum)
        return (now - req.arrival) + chunks

    def admission_order(self, sched, now):
        arrived = self._arrived(sched, now)
        order = {id(r): i for i, r in enumerate(sched.queue)}

        def key(r):
            slo = slo_of(r)
            slack = slo.ttft_target - self.projected_ttft(sched, r, now)
            return (math.isinf(slack), slack, -slo.priority, r.arrival,
                    order[id(r)])

        return sorted(arrived, key=key)[:1]


_POLICIES = {"fifo": FifoPolicy, "priority": PriorityPolicy,
             "slo": SloAwarePolicy}


def make_policy(name: str, **kw) -> SchedulerPolicy:
    """Policy factory for CLI / config strings: fifo | priority | slo."""
    try:
        return _POLICIES[name](**kw)
    except KeyError:
        raise ValueError(f"unknown scheduler policy {name!r}; "
                         f"choose from {sorted(_POLICIES)}") from None


# --------------------------------------------------- replica placement (router)


@dataclasses.dataclass(frozen=True)
class ReplicaView:
    """Read-only snapshot of one engine replica at placement time (built by
    ``ReplicaRouter`` from public engine surfaces; draining replicas are
    never offered). ``outstanding_tokens`` is the replica's owed work
    (``engine.outstanding_tokens()``: unprefilled context + undelivered
    generation budget); ``free_frac`` its dense free-page fraction
    (``engine.arena_stats()``); ``queued`` the number of requests waiting
    in its admission queue (saturation signal for backpressure)."""

    index: int
    outstanding_tokens: int
    free_frac: float
    queued: int = 0


@runtime_checkable
class PlacementPolicy(Protocol):
    """WHERE a request runs: consulted by ``ReplicaRouter.add_request`` with
    the non-draining replicas (ordered by index, never empty) AFTER session
    affinity — a pinned session bypasses placement entirely. Must return
    the ``.index`` of one offered view, deterministically (routing is
    replayable, like scheduling)."""

    name: str

    def select(self, views: list[ReplicaView], req: "Request") -> int:
        ...


class RoundRobinPlacement:
    """Cycle over the offered replicas in order — the zero-knowledge
    baseline. Stateful cursor; a drained replica simply drops out of the
    rotation."""

    name = "rr"

    def __init__(self):
        self._turn = 0

    def select(self, views, req):
        v = views[self._turn % len(views)]
        self._turn += 1
        return v.index


class LeastLoadedPlacement:
    """Least outstanding tokens first (ties by replica index): balances the
    owed work — remaining prefill plus undelivered generation budget —
    rather than raw request counts, so a replica chewing a long-context
    batch job stops attracting traffic before its queue length shows it."""

    name = "load"

    def select(self, views, req):
        return min(views, key=lambda v: (v.outstanding_tokens, v.index)).index


class SloPressurePlacement:
    """SLO- and arena-pressure-aware placement.

    Latency-bound requests (a finite ``ttft_target`` or priority at/above
    ``interactive_priority``) go to the replica with the MOST free pages
    (ties: least outstanding) — a pressured replica would admit them into
    the compressed tier, queue them behind watermark churn, or preempt
    them, all of which burn TTFT/ITL slack. Deadline-free batch work packs
    by least outstanding tokens instead (ties: most free pages), keeping
    throughput balanced without competing for the headroom the latency
    classes need."""

    name = "slo"

    def __init__(self, interactive_priority: int = 2):
        self.interactive_priority = interactive_priority

    def select(self, views, req):
        slo = slo_of(req)
        latency_bound = (math.isfinite(slo.ttft_target)
                         or slo.priority >= self.interactive_priority)
        if latency_bound:
            return max(views, key=lambda v: (v.free_frac,
                                             -v.outstanding_tokens,
                                             -v.index)).index
        return min(views, key=lambda v: (v.outstanding_tokens,
                                         -v.free_frac, v.index)).index


_PLACEMENTS = {"rr": RoundRobinPlacement, "load": LeastLoadedPlacement,
               "slo": SloPressurePlacement}


def make_placement(name: str, **kw) -> PlacementPolicy:
    """Placement factory for CLI / config strings: rr | load | slo."""
    try:
        return _PLACEMENTS[name](**kw)
    except KeyError:
        raise ValueError(f"unknown placement policy {name!r}; "
                         f"choose from {sorted(_PLACEMENTS)}") from None
