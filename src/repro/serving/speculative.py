"""Speculative decoding over paged arenas: drafting + draft bookkeeping.

Decode at low occupancy is weight-stream-bound — every generated token
re-streams the full weight set (the idle amplification bench_e2e_energy's
device model charges). Speculative decoding amortizes ONE weight stream over
up to ``ServingCfg.spec_len`` candidate tokens:

1. **Drafting is free**: ``propose_ngram`` (prompt lookup) guesses the next
   tokens from the request's OWN context — the longest suffix n-gram that
   occurred earlier proposes the tokens that followed it. No second model,
   no extra weights on the mesh.
2. **Draft rows alias the target's pages**: ``Scheduler.begin_draft`` takes
   a reference on every page the target currently maps (the PR-7 refcounted
   block tables) and allocates fresh SCRATCH pages only for the blocks the
   candidates land in — zero arena writes for the shared history. A partial
   frontier page is replaced by a payload-copied scratch page so
   verification never writes into a page the target (or a prefix sharer)
   still owns; reject leaves the target's arena bit-identical.
3. **Verification is one Q-chunk>1 paged attend**: the engine runs
   ``model.verify_chunk_rows`` — the chunked-prefill forward pass
   (per-query-row causal mask, scalar-prefetch paged kernels, shard_map
   routing under a mesh) with logits kept at EVERY position — scoring all
   k candidates in a single model invocation.
4. **Accept/reject keeps the sampler reproducible**: position ``L+i``'s
   logits are drawn through the SAME jitted ``sample_token_rows`` at stream
   index ``num_generated + i`` — a committed token is ALWAYS the request's
   own ``fold_in(seed, token_index)`` draw (argmax for greedy rows), and a
   draft token is accepted iff it EQUALS that draw. Greedy streams are
   bit-identical speculative on-vs-off; seeded streams are
   distribution-exact (every committed token is an on-policy sampler draw)
   and replay-stable across preemption and router migration.

The scheduler ops (``begin_draft`` / ``commit_draft`` / ``abort_draft``)
keep the allocator invariant — refcount == owner count, free-list
membership iff refcount 0 — under ANY interleaving with
admit/chunk/COW/preempt/escalate/retire/defrag
(``tests/test_serving_speculative.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class DraftState:
    """Page bookkeeping for one OPEN draft (between ``begin_draft`` and
    ``commit_draft``/``abort_draft``; the engine opens and closes a draft
    within a single tick, but the scheduler ops tolerate any interleaving).

    ``scratch[i]`` is the fresh page standing in for logical block
    ``blocks[i]`` in the draft's view of the row; ``aliased`` are the
    target's own pages the draft holds one reference each on (history reads
    plus the replaced frontier). ``copy_src >= 0`` names the partial
    frontier page whose payload must seed ``scratch[0]`` before the verify
    chunk runs (the engine's jitted page copy)."""

    tokens: list = field(default_factory=list)   # drafted candidate tokens
    scratch: list = field(default_factory=list)  # fresh pages, block order
    blocks: list = field(default_factory=list)   # logical blocks they cover
    aliased: list = field(default_factory=list)  # target pages incref'd
    copy_src: int = -1


def propose_ngram(ctx: np.ndarray, max_ngram: int, k: int) -> np.ndarray:
    """Prompt-lookup drafting: match the longest suffix n-gram
    (``n = max_ngram`` down to 1) against the earlier context; the LATEST
    occurrence wins (recency — repeated structure near the cursor predicts
    best) and the ``k`` tokens that followed it become the draft. Returns
    (<=k,) int32 — possibly empty (no n-gram recurs: the caller falls back
    to a normal decode step for the row)."""
    ctx = np.asarray(ctx, np.int32)
    T = int(len(ctx))
    if k <= 0 or T < 2:
        return np.zeros((0,), np.int32)
    for n in range(min(max_ngram, T - 1), 0, -1):
        pat = ctx[T - n:]
        # candidate windows start at 0..T-n-1: a match must be FOLLOWED by
        # at least one context token (the window at the suffix's own
        # position is excluded by construction)
        hay = np.lib.stride_tricks.sliding_window_view(ctx[:T - 1], n)
        hits = np.nonzero((hay == pat[None, :]).all(axis=1))[0]
        if len(hits):
            start = int(hits[-1]) + n
            return ctx[start:start + k].copy()
    return np.zeros((0,), np.int32)
