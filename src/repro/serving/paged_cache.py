"""Block-paged KV-cache arenas for continuous-batching serving.

The static containers in ``core/kv_cache.py`` dedicate a contiguous
``(B, n_max, ...)`` arena to every request slot; a short request strands the
rest of its row. Here the token axis is cut into fixed-size **pages** owned by
a shared physical pool ``(P, page_size, ...)``, and a per-slot **block table**
``(B, max_blocks)`` maps logical token blocks to physical pages (the vLLM
construction, adapted to the paper's five cache tiers). The paper's motivating
observation — "the KV cache can grow unpredictably and even surpass the
model's weight size" — becomes an allocation problem: pages are allocated at
admission/decode, freed at retirement, and the pool utilization drives the
scheduler's watermark/tier-escalation policy.

Layout invariants (shared by every paged container):

  * Physical page 0 is the reserved **null page**: unmapped block-table
    entries are 0 and the writes of inactive rows are routed there, so decode
    steps stay branch-free under jit. Its contents are garbage by design.
  * A slot's logical view is ``pages[block_table[b]]`` flattened to
    ``(max_blocks * page_size, ...)``; slots beyond ``lengths[b]`` are masked
    by every attention mode (core attention takes per-row ``(B,)`` lengths).
  * Per-token state pages; per-SEQUENCE state (CPQ scale/zero/levels,
    retrieval proxy calibration) stays slot-indexed ``(B, ...)`` — it is
    O(1) per request and is overwritten at admission.

Mode -> paged container (mirrors core/kv_cache.py):
  dense      PagedDenseKVCache   K,V pages
  decomposed PagedXCache         X pages (+ roped key pages)     (T1)
  cpq        PagedCPQKVCache     CPQ code/level pages, slot stats (T2)
  retrieval  PagedRetrievalCache K,V,proxy pages, slot calibration (T3)
  cpq+decomp PagedCPQXCache      CPQ(X) pages (+ roped key pages) (T1+T2)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CPQCfg, RetrievalCfg
from repro.core import cpq as cpq_lib
from repro.core import kv_cache as kvc

NULL_PAGE = 0


class RowState(NamedTuple):
    """Per-step request-row state threaded through the jitted decode step
    (the paged analogue of the scalar ``pos`` argument)."""

    lengths: jax.Array      # (B,) int32 — valid tokens per slot (= next position)
    block_table: jax.Array  # (B, max_blocks) int32 physical page ids; 0 = unmapped
    active: jax.Array       # (B,) bool — row decodes this step (writes commit)
    tier: jax.Array         # (B,) int32 — 0 = base tier, 1 = escalated tier
    alt_block_table: Optional[jax.Array] = None  # escalated-arena table (tiered)


# -------------------------------------------------------------- page plumbing


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize logical views: (P, page, ...) x (B, max_blocks)
    -> (B, max_blocks * page, ...). Unmapped blocks read the null page and
    must be masked by lengths downstream."""
    g = jnp.take(pages, block_table, axis=0)  # (B, max_blocks, page, ...)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


def write_token_pages(pages: jax.Array, block_table: jax.Array, lengths: jax.Array,
                      active: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter one token per row at slot ``lengths[b]``. val: (B, ...) —
    token payload per row. Inactive rows write the null page."""
    page_size, max_blocks = pages.shape[1], block_table.shape[1]
    blk = jnp.clip(lengths // page_size, 0, max_blocks - 1)
    page_idx = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    page_idx = jnp.where(active, page_idx, NULL_PAGE)
    off = lengths % page_size
    return pages.at[page_idx, off].set(val.astype(pages.dtype))


def write_prompt_pages(pages: jax.Array, block_row: jax.Array, val: jax.Array) -> jax.Array:
    """Bulk-write a prompt into one slot's pages. block_row: (max_blocks,);
    val: (S, ...). Positions whose block is unmapped or beyond max_blocks
    (bucket padding past the slot's capacity) land on the null page — they
    must never wrap around onto mapped pages."""
    S, page_size = val.shape[0], pages.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    blk = pos // page_size
    in_range = blk < block_row.shape[0]
    pidx = jnp.where(in_range,
                     block_row[jnp.clip(blk, 0, block_row.shape[0] - 1)],
                     NULL_PAGE)
    return pages.at[pidx, pos % page_size].set(val.astype(pages.dtype))


def _sel_rows(active: jax.Array, new, old):
    """Per-slot side-state commit: keep ``new`` on active rows only."""
    return jax.tree.map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
        new, old)


# ----------------------------------------------------------------- allocator


class PageAllocator:
    """Host-side free-list over the physical pool (page 0 reserved as null).

    The scheduler owns one per arena; alloc/free are O(n). ``OutOfPages`` is
    the admission-control signal, not an error state."""

    class OutOfPages(RuntimeError):
        pass

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need >= 1 allocatable page beyond the null page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))  # pop() hands out low ids first

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        return self.num_used / max(self.num_pages - 1, 1)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise self.OutOfPages(f"want {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        return out

    def free(self, pages) -> None:
        for p in pages:
            assert p != NULL_PAGE, "freeing the null page"
            assert p not in self._free, f"double free of page {p}"
            self._free.append(int(p))


def pages_needed(tokens: int, page_size: int) -> int:
    return -(-int(tokens) // page_size)


def defrag_plan(block_table, num_pages: int):
    """Compaction plan: remap every mapped page onto the lowest physical ids,
    ordered by (slot, logical block) so each request's pages become physically
    contiguous again after a churn of retirements (locality for the fused
    kernels' sequential page reads).

    ``block_table`` is a host array (B, max_blocks). Returns
    (perm, new_block_table, free): ``perm[new_id] = old_id`` — apply to every
    page-major pool with ``jnp.take(pages, perm, axis=0)`` — and ``free`` is
    the rebuilt free list (same LIFO convention as PageAllocator)."""
    import numpy as np

    bt = np.asarray(block_table)
    used: list[int] = []
    seen = set()
    for b in range(bt.shape[0]):
        for j in range(bt.shape[1]):
            p = int(bt[b, j])
            if p != NULL_PAGE and p not in seen:
                seen.add(p)
                used.append(p)
    perm = [NULL_PAGE] + used
    in_front = set(perm)
    perm += [p for p in range(num_pages) if p not in in_front]  # park stale pages
    remap = {old: new for new, old in enumerate(perm)}  # total map; 0 -> 0
    new_bt = np.array([[remap[int(p)] for p in row] for row in bt], dtype=bt.dtype)
    free = list(range(num_pages - 1, len(used), -1))  # pop() hands out low ids
    return np.asarray(perm, dtype=np.int32), new_bt, free


# ------------------------------------------------------------- paged containers


class PagedDenseKVCache(NamedTuple):
    k: jax.Array  # (P, page, KV, Dh)
    v: jax.Array  # (P, page, KV, Dh)


class PagedXCache(NamedTuple):
    x: jax.Array       # (P, page, Dm)
    k_rope: jax.Array  # (P, page, KV, R)


class PagedCPQTensor(NamedTuple):
    """CPQ arena split into per-token pages + per-slot HQE side state."""

    codes: jax.Array       # (P, page, H, D) int8
    level: jax.Array       # (P, page, H) int32
    scale: jax.Array       # (B, L, H, D) f32
    zero: jax.Array        # (B, L, H, D) f32
    num_levels: jax.Array  # (B, H) int32
    prune_thr: jax.Array   # (B, H, D) f32


class PagedCPQKVCache(NamedTuple):
    k: PagedCPQTensor
    v: PagedCPQTensor


class PagedRetrievalCache(NamedTuple):
    k: jax.Array            # (P, page, KV, Dh)
    v: jax.Array            # (P, page, KV, Dh)
    proxy: jax.Array        # (P, page, KV, Dp) int8
    proxy_scale: jax.Array  # (B, KV, Dp) f32
    proxy_zero: jax.Array   # (B, KV, Dp) f32


class PagedCPQXCache(NamedTuple):
    x: PagedCPQTensor       # H = 1, D = Dm
    k_rope: jax.Array       # (P, page, KV, R)


class TieredPagedCache(NamedTuple):
    """Dense base arena + CPQ escalation arena; ``RowState.tier`` selects the
    live one per row (the watermark policy's dense -> T2 migration target)."""

    dense: PagedDenseKVCache
    cpq: PagedCPQKVCache


PagedCache = (PagedDenseKVCache | PagedXCache | PagedCPQKVCache
              | PagedRetrievalCache | PagedCPQXCache | TieredPagedCache)


# ------------------------------------------------------------- constructors


def init_paged_dense(num_pages: int, page_size: int, kv: int, dh: int,
                     dtype=jnp.bfloat16) -> PagedDenseKVCache:
    z = jnp.zeros((num_pages, page_size, kv, dh), dtype)
    return PagedDenseKVCache(z, z)


def init_paged_x(num_pages: int, page_size: int, dm: int, kv: int, rope_dims: int,
                 dtype=jnp.bfloat16) -> PagedXCache:
    return PagedXCache(
        x=jnp.zeros((num_pages, page_size, dm), dtype),
        k_rope=jnp.zeros((num_pages, page_size, kv, rope_dims), dtype))


def _init_paged_cpq_tensor(num_pages: int, page_size: int, num_slots: int,
                           h: int, d: int, cfg: CPQCfg) -> PagedCPQTensor:
    return PagedCPQTensor(
        codes=jnp.zeros((num_pages, page_size, h, d), jnp.int8),
        level=jnp.zeros((num_pages, page_size, h), jnp.int32),
        scale=jnp.zeros((num_slots, cfg.max_levels, h, d), jnp.float32),
        zero=jnp.zeros((num_slots, cfg.max_levels, h, d), jnp.float32),
        num_levels=jnp.ones((num_slots, h), jnp.int32),
        prune_thr=jnp.zeros((num_slots, h, d), jnp.float32))


def init_paged_cpq(num_pages: int, page_size: int, num_slots: int, kv: int, dh: int,
                   cfg: CPQCfg) -> PagedCPQKVCache:
    return PagedCPQKVCache(
        k=_init_paged_cpq_tensor(num_pages, page_size, num_slots, kv, dh, cfg),
        v=_init_paged_cpq_tensor(num_pages, page_size, num_slots, kv, dh, cfg))


def init_paged_retrieval(num_pages: int, page_size: int, num_slots: int, kv: int,
                         dh: int, cfg: RetrievalCfg, dtype=jnp.bfloat16
                         ) -> PagedRetrievalCache:
    dp = cfg.proxy_dim or dh
    z = jnp.zeros((num_pages, page_size, kv, dh), dtype)
    return PagedRetrievalCache(
        k=z, v=z,
        proxy=jnp.zeros((num_pages, page_size, kv, dp), jnp.int8),
        proxy_scale=jnp.ones((num_slots, kv, dp), jnp.float32),
        proxy_zero=jnp.zeros((num_slots, kv, dp), jnp.float32))


def init_paged_cpq_x(num_pages: int, page_size: int, num_slots: int, dm: int,
                     kv: int, rope_dims: int, cfg: CPQCfg,
                     dtype=jnp.bfloat16) -> PagedCPQXCache:
    return PagedCPQXCache(
        x=_init_paged_cpq_tensor(num_pages, page_size, num_slots, 1, dm, cfg),
        k_rope=jnp.zeros((num_pages, page_size, kv, rope_dims), dtype))


# ------------------------------------------------------------ logical views


def logical_cpq(t: PagedCPQTensor, block_table: jax.Array) -> cpq_lib.CPQTensor:
    """Contiguous CPQTensor view of a paged CPQ arena (codes gathered through
    the block table; per-slot stats already contiguous). The chunked decode
    kernels consume this with per-row lengths."""
    return cpq_lib.CPQTensor(
        codes=gather_pages(t.codes, block_table),
        scale=t.scale, zero=t.zero,
        level=gather_pages(t.level, block_table),
        num_levels=t.num_levels, prune_thr=t.prune_thr)


# -------------------------------------------------------------- decode append


def append_dense(cache: PagedDenseKVCache, rows: RowState,
                 k_t: jax.Array, v_t: jax.Array) -> PagedDenseKVCache:
    """k_t/v_t: (B, 1, KV, Dh) new token per row."""
    return PagedDenseKVCache(
        k=write_token_pages(cache.k, rows.block_table, rows.lengths, rows.active, k_t[:, 0]),
        v=write_token_pages(cache.v, rows.block_table, rows.lengths, rows.active, v_t[:, 0]))


def append_x(cache: PagedXCache, rows: RowState,
             x_t: jax.Array, k_rope_t: Optional[jax.Array]) -> PagedXCache:
    return PagedXCache(
        x=write_token_pages(cache.x, rows.block_table, rows.lengths, rows.active, x_t[:, 0]),
        k_rope=(write_token_pages(cache.k_rope, rows.block_table, rows.lengths,
                                  rows.active, k_rope_t[:, 0])
                if k_rope_t is not None else cache.k_rope))


def append_cpq_tensor(t: PagedCPQTensor, rows: RowState, x_t: jax.Array,
                      cfg: CPQCfg) -> PagedCPQTensor:
    """HQE-encode one token per row (shared math with the contiguous path)
    and scatter code/level through the block table. Side-state updates only
    commit on active rows."""
    code_t, level_t, scale, zero, num_levels = cpq_lib.cpq_encode_token(
        t.scale, t.zero, t.num_levels, t.prune_thr, x_t, cfg)
    scale, zero, num_levels = _sel_rows(
        rows.active, (scale, zero, num_levels), (t.scale, t.zero, t.num_levels))
    return PagedCPQTensor(
        codes=write_token_pages(t.codes, rows.block_table, rows.lengths,
                                rows.active, code_t[:, 0]),
        level=write_token_pages(t.level, rows.block_table, rows.lengths,
                                rows.active, level_t),
        scale=scale, zero=zero, num_levels=num_levels, prune_thr=t.prune_thr)


# ------------------------------------------------------------- prefill pack


def pack_dense(cache: PagedDenseKVCache, src: kvc.DenseKVCache,
               block_row: jax.Array) -> PagedDenseKVCache:
    """Scatter a freshly prefilled contiguous B=1 cache into one slot's pages."""
    return PagedDenseKVCache(
        k=write_prompt_pages(cache.k, block_row, src.k[0]),
        v=write_prompt_pages(cache.v, block_row, src.v[0]))


def pack_x(cache: PagedXCache, src: kvc.XCache, block_row: jax.Array) -> PagedXCache:
    return PagedXCache(
        x=write_prompt_pages(cache.x, block_row, src.x[0]),
        k_rope=write_prompt_pages(cache.k_rope, block_row, src.k_rope[0]))


def pack_cpq_tensor(t: PagedCPQTensor, src: cpq_lib.CPQTensor, block_row: jax.Array,
                    slot: jax.Array) -> PagedCPQTensor:
    return PagedCPQTensor(
        codes=write_prompt_pages(t.codes, block_row, src.codes[0]),
        level=write_prompt_pages(t.level, block_row, src.level[0]),
        scale=t.scale.at[slot].set(src.scale[0]),
        zero=t.zero.at[slot].set(src.zero[0]),
        num_levels=t.num_levels.at[slot].set(src.num_levels[0]),
        prune_thr=t.prune_thr.at[slot].set(src.prune_thr[0]))


def pack_cpq(cache: PagedCPQKVCache, src: kvc.CPQKVCache, block_row: jax.Array,
             slot: jax.Array) -> PagedCPQKVCache:
    return PagedCPQKVCache(
        k=pack_cpq_tensor(cache.k, src.k, block_row, slot),
        v=pack_cpq_tensor(cache.v, src.v, block_row, slot))


def pack_retrieval(cache: PagedRetrievalCache, src: kvc.RetrievalCache,
                   block_row: jax.Array, slot: jax.Array) -> PagedRetrievalCache:
    return PagedRetrievalCache(
        k=write_prompt_pages(cache.k, block_row, src.k[0]),
        v=write_prompt_pages(cache.v, block_row, src.v[0]),
        proxy=write_prompt_pages(cache.proxy, block_row, src.proxy[0]),
        proxy_scale=cache.proxy_scale.at[slot].set(src.proxy_scale[0]),
        proxy_zero=cache.proxy_zero.at[slot].set(src.proxy_zero[0]))


def pack_cpq_x(cache: PagedCPQXCache, src: kvc.CPQXCache, block_row: jax.Array,
               slot: jax.Array) -> PagedCPQXCache:
    return PagedCPQXCache(
        x=pack_cpq_tensor(cache.x, src.x, block_row, slot),
        k_rope=write_prompt_pages(cache.k_rope, block_row, src.k_rope[0]))


def pack_into(rt_mode: str, cache, src, block_row: jax.Array, slot: jax.Array):
    """Mode dispatch for admission packing (contiguous B=1 prefill -> pages)."""
    if isinstance(cache, TieredPagedCache):
        if isinstance(src, kvc.DenseKVCache):
            return cache._replace(dense=pack_dense(cache.dense, src, block_row))
        return cache._replace(cpq=pack_cpq(cache.cpq, src, block_row, slot))
    if isinstance(cache, PagedDenseKVCache):
        return pack_dense(cache, src, block_row)
    if isinstance(cache, PagedXCache):
        return pack_x(cache, src, block_row)
    if isinstance(cache, PagedCPQKVCache):
        return pack_cpq(cache, src, block_row, slot)
    if isinstance(cache, PagedRetrievalCache):
        return pack_retrieval(cache, src, block_row, slot)
    if isinstance(cache, PagedCPQXCache):
        return pack_cpq_x(cache, src, block_row, slot)
    raise TypeError(type(cache))


# ------------------------------------------------------------------- traffic


def bytes_per_token(cache: PagedCache, page_size: int,
                    cpq_cfg: Optional[CPQCfg] = None) -> float:
    """Per-token decode traffic of the paged arena: the contiguous payload
    accounting (kv_cache.bytes_per_token / cpq accounting) plus the amortized
    block-table overhead (one int32 entry per page). Hooked by
    benchmarks/bench_e2e_energy.py and the scheduler's watermark policy."""
    overhead = 4.0 / page_size
    if isinstance(cache, TieredPagedCache):  # base-tier accounting
        return bytes_per_token(cache.dense, page_size, cpq_cfg)
    if isinstance(cache, PagedDenseKVCache):
        payload = 2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
    elif isinstance(cache, PagedXCache):
        payload = (cache.x.shape[2] * cache.x.dtype.itemsize
                   + cache.k_rope.shape[2] * cache.k_rope.shape[3]
                   * cache.k_rope.dtype.itemsize)
    elif isinstance(cache, PagedCPQKVCache):
        cfg = cpq_cfg or CPQCfg()
        payload = 2.0 * cpq_lib.cpq_bytes_per_token(
            cfg, cache.k.codes.shape[2], cache.k.codes.shape[3])
    elif isinstance(cache, PagedRetrievalCache):
        payload = (2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
                   + cache.proxy.shape[2] * cache.proxy.shape[3])
    elif isinstance(cache, PagedCPQXCache):
        cfg = cpq_cfg or CPQCfg()
        payload = (cpq_lib.cpq_bytes_per_token(cfg, 1, cache.x.codes.shape[3])
                   + cache.k_rope.shape[2] * cache.k_rope.shape[3]
                   * cache.k_rope.dtype.itemsize)
    else:
        raise TypeError(type(cache))
    return payload + overhead


def arena_bytes(cache: PagedCache) -> int:
    """Total physical bytes of the paged arena (all pools + slot side state)."""
    return int(sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache)))


# ------------------------------------------------------------- decode attend


def decode_attend_paged(
    rt,
    cache: PagedCache,
    rows: RowState,
    *,
    q: jax.Array,                   # (B, 1, H, Dh) roped query
    k_t: jax.Array,                 # (B, 1, KV, Dh) roped new key
    v_t: jax.Array,                 # (B, 1, KV, Dh)
    x_t: Optional[jax.Array],       # (B, 1, Dm)
    k_rope_t: Optional[jax.Array],  # (B, 1, KV, R)
    q_nope: Optional[jax.Array],    # (B, 1, H, Dn) content query (T1)
    q_rope: Optional[jax.Array],    # (B, 1, H, R) roped query slice (T1)
    w_k_nope: Optional[jax.Array],  # (Dm, KV, Dn) (T1)
    w_v: Optional[jax.Array],       # (Dm, KV, Dh) (T1)
    scale: float,
) -> tuple[jax.Array, PagedCache]:
    """Paged analogue of ``core.attention.decode_attend``: scatter one token
    per row through the block table, then attend with per-row lengths. With
    ``rt.paged_kernels`` (the default) the dense, CPQ, and X/MLA tiers run
    the fused paged Pallas kernels, whose grid iterates block-table entries
    and DMAs mapped pages straight from the arena into VMEM — no contiguous
    logical view is ever materialized. ``rt.paged_kernels=False`` falls back
    to the jnp gather path (the numerics oracle and benchmark foil);
    retrieval (T3, top-k slot selection) and the T1+T2 composition keep the
    gather path. Every row sits at its own position (``rows.lengths``);
    inactive rows write the null page and their output is garbage the engine
    never reads. Returns (out (B,1,H,Dv), new_cache)."""
    from repro.configs.base import AttentionRuntime
    from repro.core import attention as core_attn
    from repro.core import retrieval_attention as ret_lib
    from repro.core.decomposed_attention import decomposed_attention
    from repro.kernels.cpq_dequant_attn.ops import paged_cpq_decode_tpu
    from repro.kernels.decomposed_attn.ops import paged_decomposed_decode_tpu
    from repro.kernels.flash_attn.ops import paged_flash_decode_tpu

    fused = rt.paged_kernels
    new_len = rows.lengths + rows.active.astype(jnp.int32)

    if isinstance(cache, TieredPagedCache):
        # compute both tiers (each tier's appends masked to its own rows),
        # select per row — one jitted step serves a mixed dense/T2 batch
        rows_d = rows._replace(active=rows.active & (rows.tier == 0))
        rows_c = rows._replace(active=rows.active & (rows.tier == 1),
                               block_table=rows.alt_block_table)
        rt_c = AttentionRuntime(mode="cpq", cpq=rt.cpq, paged_kernels=fused)
        out_d, dense = decode_attend_paged(
            rt, cache.dense, rows_d, q=q, k_t=k_t, v_t=v_t, x_t=x_t,
            k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        out_c, cpq = decode_attend_paged(
            rt_c, cache.cpq, rows_c, q=q, k_t=k_t, v_t=v_t, x_t=x_t,
            k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        out = jnp.where((rows.tier == 1)[:, None, None, None], out_c, out_d)
        return out, TieredPagedCache(dense, cpq)

    if isinstance(cache, PagedDenseKVCache):
        cache = append_dense(cache, rows, k_t, v_t)
        if fused:
            out = paged_flash_decode_tpu(
                q, cache.k, cache.v, rows.block_table, new_len, scale)
        else:
            out = core_attn.dense_attention(
                q, gather_pages(cache.k, rows.block_table),
                gather_pages(cache.v, rows.block_table),
                scale, causal=False, kv_length=new_len)
        return out, cache

    if isinstance(cache, PagedXCache):
        cache = append_x(cache, rows, x_t, k_rope_t)
        if fused:
            out = paged_decomposed_decode_tpu(
                q_nope, q_rope, cache.x, cache.k_rope,
                rows.block_table, new_len, w_k_nope, w_v, scale)
        else:
            out = decomposed_attention(
                q_nope, q_rope, gather_pages(cache.x, rows.block_table),
                gather_pages(cache.k_rope, rows.block_table),
                w_k_nope, w_v, new_len, scale)
        return out, cache

    if isinstance(cache, PagedCPQKVCache):
        cache = PagedCPQKVCache(
            k=append_cpq_tensor(cache.k, rows, k_t, rt.cpq),
            v=append_cpq_tensor(cache.v, rows, v_t, rt.cpq))
        if fused:
            out = paged_cpq_decode_tpu(
                q, cache.k, cache.v, rows.block_table, new_len, scale)
        else:
            out = core_attn.cpq_chunked_decode_attention(
                q, logical_cpq(cache.k, rows.block_table),
                logical_cpq(cache.v, rows.block_table), new_len, scale)
        return out, cache

    if isinstance(cache, PagedRetrievalCache):
        dp = rt.retrieval.proxy_dim or k_t.shape[-1]
        code_t = ret_lib.encode_proxy(
            k_t[..., :dp], cache.proxy_scale, cache.proxy_zero, rt.retrieval.proxy_bits)
        cache = PagedRetrievalCache(
            k=write_token_pages(cache.k, rows.block_table, rows.lengths,
                                rows.active, k_t[:, 0]),
            v=write_token_pages(cache.v, rows.block_table, rows.lengths,
                                rows.active, v_t[:, 0]),
            proxy=write_token_pages(cache.proxy, rows.block_table, rows.lengths,
                                    rows.active, code_t[:, 0]),
            proxy_scale=cache.proxy_scale, proxy_zero=cache.proxy_zero)
        out = ret_lib.retrieval_attention(
            q, gather_pages(cache.k, rows.block_table),
            gather_pages(cache.v, rows.block_table),
            gather_pages(cache.proxy, rows.block_table),
            cache.proxy_scale, cache.proxy_zero, new_len, rt.retrieval, scale)
        return out, cache

    if isinstance(cache, PagedCPQXCache):
        cache = PagedCPQXCache(
            x=append_cpq_tensor(cache.x, rows, x_t[:, :, None, :], rt.cpq),
            k_rope=(write_token_pages(cache.k_rope, rows.block_table, rows.lengths,
                                      rows.active, k_rope_t[:, 0])
                    if k_rope_t is not None else cache.k_rope))
        out = core_attn.decomposed_cpq_chunked_decode(
            q_nope, q_rope, logical_cpq(cache.x, rows.block_table),
            gather_pages(cache.k_rope, rows.block_table),
            w_k_nope, w_v, new_len, scale)
        return out, cache

    raise TypeError(type(cache))


# ------------------------------------------------------- tier escalation (T2)


def compress_dense_slot(k_log: jax.Array, v_log: jax.Array, length: jax.Array,
                        cfg: CPQCfg) -> kvc.CPQKVCache:
    """Re-compress one slot's gathered dense K/V into CPQ tensors — the
    watermark policy's dense -> T2 migration. Only dense is escalatable
    post-hoc: T1 needs the pre-projection operand X, which a dense cache
    never stored; T2 compresses exactly what is cached.

    k_log/v_log: (1, Npad, KV, Dh) logical views; slots beyond ``length`` are
    replaced by the last valid token so the prefill statistics (prune
    quantile, level-0 range) see only real data."""
    pos = jnp.arange(k_log.shape[1], dtype=jnp.int32)
    last = jnp.clip(length - 1, 0, k_log.shape[1] - 1)

    def valid_only(a):
        edge = jax.lax.dynamic_index_in_dim(a, last, axis=1)  # (1, 1, KV, Dh)
        return jnp.where((pos < length)[None, :, None, None], a, edge)

    kt = cpq_lib.cpq_compress_prefill(valid_only(k_log), cfg, k_log.shape[1])
    vt = cpq_lib.cpq_compress_prefill(valid_only(v_log), cfg, v_log.shape[1])
    return kvc.CPQKVCache(kt, vt, length)
