"""Block-paged KV-cache arenas for continuous-batching serving.

The static containers in ``core/kv_cache.py`` dedicate a contiguous
``(B, n_max, ...)`` arena to every request slot; a short request strands the
rest of its row. Here the token axis is cut into fixed-size **pages** owned by
a shared physical pool ``(P, page_size, ...)``, and a per-slot **block table**
``(B, max_blocks)`` maps logical token blocks to physical pages (the vLLM
construction, adapted to the paper's five cache tiers). The paper's motivating
observation — "the KV cache can grow unpredictably and even surpass the
model's weight size" — becomes an allocation problem: pages are allocated at
admission/decode, freed at retirement, and the pool utilization drives the
scheduler's watermark/tier-escalation policy.

Layout invariants (shared by every paged container):

  * Physical page 0 is the reserved **null page**: unmapped block-table
    entries are 0 and the writes of inactive rows are routed there, so decode
    steps stay branch-free under jit. Its contents are garbage by design.
  * A slot's logical view is ``pages[block_table[b]]`` flattened to
    ``(max_blocks * page_size, ...)``; slots beyond ``lengths[b]`` are masked
    by every attention mode (core attention takes per-row ``(B,)`` lengths).
  * Per-token state pages; per-SEQUENCE state (CPQ scale/zero/levels,
    retrieval proxy calibration) stays slot-indexed ``(B, ...)`` — it is
    O(1) per request and is overwritten at admission.

Mode -> paged container (mirrors core/kv_cache.py):
  dense      PagedDenseKVCache   K,V pages
  decomposed PagedXCache         X pages (+ roped key pages)     (T1)
  cpq        PagedCPQKVCache     CPQ code/level pages, slot stats (T2)
  retrieval  PagedRetrievalCache K,V,proxy pages, slot calibration (T3)
  cpq+decomp PagedCPQXCache      CPQ(X) pages (+ roped key pages) (T1+T2)
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import CPQCfg, RetrievalCfg
from repro.core import cpq as cpq_lib
from repro.core import kv_cache as kvc

NULL_PAGE = 0


class RowState(NamedTuple):
    """Per-step request-row state threaded through the jitted decode step
    (the paged analogue of the scalar ``pos`` argument)."""

    lengths: jax.Array      # (B,) int32 — valid tokens per slot (= next position)
    block_table: jax.Array  # (B, max_blocks) int32 physical page ids; 0 = unmapped
    active: jax.Array       # (B,) bool — row decodes this step (writes commit)
    tier: jax.Array         # (B,) int32 — 0 = base tier, 1 = escalated tier
    alt_block_table: Optional[jax.Array] = None  # escalated-arena table (tiered)


# -------------------------------------------------------------- page plumbing


def gather_pages(pages: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize logical views: (P, page, ...) x (B, max_blocks)
    -> (B, max_blocks * page, ...). Unmapped blocks read the null page and
    must be masked by lengths downstream."""
    g = jnp.take(pages, block_table, axis=0)  # (B, max_blocks, page, ...)
    return g.reshape(g.shape[0], g.shape[1] * g.shape[2], *g.shape[3:])


def write_token_pages(pages: jax.Array, block_table: jax.Array, lengths: jax.Array,
                      active: jax.Array, val: jax.Array) -> jax.Array:
    """Scatter one token per row at slot ``lengths[b]``. val: (B, ...) —
    token payload per row. Inactive rows write the null page."""
    page_size, max_blocks = pages.shape[1], block_table.shape[1]
    blk = jnp.clip(lengths // page_size, 0, max_blocks - 1)
    page_idx = jnp.take_along_axis(block_table, blk[:, None], axis=1)[:, 0]
    page_idx = jnp.where(active, page_idx, NULL_PAGE)
    off = lengths % page_size
    return pages.at[page_idx, off].set(val.astype(pages.dtype))


def write_prompt_pages(pages: jax.Array, block_row: jax.Array, val: jax.Array) -> jax.Array:
    """Bulk-write a prompt into one slot's pages. block_row: (max_blocks,);
    val: (S, ...). Positions whose block is unmapped or beyond max_blocks
    (bucket padding past the slot's capacity) land on the null page — they
    must never wrap around onto mapped pages."""
    S, page_size = val.shape[0], pages.shape[1]
    pos = jnp.arange(S, dtype=jnp.int32)
    blk = pos // page_size
    in_range = blk < block_row.shape[0]
    pidx = jnp.where(in_range,
                     block_row[jnp.clip(blk, 0, block_row.shape[0] - 1)],
                     NULL_PAGE)
    return pages.at[pidx, pos % page_size].set(val.astype(pages.dtype))


def write_chunk_pages(pages: jax.Array, block_row: jax.Array, offset: jax.Array,
                      valid: jax.Array, vals: jax.Array) -> jax.Array:
    """Bulk-write one prompt CHUNK into one slot's pages at positions
    ``offset .. offset+C-1`` (chunked paged prefill: the chunk's payload goes
    straight into the arena — no contiguous scratch cache). vals: (C, ...).
    Positions past ``offset + valid`` (the chunk's jit padding), positions
    whose block is unmapped, and positions beyond the slot's page capacity
    all land on the null page, so page contents are independent of how a
    prompt is split into chunks (property-tested)."""
    C, page_size = vals.shape[0], pages.shape[1]
    idx = jnp.arange(C, dtype=jnp.int32)
    pos = offset + idx
    blk = pos // page_size
    ok = (idx < valid) & (blk < block_row.shape[0])
    pidx = jnp.where(ok,
                     block_row[jnp.clip(blk, 0, block_row.shape[0] - 1)],
                     NULL_PAGE)
    return pages.at[pidx, pos % page_size].set(vals.astype(pages.dtype))


def _sel_rows(active: jax.Array, new, old):
    """Per-slot side-state commit: keep ``new`` on active rows only."""
    return jax.tree.map(
        lambda n, o: jnp.where(active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
        new, old)


# ----------------------------------------------------------------- allocator


class PageAllocator:
    """Host-side free-list over the physical pool (page 0 reserved as null),
    with a per-page REFCOUNT: prefix sharing maps one physical page into
    several block tables (``incref``), and the page returns to the free list
    only when the last owner releases it. ``alloc`` hands out pages at
    refcount 1, so refcount-oblivious callers see the old exclusive-ownership
    semantics unchanged.

    The scheduler owns one per arena; alloc/free are O(n). ``OutOfPages`` is
    the admission-control signal, not an error state; ``DoubleFree`` IS an
    error — releasing a page more often than it was referenced corrupts the
    free list (it used to be an ``assert``, which vanishes under ``-O``)."""

    class OutOfPages(RuntimeError):
        pass

    class DoubleFree(RuntimeError):
        """A page was released more times than it was referenced (or the
        null page was released). Raised, not asserted: a silent free-list
        corruption here double-allocates live KV pages later."""

    def __init__(self, num_pages: int):
        assert num_pages >= 2, "need >= 1 allocatable page beyond the null page"
        self.num_pages = num_pages
        self._free = list(range(num_pages - 1, 0, -1))  # pop() hands out low ids first
        self._refs = [0] * num_pages                    # [NULL_PAGE] stays 0

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_used(self) -> int:
        return (self.num_pages - 1) - len(self._free)

    @property
    def utilization(self) -> float:
        return self.num_used / max(self.num_pages - 1, 1)

    def can_alloc(self, n: int) -> bool:
        return len(self._free) >= n

    def alloc(self, n: int) -> list[int]:
        if n > len(self._free):
            raise self.OutOfPages(f"want {n} pages, {len(self._free)} free")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._refs[p] = 1
        return out

    def refcount(self, page: int) -> int:
        return self._refs[int(page)]

    def incref(self, page: int) -> None:
        """Add an owner to an already-allocated page (prefix sharing: the
        admission maps an existing physical page into another block table)."""
        p = int(page)
        if p == NULL_PAGE or self._refs[p] <= 0:
            raise self.DoubleFree(f"incref of unowned page {p}")
        self._refs[p] += 1

    def free(self, pages) -> list[int]:
        """Drop one reference per listed page. A page rejoins the free list
        only at refcount zero; returns the pages that did (the caller
        invalidates any prefix-index entries for exactly those)."""
        released = []
        for p in pages:
            p = int(p)
            if p == NULL_PAGE:
                raise self.DoubleFree("freeing the null page")
            if self._refs[p] <= 0:
                raise self.DoubleFree(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                released.append(p)
        return released

    def reset_free(self, free: list[int]) -> None:
        """Install a rebuilt free list (defrag: page ids were relabeled) for
        a refcount-OBLIVIOUS owner: every used page is assumed exclusively
        owned (refcount 1). Shared arenas must use ``relabel`` instead."""
        assert len(free) == len(self._free), (len(free), len(self._free))
        self._free = [int(p) for p in free]
        in_free = set(self._free)
        self._refs = [0 if (p in in_free or p == NULL_PAGE) else 1
                      for p in range(self.num_pages)]

    def relabel(self, perm, free: list[int]) -> None:
        """Defrag relabeling that PRESERVES refcounts: page ``perm[new]``
        moves to id ``new`` and carries its count. Asserts the refcount
        multiset is unchanged and the new free list is exactly the zero-
        refcount pages (the invariant ``permute_pool`` relies on)."""
        new_refs = [self._refs[int(old)] for old in perm]
        if sorted(new_refs) != sorted(self._refs):
            raise self.DoubleFree("relabel dropped or duplicated refcounts")
        zero = {p for p in range(1, self.num_pages) if new_refs[p] == 0}
        if set(int(p) for p in free) != zero:
            raise self.DoubleFree("relabel free list != zero-refcount pages")
        self._refs = new_refs
        self._free = [int(p) for p in free]


def pages_needed(tokens: int, page_size: int) -> int:
    return -(-int(tokens) // page_size)


def defrag_plan(block_table, num_pages: int, shared=None):
    """Compaction plan: remap every mapped page onto the lowest physical ids,
    ordered by (slot, logical block) so each request's pages become physically
    contiguous again after a churn of retirements (locality for the fused
    kernels' sequential page reads).

    ``shared`` (optional) is the set of pages with refcount > 1 (prefix
    sharing): they are stably partitioned to the FRONT of the compacted
    range, so the pages every sharer re-reads each tick cluster on the
    lowest ids — one hot region instead of being interleaved with
    single-owner pages (and they stay put across repeated compactions,
    keeping the prefix index's physical ids maximally stable).

    ``block_table`` is a host array (B, max_blocks). Returns
    (perm, new_block_table, free): ``perm[new_id] = old_id`` — apply to every
    page-major pool with ``jnp.take(pages, perm, axis=0)`` — and ``free`` is
    the rebuilt free list (same LIFO convention as PageAllocator)."""
    import numpy as np

    bt = np.asarray(block_table)
    used: list[int] = []
    seen = set()
    for b in range(bt.shape[0]):
        for j in range(bt.shape[1]):
            p = int(bt[b, j])
            if p != NULL_PAGE and p not in seen:
                seen.add(p)
                used.append(p)
    if shared:
        used = ([p for p in used if p in shared]
                + [p for p in used if p not in shared])
    perm = [NULL_PAGE] + used
    in_front = set(perm)
    perm += [p for p in range(num_pages) if p not in in_front]  # park stale pages
    remap = {old: new for new, old in enumerate(perm)}  # total map; 0 -> 0
    new_bt = np.array([[remap[int(p)] for p in row] for row in bt], dtype=bt.dtype)
    free = list(range(num_pages - 1, len(used), -1))  # pop() hands out low ids
    return np.asarray(perm, dtype=np.int32), new_bt, free


def permute_pool(cache: "PagedCache", perm: jax.Array) -> "PagedCache":
    """Apply a defrag permutation (``perm[new_id] = old_id``) to every
    BASE-arena page pool of a paged container; per-slot side state and the
    tiered CPQ escalation arena (its own allocator/tables) are untouched.
    Works identically on sharded pools: the pool axis is never partitioned,
    so the take is local on every device."""
    def pcpq(t: PagedCPQTensor) -> PagedCPQTensor:
        return t._replace(codes=jnp.take(t.codes, perm, axis=0),
                          level=jnp.take(t.level, perm, axis=0))

    if isinstance(cache, TieredPagedCache):
        return cache._replace(dense=permute_pool(cache.dense, perm))
    if isinstance(cache, PagedDenseKVCache):
        return PagedDenseKVCache(k=jnp.take(cache.k, perm, axis=0),
                                 v=jnp.take(cache.v, perm, axis=0))
    if isinstance(cache, PagedXCache):
        return PagedXCache(x=jnp.take(cache.x, perm, axis=0),
                           k_rope=jnp.take(cache.k_rope, perm, axis=0))
    if isinstance(cache, PagedCPQKVCache):
        return PagedCPQKVCache(k=pcpq(cache.k), v=pcpq(cache.v))
    if isinstance(cache, PagedRetrievalCache):
        return cache._replace(k=jnp.take(cache.k, perm, axis=0),
                              v=jnp.take(cache.v, perm, axis=0),
                              proxy=jnp.take(cache.proxy, perm, axis=0))
    if isinstance(cache, PagedCPQXCache):
        return PagedCPQXCache(x=pcpq(cache.x),
                              k_rope=jnp.take(cache.k_rope, perm, axis=0))
    raise TypeError(type(cache))


def copy_page(cache: "PagedCache", src: jax.Array, dst: jax.Array) -> "PagedCache":
    """Copy one physical page's payload ``src -> dst`` in every BASE-arena
    pool — the copy-on-write split: a writer diverging inside a shared page
    gets a private copy before its first write. Only the positional per-token
    pools move; per-slot side state is already private to the writer. Tiered
    arenas copy the dense arm only (sharing is a tier-0 feature); the CPQ /
    retrieval tiers never share pages (their dequant reads go through per-slot
    side state fitted to one request's stream), so no copy is defined.
    Works identically on sharded pools: the pool axis is never partitioned,
    so the dynamic-index copy is local on every device."""
    cp = lambda pool: pool.at[dst].set(pool[src])  # noqa: E731

    if isinstance(cache, TieredPagedCache):
        return cache._replace(dense=copy_page(cache.dense, src, dst))
    if isinstance(cache, PagedDenseKVCache):
        return PagedDenseKVCache(k=cp(cache.k), v=cp(cache.v))
    if isinstance(cache, PagedXCache):
        return PagedXCache(x=cp(cache.x), k_rope=cp(cache.k_rope))
    raise TypeError(f"copy-on-write is undefined for {type(cache).__name__}")


# ------------------------------------------------------------- paged containers


class PagedDenseKVCache(NamedTuple):
    k: jax.Array  # (P, page, KV, Dh)
    v: jax.Array  # (P, page, KV, Dh)


class PagedXCache(NamedTuple):
    x: jax.Array       # (P, page, Dm)
    k_rope: jax.Array  # (P, page, KV, R)


class PagedCPQTensor(NamedTuple):
    """CPQ arena split into per-token pages + per-slot HQE side state."""

    codes: jax.Array       # (P, page, H, D) int8
    level: jax.Array       # (P, page, H) int32
    scale: jax.Array       # (B, L, H, D) f32
    zero: jax.Array        # (B, L, H, D) f32
    num_levels: jax.Array  # (B, H) int32
    prune_thr: jax.Array   # (B, H, D) f32


class PagedCPQKVCache(NamedTuple):
    k: PagedCPQTensor
    v: PagedCPQTensor


class PagedRetrievalCache(NamedTuple):
    k: jax.Array            # (P, page, KV, Dh)
    v: jax.Array            # (P, page, KV, Dh)
    proxy: jax.Array        # (P, page, KV, Dp) int8
    proxy_scale: jax.Array  # (B, KV, Dp) f32
    proxy_zero: jax.Array   # (B, KV, Dp) f32


class PagedCPQXCache(NamedTuple):
    x: PagedCPQTensor       # H = 1, D = Dm
    k_rope: jax.Array       # (P, page, KV, R)


class TieredPagedCache(NamedTuple):
    """Dense base arena + CPQ escalation arena; ``RowState.tier`` selects the
    live one per row (the watermark policy's dense -> T2 migration target)."""

    dense: PagedDenseKVCache
    cpq: PagedCPQKVCache


PagedCache = (PagedDenseKVCache | PagedXCache | PagedCPQKVCache
              | PagedRetrievalCache | PagedCPQXCache | TieredPagedCache)


# ------------------------------------------------------------- constructors


def init_paged_dense(num_pages: int, page_size: int, kv: int, dh: int,
                     dtype=jnp.bfloat16) -> PagedDenseKVCache:
    z = jnp.zeros((num_pages, page_size, kv, dh), dtype)
    return PagedDenseKVCache(z, z)


def init_paged_x(num_pages: int, page_size: int, dm: int, kv: int, rope_dims: int,
                 dtype=jnp.bfloat16) -> PagedXCache:
    return PagedXCache(
        x=jnp.zeros((num_pages, page_size, dm), dtype),
        k_rope=jnp.zeros((num_pages, page_size, kv, rope_dims), dtype))


def _init_paged_cpq_tensor(num_pages: int, page_size: int, num_slots: int,
                           h: int, d: int, cfg: CPQCfg) -> PagedCPQTensor:
    return PagedCPQTensor(
        codes=jnp.zeros((num_pages, page_size, h, d), jnp.int8),
        level=jnp.zeros((num_pages, page_size, h), jnp.int32),
        scale=jnp.zeros((num_slots, cfg.max_levels, h, d), jnp.float32),
        zero=jnp.zeros((num_slots, cfg.max_levels, h, d), jnp.float32),
        num_levels=jnp.ones((num_slots, h), jnp.int32),
        prune_thr=jnp.zeros((num_slots, h, d), jnp.float32))


def init_paged_cpq(num_pages: int, page_size: int, num_slots: int, kv: int, dh: int,
                   cfg: CPQCfg) -> PagedCPQKVCache:
    return PagedCPQKVCache(
        k=_init_paged_cpq_tensor(num_pages, page_size, num_slots, kv, dh, cfg),
        v=_init_paged_cpq_tensor(num_pages, page_size, num_slots, kv, dh, cfg))


def init_paged_retrieval(num_pages: int, page_size: int, num_slots: int, kv: int,
                         dh: int, cfg: RetrievalCfg, dtype=jnp.bfloat16
                         ) -> PagedRetrievalCache:
    dp = cfg.proxy_dim or dh
    z = jnp.zeros((num_pages, page_size, kv, dh), dtype)
    return PagedRetrievalCache(
        k=z, v=z,
        proxy=jnp.zeros((num_pages, page_size, kv, dp), jnp.int8),
        proxy_scale=jnp.ones((num_slots, kv, dp), jnp.float32),
        proxy_zero=jnp.zeros((num_slots, kv, dp), jnp.float32))


def init_paged_cpq_x(num_pages: int, page_size: int, num_slots: int, dm: int,
                     kv: int, rope_dims: int, cfg: CPQCfg,
                     dtype=jnp.bfloat16) -> PagedCPQXCache:
    return PagedCPQXCache(
        x=_init_paged_cpq_tensor(num_pages, page_size, num_slots, 1, dm, cfg),
        k_rope=jnp.zeros((num_pages, page_size, kv, rope_dims), dtype))


# ------------------------------------------------------------ logical views


def logical_cpq(t: PagedCPQTensor, block_table: jax.Array) -> cpq_lib.CPQTensor:
    """Contiguous CPQTensor view of a paged CPQ arena (codes gathered through
    the block table; per-slot stats already contiguous). The chunked decode
    kernels consume this with per-row lengths."""
    return cpq_lib.CPQTensor(
        codes=gather_pages(t.codes, block_table),
        scale=t.scale, zero=t.zero,
        level=gather_pages(t.level, block_table),
        num_levels=t.num_levels, prune_thr=t.prune_thr)


# -------------------------------------------------------------- decode append


def append_dense(cache: PagedDenseKVCache, rows: RowState,
                 k_t: jax.Array, v_t: jax.Array) -> PagedDenseKVCache:
    """k_t/v_t: (B, 1, KV, Dh) new token per row."""
    return PagedDenseKVCache(
        k=write_token_pages(cache.k, rows.block_table, rows.lengths, rows.active, k_t[:, 0]),
        v=write_token_pages(cache.v, rows.block_table, rows.lengths, rows.active, v_t[:, 0]))


def append_x(cache: PagedXCache, rows: RowState,
             x_t: jax.Array, k_rope_t: Optional[jax.Array]) -> PagedXCache:
    return PagedXCache(
        x=write_token_pages(cache.x, rows.block_table, rows.lengths, rows.active, x_t[:, 0]),
        k_rope=(write_token_pages(cache.k_rope, rows.block_table, rows.lengths,
                                  rows.active, k_rope_t[:, 0])
                if k_rope_t is not None else cache.k_rope))


def append_cpq_tensor(t: PagedCPQTensor, rows: RowState, x_t: jax.Array,
                      cfg: CPQCfg) -> PagedCPQTensor:
    """HQE-encode one token per row (shared math with the contiguous path)
    and scatter code/level through the block table. Side-state updates only
    commit on active rows."""
    code_t, level_t, scale, zero, num_levels = cpq_lib.cpq_encode_token(
        t.scale, t.zero, t.num_levels, t.prune_thr, x_t, cfg)
    scale, zero, num_levels = _sel_rows(
        rows.active, (scale, zero, num_levels), (t.scale, t.zero, t.num_levels))
    return PagedCPQTensor(
        codes=write_token_pages(t.codes, rows.block_table, rows.lengths,
                                rows.active, code_t[:, 0]),
        level=write_token_pages(t.level, rows.block_table, rows.lengths,
                                rows.active, level_t),
        scale=scale, zero=zero, num_levels=num_levels, prune_thr=t.prune_thr)


# ------------------------------------------------------------- prefill pack


def pack_dense(cache: PagedDenseKVCache, src: kvc.DenseKVCache,
               block_row: jax.Array) -> PagedDenseKVCache:
    """Scatter a freshly prefilled contiguous B=1 cache into one slot's pages."""
    return PagedDenseKVCache(
        k=write_prompt_pages(cache.k, block_row, src.k[0]),
        v=write_prompt_pages(cache.v, block_row, src.v[0]))


def pack_x(cache: PagedXCache, src: kvc.XCache, block_row: jax.Array) -> PagedXCache:
    return PagedXCache(
        x=write_prompt_pages(cache.x, block_row, src.x[0]),
        k_rope=write_prompt_pages(cache.k_rope, block_row, src.k_rope[0]))


def pack_cpq_tensor(t: PagedCPQTensor, src: cpq_lib.CPQTensor, block_row: jax.Array,
                    slot: jax.Array) -> PagedCPQTensor:
    return PagedCPQTensor(
        codes=write_prompt_pages(t.codes, block_row, src.codes[0]),
        level=write_prompt_pages(t.level, block_row, src.level[0]),
        scale=t.scale.at[slot].set(src.scale[0]),
        zero=t.zero.at[slot].set(src.zero[0]),
        num_levels=t.num_levels.at[slot].set(src.num_levels[0]),
        prune_thr=t.prune_thr.at[slot].set(src.prune_thr[0]))


def pack_cpq(cache: PagedCPQKVCache, src: kvc.CPQKVCache, block_row: jax.Array,
             slot: jax.Array) -> PagedCPQKVCache:
    return PagedCPQKVCache(
        k=pack_cpq_tensor(cache.k, src.k, block_row, slot),
        v=pack_cpq_tensor(cache.v, src.v, block_row, slot))


def pack_retrieval(cache: PagedRetrievalCache, src: kvc.RetrievalCache,
                   block_row: jax.Array, slot: jax.Array) -> PagedRetrievalCache:
    return PagedRetrievalCache(
        k=write_prompt_pages(cache.k, block_row, src.k[0]),
        v=write_prompt_pages(cache.v, block_row, src.v[0]),
        proxy=write_prompt_pages(cache.proxy, block_row, src.proxy[0]),
        proxy_scale=cache.proxy_scale.at[slot].set(src.proxy_scale[0]),
        proxy_zero=cache.proxy_zero.at[slot].set(src.proxy_zero[0]))


def pack_cpq_x(cache: PagedCPQXCache, src: kvc.CPQXCache, block_row: jax.Array,
               slot: jax.Array) -> PagedCPQXCache:
    return PagedCPQXCache(
        x=pack_cpq_tensor(cache.x, src.x, block_row, slot),
        k_rope=write_prompt_pages(cache.k_rope, block_row, src.k_rope[0]))


def pack_into(rt_mode: str, cache, src, block_row: jax.Array, slot: jax.Array):
    """Mode dispatch for admission packing (contiguous B=1 prefill -> pages)."""
    if isinstance(cache, TieredPagedCache):
        if isinstance(src, kvc.DenseKVCache):
            return cache._replace(dense=pack_dense(cache.dense, src, block_row))
        return cache._replace(cpq=pack_cpq(cache.cpq, src, block_row, slot))
    if isinstance(cache, PagedDenseKVCache):
        return pack_dense(cache, src, block_row)
    if isinstance(cache, PagedXCache):
        return pack_x(cache, src, block_row)
    if isinstance(cache, PagedCPQKVCache):
        return pack_cpq(cache, src, block_row, slot)
    if isinstance(cache, PagedRetrievalCache):
        return pack_retrieval(cache, src, block_row, slot)
    if isinstance(cache, PagedCPQXCache):
        return pack_cpq_x(cache, src, block_row, slot)
    raise TypeError(type(cache))


# ---------------------------------------------------------- chunked prefill


def _slot_cpq(t: PagedCPQTensor, block_row: jax.Array, slot: jax.Array
              ) -> cpq_lib.CPQTensor:
    """One slot's logical CPQTensor view (B=1): codes/levels gathered through
    the slot's block row, HQE side state sliced at ``slot``."""
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)  # noqa: E731
    return cpq_lib.CPQTensor(
        codes=gather_pages(t.codes, block_row[None]),
        scale=sl(t.scale), zero=sl(t.zero),
        level=gather_pages(t.level, block_row[None]),
        num_levels=sl(t.num_levels), prune_thr=sl(t.prune_thr))


def chunk_cpq_tensor(t: PagedCPQTensor, slot: jax.Array, block_row: jax.Array,
                     offset: jax.Array, valid: jax.Array, x_c: jax.Array,
                     cfg: CPQCfg, first: bool) -> PagedCPQTensor:
    """Incrementally CPQ-compress one prompt chunk into a slot's code pages
    (chunked paged prefill): the FIRST chunk fits the per-channel prune
    threshold and level-0 scale/zero (the role the whole prompt plays in
    ``cpq_compress_prefill``); continuation chunks HQE-extend token by token
    exactly like decode appends — no re-compression of earlier tokens, ever.
    x_c: (1, C, H, D); ``first`` is static (one compiled variant each)."""
    sl = lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)  # noqa: E731
    if first:
        codes, level, scale, zero, num_levels, thr = cpq_lib.cpq_fit_chunk(
            x_c, valid, cfg)
        prune_thr = t.prune_thr.at[slot].set(thr[0])
    else:
        codes, level, scale, zero, num_levels = cpq_lib.cpq_encode_chunk(
            sl(t.scale), sl(t.zero), sl(t.num_levels), sl(t.prune_thr),
            x_c, valid, cfg)
        prune_thr = t.prune_thr
    return PagedCPQTensor(
        codes=write_chunk_pages(t.codes, block_row, offset, valid, codes[0]),
        level=write_chunk_pages(t.level, block_row, offset, valid, level[0]),
        scale=t.scale.at[slot].set(scale[0]),
        zero=t.zero.at[slot].set(zero[0]),
        num_levels=t.num_levels.at[slot].set(num_levels[0]),
        prune_thr=prune_thr)


def _chunk_mask_bias(n_prev: int, chunk: int, offset: jax.Array,
                     valid: jax.Array) -> jax.Array:
    """(C, n_prev + C) additive mask for chunk attention over [earlier-pages
    view | raw chunk]: earlier key j is live iff j < offset (cross-chunk keys
    read what decode reads); chunk key i is live iff i < valid and i <= the
    query's chunk index (causal)."""
    kp = jnp.concatenate([jnp.arange(n_prev, dtype=jnp.int32),
                          offset + jnp.arange(chunk, dtype=jnp.int32)])
    live = jnp.concatenate([jnp.arange(n_prev, dtype=jnp.int32) < offset,
                            jnp.arange(chunk, dtype=jnp.int32) < valid])
    qp = offset + jnp.arange(chunk, dtype=jnp.int32)
    ok = live[None, :] & (kp[None, :] <= qp[:, None])
    return jnp.where(ok, 0.0, -1e30)


def cpq_chunk_prefill_attention(q, kt: PagedCPQTensor, vt: PagedCPQTensor,
                                block_row, slot, k_raw, v_raw, offset, valid,
                                scale: float) -> jax.Array:
    """jnp gather-path oracle of the fused paged T2 prefill kernel: earlier
    chunks are read back as dequantized codes (what decode reads), the
    current chunk attends its RAW roped K/V causally — a single-chunk
    admission therefore reproduces the one-shot prefill's raw-attention
    numerics. q: (1, C, H, Dh); k_raw/v_raw: (1, C, KV, Dh|Dv)."""
    from repro.core import attention as core_attn

    k_hat = cpq_lib.cpq_dequant(_slot_cpq(kt, block_row, slot))
    v_hat = cpq_lib.cpq_dequant(_slot_cpq(vt, block_row, slot))
    k_all = jnp.concatenate([k_hat.astype(q.dtype), k_raw], axis=1)
    v_all = jnp.concatenate([v_hat.astype(q.dtype), v_raw], axis=1)
    bias = _chunk_mask_bias(k_hat.shape[1], q.shape[1], offset, valid)
    return core_attn.dense_attention(
        q, k_all, v_all, scale, causal=False, logit_bias=bias[None, :, None, :])


def decomposed_cpq_chunk_prefill(q_nope, q_rope, xt: PagedCPQTensor,
                                 kr_pages, block_row, slot, x_raw, k_rope_raw,
                                 offset, valid, w_k_nope, w_v,
                                 scale: float) -> jax.Array:
    """T1+T2 / MLA-CPQ chunk prefill attention (gather path — this
    composition has no fused kernel, matching its decode path): earlier X
    codes are dequantized, the current chunk contributes its raw operand;
    both cascaded MatMuls of the decomposition run over the combined axis.
    q_nope: (1, C, H, Dn); x_raw: (1, C, Dm); k_rope_raw: (1, C, KV, R)."""
    from repro.core.decomposed_attention import (decomposed_query_transform,
                                                 decomposed_values)

    B, C, H, _ = q_nope.shape
    x_hat = cpq_lib.cpq_dequant(
        _slot_cpq(xt, block_row, slot))[:, :, 0, :]             # (1, Nprev, Dm)
    x_all = jnp.concatenate([x_hat.astype(x_raw.dtype), x_raw], axis=1)
    r = decomposed_query_transform(q_nope, w_k_nope)            # (1, C, H, Dm)
    s = jnp.einsum("bchm,bnm->bchn", r, x_all)
    if q_rope is not None and q_rope.shape[-1] > 0:
        kr_prev = gather_pages(kr_pages, block_row[None])       # (1, Nprev, KV, R)
        kr_all = jnp.concatenate([kr_prev.astype(k_rope_raw.dtype),
                                  k_rope_raw], axis=1)
        kv_r = kr_all.shape[2]
        g_r = H // kv_r
        qg = q_rope.reshape(B, C, kv_r, g_r, q_rope.shape[-1])
        s = s + jnp.einsum("bckgr,bnkr->bckgn", qg, kr_all).reshape(
            B, C, H, s.shape[-1])
    s = s.astype(jnp.float32) * scale
    s = s + _chunk_mask_bias(x_hat.shape[1], C, offset, valid)[None, :, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(x_all.dtype)
    return decomposed_values(w, x_all, w_v)


def chunk_attend_paged(
    rt,
    cache: PagedCache,
    *,
    tier: int,                      # static: tiered-arena arm (0 dense, 1 CPQ)
    first: bool,                    # static: first chunk of this admission
    slot: jax.Array,                # () int32 request slot
    block_row: jax.Array,           # (max_blocks,) slot's block-table row
    offset: jax.Array,              # () int32 tokens already written
    valid: jax.Array,               # () int32 real tokens in this chunk
    q: jax.Array,                   # (1, C, H, Dh) roped chunk queries
    k_c: jax.Array,                 # (1, C, KV, Dh) roped chunk keys
    v_c: jax.Array,                 # (1, C, KV, Dh)
    x_c: Optional[jax.Array],       # (1, C, Dm) block input (T1/MLA operand)
    k_rope_c: Optional[jax.Array],  # (1, C, KV, R)
    q_nope: Optional[jax.Array],    # (1, C, H, Dn)
    q_rope: Optional[jax.Array],    # (1, C, H, R)
    w_k_nope: Optional[jax.Array],  # (Dm, KV, Dn)
    w_v: Optional[jax.Array],       # (Dm, KV, Dh)
    scale: float,
) -> tuple[jax.Array, PagedCache]:
    """Chunked paged-prefill analogue of ``decode_attend_paged``: write one
    prompt chunk's payload STRAIGHT into the slot's arena pages (no
    contiguous scratch cache, no pack copy), then attend the chunk's C
    queries over the slot's pages [0, offset + valid) — fused Q-chunk>1
    paged kernels when ``rt.paged_kernels`` (dense, CPQ, X/MLA tiers), jnp
    gather otherwise. CPQ tiers compress incrementally (level-0 fit on the
    first chunk, HQE extension after) and attend earlier chunks through
    their own codes — cross-chunk prefill reads exactly what decode reads.
    Returns (out (1, C, H, Dv), new_cache); query rows past ``valid`` are
    jit-padding garbage the caller never reads."""
    from repro.configs.base import AttentionRuntime
    from repro.core import attention as core_attn
    from repro.core import retrieval_attention as ret_lib
    from repro.core.decomposed_attention import decomposed_attention
    from repro.kernels.cpq_dequant_attn.ops import paged_cpq_prefill_tpu
    from repro.kernels.decomposed_attn.ops import paged_decomposed_prefill_tpu
    from repro.kernels.flash_attn.ops import paged_flash_prefill_tpu

    if getattr(rt, "mesh", None) is not None:
        from repro.serving import sharded

        if sharded.supports(cache):
            return sharded.chunk_attend_sharded(
                rt, cache, tier=tier, first=first, slot=slot,
                block_row=block_row, offset=offset, valid=valid, q=q, k_c=k_c,
                v_c=v_c, x_c=x_c, k_rope_c=k_rope_c, q_nope=q_nope,
                q_rope=q_rope, w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        # T3 / T1+T2 keep global-semantics compute over (possibly storage-
        # sharded) arenas — GSPMD inserts the gathers
        import dataclasses as _dc
        rt = _dc.replace(rt, mesh=None)

    fused = rt.paged_kernels
    total = offset + valid
    qpos = offset + jnp.arange(q.shape[1], dtype=jnp.int32)

    if isinstance(cache, TieredPagedCache):
        # the admission tier is host-static for the whole prefill: compile
        # one chunk function per arm instead of computing both tiers
        if tier == 0:
            out, dense = chunk_attend_paged(
                rt, cache.dense, tier=0, first=first, slot=slot,
                block_row=block_row, offset=offset, valid=valid, q=q, k_c=k_c,
                v_c=v_c, x_c=x_c, k_rope_c=k_rope_c, q_nope=q_nope,
                q_rope=q_rope, w_k_nope=w_k_nope, w_v=w_v, scale=scale)
            return out, cache._replace(dense=dense)
        rt_c = AttentionRuntime(mode="cpq", cpq=rt.cpq, paged_kernels=fused)
        out, cpq = chunk_attend_paged(
            rt_c, cache.cpq, tier=0, first=first, slot=slot,
            block_row=block_row, offset=offset, valid=valid, q=q, k_c=k_c,
            v_c=v_c, x_c=x_c, k_rope_c=k_rope_c, q_nope=q_nope,
            q_rope=q_rope, w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        return out, cache._replace(cpq=cpq)

    if isinstance(cache, PagedDenseKVCache):
        cache = PagedDenseKVCache(
            k=write_chunk_pages(cache.k, block_row, offset, valid, k_c[0]),
            v=write_chunk_pages(cache.v, block_row, offset, valid, v_c[0]))
        if fused:
            out = paged_flash_prefill_tpu(q, cache.k, cache.v, block_row,
                                          offset, valid, scale)
        else:
            out = core_attn.dense_attention(
                q, gather_pages(cache.k, block_row[None]),
                gather_pages(cache.v, block_row[None]),
                scale, causal=True, q_offset=offset, kv_length=total)
        return out, cache

    if isinstance(cache, PagedXCache):
        cache = PagedXCache(
            x=write_chunk_pages(cache.x, block_row, offset, valid, x_c[0]),
            k_rope=(write_chunk_pages(cache.k_rope, block_row, offset, valid,
                                      k_rope_c[0])
                    if k_rope_c is not None else cache.k_rope))
        if fused:
            out = paged_decomposed_prefill_tpu(
                q_nope, q_rope, cache.x, cache.k_rope, block_row, offset,
                valid, w_k_nope, w_v, scale)
        else:
            out = decomposed_attention(
                q_nope, q_rope, gather_pages(cache.x, block_row[None]),
                gather_pages(cache.k_rope, block_row[None]),
                w_k_nope, w_v, total, scale, query_positions=qpos)
        return out, cache

    if isinstance(cache, PagedCPQKVCache):
        cache = PagedCPQKVCache(
            k=chunk_cpq_tensor(cache.k, slot, block_row, offset, valid,
                               k_c, rt.cpq, first),
            v=chunk_cpq_tensor(cache.v, slot, block_row, offset, valid,
                               v_c, rt.cpq, first))
        if fused:
            out = paged_cpq_prefill_tpu(q, cache.k, cache.v, k_c, v_c, slot,
                                        block_row, offset, valid, scale)
        else:
            out = cpq_chunk_prefill_attention(
                q, cache.k, cache.v, block_row, slot, k_c, v_c, offset,
                valid, scale)
        return out, cache

    if isinstance(cache, PagedRetrievalCache):
        dp = rt.retrieval.proxy_dim or k_c.shape[-1]
        # proxy fit is min/max per channel: masking the chunk's jit padding
        # with the last valid key keeps the first-chunk fit exact
        idx = jnp.arange(k_c.shape[1], dtype=jnp.int32)
        edge = jax.lax.dynamic_index_in_dim(
            k_c, jnp.maximum(valid - 1, 0), axis=1)             # (1, 1, KV, Dh)
        k_fit = jnp.where((idx < valid)[None, :, None, None], k_c, edge)
        if first:
            code_c, pscale, pzero = ret_lib.fit_proxy(
                k_fit[..., :dp], rt.retrieval.proxy_bits)
            proxy_scale = cache.proxy_scale.at[slot].set(pscale[0])
            proxy_zero = cache.proxy_zero.at[slot].set(pzero[0])
        else:
            sl = lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=0)  # noqa: E731
            code_c = ret_lib.encode_proxy(
                k_c[..., :dp], sl(cache.proxy_scale), sl(cache.proxy_zero),
                rt.retrieval.proxy_bits)
            proxy_scale, proxy_zero = cache.proxy_scale, cache.proxy_zero
        cache = PagedRetrievalCache(
            k=write_chunk_pages(cache.k, block_row, offset, valid, k_c[0]),
            v=write_chunk_pages(cache.v, block_row, offset, valid, v_c[0]),
            proxy=write_chunk_pages(cache.proxy, block_row, offset, valid,
                                    code_c[0]),
            proxy_scale=proxy_scale, proxy_zero=proxy_zero)
        # prefill COMPUTE is dense (T3 gates decode reads only): K/V pages
        # hold raw payload, so the dense chunk kernels serve this tier too
        if fused:
            out = paged_flash_prefill_tpu(q, cache.k, cache.v, block_row,
                                          offset, valid, scale)
        else:
            out = core_attn.dense_attention(
                q, gather_pages(cache.k, block_row[None]),
                gather_pages(cache.v, block_row[None]),
                scale, causal=True, q_offset=offset, kv_length=total)
        return out, cache

    if isinstance(cache, PagedCPQXCache):
        cache = PagedCPQXCache(
            x=chunk_cpq_tensor(cache.x, slot, block_row, offset, valid,
                               x_c[:, :, None, :], rt.cpq, first),
            k_rope=(write_chunk_pages(cache.k_rope, block_row, offset, valid,
                                      k_rope_c[0])
                    if k_rope_c is not None else cache.k_rope))
        out = decomposed_cpq_chunk_prefill(
            q_nope, q_rope, cache.x, cache.k_rope, block_row, slot, x_c,
            k_rope_c if k_rope_c is not None
            else jnp.zeros((1, q.shape[1], 1, 0), x_c.dtype),
            offset, valid, w_k_nope, w_v, scale)
        return out, cache

    raise TypeError(type(cache))


# ------------------------------------------------------------------- traffic


def bytes_per_token(cache: PagedCache, page_size: int,
                    cpq_cfg: Optional[CPQCfg] = None) -> float:
    """Per-token decode traffic of the paged arena: the contiguous payload
    accounting (kv_cache.bytes_per_token / cpq accounting) plus the amortized
    block-table overhead (one int32 entry per page). Hooked by
    benchmarks/bench_e2e_energy.py and the scheduler's watermark policy."""
    overhead = 4.0 / page_size
    if isinstance(cache, TieredPagedCache):  # base-tier accounting
        return bytes_per_token(cache.dense, page_size, cpq_cfg)
    if isinstance(cache, PagedDenseKVCache):
        payload = 2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
    elif isinstance(cache, PagedXCache):
        payload = (cache.x.shape[2] * cache.x.dtype.itemsize
                   + cache.k_rope.shape[2] * cache.k_rope.shape[3]
                   * cache.k_rope.dtype.itemsize)
    elif isinstance(cache, PagedCPQKVCache):
        cfg = cpq_cfg or CPQCfg()
        payload = 2.0 * cpq_lib.cpq_bytes_per_token(
            cfg, cache.k.codes.shape[2], cache.k.codes.shape[3])
    elif isinstance(cache, PagedRetrievalCache):
        payload = (2.0 * cache.k.shape[2] * cache.k.shape[3] * cache.k.dtype.itemsize
                   + cache.proxy.shape[2] * cache.proxy.shape[3])
    elif isinstance(cache, PagedCPQXCache):
        cfg = cpq_cfg or CPQCfg()
        payload = (cpq_lib.cpq_bytes_per_token(cfg, 1, cache.x.codes.shape[3])
                   + cache.k_rope.shape[2] * cache.k_rope.shape[3]
                   * cache.k_rope.dtype.itemsize)
    else:
        raise TypeError(type(cache))
    return payload + overhead


def arena_bytes(cache: PagedCache) -> int:
    """Total physical bytes of the paged arena (all pools + slot side state)."""
    return int(sum(a.size * a.dtype.itemsize for a in jax.tree.leaves(cache)))


# ------------------------------------------------------------- decode attend


def decode_attend_paged(
    rt,
    cache: PagedCache,
    rows: RowState,
    *,
    q: jax.Array,                   # (B, 1, H, Dh) roped query
    k_t: jax.Array,                 # (B, 1, KV, Dh) roped new key
    v_t: jax.Array,                 # (B, 1, KV, Dh)
    x_t: Optional[jax.Array],       # (B, 1, Dm)
    k_rope_t: Optional[jax.Array],  # (B, 1, KV, R)
    q_nope: Optional[jax.Array],    # (B, 1, H, Dn) content query (T1)
    q_rope: Optional[jax.Array],    # (B, 1, H, R) roped query slice (T1)
    w_k_nope: Optional[jax.Array],  # (Dm, KV, Dn) (T1)
    w_v: Optional[jax.Array],       # (Dm, KV, Dh) (T1)
    scale: float,
) -> tuple[jax.Array, PagedCache]:
    """Paged analogue of ``core.attention.decode_attend``: scatter one token
    per row through the block table, then attend with per-row lengths. With
    ``rt.paged_kernels`` (the default) the dense, CPQ, and X/MLA tiers run
    the fused paged Pallas kernels, whose grid iterates block-table entries
    and DMAs mapped pages straight from the arena into VMEM — no contiguous
    logical view is ever materialized. ``rt.paged_kernels=False`` falls back
    to the jnp gather path (the numerics oracle and benchmark foil);
    retrieval (T3, top-k slot selection) and the T1+T2 composition keep the
    gather path. Every row sits at its own position (``rows.lengths``);
    inactive rows write the null page and their output is garbage the engine
    never reads. Returns (out (B,1,H,Dv), new_cache)."""
    from repro.configs.base import AttentionRuntime
    from repro.core import attention as core_attn
    from repro.core import retrieval_attention as ret_lib
    from repro.core.decomposed_attention import decomposed_attention
    from repro.kernels.cpq_dequant_attn.ops import paged_cpq_decode_tpu
    from repro.kernels.decomposed_attn.ops import paged_decomposed_decode_tpu
    from repro.kernels.flash_attn.ops import paged_flash_decode_tpu

    if getattr(rt, "mesh", None) is not None:
        from repro.serving import sharded

        if sharded.supports(cache):
            return sharded.decode_attend_sharded(
                rt, cache, rows, q=q, k_t=k_t, v_t=v_t, x_t=x_t,
                k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
                w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        # T3 / T1+T2 keep global-semantics compute over (possibly storage-
        # sharded) arenas — GSPMD inserts the gathers
        import dataclasses as _dc
        rt = _dc.replace(rt, mesh=None)

    fused = rt.paged_kernels
    new_len = rows.lengths + rows.active.astype(jnp.int32)

    if isinstance(cache, TieredPagedCache):
        # compute both tiers (each tier's appends masked to its own rows),
        # select per row — one jitted step serves a mixed dense/T2 batch
        rows_d = rows._replace(active=rows.active & (rows.tier == 0))
        rows_c = rows._replace(active=rows.active & (rows.tier == 1),
                               block_table=rows.alt_block_table)
        rt_c = AttentionRuntime(mode="cpq", cpq=rt.cpq, paged_kernels=fused)
        out_d, dense = decode_attend_paged(
            rt, cache.dense, rows_d, q=q, k_t=k_t, v_t=v_t, x_t=x_t,
            k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        out_c, cpq = decode_attend_paged(
            rt_c, cache.cpq, rows_c, q=q, k_t=k_t, v_t=v_t, x_t=x_t,
            k_rope_t=k_rope_t, q_nope=q_nope, q_rope=q_rope,
            w_k_nope=w_k_nope, w_v=w_v, scale=scale)
        out = jnp.where((rows.tier == 1)[:, None, None, None], out_c, out_d)
        return out, TieredPagedCache(dense, cpq)

    if isinstance(cache, PagedDenseKVCache):
        cache = append_dense(cache, rows, k_t, v_t)
        if fused:
            out = paged_flash_decode_tpu(
                q, cache.k, cache.v, rows.block_table, new_len, scale)
        else:
            out = core_attn.dense_attention(
                q, gather_pages(cache.k, rows.block_table),
                gather_pages(cache.v, rows.block_table),
                scale, causal=False, kv_length=new_len)
        return out, cache

    if isinstance(cache, PagedXCache):
        cache = append_x(cache, rows, x_t, k_rope_t)
        if fused:
            out = paged_decomposed_decode_tpu(
                q_nope, q_rope, cache.x, cache.k_rope,
                rows.block_table, new_len, w_k_nope, w_v, scale)
        else:
            out = decomposed_attention(
                q_nope, q_rope, gather_pages(cache.x, rows.block_table),
                gather_pages(cache.k_rope, rows.block_table),
                w_k_nope, w_v, new_len, scale)
        return out, cache

    if isinstance(cache, PagedCPQKVCache):
        cache = PagedCPQKVCache(
            k=append_cpq_tensor(cache.k, rows, k_t, rt.cpq),
            v=append_cpq_tensor(cache.v, rows, v_t, rt.cpq))
        if fused:
            out = paged_cpq_decode_tpu(
                q, cache.k, cache.v, rows.block_table, new_len, scale)
        else:
            out = core_attn.cpq_chunked_decode_attention(
                q, logical_cpq(cache.k, rows.block_table),
                logical_cpq(cache.v, rows.block_table), new_len, scale)
        return out, cache

    if isinstance(cache, PagedRetrievalCache):
        dp = rt.retrieval.proxy_dim or k_t.shape[-1]
        code_t = ret_lib.encode_proxy(
            k_t[..., :dp], cache.proxy_scale, cache.proxy_zero, rt.retrieval.proxy_bits)
        cache = PagedRetrievalCache(
            k=write_token_pages(cache.k, rows.block_table, rows.lengths,
                                rows.active, k_t[:, 0]),
            v=write_token_pages(cache.v, rows.block_table, rows.lengths,
                                rows.active, v_t[:, 0]),
            proxy=write_token_pages(cache.proxy, rows.block_table, rows.lengths,
                                    rows.active, code_t[:, 0]),
            proxy_scale=cache.proxy_scale, proxy_zero=cache.proxy_zero)
        out = ret_lib.retrieval_attention(
            q, gather_pages(cache.k, rows.block_table),
            gather_pages(cache.v, rows.block_table),
            gather_pages(cache.proxy, rows.block_table),
            cache.proxy_scale, cache.proxy_zero, new_len, rt.retrieval, scale)
        return out, cache

    if isinstance(cache, PagedCPQXCache):
        cache = PagedCPQXCache(
            x=append_cpq_tensor(cache.x, rows, x_t[:, :, None, :], rt.cpq),
            k_rope=(write_token_pages(cache.k_rope, rows.block_table, rows.lengths,
                                      rows.active, k_rope_t[:, 0])
                    if k_rope_t is not None else cache.k_rope))
        out = core_attn.decomposed_cpq_chunked_decode(
            q_nope, q_rope, logical_cpq(cache.x, rows.block_table),
            gather_pages(cache.k_rope, rows.block_table),
            w_k_nope, w_v, new_len, scale)
        return out, cache

    raise TypeError(type(cache))


# ------------------------------------------------------- tier escalation (T2)


def compress_dense_slot(k_log: jax.Array, v_log: jax.Array, length: jax.Array,
                        cfg: CPQCfg) -> kvc.CPQKVCache:
    """Re-compress one slot's gathered dense K/V into CPQ tensors — the
    watermark policy's dense -> T2 migration. Only dense is escalatable
    post-hoc: T1 needs the pre-projection operand X, which a dense cache
    never stored; T2 compresses exactly what is cached.

    k_log/v_log: (1, Npad, KV, Dh) logical views; slots beyond ``length`` are
    replaced by the last valid token so the prefill statistics (prune
    quantile, level-0 range) see only real data."""
    pos = jnp.arange(k_log.shape[1], dtype=jnp.int32)
    last = jnp.clip(length - 1, 0, k_log.shape[1] - 1)

    def valid_only(a):
        edge = jax.lax.dynamic_index_in_dim(a, last, axis=1)  # (1, 1, KV, Dh)
        return jnp.where((pos < length)[None, :, None, None], a, edge)

    kt = cpq_lib.cpq_compress_prefill(valid_only(k_log), cfg, k_log.shape[1])
    vt = cpq_lib.cpq_compress_prefill(valid_only(v_log), cfg, v_log.shape[1])
    return kvc.CPQKVCache(kt, vt, length)
