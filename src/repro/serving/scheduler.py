"""Host-side continuous-batching scheduler.

Pure bookkeeping, no JAX: an admission queue, a slot table, per-arena page
allocators, and the memory watermark policy. The engine (engine.py) consults
it every step and turns its decisions into jitted cache operations.

Request lifecycle:

    queued --admit--> prefilling --finish_prefill--> running --retire--> done
                \\          |                           | preempt (out of
                 \\         | preempt /                 | pages: recompute-
                  <---------+--- deescalate ------------+ style, vLLM)

``prefilling`` is the chunked-admission window: the slot and its pages are
owned, but the prompt is still streaming into the arena chunk by chunk
(at most one chunk per engine tick, interleaved with the decode step) and
the row does not decode yet. The one-shot path (prefill_chunk == 0)
passes through it within a single engine tick.

Decision/mechanism split: WHICH request admits (and into which tier), which
slot holder a page-starved grower evicts, which dense row escalates under
critical pressure, and which T2 row de-escalates when pressure clears are
all delegated to a ``SchedulerPolicy`` (serving/policies.py; default
``FifoPolicy`` is decision-identical to the pre-policy scheduler). This
module keeps the mechanisms those decisions drive.

Watermark policy (free-page fraction of the DENSE base arena):

  * ``free < low_watermark``       new admissions are assigned the compressed
                                   tier (T2 CPQ arena) — the paper's
                                   "dynamically compress" applied at entry.
  * ``free < critical_watermark``  the longest running dense request is
                                   escalated in place: its K/V pages are
                                   re-compressed into the CPQ arena and the
                                   dense pages freed (engine runs the jitted
                                   ``model.escalate_slot``).
  * ``free > high_watermark``      (policies with de-escalation enabled)
                                   an escalated row is restored to the dense
                                   tier by chunked re-admission — CPQ codes
                                   are lossy, so the dense K/V is rebuilt by
                                   the same exact context replay preemption
                                   uses.

Only dense -> T2 is escalatable post-hoc: T1 (decomposed) needs the
pre-projection operand X, which a dense cache never stored; T2 compresses
exactly what is cached. T1 tiers are chosen at engine construction instead.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

from repro.configs.base import ServingCfg
from repro.serving.paged_cache import (NULL_PAGE, PageAllocator, defrag_plan,
                                       pages_needed)
from repro.serving.prefix_index import PrefixIndex
from repro.serving.request import SamplingParams, SloClass


class SchedulerConfigError(ValueError):
    pass


@dataclass
class Request:
    """One serving request. ``prompt`` is immutable; ``generated`` accumulates
    across preemptions (re-admission prefills prompt + generated)."""

    rid: int
    prompt: np.ndarray                      # (S,) int32
    max_new_tokens: int
    arrival: float = 0.0                    # decode-step time units
    # -- request-centric API (serving/request.py); None = legacy defaults
    # derived by the engine from its GenerationConfig on admission --
    sampling: Optional[SamplingParams] = None
    slo: Optional[SloClass] = None          # policies read via slo_of()
    stream: Optional[Callable] = None       # per-token RequestOutput callback
    session_id: Optional[str] = None        # replica-affinity key (router)
    # -- scheduler-owned state --
    state: str = "queued"                   # queued | prefilling | running | done
    slot: int = -1
    tier: int = 0                           # 0 = base, 1 = escalated/compressed
    pages: list = field(default_factory=list)
    generated: list = field(default_factory=list)
    length: int = 0                         # valid cache tokens
    prefill_target: int = 0                 # context tokens this admission owes
    token_steps: list = field(default_factory=list)  # emission tick per token
    admitted_step: int = -1
    first_token_step: int = -1
    done_step: int = -1
    finish_reason: str = ""
    preemptions: int = 0
    escalated: bool = False
    deescalations: int = 0
    # prefix sharing bookkeeping: tokens mounted from the index at the LAST
    # admission (zero arena writes; chunked prefill starts at this offset)
    # and the high-water block count already registered into the index
    shared_tokens: int = 0
    indexed_blocks: int = 0
    cow_copies: int = 0
    # set between deescalate() and the re-admission it exists for: the
    # recovery replay must land DENSE (policies pin its tier; falling back
    # to T2 would be a full-context recompute for nothing)
    recovering: bool = False
    # deadline-aware shedding (policies.derive_deadlines): ABSOLUTE engine
    # ticks; math.inf = none. Blown budgets retire the request with
    # finish_reason "timeout" at the next tick boundary. ttft_deadline only
    # applies while no first token has been emitted.
    deadline: float = float("inf")
    ttft_deadline: float = float("inf")
    # open speculative draft (serving/speculative.py): scratch pages +
    # aliased-page references between begin_draft and commit/abort. Any
    # release path (retire/preempt/escalate/deescalate) aborts it first.
    draft: Optional[object] = None

    @property
    def context(self) -> np.ndarray:
        """Tokens to prefill on (re-)admission."""
        return np.concatenate(
            [self.prompt, np.asarray(self.generated, np.int32)]).astype(np.int32)

    @property
    def num_generated(self) -> int:
        return len(self.generated)

    @property
    def stop_ids(self) -> frozenset:
        return (frozenset(self.sampling.stop_token_ids)
                if self.sampling is not None else frozenset())


class Scheduler:
    def __init__(self, serving: ServingCfg, tiered: bool = False,
                 policy=None, share_prefix: Optional[bool] = None):
        from repro.serving.policies import FifoPolicy

        self.cfg = serving
        self.tiered = tiered
        self.policy = policy if policy is not None else FifoPolicy()
        if serving.max_len < 2:
            raise SchedulerConfigError("max_len < 2")
        self.dense_alloc = PageAllocator(serving.num_pages)
        self.cpq_alloc = PageAllocator(serving.escalated_pages) if tiered else None
        # prefix sharing: a WEAK index over the BASE (dense-tier) arena only
        # — CPQ / retrieval pages dequantize through per-slot side state
        # fitted to one request's stream, so mounting them elsewhere would
        # break bit-parity. The engine passes its own gate (chunked modes
        # only); direct constructions default to ServingCfg.share_prefix.
        if share_prefix is None:
            share_prefix = getattr(serving, "share_prefix", False)
        self.prefix_index = (PrefixIndex(serving.page_size)
                             if share_prefix else None)
        self.queue: deque[Request] = deque()
        self.slots: list[Optional[Request]] = [None] * serving.num_slots
        S, M = serving.num_slots, serving.max_blocks_per_slot
        self.block_tables = np.zeros((S, M), np.int32)       # base arena
        self.alt_block_tables = np.zeros((S, M), np.int32) if tiered else None
        self.lengths = np.zeros((S,), np.int32)
        self.tiers = np.zeros((S,), np.int32)
        self.stats = {"admitted": 0, "retired": 0, "preemptions": 0,
                      "escalations": 0, "deescalations": 0,
                      "peak_dense_pages": 0, "defrags": 0,
                      "prefix_hits": 0, "shared_prefix_tokens": 0,
                      "shared_prefix_pages": 0, "cow_copies": 0,
                      "timeouts": 0, "spec_steps": 0, "spec_drafted": 0,
                      "spec_accepted": 0}

    # ------------------------------------------------------------- queries

    def has_work(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.slots)

    def occupied(self) -> list[Request]:
        """Every slot holder — decoding AND mid-prefill (all own pages)."""
        return [r for r in self.slots if r is not None]

    def running(self) -> list[Request]:
        """Rows that decode this step (prefill finished)."""
        return [r for r in self.slots if r is not None and r.state == "running"]

    def prefilling(self) -> list[Request]:
        """Chunked admissions still streaming their prompt, oldest first."""
        rows = [r for r in self.slots
                if r is not None and r.state == "prefilling"]
        return sorted(rows, key=lambda r: r.admitted_step)

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None and r.state == "running"
                         for r in self.slots], bool)

    def free_frac(self) -> float:
        return self.dense_alloc.num_free / max(self.dense_alloc.num_pages - 1, 1)

    def arena_stats(self) -> dict:
        """Public allocator/defrag counters (the engine folds these into its
        serve() stats; bench_serving and the sharded watermark read them here
        instead of reaching into ``dense_alloc`` / ``cpq_alloc``). All counts
        are LOGICAL pages — under a model-sharded mesh every logical page is
        one per-device slice, so fractions (and the watermark thresholds
        derived from them) are mesh-invariant."""
        out = {
            "dense_pages_used": self.dense_alloc.num_used,
            "dense_pages_free": self.dense_alloc.num_free,
            "dense_arena_utilization": self.dense_alloc.utilization,
            "defrags": self.stats["defrags"],
        }
        if self.cpq_alloc is not None:
            out["cpq_pages_used"] = self.cpq_alloc.num_used
            out["cpq_arena_utilization"] = self.cpq_alloc.utilization
        if self.prefix_index is not None:
            out["prefix_index_pages"] = len(self.prefix_index)
            out["prefix_hits"] = self.stats["prefix_hits"]
        return out

    def plan_defrag(self):
        """Compact the BASE (dense-tier) arena: relabel every mapped page
        onto the lowest physical ids (paged_cache.defrag_plan), rewrite the
        block tables and every tier-0 request's page list, and rebuild the
        allocator free list. SHARED pages (refcount > 1) compact FIRST —
        every sharer's sequential page reads start from the same dense
        low-id cluster, so the hottest pages get the tightest locality.
        Returns the (num_pages,) permutation to apply to every base-arena
        page pool (``perm[new_id] = old_id``), or None when the arena is
        already compact. Escalated (tier-1) pages live in the CPQ arena and
        are untouched."""
        if any(r.draft is not None for r in self.occupied()):
            # an open speculative draft owns scratch pages that are
            # invisible to the block tables — relabeling now would mark
            # them free (DoubleFree in relabel). Drafts close within the
            # engine tick; compaction just waits one tick.
            return None
        shared = {p for p in range(1, self.cfg.num_pages)
                  if self.dense_alloc.refcount(p) > 1}
        perm, new_bt, free = defrag_plan(self.block_tables,
                                         self.cfg.num_pages, shared=shared)
        if all(int(p) == i for i, p in enumerate(perm)):
            return None
        remap = {int(old): new for new, old in enumerate(perm)}
        self.block_tables[:] = new_bt
        for r in self.occupied():
            if r.tier == 0:
                r.pages = [remap[int(p)] for p in r.pages]
        # shared pages move ONCE (defrag_plan dedups via its ``seen`` set)
        # and every owner's table entry was rewritten above; the allocator
        # carries each page's refcount to its new id and the prefix index
        # renames its physical ids (keys are content-addressed)
        self.dense_alloc.relabel(perm, free)
        if self.prefix_index is not None:
            self.prefix_index.relabel(remap)
        self.stats["defrags"] += 1
        return perm

    def _arena(self, tier: int) -> PageAllocator:
        return self.cpq_alloc if tier == 1 else self.dense_alloc

    def _tables(self, tier: int) -> np.ndarray:
        return self.alt_block_tables if tier == 1 else self.block_tables

    # ----------------------------------------------------------- admission

    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.cfg.max_len:
            raise SchedulerConfigError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new {req.max_new_tokens} exceeds max_len {self.cfg.max_len}")
        req.state = "queued"
        self.queue.append(req)

    def admit_next(self, now: float, step: int) -> Optional[Request]:
        """Admit the policy's pick into a vacated slot. The policy chooses
        WHICH arrived request and WHICH tier (``select_admission``; the
        default FifoPolicy requires the queue head to be admissible — no
        head-of-line bypass); this method performs the mechanics."""
        if not self.queue:
            return None
        try:
            slot = self.slots.index(None)
        except ValueError:
            return None
        sel = self.policy.select_admission(self, now)
        if sel is None:
            return None
        req, tier = sel
        arena = self._arena(tier)
        ctx = req.context
        need = pages_needed(len(ctx), self.cfg.page_size)
        # prefix sharing (base tier only): mount already-resident pages for
        # the longest indexed prefix — refcount bumps, ZERO arena writes —
        # and stream chunked prefill over the unshared tail only. The match
        # is capped at len(ctx)-1 so the first token's logits always come
        # from a computed tail chunk (token-exactness).
        shared_pages: list[int] = []
        shared_tokens = 0
        if tier == 0 and self.prefix_index is not None:
            # heal first: a retirement may have just forgotten entries whose
            # content is still resident in OTHER rows' pages (their earlier
            # registrations deduped against the retiree's). Re-registering
            # live rows is watermark-cheap and closes the one-tick window
            # between a registrant's release and the next chunk pump.
            for live in self.slots:
                if live is not None:
                    self.register_prefix(live)
            shared_pages, shared_tokens = self.prefix_index.match(ctx)
        self.queue.remove(req)
        req.recovering = False
        fresh = arena.alloc(need - len(shared_pages))
        for p in shared_pages:
            arena.incref(p)
        req.pages = [int(p) for p in shared_pages] + fresh
        req.state, req.slot, req.tier = "prefilling", slot, tier
        req.prefill_target = len(ctx)
        req.length = shared_tokens  # prefix pre-mounted; chunks grow the tail
        req.shared_tokens = shared_tokens
        req.indexed_blocks = 0
        if req.admitted_step < 0:
            req.admitted_step = step
        self.slots[slot] = req
        tables = self._tables(tier)
        tables[slot, :] = NULL_PAGE
        tables[slot, :need] = req.pages
        if self.tiered:
            self._tables(1 - tier)[slot, :] = NULL_PAGE
        self.lengths[slot] = shared_tokens
        self.tiers[slot] = tier
        if shared_tokens:
            self.stats["prefix_hits"] += 1
            self.stats["shared_prefix_tokens"] += shared_tokens
            self.stats["shared_prefix_pages"] += len(shared_pages)
        self.stats["admitted"] += 1
        self.stats["peak_dense_pages"] = max(self.stats["peak_dense_pages"],
                                             self.dense_alloc.num_used)
        return req

    def note_chunk(self, req: Request, n_tokens: int) -> None:
        """A prompt chunk of ``n_tokens`` valid tokens landed in the arena."""
        assert req.state == "prefilling"
        req.length = min(req.length + n_tokens, req.prefill_target)
        self.lengths[req.slot] = req.length

    def finish_prefill(self, req: Request) -> None:
        """The full context is in the arena: the row starts decoding."""
        assert req.state == "prefilling"
        req.state = "running"
        req.length = req.prefill_target
        self.lengths[req.slot] = req.length

    # -------------------------------------------------------------- growth

    def ensure_writable(self, req: Request) -> bool:
        """Map a page for the next token write (position ``req.length``).
        False => the tier arena is out of pages (caller preempts/escalates)."""
        blk = req.length // self.cfg.page_size
        if blk >= self.cfg.max_blocks_per_slot:
            return False  # context ceiling — caller retires
        tables = self._tables(req.tier)
        if tables[req.slot, blk] != NULL_PAGE:
            return True
        arena = self._arena(req.tier)
        if not arena.can_alloc(1):
            return False
        page = arena.alloc(1)
        req.pages += page
        tables[req.slot, blk] = page[0]
        self.stats["peak_dense_pages"] = max(self.stats["peak_dense_pages"],
                                             self.dense_alloc.num_used)
        return True

    # ------------------------------------------------- speculative drafts

    def begin_draft(self, req: Request, k: int):
        """Open a speculative draft of ``k`` candidate tokens on a running
        tier-0 row: take one reference on EVERY page the row currently maps
        (the draft aliases the target's history — zero arena writes) and
        allocate fresh SCRATCH pages for the blocks positions
        ``length..length+k`` land in. A PARTIAL frontier page is replaced
        by a scratch page (``copy_src`` names it — the engine seeds the
        payload with the jitted page copy) so verification never writes
        into a page the target or a prefix sharer owns; a mapped EMPTY
        frontier at a page boundary stays target-owned (nothing valid to
        preserve, exclusively owned by construction). Returns the
        DraftState, or None when the draft cannot be opened (arena
        pressure / block ceiling) — the caller falls back to a normal
        decode step."""
        from repro.serving.speculative import DraftState

        assert req.draft is None, "draft already open"
        assert req.state == "running" and req.tier == 0 and req.slot >= 0
        assert k >= 1
        ps = self.cfg.page_size
        L = req.length
        b1 = (L + k) // ps
        if b1 >= self.cfg.max_blocks_per_slot:
            return None
        n_mapped = len(req.pages)
        if L % ps:
            first_blk, copy_src = L // ps, int(req.pages[L // ps])
        else:
            # frontier at a page boundary: n_mapped is b0 (unmapped) or
            # b0+1 (pre-mapped empty by the growth phase) — scratch starts
            # right after the mapped blocks either way
            first_blk, copy_src = n_mapped, -1
        blocks = list(range(first_blk, b1 + 1))
        if not self.dense_alloc.can_alloc(len(blocks)):
            return None
        scratch = self.dense_alloc.alloc(len(blocks))
        aliased = [int(p) for p in req.pages]
        for p in aliased:
            self.dense_alloc.incref(p)
        req.draft = DraftState(scratch=scratch, blocks=blocks,
                               aliased=aliased, copy_src=copy_src)
        self.stats["peak_dense_pages"] = max(self.stats["peak_dense_pages"],
                                             self.dense_alloc.num_used)
        return req.draft

    def draft_block_row(self, req: Request) -> np.ndarray:
        """The draft's logical view of the row: the target's block row with
        the scratch tail installed (history blocks read the target's own
        pages — that is the aliasing)."""
        d = req.draft
        row = self.block_tables[req.slot].copy()
        for b, p in zip(d.blocks, d.scratch):
            row[b] = p
        return row

    def commit_draft(self, req: Request, n_accept: int) -> None:
        """Close the draft accepting ``n_accept`` committed tokens (the
        verified draws; always >= 1 — the position-``length`` draw is the
        tick's own next token). Scratch pages covering the newly valid
        positions are ADOPTED into the row's page list in block order (a
        replaced partial frontier decrefs the original — the adopted copy
        doubles as its copy-on-write split if a sharer holds it); surplus
        scratch and every aliased reference are released. The row's length
        grows via the engine's per-token emits, not here."""
        d = req.draft
        assert d is not None and n_accept >= 1
        last_blk = (req.length + n_accept - 1) // self.cfg.page_size
        for b, p in zip(d.blocks, d.scratch):
            if b > last_blk:
                self._free_pages(0, [p])        # surplus: never became valid
            elif b < len(req.pages):
                old = req.pages[b]              # replaced partial frontier
                req.pages[b] = p
                self.block_tables[req.slot, b] = p
                self._free_pages(0, [old])
            else:
                assert b == len(req.pages), "scratch adoption out of order"
                req.pages.append(p)
                self.block_tables[req.slot, b] = p
        self._free_pages(0, d.aliased)
        req.draft = None
        self.stats["spec_steps"] += 1
        self.stats["spec_drafted"] += len(d.tokens)
        self.stats["spec_accepted"] += n_accept - 1

    def abort_draft(self, req: Request) -> None:
        """Close the draft accepting nothing: drop the aliased references
        and free the scratch pages. The target row is untouched — reject
        costs zero arena writes."""
        d = req.draft
        if d is None:
            return
        self._free_pages(0, d.aliased)
        self._free_pages(0, d.scratch)
        req.draft = None

    # ------------------------------------------------ prefix sharing / COW

    def _free_pages(self, tier: int, pages) -> None:
        """The ONE funnel every page release goes through: the allocator
        decrefs, and pages whose refcount hit zero leave the prefix index
        (free-list membership <=> refcount 0 <=> not indexed)."""
        released = self._arena(tier).free(pages)
        if tier == 0 and self.prefix_index is not None:
            for p in released:
                self.prefix_index.forget(p)

    def cow_plan(self, req: Request) -> Optional[tuple[int, int]]:
        """Copy-on-write guard, called BEFORE any write into the block that
        holds position ``req.length`` (the next chunk/decode write target).

        A shared mapping there (refcount > 1) splits: allocate a private
        page, remap this owner's block-table entry, decref the shared page
        — the caller must then run the jitted page copy ``src -> dst``
        before writing. A lone-owner mapping that is still REGISTERED is
        about to stop matching its key (the write diverges mid-page), so it
        just leaves the index in place. Raises ``PageAllocator.OutOfPages``
        when the split cannot get a page (caller applies the same pressure
        valves as page growth). Returns (src, dst) or None."""
        if req.tier != 0 or req.slot < 0:
            return None
        blk = req.length // self.cfg.page_size
        if blk >= self.cfg.max_blocks_per_slot:
            return None  # growth's length-cap path owns this case
        page = int(self.block_tables[req.slot, blk])
        if page == NULL_PAGE:
            return None
        if self.dense_alloc.refcount(page) <= 1:
            # private already — but a registered page's content is about to
            # diverge from its key past position ``length``: unregister
            if self.prefix_index is not None:
                self.prefix_index.forget(page)
            return None
        dst = self.dense_alloc.alloc(1)[0]
        self.block_tables[req.slot, blk] = dst
        req.pages[req.pages.index(page)] = dst
        self._free_pages(0, [page])  # decref; other owners keep the original
        req.cow_copies += 1
        self.stats["cow_copies"] += 1
        self.stats["peak_dense_pages"] = max(self.stats["peak_dense_pages"],
                                             self.dense_alloc.num_used)
        return page, dst

    def register_prefix(self, req: Request) -> None:
        """Register every newly COMPLETED page of ``req``'s context into the
        prefix index (full pages are immutable, hence safe to share). Called
        after prefill finishes and whenever decode fills a page — so a
        multi-turn follow-up sharing this request's whole history mounts it
        from the index. Registration never takes a reference: the index is
        weak, and entries die with the page (``_free_pages``)."""
        if (self.prefix_index is None or req.tier != 0 or req.slot < 0
                or req.state not in ("prefilling", "running")):
            return
        ctx = req.context
        full = min(req.length, len(ctx)) // self.cfg.page_size
        if full > req.indexed_blocks:
            req.indexed_blocks = self.prefix_index.insert(
                ctx, req.pages, req.indexed_blocks, full)

    # ---------------------------------------------------- retire / preempt

    def _release(self, req: Request) -> None:
        self.abort_draft(req)
        self._free_pages(req.tier, req.pages)
        req.pages = []
        req.indexed_blocks = 0
        slot = req.slot
        self.block_tables[slot, :] = NULL_PAGE
        if self.tiered:
            self.alt_block_tables[slot, :] = NULL_PAGE
        self.lengths[slot] = 0
        self.tiers[slot] = 0
        self.slots[slot] = None
        req.slot = -1

    def retire(self, req: Request, step: int, reason: str) -> None:
        self._release(req)
        req.state, req.done_step, req.finish_reason = "done", step, reason
        req.tier = 0
        self.stats["retired"] += 1

    def preempt(self, req: Request) -> None:
        """Recompute-style preemption: free everything, requeue at the FRONT
        (its context re-prefills on the next admission)."""
        self._release(req)
        req.state, req.tier, req.length = "queued", 0, 0
        req.preemptions += 1
        self.stats["preemptions"] += 1
        self.queue.appendleft(req)

    def preemption_victim(self, exclude: Request) -> Optional[Request]:
        """Policy-chosen eviction victim among slot holders (decoding or
        mid-prefill — both own pages) in the SAME arena the blocked request
        allocates from. Default (fifo): the youngest."""
        return self.policy.preemption_victim(self, exclude)

    # ------------------------------------------------- escalation / recovery

    def escalation_candidate(self) -> Optional[Request]:
        """Under critical pressure: the policy's pick among running dense
        requests whose compressed footprint fits the CPQ arena. Default
        (fifo): the longest."""
        if not self.tiered:
            return None
        return self.policy.escalation_candidate(self)

    def deescalation_candidate(self) -> Optional[Request]:
        """When dense pressure clears (free fraction above the HIGH
        watermark): the policy's pick among escalated (T2) running rows
        whose full context fits the dense arena, or None (default fifo:
        de-escalation is opt-in)."""
        if not self.tiered:
            return None
        return self.policy.deescalation_candidate(self)

    def deescalate(self, req: Request) -> None:
        """T2 -> dense recovery via chunked re-admission: CPQ codes are
        lossy, so the dense K/V is rebuilt by replaying the request's
        ``prompt + generated`` context through the normal (chunked)
        admission path. Mechanically a preemption — free everything, requeue
        at the FRONT — tracked separately in the stats; the re-admission
        lands dense because the policy only volunteers rows when the free
        fraction sits above ``high_watermark`` (hysteresis)."""
        assert req.tier == 1 and req.slot >= 0, "de-escalating a dense row"
        self._release(req)
        req.state, req.tier, req.length = "queued", 0, 0
        req.deescalations += 1
        req.recovering = True
        self.stats["deescalations"] += 1
        self.queue.appendleft(req)

    def apply_escalation(self, req: Request) -> tuple[np.ndarray, np.ndarray]:
        """Move ``req``'s page ownership dense -> CPQ arena. Returns
        (dense_row, cpq_row) block rows for the jitted re-compression (the
        dense_row is the PRE-escalation mapping the gather reads)."""
        assert self.tiered and req.tier == 0
        self.abort_draft(req)   # drafts are a tier-0 feature
        slot = req.slot
        dense_row = self.block_tables[slot].copy()
        need = pages_needed(req.length + 1, self.cfg.page_size)
        new_pages = self.cpq_alloc.alloc(need)
        # shared dense pages just decref (another owner may keep them live);
        # the re-compressed CPQ copy is private to this slot either way
        self._free_pages(0, req.pages)
        req.pages = new_pages
        req.indexed_blocks = 0
        req.tier, req.escalated = 1, True
        self.tiers[slot] = 1
        self.block_tables[slot, :] = NULL_PAGE
        self.alt_block_tables[slot, :] = NULL_PAGE
        self.alt_block_tables[slot, :need] = new_pages
        self.stats["escalations"] += 1
        return dense_row, self.alt_block_tables[slot].copy()