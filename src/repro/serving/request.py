"""Request-centric serving API: the public dataclasses.

The serving front-end used to be batch-shaped — one engine-global
``GenerationConfig`` per ``serve()`` call, results only at the end, and a
hard-coded FIFO admission order. This module defines the request-level
vocabulary the redesigned engine speaks:

  ``SamplingParams``   per-request decoding knobs (temperature / top-k /
                       top-p / seed / stop tokens / token budget). The engine
                       vectorizes them into per-row arrays consumed by ONE
                       jitted sampler — greedy rows (``temperature <= 0``)
                       take the same argmax as before, bit-identically.
  ``SloClass``         the request's service class: a strict priority level
                       plus TTFT / ITL targets in engine ticks. Pure
                       metadata to the engine; ``serving/policies.py`` turns
                       it into admission / preemption / escalation decisions
                       and benchmarks score attainment against the targets.
  ``ServeRequest``     the immutable user-facing request spec
                       (prompt + sampling + slo + arrival + optional
                       streaming callback). ``ContinuousServeEngine
                       .add_request`` converts it into the scheduler-owned
                       mutable ``Request`` record.
  ``RequestOutput``    one incremental output event: a single generated
                       token with its stream index, the engine tick it
                       became available at, and the finish flag/reason on
                       the last one. ``engine.step()`` returns the tick's
                       events; per-request ``stream`` callbacks get them as
                       they are committed.

Seeded sampling is reproducible by construction: token ``i`` of a request is
drawn with ``fold_in(PRNGKey(seed), i)``, a function of the request alone —
never of the slot it landed in, the co-resident batch, or preemption history
(recompute replays the context and re-draws the same keys).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding parameters (vLLM-style).

    ``temperature <= 0`` selects greedy argmax (the default) — such rows are
    bit-identical to the pre-request-API engine. ``top_k == 0`` disables the
    top-k filter; ``top_p == 1.0`` disables the nucleus filter. ``seed``
    names the request's private sample stream (see module docstring);
    ``stop_token_ids`` retire the request exactly like EOS (pages freed, slot
    refilled) with finish_reason ``"stop"``."""

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_tokens: int = 32
    stop_token_ids: tuple[int, ...] = ()
    seed: int = 0
    # explicit total-latency budget in engine ticks RELATIVE to arrival
    # (math.inf = none). At a tick boundary where the budget is blown the
    # request retires with finish_reason "timeout" (pages freed, counted in
    # the ``timeouts`` stat). Overrides any SloClass-derived budget.
    deadline: float = math.inf
    # per-request opt-out of speculative decoding (engines with
    # ``ServingCfg.spec_len > 0``). Output-invisible either way: committed
    # tokens are always the request's own fold_in(seed, token_index) draws
    # (argmax for greedy), speculation only changes WHEN they land.
    speculate: bool = True

    def __post_init__(self):
        assert self.max_tokens >= 1, "max_tokens must be >= 1"
        assert self.top_k >= 0, "top_k < 0 (0 disables the filter)"
        assert 0.0 < self.top_p <= 1.0, "top_p must be in (0, 1]"
        assert self.deadline > 0, "deadline must be > 0 ticks (inf = none)"
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))


@dataclasses.dataclass(frozen=True)
class SloClass:
    """Service-level class: strict priority + latency targets.

    ``priority`` orders classes (higher = more urgent); ``ttft_target`` /
    ``itl_target`` are time-to-first-token / inter-token-latency targets in
    engine ticks (the decode-step clock every serve stat is measured in).
    ``math.inf`` targets mean "no deadline" — `SloAwarePolicy` treats such
    requests as infinitely patient and benchmarks score them as always
    attained."""

    name: str = "standard"
    priority: int = 1
    ttft_target: float = math.inf
    itl_target: float = math.inf


# canonical classes (benchmarks and examples use these; any SloClass works)
INTERACTIVE = SloClass("interactive", priority=2, ttft_target=8.0,
                       itl_target=3.0)
STANDARD = SloClass("standard", priority=1, ttft_target=32.0, itl_target=8.0)
BATCH = SloClass("batch", priority=0)


@dataclasses.dataclass(frozen=True)
class ServeRequest:
    """User-facing request spec. ``prompt`` is any int sequence; ``stream``
    (optional) is called with each ``RequestOutput`` as it is committed.
    ``arrival`` is in decode-step units (0.0 = already arrived), matching
    the engine's simulation clock. ``session_id`` (optional) names a
    multi-turn conversation: the replica router pins every request of a
    session to the replica that served its earlier turns (the replica
    holding the session's arena pages), remapping only on drain — a single
    engine ignores it."""

    prompt: np.ndarray
    sampling: SamplingParams = SamplingParams()
    slo: SloClass = STANDARD
    rid: Optional[int] = None          # None => engine assigns the next id
    arrival: float = 0.0
    stream: Optional[Callable[["RequestOutput"], None]] = None
    session_id: Optional[str] = None   # replica-affinity key (router)

    def __post_init__(self):
        object.__setattr__(self, "prompt",
                           np.asarray(self.prompt, np.int32).reshape(-1))
        assert len(self.prompt) >= 1, "empty prompt"


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """One streamed token. ``index`` is the token's position in the request's
    generated stream (0-based); ``step`` the engine tick it became available
    at (end-of-work convention, same clock as ``token_steps`` in results).
    ``finished`` is True on the request's final event, with ``finish_reason``
    in {eos, stop, max_tokens, length_cap, oom, unschedulable, timeout,
    shed}. ``timeout``/``shed`` finishes carry ``token == -1`` — a
    finish-only event with no token payload (the stream up to ``index``
    tokens is still gapless)."""

    rid: int
    token: int
    index: int
    step: int
    finished: bool = False
    finish_reason: str = ""
