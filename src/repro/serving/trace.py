"""Reusable serving traces: workload builders + the continuous-run harness.

Extracted from ``benchmarks/bench_serving.py`` so programmatic callers (the
auto-tuner in ``repro/tuning``, notebooks, tests) can run the REAL
``ContinuousServeEngine`` on a seeded trace and read structured metrics
without shelling out to the benchmark CLI. ``bench_serving`` now imports
everything here and stays the thin comparison/reporting wrapper.

The module has three layers:

  * ``WorkItem`` + ``make_*_workload`` — deterministic seeded traces
    (Poisson mixed-length, shared-system-prompt templated, mixed-SLO-class,
    heavy-tailed burst, self-similar loopy).
  * ``equal_arena_serving`` — the hand-tuned ``ServingCfg`` construction the
    benchmarks use everywhere (page pool sized to the static engine's token
    capacity). This is the baseline the auto-tuner must beat.
  * ``run_trace`` — one engine, one trace, one metrics dict. Deterministic
    under greedy decoding for a fixed (cfg, serving, work): every metric
    except the ``wall_time_s``/``tokens_per_s`` timers is computed on the
    engine's tick clock.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs import ServingCfg
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.paged_cache import pages_needed
from repro.serving.scheduler import Request


@dataclasses.dataclass
class WorkItem:
    rid: int
    prompt: np.ndarray
    target: int          # tokens the request actually wants
    arrival: float       # decode-step units


def make_workload(seed: int, n_requests: int, vocab: int, rate: float,
                  prompt_lens=(4, 28), short=(2, 9), long=(48, 80),
                  p_long=0.25, long_prompt=(0, 0), p_long_prompt=0.0
                  ) -> list[WorkItem]:
    """Poisson arrivals; heavy-tailed generation targets (the realistic mixed
    traffic where static batching pads every row to the batch straggler).
    ``long_prompt``/``p_long_prompt`` mix in occasional long prompts — the
    head-of-line hazard that makes monolithic admission stall decode."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        tgt = int(rng.integers(*long) if rng.random() < p_long
                  else rng.integers(*short))
        plen = (int(rng.integers(*long_prompt))
                if p_long_prompt and rng.random() < p_long_prompt
                else int(rng.integers(*prompt_lens)))
        out.append(WorkItem(
            rid=i,
            prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            target=tgt,
            arrival=t))
    return out


def make_templated_workload(seed: int, n_sessions: int, vocab: int,
                            rate: float, *, sys_tokens: int = 24,
                            turns: int = 3, turn_step: int = 10,
                            target=(3, 7), long=(24, 48),
                            p_long: float = 0.25) -> list[WorkItem]:
    """Shared-system-prompt multi-turn trace (the prefix-sharing workload):
    every request opens with ONE ``sys_tokens``-token system prompt, and each
    session's turns replay a growing slice of that session's private token
    stream (turn k's prompt = system + history[:k * turn_step] — the
    multi-turn chat shape where each follow-up resends the whole
    conversation). Prefix sharing mounts the system prompt (and any still-
    resident session history) as refcount bumps; sharing OFF rewrites it per
    request. Poisson arrivals interleave the sessions so the system-prompt
    pages stay hot. Generation targets keep the mixed trace's heavy tail
    (``p_long`` of turns draw from ``long``) — chat responses vary wildly in
    length, and that spread is what static batching pads for."""
    rng = np.random.default_rng(seed)
    sys_p = rng.integers(1, vocab, size=sys_tokens).astype(np.int32)
    t0 = 0.0  # session starts form their own Poisson process; turn gaps
    out = []  # within a session extend past later sessions' starts, so the
    rid = 0   # sorted trace interleaves turns from different sessions
    for _ in range(n_sessions):
        t0 += rng.exponential(1.0 / max(rate, 1e-9))
        t = t0
        hist = rng.integers(1, vocab, size=turns * turn_step).astype(np.int32)
        for k in range(1, turns + 1):
            t += rng.exponential(turns / max(rate, 1e-9))
            tgt = int(rng.integers(*long) if rng.random() < p_long
                      else rng.integers(*target))
            out.append(WorkItem(
                rid=rid,
                prompt=np.concatenate([sys_p, hist[:k * turn_step]]),
                target=tgt,
                arrival=t))
            rid += 1
    out.sort(key=lambda w: w.arrival)
    return out


def make_loopy_workload(seed: int, n_requests: int, vocab: int, *,
                        motif: int = 8, reps: int = 3, target: int = 48,
                        gap: float = 0.0) -> list[WorkItem]:
    """Self-similar prompts (one random motif tiled ``reps`` times plus a
    short unique tail) with LONG generation targets — the structure
    prompt-lookup drafting exploits. A tiny random model decoding greedily
    over a long horizon falls into short cycles, so the row's suffix n-gram
    recurs in its own context and verification accepts multi-token runs:
    the bench analogue of the repetition real decode traces show (code,
    templated text, chat boilerplate). ``gap`` spaces arrivals in
    decode-step units; a gap larger than a request's lifetime serializes
    the trace to occupancy 1 — the weight-stream-bound regime speculative
    decoding targets."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        m = rng.integers(1, vocab, size=motif).astype(np.int32)
        prompt = np.concatenate(
            [np.tile(m, reps),
             rng.integers(1, vocab, size=2).astype(np.int32)])
        out.append(WorkItem(rid=i, prompt=prompt, target=target,
                            arrival=i * gap))
    return out


def make_slo_workload(seed: int, n_requests: int, vocab: int, rate: float,
                      p_interactive: float = 0.35):
    """Mixed-class Poisson trace for the policy comparison: mostly
    low-priority batch jobs (longer prompts, heavy generation targets) with
    interleaved high-priority interactive arrivals (short prompts, short
    targets, tight TTFT/ITL deadlines). Under FIFO the interactive requests
    queue behind whatever batch work arrived first — exactly the contention
    priority/slo scheduling exists to resolve. Returns (work, slos)."""
    from repro.serving.request import SloClass

    interactive = SloClass("interactive", priority=2, ttft_target=10.0,
                           itl_target=4.0)
    batch = SloClass("batch", priority=0, ttft_target=96.0, itl_target=16.0)
    rng = np.random.default_rng(seed)
    t = 0.0
    work, slos = [], []
    for i in range(n_requests):
        t += rng.exponential(1.0 / max(rate, 1e-9))
        if rng.random() < p_interactive:
            slo, plen, tgt = interactive, int(rng.integers(3, 9)), \
                int(rng.integers(2, 7))
        else:
            # the batch class keeps the acceptance workload's heavy tail
            # (static padding waste is what the 1.5x bar measures)
            slo = batch
            plen = int(rng.integers(4, 28))
            tgt = (int(rng.integers(48, 80)) if rng.random() < 0.25
                   else int(rng.integers(2, 9)))
        work.append(WorkItem(
            rid=i, prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            target=tgt, arrival=t))
        slos.append(slo)
    return work, slos


def make_burst_workload(seed: int, n_requests: int, vocab: int, rate: float,
                        p_interactive: float = 0.4, alpha: float = 1.5):
    """Heavy-tailed router traffic: Pareto inter-arrival gaps (bursty — most
    gaps tiny, occasional long lulls, infinite variance at ``alpha <= 2``)
    carrying the mixed Poisson-style class draw of ``make_slo_workload``
    (interactive = short prompt/target + tight deadlines, batch = heavy
    generation tail). Bursts are what make single-engine queueing collapse
    and what placement policies must absorb. Returns (work, slos)."""
    from repro.serving.request import SloClass

    interactive = SloClass("interactive", priority=2, ttft_target=10.0,
                           itl_target=4.0)
    batch = SloClass("batch", priority=0, ttft_target=96.0, itl_target=16.0)
    rng = np.random.default_rng(seed)
    # Lomax (Pareto II) gaps scaled to the requested mean arrival rate:
    # mean gap = scale / (alpha - 1)
    scale = (alpha - 1.0) / max(rate, 1e-9)
    t = 0.0
    work, slos = [], []
    for i in range(n_requests):
        t += float(rng.pareto(alpha) * scale)
        if rng.random() < p_interactive:
            slo, plen, tgt = interactive, int(rng.integers(3, 9)), \
                int(rng.integers(2, 7))
        else:
            # tail targets stay shorter than a replica's share of the trace:
            # a lone straggler decoding at 1 token/step sets the lockstep
            # clock and would cap aggregate scaling no matter the placement
            slo = batch
            plen = int(rng.integers(4, 28))
            tgt = (int(rng.integers(16, 28)) if rng.random() < 0.25
                   else int(rng.integers(2, 9)))
        work.append(WorkItem(
            rid=i, prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
            target=tgt, arrival=t))
        slos.append(slo)
    return work, slos


def equal_arena_serving(num_slots: int, max_len: int, page_size: int,
                        prefill_chunk: int = 16,
                        bucket: int | None = None) -> ServingCfg:
    """Page pool with the SAME token capacity the static engine provisions
    (num_slots contiguous worst-case rows), plus the reserved null page.
    ``prefill_chunk=0`` selects the one-shot admission foil; pass ``bucket``
    = the chunked config's chunk size so both engines charge prefill work at
    the same clock quantum (fair ITL comparison)."""
    return ServingCfg(
        num_slots=num_slots,
        page_size=page_size,
        num_pages=num_slots * pages_needed(max_len, page_size) + 1,
        max_blocks_per_slot=pages_needed(max_len, page_size),
        prefill_bucket=bucket or prefill_chunk or page_size,
        prefill_chunk=prefill_chunk)


def run_trace(cfg, params, work: list[WorkItem], serving: ServingCfg,
              mode_rt=None, policy=None, slos=None, donor=None):
    """Serve one trace through a fresh ``ContinuousServeEngine`` and return
    the metrics dict. ``policy`` is a SchedulerPolicy (or name); ``slos`` an
    optional per-request SloClass list aligned with ``work`` (per-class tail
    metrics are added when given). ``donor`` is any engine of the same
    (cfg, rt) whose jitted step functions are adopted — sweeping many
    ServingCfgs (the auto-tuner's loop) compiles each step shape once."""
    eng = ContinuousServeEngine(cfg, params, rt=mode_rt, serving=serving,
                                policy=policy)
    if donor is not None:
        eng.adopt_compiled(donor)
    reqs = [Request(rid=w.rid, prompt=w.prompt, max_new_tokens=w.target,
                    arrival=w.arrival,
                    slo=None if slos is None else slos[i])
            for i, w in enumerate(work)]
    # max_new is per request; gen caps nothing here (eos disabled)
    res, stats = eng.serve(reqs, GenerationConfig(max_new_tokens=max(
        w.target for w in work)))
    latencies = [res[w.rid]["done_step"] - w.arrival for w in work]
    ttfts = [res[w.rid]["first_token_step"] - w.arrival for w in work]
    itls = np.concatenate(
        [np.diff(res[w.rid]["token_steps"]) for w in work
         if len(res[w.rid]["token_steps"]) > 1] or [np.zeros(1)])
    out = {
        "engine": "continuous" + ("-chunked" if eng.chunked else "-oneshot"),
        "useful_tokens": stats["generated_tokens"],
        "waste_tokens": 0,
        "decode_steps": stats["decode_steps"],
        "tokens_per_step": stats["generated_tokens"] / max(stats["decode_steps"], 1),
        "latency_mean": float(np.mean(latencies)),
        "latency_p90": float(np.percentile(latencies, 90)),
        "ttft_p50": float(np.percentile(ttfts, 50)),
        "ttft_p95": float(np.percentile(ttfts, 95)),
        "itl_p50": float(np.percentile(itls, 50)),
        "itl_p95": float(np.percentile(itls, 95)),
        "arena_utilization": stats["arena_utilization_mean"],
        "wall_time_s": stats["wall_time_s"],
        "tokens_per_s": stats["tokens_per_s"],
        "preemptions": stats["preemptions"],
        "escalations": stats["escalations"],
        "deescalations": stats["deescalations"],
        "prefill_chunks": stats["prefill_chunks"],
        "itl_mean": float(np.mean(itls)),
        # speculative-decoding surface (zeros with spec_len == 0)
        "spec_steps": stats["spec_steps"],
        "spec_accept_rate": stats["spec_accept_rate"],
        "spec_accepted_per_step": (stats["spec_accepted"]
                                   / max(stats["decode_steps"], 1)),
        # mesh / allocator surface (public engine stats, no private state)
        "tokens": np.concatenate([res[w.rid]["tokens"] for w in work]),
        "model_shards": stats["model_shards"],
        "arena_bytes_total": stats["arena_bytes_total"],
        "arena_bytes_per_device": stats["arena_bytes_per_device"],
        "interconnect_bytes_per_token": stats["interconnect_bytes_per_token"],
        "dense_arena_utilization": stats["dense_arena_utilization"],
        "defrags": stats["defrags"],
        # prefix-sharing surface (zeros with sharing off)
        "prefill_write_bytes": stats["prefill_write_bytes"],
        "prefix_hits": stats["prefix_hits"],
        "shared_prefix_tokens": stats["shared_prefix_tokens"],
        "shared_prefix_pages": stats["shared_prefix_pages"],
        "cow_copies": stats["cow_copies"],
        # per-tick idle-vs-active traces (what bench_e2e_energy's device
        # model charges idle energy from) + the per-request records the
        # policy metrics are scored on
        "policy": stats["policy"],
        "slot_utilization": stats["slot_utilization"],
        "trace_active_rows": stats["trace_active_rows"],
        "trace_arena_util": stats["trace_arena_util"],
        "results": res,
    }
    if slos is not None:
        out.update(class_tails(out, work, slos))
    return out


def class_tails(run: dict, work: list[WorkItem], slos) -> dict:
    """Per-SLO-class tail latencies on the engine tick clock:
    ``ttft_p95_<class>`` / ``itl_p95_<class>`` / ``unserved_<class>``.
    Requests that never produced a token (oom / unschedulable / shed) are
    excluded from the percentiles (their sentinel -1 stamp is not a latency)
    and counted in ``unserved_<class>`` instead."""
    res = run["results"]
    ttft_by: dict[str, list] = {}
    itl_by: dict[str, list] = {}
    unserved: dict[str, int] = {}
    for w, slo in zip(work, slos):
        r = res[w.rid]
        if r["first_token_step"] < 0:
            unserved[slo.name] = unserved.get(slo.name, 0) + 1
            continue
        ttft_by.setdefault(slo.name, []).append(
            r["first_token_step"] - w.arrival)
        gaps = (np.diff(r["token_steps"])
                if len(r["token_steps"]) > 1 else np.zeros(1))
        itl_by.setdefault(slo.name, []).append(float(np.percentile(gaps, 95)))
    out = {}
    for name, vals in ttft_by.items():
        out[f"ttft_p95_{name}"] = float(np.percentile(vals, 95))
        out[f"itl_p95_{name}"] = float(np.percentile(itl_by[name], 95))
    for name, n in unserved.items():
        out[f"unserved_{name}"] = n
    return out
