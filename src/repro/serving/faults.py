"""Deterministic fault injection for the serving stack.

Failure handling is only trustworthy if failures are REPRODUCIBLE: a chaos
test that crashes a replica at a random wall-clock moment cannot be
replayed, bisected, or asserted token-exact against a fault-free run. This
module makes faults part of the deterministic simulation instead:

  ``FaultEvent``     one scheduled fault: at logical clock tick ``tick``
                     (the wrapper's own event clock, see below), behave as
                     ``kind`` for ``duration`` consecutive clock advances.
  ``FaultPlan``      an immutable schedule of events. ``FaultPlan.random``
                     derives one from a seed — the chaos property feeds
                     hypothesis-drawn seeds through it, so every failing
                     schedule is a single integer to replay.
  ``FaultyReplica``  a transparent wrapper around a ``ContinuousServeEngine``
                     that consults the plan on every ``step()`` / ``health()``
                     call and misbehaves on schedule. Everything else
                     forwards to the wrapped engine untouched.

Fault kinds:

  ``crash``    ``step()``/``health()`` raise ``ReplicaFault`` BEFORE touching
               the inner engine — its state stays exactly as the previous
               tick left it, so a subsequent ``drain()`` snapshot is
               token-exact (fail-stop, not fail-corrupt).
  ``stall``    ``step()`` returns no outputs and performs no work (a wedged
               device: alive, unresponsive). ``health()`` succeeds but shows
               no progress, which trips the monitor's progress probe.
  ``exhaust``  the replica reports a full arena (``free_frac`` 0.0 and an
               explicit ``exhausted`` flag) while stepping normally —
               models allocator-pressure pathologies the watermark machinery
               cannot clear.

The wrapper clock advances once per ``step()`` call AND once per ``health()``
probe. A drained replica is no longer stepped, but the HealthMonitor keeps
probing it on backoff — those probes advance the clock through the fault
window, so a crashed replica RECOVERS (and re-admits) a deterministic number
of probes later. Fault windows are logical events, not wall time.
"""
from __future__ import annotations

import dataclasses

import numpy as np

FAULT_KINDS = ("crash", "stall", "exhaust")


class ReplicaFault(RuntimeError):
    """Raised by a ``FaultyReplica`` during an active ``crash`` window.

    The router catches it per-replica (``HealthMonitor.note_fault``); it
    escaping a test means some caller stepped a replica outside the
    router's supervision."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault window: [tick, tick + duration) on the wrapper's
    event clock."""

    tick: int
    kind: str
    duration: int = 1

    def __post_init__(self):
        assert self.kind in FAULT_KINDS, self.kind
        assert self.tick >= 0 and self.duration >= 1

    def active_at(self, clock: int) -> bool:
        return self.tick <= clock < self.tick + self.duration


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable fault schedule. Overlapping windows resolve to the
    EARLIEST event (ties by position in ``events``) — deterministic either
    way. An empty plan is a no-op wrapper (useful as the control arm)."""

    events: tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    def active_at(self, clock: int):
        """The governing FaultEvent at ``clock``, or None."""
        live = [e for e in self.events if e.active_at(clock)]
        return min(live, key=lambda e: e.tick) if live else None

    def horizon(self) -> int:
        """First clock tick past every window (0 for the empty plan)."""
        return max((e.tick + e.duration for e in self.events), default=0)

    @classmethod
    def random(cls, seed: int, horizon: int = 32, n_events: int = 2,
               kinds=FAULT_KINDS, max_duration: int = 3) -> "FaultPlan":
        """Seed-derived schedule: ``n_events`` faults at ticks in
        [1, horizon) with durations in [1, max_duration]. Same seed, same
        plan — the chaos suite's whole replay story."""
        rng = np.random.default_rng(seed)
        kinds = tuple(kinds)
        events = []
        for _ in range(n_events):
            events.append(FaultEvent(
                tick=int(rng.integers(1, max(horizon, 2))),
                kind=kinds[int(rng.integers(len(kinds)))],
                duration=int(rng.integers(1, max_duration + 1))))
        return cls(tuple(sorted(events, key=lambda e: (e.tick, e.kind))))


class FaultyReplica:
    """Transparent fault-injecting wrapper around a serve engine.

    Drop-in for the router: every attribute not intercepted here forwards
    to the wrapped engine, so ``adopt_compiled``, ``drain``, ``stats`` etc.
    behave identically. Only ``step`` / ``health`` / ``arena_stats``
    consult the plan. ``faults_injected`` counts fired windows by kind."""

    def __init__(self, engine, plan: FaultPlan):
        self.engine = engine
        self.plan = plan
        self.clock = 0
        self.faults_injected = {k: 0 for k in FAULT_KINDS}

    def __getattr__(self, name):
        return getattr(self.engine, name)

    def _advance(self):
        ev = self.plan.active_at(self.clock)
        self.clock += 1
        if ev is not None:
            self.faults_injected[ev.kind] += 1
        return ev

    # -- intercepted surface ----------------------------------------------

    def step(self):
        ev = self._advance()
        if ev is not None and ev.kind == "crash":
            # raise BEFORE the inner step: fail-stop, state untouched
            raise ReplicaFault(
                f"injected crash (tick {self.clock - 1}, event @{ev.tick})")
        if ev is not None and ev.kind == "stall":
            return []  # wedged: alive, no work done, no outputs
        return self.engine.step()

    def health(self) -> dict:
        ev = self._advance()
        if ev is not None and ev.kind == "crash":
            raise ReplicaFault(
                f"injected crash on probe (tick {self.clock - 1})")
        h = self.engine.health()
        if ev is not None and ev.kind == "exhaust":
            h = dict(h, free_frac=0.0, exhausted=True)
        return h

    def arena_stats(self) -> dict:
        ev = self.plan.active_at(self.clock)  # peek: stats don't advance
        st = self.engine.arena_stats()
        if ev is not None and ev.kind == "exhaust":
            st = dict(st, free_frac=0.0)
        return st
