"""Prefix index over page-aligned token prefixes (prefix sharing / COW).

At millions of users most prompts open with a shared system prefix or a
multi-turn chat history already served once — the paper's KV-growth
bottleneck is mostly DUPLICATED cache. This index maps page-aligned token
prefixes to the physical pages that already hold their K/V, so admission can
mount a request's shared prefix as refcount bumps (zero arena writes) and
chunked prefill streams only the unshared tail.

Structure: a hash-consed radix over FULL pages of token ids. Every node is
keyed by the byte string of the WHOLE prefix up to and including its page
(int32 little-endian), so a key is content-addressed — independent of which
request registered it and of the physical page id currently serving it. A
parent->children edge set supports the one partial match allowed per lookup
(divergence MID-page: the request mounts a full registered page but only its
first j < page_size tokens; the first tail write then copy-on-writes it).

The index is WEAK — it holds no page references and never contributes to a
refcount. That keeps the serving invariant crisp (a page's refcount equals
the number of block-table entries mapping it; free-list membership <=>
refcount 0, property-tested in tests/test_serving_prefix.py). The owner
(the scheduler) must therefore:

  * ``forget(page)`` when a page's refcount hits zero (the allocator's
    ``free`` returns exactly those), and when a lone owner is about to
    overwrite a registered page in place (content would no longer match);
  * ``relabel(remap)`` when defrag renames physical pages.

Unreachable entries are self-healing: dropping a node orphans its subtree,
but keys are full-prefix content hashes, so re-registering the parent prefix
under any page makes the (still content-correct) descendants reachable again.

Only FULL pages register: a full page is immutable under normal operation
(its owner writes at positions >= its length only), which is what makes the
mapped payload safe to share by construction.
"""
from __future__ import annotations

import numpy as np

_ROOT = b""


class PrefixIndex:
    """Weak page-aligned token-prefix -> physical-page index (one arena)."""

    def __init__(self, page_size: int):
        assert page_size >= 1
        self.page_size = page_size
        self._page_of: dict[bytes, int] = {}   # prefix key -> physical page
        self._key_of: dict[int, bytes] = {}    # physical page -> its key
        self._children: dict[bytes, set[bytes]] = {}  # parent key -> child keys
        self.hits = 0        # lookups that matched >= 1 token
        self.misses = 0

    def __len__(self) -> int:
        return len(self._page_of)

    @staticmethod
    def _key(ctx: np.ndarray, n_tokens: int) -> bytes:
        return np.ascontiguousarray(ctx[:n_tokens], dtype="<i4").tobytes()

    # ------------------------------------------------------------- lookup

    def match(self, context) -> tuple[list[int], int]:
        """Longest indexed prefix of ``context``: the chain of full-page
        matches plus at most one partial match into a child page (shared
        for reads — attention masks by length — and COW'd at first write).
        Capped at ``len(context) - 1`` tokens so at least one tail token
        remains to prefill (the first emitted token's logits must come from
        a computed tail chunk). Returns (pages in block order, tokens)."""
        ctx = np.asarray(context, np.int32)
        ps = self.page_size
        limit = len(ctx) - 1
        pages: list[int] = []
        shared = 0
        key = _ROOT
        while shared + ps <= limit:
            nxt = self._key(ctx, shared + ps)
            page = self._page_of.get(nxt)
            if page is None:
                break
            pages.append(page)
            shared += ps
            key = nxt
        # one partial continuation: the child page sharing the longest
        # non-empty token run with the tail (mid-page divergence)
        best_page, best_j = None, 0
        for ck in self._children.get(key, ()):
            page = self._page_of.get(ck)
            if page is None:
                continue  # orphaned edge (child re-registers it later)
            blk = np.frombuffer(ck, dtype="<i4")[shared:]
            cap = min(len(blk), limit - shared)
            j = 0
            while j < cap and blk[j] == ctx[shared + j]:
                j += 1
            if j > best_j:
                best_page, best_j = page, j
        if best_page is not None:
            pages.append(best_page)
            shared += best_j
        self.hits += bool(shared)
        self.misses += not shared
        return pages, shared

    # ----------------------------------------------------------- maintain

    def insert(self, context, pages, start_block: int, end_block: int) -> int:
        """Register blocks ``[start_block, end_block)`` of a request whose
        cache holds ``context`` with its block-ordered physical ``pages``.
        Returns the caller's new durable watermark: the first block index NOT
        covered by an entry the caller can rely on. Entries pointing at the
        caller's OWN pages are durable (they live exactly as long as the
        caller holds the page), so the watermark advances past them; a key
        already held by a DIFFERENT page (a concurrent owner of the same
        prefix registered first) keeps its incumbent — dedup — but stops the
        walk WITHOUT advancing, so the caller retries that block on its next
        call and re-registers its own copy if the incumbent has since been
        forgotten. That retry is what lets the index survive the original
        registrant's retirement while equal-content pages are still
        resident."""
        ctx = np.asarray(context, np.int32)
        ps = self.page_size
        for b in range(start_block, end_block):
            page = int(pages[b])
            key = self._key(ctx, (b + 1) * ps)
            incumbent = self._page_of.get(key)
            if incumbent == page:
                continue  # already ours (e.g. mounted FROM the index)
            if incumbent is not None or page in self._key_of:
                return b  # foreign incumbent (or page answers another key)
            self._page_of[key] = page
            self._key_of[page] = key
            self._children.setdefault(key[:-4 * ps], set()).add(key)
        return end_block

    def forget(self, page: int) -> bool:
        """Drop one page's registration (refcount hit zero, or its lone
        owner is about to overwrite it in place). Descendant entries stay:
        they are unreachable until the same prefix re-registers, at which
        point they are reachable AND still content-correct."""
        key = self._key_of.pop(int(page), None)
        if key is None:
            return False
        del self._page_of[key]
        parent = key[:-4 * self.page_size]
        kids = self._children.get(parent)
        if kids is not None:
            kids.discard(key)
            if not kids:
                del self._children[parent]
        return True

    def relabel(self, remap) -> None:
        """Defrag renamed physical pages: ``remap[old_id] -> new_id`` (dict
        or array). Keys are content-addressed and do not change."""
        self._page_of = {k: int(remap[p]) for k, p in self._page_of.items()}
        self._key_of = {int(remap[p]): k for p, k in self._key_of.items()}

    def registered_pages(self) -> set[int]:
        return set(self._key_of)
