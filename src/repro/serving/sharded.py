"""Mesh-native paged serving attention: shard_map over the kv-head axis.

The paper's central claim is that attention should execute where the KV
lives — each PIM bank holds its slice of the cache and computes locally,
with only small per-head partials crossing the interconnect. The serving
analogue implemented here: every paged arena partitions over its KV-HEAD
axis (``distributed/cache_specs.paged_layer_cache_specs``), and the paged
decode / chunked-prefill attention calls run under ``shard_map`` so each
device sweeps only its LOCAL head shard of the page pool — block tables,
``RowState``, and scheduler state stay replicated (the allocator operates on
logical pages; a logical page is one slice per device), and the only
cross-device traffic is the concatenation of per-head attention outputs
(``out_specs`` sharded on the head axis).

Tier routing (mirrors ``decode_attend_paged``):

  dense / T2 CPQ / tiered   embarrassingly head-parallel: per-shard call of
                            the SAME fused Pallas kernel (or jnp gather
                            oracle) over the local (KV/mp)-head arena slice.
  T1 X / MLA latent         the pool has no head axis; its FEATURE axis is
                            storage-sharded for HBM capacity and all-gathered
                            locally before the absorbed attend (query heads
                            and the W_UK/W_UV slices stay sharded, so score
                            and value stages still run head-parallel).
  T3 retrieval              keeps global-semantics compute over its (still
                            head-sharded) arenas — safe because the kv-head
                            axis is batch-like in every contraction.
  T1+T2 / MLA-CPQ           replicate their code pools: feature-sharding
                            would split the attend's f32 reduction under
                            GSPMD and break single-device token parity.

With ``AttentionRuntime.mesh is None`` nothing in this module runs and the
single-device path is bit-identical to before. Numerics under a mesh: every
head's math is computed once on exactly one device from the same operands,
so sharded-vs-single-device greedy decode is token-exact at f32
(tests/test_serving_sharded.py).

Per-request sampling under a mesh: the vectorized per-row sampling
parameters (temperature / top-k / top-p / seed / stream-index arrays) cross
the mesh REPLICATED — the sampler consumes the already-concatenated (B, V)
logits after the shard_map'd attention, so every device draws the identical
token from identical operands (``replicate_on_mesh``). Sampled decode is
therefore mesh-invariant exactly like greedy decode: the categorical draw is
a deterministic function of (logits, seed, stream index), none of which
shard.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:
    from jax import shard_map as _shard_map_impl
except ImportError:  # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

# replication checking is off: out_specs mix head-sharded attention outputs
# with replicated cache side state that the checker cannot always prove
# replicated. The kwarg was renamed check_rep -> check_vma across jax
# versions; pick whichever this jax exposes.
import inspect as _inspect

_CHECK_KW = ("check_vma" if "check_vma"
             in _inspect.signature(_shard_map_impl).parameters else "check_rep")


def _shard_map(f, mesh, in_specs, out_specs):
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **{_CHECK_KW: False})


MODEL_AXIS = "model"


def replicate_on_mesh(mesh, tree):
    """Pin a host pytree (per-row sampling parameter arrays, scheduler-side
    scalars) onto every device of the serving mesh REPLICATED, so the jitted
    per-row sampler sees one committed layout instead of letting GSPMD infer
    placement per call site. Identity when ``mesh`` is None."""
    if mesh is None:
        return tree
    from jax.sharding import NamedSharding

    return jax.device_put(tree, NamedSharding(mesh, P()))

# intent specs for the per-call attention operands (fitted to shapes; the
# kv/query-head axis shards, everything else is replicated)
_ARG_SPECS = {
    "q": P(None, None, MODEL_AXIS, None),
    "k_t": P(None, None, MODEL_AXIS, None),
    "v_t": P(None, None, MODEL_AXIS, None),
    "k_c": P(None, None, MODEL_AXIS, None),
    "v_c": P(None, None, MODEL_AXIS, None),
    "x_t": P(None, None, MODEL_AXIS),
    "x_c": P(None, None, MODEL_AXIS),
    "k_rope_t": P(None, None, MODEL_AXIS, None),
    "k_rope_c": P(None, None, MODEL_AXIS, None),
    "q_nope": P(None, None, MODEL_AXIS, None),
    "q_rope": P(None, None, MODEL_AXIS, None),
    "w_k_nope": P(None, MODEL_AXIS, None),
    "w_v": P(None, MODEL_AXIS, None),
}


def supports(cache) -> bool:
    """Tiers routed through shard_map (per-shard kernel calls). T3 retrieval
    (top-k slot selection) and the T1+T2 CPQ(X) composition keep global-
    semantics compute, exactly as they keep the gather path."""
    from repro.serving import paged_cache as pgc

    return isinstance(cache, (pgc.PagedDenseKVCache, pgc.PagedCPQKVCache,
                              pgc.PagedXCache, pgc.TieredPagedCache))


def _fit(spec: P, shape: tuple, mesh) -> P:
    from repro.distributed.sharding import fit_spec_to_shape

    return fit_spec_to_shape(spec, shape, mesh)


def container_specs(cache, mesh):
    """Fitted PartitionSpec tree for a paged container (shard_map in/out
    specs): the SAME ``cache_specs.paged_container_specs`` intent the engine
    places arenas with, fitted to the concrete shapes — placement and
    shard_map can never disagree. Non-dividing axes (e.g. MLA's shared
    kv_r == 1 rope head) drop to replicated."""
    from repro.distributed.cache_specs import paged_container_specs

    return jax.tree.map(lambda sp, a: _fit(sp, a.shape, mesh),
                        paged_container_specs(cache), cache,
                        is_leaf=lambda x: isinstance(x, P))


def _x_is_sharded(cspec) -> bool:
    """Whether the latent pool's feature axis actually sharded (fit kept it)."""
    return tuple(cspec.x) and tuple(cspec.x)[-1] is not None


def _gather_latent(x_local: jax.Array) -> jax.Array:
    """Reassemble the full latent feature axis from the per-device storage
    shards (the absorbed attend needs every feature; queries stay sharded)."""
    return jax.lax.all_gather(x_local, MODEL_AXIS, axis=x_local.ndim - 1,
                              tiled=True)


def _split(kw: dict, mesh):
    """(present-operands dict, fitted specs dict) — None operands stay out of
    the shard_map argument tree and are reinstated in the body."""
    present = {k: v for k, v in kw.items() if v is not None}
    specs = {k: _fit(_ARG_SPECS[k], v.shape, mesh) for k, v in present.items()}
    return present, specs


def decode_attend_sharded(
    rt, cache, rows, *, q, k_t, v_t, x_t, k_rope_t, q_nope, q_rope,
    w_k_nope, w_v, scale: float,
):
    """shard_map wrapper of ``decode_attend_paged``: per-device sweep of the
    local head shard; only per-head outputs are concatenated. Returns
    (out (B,1,H,Dv) head-sharded, new_cache) with cache specs preserved."""
    from repro.serving import paged_cache as pgc

    mesh = rt.mesh
    rt_local = dataclasses.replace(rt, mesh=None)
    cspecs = container_specs(cache, mesh)
    rspecs = jax.tree.map(lambda _: P(), rows)
    latent = isinstance(cache, pgc.PagedXCache)
    gather_x = latent and _x_is_sharded(cspecs)
    kw = dict(q=q, k_t=k_t, v_t=v_t, x_t=x_t, k_rope_t=k_rope_t,
              q_nope=q_nope, q_rope=q_rope, w_k_nope=w_k_nope, w_v=w_v)
    present, pspecs = _split(kw, mesh)

    def body(cache, rows, ops):
        a = {k: ops.get(k) for k in kw}
        if latent:
            # storage-sharded latent: append the local feature slice, then
            # all-gather pages for the absorbed attend (heads stay sharded)
            cache = pgc.append_x(cache, rows, a["x_t"], a["k_rope_t"])
            x_pages = _gather_latent(cache.x) if gather_x else cache.x
            new_len = rows.lengths + rows.active.astype(jnp.int32)
            if rt_local.paged_kernels:
                from repro.kernels.decomposed_attn.ops import (
                    paged_decomposed_decode_tpu)

                out = paged_decomposed_decode_tpu(
                    a["q_nope"], a["q_rope"], x_pages, cache.k_rope,
                    rows.block_table, new_len, a["w_k_nope"], a["w_v"], scale)
            else:
                from repro.core.decomposed_attention import decomposed_attention

                out = decomposed_attention(
                    a["q_nope"], a["q_rope"],
                    pgc.gather_pages(x_pages, rows.block_table),
                    pgc.gather_pages(cache.k_rope, rows.block_table),
                    a["w_k_nope"], a["w_v"], new_len, scale)
            return out, cache
        return pgc.decode_attend_paged(rt_local, cache, rows, scale=scale, **a)

    return _shard_map(
        body, mesh,
        in_specs=(cspecs, rspecs, pspecs),
        out_specs=(P(None, None, MODEL_AXIS, None), cspecs),
    )(cache, rows, present)


def chunk_attend_sharded(
    rt, cache, *, tier: int, first: bool, slot, block_row, offset, valid,
    q, k_c, v_c, x_c, k_rope_c, q_nope, q_rope, w_k_nope, w_v, scale: float,
):
    """shard_map wrapper of ``chunk_attend_paged`` (chunked paged prefill):
    the chunk's payload lands in each device's local arena shard and its C
    queries attend per head shard. Returns (out (1,C,H,Dv) head-sharded,
    new_cache).

    C is whatever the caller compiled — prompt chunks (``prefill_chunk``)
    and speculative verify chunks (``spec_len + 1``; engine._verify_fn)
    share this wrapper, so mesh serving gets speculative decoding with no
    extra collectives: the verify chunk pays exactly one prompt-chunk's
    interconnect (per-head output concat + latent pool gather)."""
    from repro.serving import paged_cache as pgc

    mesh = rt.mesh
    rt_local = dataclasses.replace(rt, mesh=None)
    cspecs = container_specs(cache, mesh)
    latent = isinstance(cache, pgc.PagedXCache)
    gather_x = latent and _x_is_sharded(cspecs)
    kw = dict(q=q, k_c=k_c, v_c=v_c, x_c=x_c, k_rope_c=k_rope_c,
              q_nope=q_nope, q_rope=q_rope, w_k_nope=w_k_nope, w_v=w_v)
    present, pspecs = _split(kw, mesh)
    scalars = (slot, block_row, offset, valid)
    sspecs = jax.tree.map(lambda _: P(), scalars)

    def body(cache, scalars, ops):
        slot, block_row, offset, valid = scalars
        a = {k: ops.get(k) for k in kw}
        if latent:
            cache = pgc.PagedXCache(
                x=pgc.write_chunk_pages(cache.x, block_row, offset, valid,
                                        a["x_c"][0]),
                k_rope=(pgc.write_chunk_pages(cache.k_rope, block_row, offset,
                                              valid, a["k_rope_c"][0])
                        if a["k_rope_c"] is not None else cache.k_rope))
            x_pages = _gather_latent(cache.x) if gather_x else cache.x
            C = a["q_nope"].shape[1]
            if rt_local.paged_kernels:
                from repro.kernels.decomposed_attn.ops import (
                    paged_decomposed_prefill_tpu)

                out = paged_decomposed_prefill_tpu(
                    a["q_nope"], a["q_rope"], x_pages, cache.k_rope,
                    block_row, offset, valid, a["w_k_nope"], a["w_v"], scale)
            else:
                from repro.core.decomposed_attention import decomposed_attention

                out = decomposed_attention(
                    a["q_nope"], a["q_rope"],
                    pgc.gather_pages(x_pages, block_row[None]),
                    pgc.gather_pages(cache.k_rope, block_row[None]),
                    a["w_k_nope"], a["w_v"], offset + valid, scale,
                    query_positions=offset + jnp.arange(C, dtype=jnp.int32))
            return out, cache
        return pgc.chunk_attend_paged(
            rt_local, cache, tier=tier, first=first, slot=slot,
            block_row=block_row, offset=offset, valid=valid, scale=scale, **a)

    return _shard_map(
        body, mesh,
        in_specs=(cspecs, sspecs, pspecs),
        out_specs=(P(None, None, MODEL_AXIS, None), cspecs),
    )(cache, scalars, present)


def validate_serve_mesh(cfg, rt, tiered: bool = False) -> int:
    """Engine-construction guard: the ``model`` axis must divide every axis
    it shards, or the per-shard GQA group structure breaks. Returns the
    model-axis size (1 = no model sharding)."""
    from repro.serving.scheduler import SchedulerConfigError

    mesh = rt.mesh
    if mesh is None:
        return 1
    if MODEL_AXIS not in mesh.axis_names:
        raise SchedulerConfigError(
            f"serving mesh needs a {MODEL_AXIS!r} axis; got {mesh.axis_names}")
    mp = mesh.shape[MODEL_AXIS]
    if mp == 1:
        return 1
    kinds = set(m for m, _ in cfg.layer_kinds)
    if cfg.num_heads % mp:
        raise SchedulerConfigError(
            f"model axis {mp} must divide num_heads {cfg.num_heads}")
    head_paged = "attn" in kinds and (tiered or rt.mode in (
        "dense", "cpq", "retrieval", "decomposed"))
    if head_paged and cfg.num_kv_heads % mp:
        raise SchedulerConfigError(
            f"model axis {mp} must divide num_kv_heads {cfg.num_kv_heads}")
    # CPQ-X latent tiers (decomposed_cpq / MLA-CPQ) replicate their code
    # pools (see cache_specs._paged_cpq_specs), so only the shard_map'd
    # latent pools constrain the mesh
    if "attn" in kinds and rt.mode == "decomposed" and cfg.d_model % mp:
        raise SchedulerConfigError(
            f"model axis {mp} must divide d_model {cfg.d_model} (T1 X pages)")
    if "mla" in kinds and rt.mode != "cpq" and cfg.mla is not None \
            and cfg.mla.kv_lora_rank % mp:
        raise SchedulerConfigError(
            f"model axis {mp} must divide kv_lora_rank {cfg.mla.kv_lora_rank}")
    return mp
