"""Serving subsystem: paged KV-cache arenas + continuous-batching engine.

Modules:
  paged_cache — block-paged arenas for the five cache tiers (leaf module;
                imported by models/* for the paged decode path)
  scheduler   — host-side admission queue, slot table, watermark policy
  engine      — ServeEngine (static batch) + ContinuousServeEngine

Engine symbols are re-exported lazily (PEP 562) so importing
``repro.serving.paged_cache`` from the model stack does not recurse through
the engine -> model import chain.
"""

_ENGINE_EXPORTS = ("GenerationConfig", "ServeEngine", "ContinuousServeEngine")
_SCHEDULER_EXPORTS = ("Request", "Scheduler", "SchedulerConfigError")

__all__ = list(_ENGINE_EXPORTS + _SCHEDULER_EXPORTS)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine
        return getattr(engine, name)
    if name in _SCHEDULER_EXPORTS:
        from repro.serving import scheduler
        return getattr(scheduler, name)
    raise AttributeError(name)
