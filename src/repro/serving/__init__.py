"""Serving subsystem: paged KV-cache arenas + continuous-batching engine.

Modules:
  paged_cache — block-paged arenas for the five cache tiers (leaf module;
                imported by models/* for the paged decode path)
  request     — the public request-centric API dataclasses (SamplingParams,
                SloClass, ServeRequest, RequestOutput)
  policies    — pluggable SchedulerPolicy implementations (fifo / priority /
                slo-aware with de-escalation) and PlacementPolicy
                implementations for the replica router (rr / load / slo)
  prefix_index — weak content-addressed index over page-aligned token
                prefixes (prefix sharing: admission mounts resident pages
                by refcount bump; copy-on-write splits at divergence)
  scheduler   — host-side admission queue, slot table, watermark mechanisms
  engine      — ServeEngine (static batch) + ContinuousServeEngine
                (add_request()/step() streaming interface; serve()/generate()
                batch wrappers)
  router      — ReplicaRouter: data-parallel fan-out over N engine replicas
                with SLO-aware placement, session affinity, drain,
                rebalance (migrate without drain), and a parked backlog
  faults      — deterministic seed-driven fault injection (FaultPlan /
                FaultyReplica: crash / stall / exhaust on schedule)
  health      — HealthMonitor: liveness/progress/pressure probes with
                consecutive-failure thresholds, auto-drain, and
                exponential-backoff recovery re-admission

Engine symbols are re-exported lazily (PEP 562) so importing
``repro.serving.paged_cache`` from the model stack does not recurse through
the engine -> model import chain.
"""

_ENGINE_EXPORTS = ("GenerationConfig", "ServeEngine", "ContinuousServeEngine")
_SCHEDULER_EXPORTS = ("Request", "Scheduler", "SchedulerConfigError")
_REQUEST_EXPORTS = ("SamplingParams", "SloClass", "ServeRequest",
                    "RequestOutput", "INTERACTIVE", "STANDARD", "BATCH")
_POLICY_EXPORTS = ("SchedulerPolicy", "FifoPolicy", "PriorityPolicy",
                   "SloAwarePolicy", "make_policy", "PlacementPolicy",
                   "ReplicaView", "RoundRobinPlacement", "LeastLoadedPlacement",
                   "SloPressurePlacement", "make_placement")
_ROUTER_EXPORTS = ("ReplicaRouter",)
_PREFIX_EXPORTS = ("PrefixIndex",)
_FAULT_EXPORTS = ("FaultEvent", "FaultPlan", "FaultyReplica", "ReplicaFault")
_HEALTH_EXPORTS = ("HealthMonitor", "ReplicaHealth")

__all__ = list(_ENGINE_EXPORTS + _SCHEDULER_EXPORTS + _REQUEST_EXPORTS
               + _POLICY_EXPORTS + _ROUTER_EXPORTS + _PREFIX_EXPORTS
               + _FAULT_EXPORTS + _HEALTH_EXPORTS)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from repro.serving import engine
        return getattr(engine, name)
    if name in _SCHEDULER_EXPORTS:
        from repro.serving import scheduler
        return getattr(scheduler, name)
    if name in _REQUEST_EXPORTS:
        from repro.serving import request
        return getattr(request, name)
    if name in _POLICY_EXPORTS:
        from repro.serving import policies
        return getattr(policies, name)
    if name in _ROUTER_EXPORTS:
        from repro.serving import router
        return getattr(router, name)
    if name in _PREFIX_EXPORTS:
        from repro.serving import prefix_index
        return getattr(prefix_index, name)
    if name in _FAULT_EXPORTS:
        from repro.serving import faults
        return getattr(faults, name)
    if name in _HEALTH_EXPORTS:
        from repro.serving import health
        return getattr(health, name)
    raise AttributeError(name)
