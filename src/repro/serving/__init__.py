from repro.serving.engine import GenerationConfig, ServeEngine  # noqa: F401
