"""Serving engines: the paper's end-to-end inference path.

``ServeEngine`` — the original static-batch engine (kept as the back-compat
baseline and as the benchmark foil): one right-padded batch runs prefill then
a jitted decode loop to completion; every row owns a contiguous
``(n_max, ...)`` arena slice for the whole run.

``ContinuousServeEngine`` — continuous batching over block-paged arenas
(serving/paged_cache.py) driven by the host-side scheduler
(serving/scheduler.py): requests are admitted into vacated slots as soon as
pages are free, every row decodes at its own position (one jitted step over
per-row lengths), rows retire at EOS / stop tokens and free their pages
immediately, and the memory watermark policy escalates cache tiers
(dense -> T2 CPQ) under pressure — the paper's "dynamically compress and
prune" story operationalized at the request level.

The continuous engine's primary interface is request-centric (vLLM-style):

    eng.add_request(ServeRequest(prompt, sampling=SamplingParams(...),
                                 slo=INTERACTIVE), stream=callback)
    while eng.has_unfinished():
        for out in eng.step():        # one tick; incremental RequestOutputs
            ...

Sampling is per request — ``SamplingParams`` vectorize into per-row
temperature/top-k/top-p/seed arrays consumed by ONE jitted sampler
(``sample_token_rows``); greedy rows take the same argmax as ever,
bit-identically. Scheduling decisions (admission order, tier assignment,
preemption victims, escalation / de-escalation) come from the pluggable
``SchedulerPolicy`` (serving/policies.py). ``serve(requests, gen)`` and
``generate(batch, gen)`` remain as thin batch-shaped wrappers over
add_request()/step() — their greedy outputs are token-identical to the
pre-request-API engine.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionRuntime, CPQCfg, ModelConfig, ServingCfg
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.request import RequestOutput, SamplingParams, ServeRequest
from repro.serving.scheduler import Request, Scheduler, SchedulerConfigError


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_p: float = 1.0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def sample_tokens(logits: jax.Array, key, gen: GenerationConfig) -> jax.Array:
    """(B, V) logits -> (B,) int32 samples (greedy / temperature / top-p)."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / gen.temperature
    if gen.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        k = jnp.sum(cum < gen.top_p, axis=-1, keepdims=True)
        thresh = jnp.take_along_axis(sorted_l, k, axis=-1)
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


def sample_token_rows(logits: jax.Array, temps: jax.Array, top_ks: jax.Array,
                      top_ps: jax.Array, seeds: jax.Array,
                      indices: jax.Array) -> jax.Array:
    """Vectorized per-request sampler: (B, V) logits + per-row (B,) arrays of
    temperature / top-k / top-p / seed -> (B,) int32 tokens, one jitted call
    for the whole mixed batch.

    Greedy rows (``temps <= 0``) take ``jnp.argmax`` over the unmodified
    logits — bit-identical to the engine-global greedy path. Sampled rows
    filter per row (``top_k == 0`` / ``top_p == 1`` disable a filter) and
    draw with ``fold_in(PRNGKey(seed_r), index_r)`` where ``indices`` is the
    token's position in the request's generated stream: the draw is a
    function of the request alone — independent of slot placement, the
    co-resident batch, and preemption history (recompute replays the same
    keys)."""
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    l = logits.astype(jnp.float32) / jnp.where(temps > 0, temps, 1.0)[:, None]
    # per-row top-k: mask everything below the k-th largest (k = V when off)
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    k = jnp.clip(jnp.where(top_ks > 0, top_ks, V), 1, V)
    kth = jnp.take_along_axis(desc, (k - 1)[:, None], axis=-1)
    l = jnp.where(l < kth, -1e30, l)
    # per-row top-p over the top-k-filtered distribution (same nucleus
    # construction as the legacy global sampler)
    desc = jnp.sort(l, axis=-1)[:, ::-1]
    cum = jnp.cumsum(jax.nn.softmax(desc, axis=-1), axis=-1)
    j = jnp.sum(cum < top_ps[:, None], axis=-1, keepdims=True)
    thresh = jnp.take_along_axis(desc, j, axis=-1)  # jax clamps j == V
    l = jnp.where(l < thresh, -1e30, l)
    keys = jax.vmap(lambda s, i: jax.random.fold_in(jax.random.PRNGKey(s), i))(
        seeds, indices)
    sampled = jax.vmap(jax.random.categorical)(keys, l).astype(jnp.int32)
    return jnp.where(temps <= 0.0, greedy, sampled)


# --------------------------------------------------------------- static engine


class ServeEngine:
    """Static-batch engine: fixed batch, right-padded prompts, run to
    completion. Kept as the contiguous-arena baseline."""

    def __init__(self, cfg: ModelConfig, params, rt: Optional[AttentionRuntime] = None,
                 max_len: int = 4096):
        self.cfg = cfg
        self.rt = rt or cfg.attention
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(partial(M.prefill, cfg, self.rt))
        self._decode = jax.jit(partial(M.decode_step, cfg, self.rt))

    def _sample(self, logits: jax.Array, key, gen: GenerationConfig) -> jax.Array:
        return sample_tokens(logits, key, gen)

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """batch: {'tokens': (B, S)} (+frames/patches per input_kind).
        Returns (generated (B, max_new_tokens) int32, stats dict)."""
        cfg = self.cfg
        prompt = batch.get("tokens", batch.get("frames"))
        B, S = prompt.shape[0], prompt.shape[1]
        n_max = S + gen.max_new_tokens
        assert n_max <= self.max_len + gen.max_new_tokens

        caches = M.init_caches(cfg, self.rt, B, n_max)
        logits, caches = self._prefill(self.params, batch, caches)

        key = jax.random.PRNGKey(gen.seed)
        toks = []
        done = jnp.zeros((B,), bool)
        live_tokens = 0
        decode_calls = 0
        tok = self._sample(logits, key, gen)
        for t in range(gen.max_new_tokens):
            if gen.eos_id >= 0:
                # rows past their EOS emit eos_id, not fresh samples
                tok = jnp.where(done, gen.eos_id, tok)
            toks.append(np.asarray(tok))
            live_tokens += int(jnp.sum(~done))  # EOS itself counts; padding doesn't
            if gen.eos_id >= 0:
                done = done | (tok == gen.eos_id)
                if bool(jnp.all(done)):
                    break
            if t == gen.max_new_tokens - 1:
                break  # the last appended token needs no further decode
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None],
                                          jnp.asarray(S + t, jnp.int32), caches)
            decode_calls += 1
            tok = self._sample(logits, sub, gen)
        out = np.stack(toks, axis=1)
        stats = {
            "prompt_tokens": int(B * S),
            "generated_tokens": live_tokens,
            "decode_steps": decode_calls,
            "cache_mode": self.rt.mode,
        }
        return out, stats


# ----------------------------------------------------------- continuous engine


class _ServeState:
    """Mutable per-session serving state behind ``add_request()``/``step()``:
    the scheduler, the paged cache pytree, per-slot sampling-parameter
    arrays, the tick clock, counters, and the pending-output buffer. One
    ``serve()`` call owns exactly one (it resets); step-API users keep one
    across calls until ``reset()``."""

    def __init__(self, eng: "ContinuousServeEngine", gen: "GenerationConfig"):
        B = eng.serving.num_slots
        self.gen = gen
        self.sched = Scheduler(eng.serving, eng.tiered,
                               policy=eng.make_policy(),
                               share_prefix=eng.share_prefix)
        self.caches = M.init_paged_caches(eng.cfg, eng.rt, eng.serving,
                                          eng.tiered)
        if eng.mesh is not None:
            # place the arenas per the paged cache specs: kv-head / latent
            # feature axes over "model", pools and slot state replicated
            self.caches = jax.device_put(self.caches, eng._cache_shardings)
        self.last_tok = np.zeros((B,), np.int32)
        # per-slot sampling parameters, vectorized for the jitted sampler
        # (rows overwritten on admission; inactive rows' samples are unused)
        self.temp = np.zeros((B,), np.float32)
        self.top_k = np.zeros((B,), np.int32)
        self.top_p = np.ones((B,), np.float32)
        self.seed = np.zeros((B,), np.int32)
        self.results: dict[int, dict] = {}
        self.outputs: list[RequestOutput] = []       # pending (undrained)
        self.step_outputs: list[RequestOutput] = []  # this tick's events
        self.next_rid = 0
        self.step = 0                 # model-invocation tick clock
        self.decode_steps = self.live_steps = self.prefill_chunks = 0
        self.prefill_tokens = self.generated = 0
        self.traffic = self.prefill_write_bytes = self.interconnect = 0.0
        self.util_peak = self.util_sum = 0.0
        self.util_n = 0
        self.defrag_mark = 0          # retirements at the last compaction
        self.has_deadlines = False    # any finite request deadline admitted
        # per-decode-tick utilization traces (active rows / arena fill) —
        # the idle-vs-active series bench_e2e_energy's device model charges
        self.trace_active: list[int] = []
        self.trace_util: list[float] = []
        self.t0 = time.time()


class ContinuousServeEngine:
    """Continuous batching over block-paged arenas.

    One engine instance holds the jitted step functions. The request-centric
    interface is ``add_request()`` + ``step()`` (one engine tick per call,
    returning that tick's incremental ``RequestOutput`` events);
    ``serve(requests, gen)`` wraps it batch-style — it resets the session,
    submits everything, and drains. The decode clock is the simulation time
    base: a request with ``arrival=t`` becomes admissible after t decode
    steps (Poisson-arrival benchmarks feed arrivals in these units; online
    use passes 0.0). ``policy`` (object, or via ``ServingCfg.policy`` name)
    selects the scheduling policy; the default FIFO policy plus greedy
    sampling reproduces the pre-request-API engine token-exactly.
    """

    def __init__(self, cfg: ModelConfig, params, rt: Optional[AttentionRuntime] = None,
                 serving: ServingCfg = ServingCfg(), mesh=None, policy=None):
        self.cfg = cfg
        self.params = params
        self.serving = serving
        try:
            # full cross-knob validation up front: a bad combination fails
            # HERE with the knob names spelled out, not deep in the scheduler
            serving.validate()
        except ValueError as e:
            raise SchedulerConfigError(str(e)) from None
        rt = rt or cfg.attention
        if mesh is not None:
            if getattr(rt, "mesh", None) is not None and rt.mesh != mesh:
                raise SchedulerConfigError(
                    "conflicting device meshes: rt.mesh and the mesh= "
                    "argument disagree — set one or make them equal")
            rt = dataclasses.replace(rt, mesh=mesh)
        self.mesh = getattr(rt, "mesh", None)
        if (serving.use_paged_kernels is not None
                and rt.paged_kernels != serving.use_paged_kernels):
            # explicit serving-config override of the decode-kernel choice
            # (fused paged kernels vs the jnp gather path); None defers to rt
            rt = dataclasses.replace(rt, paged_kernels=serving.use_paged_kernels)
        self.tiered = bool(serving.enable_escalation and rt.mode == "dense")
        if self.tiered and rt.cpq is None:
            rt = dataclasses.replace(rt, cpq=CPQCfg())
        if self.tiered and any(m == "mla" for m, _ in cfg.layer_kinds):
            raise SchedulerConfigError(
                "tier escalation supports plain-attention stacks only "
                "(MLA already caches the compressed latent)")
        if cfg.input_kind != "tokens":
            raise SchedulerConfigError(
                "continuous serving drives token prompts; "
                f"input_kind={cfg.input_kind!r} needs the static engine")
        self.rt = rt
        # mesh-native serving: validate the model axis divides every head /
        # latent axis it shards, pin the replicated params once, and build
        # the fitted NamedSharding tree the paged arenas are placed with
        from repro.serving import sharded as _sharded

        self.model_shards = _sharded.validate_serve_mesh(cfg, rt, self.tiered)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as PS

            from repro.distributed.cache_specs import paged_cache_pspecs
            from repro.distributed.sharding import fit_spec_to_shape

            self.params = jax.device_put(
                params, NamedSharding(self.mesh, PS()))
            shapes = jax.eval_shape(partial(M.init_paged_caches, cfg, rt,
                                            serving, self.tiered))
            specs = paged_cache_pspecs(cfg, rt, serving, self.tiered)
            self._cache_shardings = jax.tree.map(
                lambda sp, a: NamedSharding(
                    self.mesh, fit_spec_to_shape(sp, a.shape, self.mesh)),
                specs, shapes, is_leaf=lambda x: isinstance(x, PS))
        # recurrent mixers integrate every prefill token into their state, so
        # bucket padding would pollute it (attention only masks); those archs
        # prefill at exact lengths (more jit variants, exact math)
        self._exact_prefill = any(m in ("mamba", "mlstm", "slstm")
                                  for m, _ in cfg.layer_kinds)
        self._decode = jax.jit(partial(M.decode_step_rows, cfg, rt))
        self._pack = jax.jit(partial(M.pack_prefill_caches, cfg, rt))
        self._escalate = jax.jit(partial(M.escalate_slot, cfg, rt))
        self._defrag = jax.jit(partial(M.defrag_caches, cfg, rt))
        self._prefills: dict[str, object] = {}   # one-shot oracle path only
        self._chunk_fns: dict[tuple[int, bool], object] = {}
        # two layer families keep the exact one-shot admission: recurrent
        # mixers integrate every token into O(1) state that cannot be cut at
        # page boundaries, and capacity-factor MoE routing makes prefill a
        # function of the token GROUP (chunking the group changes the drop
        # pattern). Everything else streams chunks into the arena.
        self._group_routed = any(mlp == "moe" for _, mlp in cfg.layer_kinds)
        self.chunked = (bool(serving.prefill_chunk) and not self._exact_prefill
                        and not self._group_routed)
        # prefix sharing + copy-on-write: chunked admissions only (the tail
        # streams from a mid-context offset), and only for modes whose BASE
        # arena payload is purely positional — dense, decomposed (T1), MLA
        # latent, and the tiered engine's dense arm. CPQ / retrieval pages
        # read through per-slot side state fitted to ONE request's stream,
        # so mounting them under another slot would break bit-parity.
        self.share_prefix = (bool(getattr(serving, "share_prefix", False))
                             and self.chunked
                             and rt.mode in ("dense", "decomposed"))
        # speculative decoding (serving/speculative.py): same gate family as
        # prefix sharing — the verify chunk IS a chunked paged forward pass,
        # and draft scratch pages carry purely positional payload. Tiered
        # engines speculate on tier-0 rows only (_spec_eligible).
        self.spec_on = (serving.spec_len > 0 and self.chunked
                        and rt.mode in ("dense", "decomposed"))
        self._verify_fns: dict[int, object] = {}
        self._copy_page = jax.jit(partial(M.copy_page_caches, cfg, rt))
        # cache-bearing layer count for the traffic model
        self._n_cache_layers = sum(1 for m, _ in cfg.layer_kinds if m in ("attn", "mla"))
        self.policy = policy          # object/str override of serving.policy
        self._sample_rows = jax.jit(sample_token_rows)
        self._st: Optional[_ServeState] = None

    def make_policy(self):
        """Resolve the scheduling policy: an explicit object wins, a string
        (constructor arg or ``ServingCfg.policy``) goes through the
        factory. Called once per serving session (``reset``)."""
        from repro.serving.policies import make_policy

        if self.policy is None:
            return make_policy(self.serving.policy)
        if isinstance(self.policy, str):
            return make_policy(self.policy)
        return self.policy

    # ------------------------------------------------------------- helpers

    def _rt_for_tier(self, tier: int) -> AttentionRuntime:
        if tier == 0:
            return self.rt
        return AttentionRuntime(mode="cpq", cpq=self.rt.cpq,
                                paged_kernels=self.rt.paged_kernels,
                                mesh=self.mesh)

    def _prefill_for(self, rt: AttentionRuntime):
        if rt.mode not in self._prefills:
            self._prefills[rt.mode] = jax.jit(partial(M.prefill, self.cfg, rt))
        return self._prefills[rt.mode]

    def _chunk_fn(self, tier: int, first: bool):
        """Jitted chunk-prefill step: ONE compiled shape per (tier mode,
        first-chunk) pair — every prompt length reuses it (the old
        per-(mode x padded-length) prefill variant zoo is gone)."""
        key = (tier, first)
        if key not in self._chunk_fns:
            rt_t = self._rt_for_tier(tier)
            self._chunk_fns[key] = jax.jit(
                partial(M.prefill_chunk_rows, self.cfg, rt_t, tier, first))
        return self._chunk_fns[key]

    def _verify_fn(self, tier: int):
        """Jitted speculative-verify step (the chunk forward pass with
        logits kept at EVERY position): ONE compiled shape —
        ``spec_len + 1`` wide — serves every draft, every request
        (``first=False``: a running row always has history)."""
        if tier not in self._verify_fns:
            rt_t = self._rt_for_tier(tier)
            self._verify_fns[tier] = jax.jit(
                partial(M.verify_chunk_rows, self.cfg, rt_t, tier, False))
        return self._verify_fns[tier]

    def _bucketed(self, ctx: np.ndarray) -> tuple[np.ndarray, int]:
        """Right-pad to the prefill bucket with the edge token (padding never
        enters attention: causal mask + true-length logits index; cache slots
        beyond the true length map to the null page)."""
        S = len(ctx)
        b = 1 if self._exact_prefill else self.serving.prefill_bucket
        S_pad = max(b, -(-S // b) * b)
        if S_pad == S:
            return ctx, S
        return np.concatenate([ctx, np.full((S_pad - S,), ctx[-1], np.int32)]), S

    def _admit(self, req: Request, st: _ServeState):
        """ONE-SHOT admission (the construction-exact oracle path, selected
        by ``prefill_chunk == 0`` and kept for recurrent stacks): B=1 prefill
        of the whole context into a contiguous scratch cache, scatter-packed
        into the slot's pages. Samples the request's first token with its
        own SamplingParams. Returns (first_token, padded_len)."""
        sched = st.sched
        padded, S = self._bucketed(req.context)
        rt_t = self._rt_for_tier(req.tier)
        ctg = M.init_caches(self.cfg, rt_t, 1, len(padded))
        logits, ctg = self._prefill_for(rt_t)(
            self.params, {"tokens": jnp.asarray(padded[None])}, ctg,
            jnp.asarray(S - 1, jnp.int32))
        tables = sched.alt_block_tables if req.tier == 1 else sched.block_tables
        st.caches = self._pack(st.caches, ctg, jnp.asarray(tables[req.slot]),
                               jnp.asarray(req.slot, jnp.int32))
        sched.finish_prefill(req)
        return self._sample_one(req, logits), len(padded)

    def _prefill_chunk(self, req: Request, st: _ServeState):
        """Stream the next ``prefill_chunk`` prompt tokens STRAIGHT into the
        request's arena pages (no scratch cache, no pack copy); on the final
        chunk, samples the first token from the last valid position's logits.
        Returns (first_token | None, valid_tokens_this_chunk)."""
        sched = st.sched
        C = self.serving.prefill_chunk
        ctx = req.context
        off = req.length
        valid = min(C, req.prefill_target - off)
        chunk = ctx[off:off + valid]
        if valid < C:  # jit padding with the edge token (masked everywhere)
            chunk = np.concatenate(
                [chunk, np.full((C - valid,), chunk[-1], np.int32)])
        tables = sched.alt_block_tables if req.tier == 1 else sched.block_tables
        logits, st.caches = self._chunk_fn(req.tier, off == 0)(
            self.params, jnp.asarray(chunk[None]),
            jnp.asarray(req.slot, jnp.int32),
            jnp.asarray(tables[req.slot]),
            jnp.asarray(off, jnp.int32), jnp.asarray(valid, jnp.int32),
            st.caches)
        sched.note_chunk(req, valid)
        if req.length < req.prefill_target:
            return None, valid
        sched.finish_prefill(req)
        return self._sample_one(req, logits), valid

    # ---------------------------------------------------- per-row sampling

    def _resolve_sampling(self, req: Request, st: _ServeState) -> None:
        """Pin the request's SamplingParams (legacy Requests derive them from
        the session GenerationConfig once, on first admission) and load them
        into the slot's row of the vectorized sampler arrays."""
        if req.sampling is None:
            g = st.gen
            req.sampling = SamplingParams(
                temperature=g.temperature, top_p=g.top_p,
                max_tokens=req.max_new_tokens,
                seed=(g.seed + req.rid) & 0x7fffffff)
        s = req.slot
        st.temp[s] = req.sampling.temperature
        st.top_k[s] = req.sampling.top_k
        st.top_p[s] = req.sampling.top_p
        st.seed[s] = req.sampling.seed & 0x7fffffff

    def _place_replicated(self, tree):
        """Sampling-parameter arrays cross a serving mesh REPLICATED (the
        sampler runs on the already-concatenated logits; see
        serving/sharded.py)."""
        if self.mesh is None:
            return tree
        from repro.serving.sharded import replicate_on_mesh

        return replicate_on_mesh(self.mesh, tree)

    def _sample_one(self, req: Request, logits: jax.Array) -> int:
        """First-token sampling at the end of a prefill: the (1, V) call of
        the same jitted per-row sampler, at stream index ``num_generated``
        (0 on fresh admission; the replay index after preemption, so
        recompute re-draws identical keys). Greedy requests short-circuit
        to the plain argmax (the legacy ops, at the legacy cost)."""
        sp = req.sampling
        if sp.temperature <= 0.0:
            return int(np.asarray(jnp.argmax(logits, axis=-1))[0])
        args = (jnp.full((1,), sp.temperature, jnp.float32),
                jnp.full((1,), sp.top_k, jnp.int32),
                jnp.full((1,), sp.top_p, jnp.float32),
                jnp.full((1,), sp.seed & 0x7fffffff, jnp.int32),
                jnp.full((1,), req.num_generated, jnp.int32))
        out = self._sample_rows(logits, *self._place_replicated(args))
        return int(np.asarray(out)[0])

    def _sample_active(self, st: _ServeState, logits: jax.Array) -> np.ndarray:
        """One jitted per-row sampling call over the decode batch. Row r's
        stream index is its request's ``num_generated`` (the index of the
        token being drawn); inactive rows sample garbage that the caller
        masks out, exactly as their logits always were. An all-greedy batch
        (the default, and every legacy suite) skips the sampler entirely for
        the single argmax the old engine ran — ``temps`` is host state, so
        the check costs nothing and the jitted sort/softmax/categorical
        machinery never enters the greedy hot path."""
        if (st.temp <= 0.0).all():
            return np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        sched = st.sched
        idx = np.array([r.num_generated if (r := sched.slots[s]) is not None
                        else 0 for s in range(self.serving.num_slots)],
                       np.int32)
        args = (jnp.asarray(st.temp), jnp.asarray(st.top_k),
                jnp.asarray(st.top_p), jnp.asarray(st.seed),
                jnp.asarray(idx))
        return np.asarray(self._sample_rows(logits,
                                            *self._place_replicated(args)))

    def _row_state(self, sched: Scheduler, active=None) -> pgc.RowState:
        return pgc.RowState(
            lengths=jnp.asarray(sched.lengths),
            block_table=jnp.asarray(sched.block_tables),
            active=jnp.asarray(sched.active_mask() if active is None else active),
            tier=jnp.asarray(sched.tiers),
            alt_block_table=(jnp.asarray(sched.alt_block_tables)
                             if sched.tiered else None))

    def _tier_bpt(self, caches) -> tuple[float, float]:
        """(base, escalated) per-token decode traffic per cache-bearing layer."""
        n_prefix = len(self.cfg.prefix_pattern)
        entries = list(zip(self.cfg.prefix_pattern + self.cfg.block_pattern,
                           caches["prefix"] + caches["blocks"]))
        for i, (kind, c) in enumerate(entries):
            if kind[0] not in ("attn", "mla"):
                continue
            c0 = jax.tree.map(lambda a: a[0], c) if i >= n_prefix else c
            ps = self.serving.page_size
            if isinstance(c0, pgc.TieredPagedCache):
                return (pgc.bytes_per_token(c0.dense, ps),
                        pgc.bytes_per_token(c0.cpq, ps, self.rt.cpq))
            b = pgc.bytes_per_token(c0, ps, self.rt.cpq)
            return b, b
        return 0.0, 0.0

    # ------------------------------------------------- request-centric API

    def reset(self, gen: GenerationConfig = GenerationConfig()) -> None:
        """Start a fresh serving session: new scheduler (fresh policy
        instance), empty arenas, empty output buffer. ``gen`` supplies
        session-wide legacy defaults — ``eos_id`` and the SamplingParams
        derived for plain scheduler ``Request`` objects."""
        st = _ServeState(self, gen)
        st.bpt0, st.bpt1 = self._tier_bpt(st.caches)
        st.quantum = self.serving.prefill_chunk or self.serving.prefill_bucket
        # interconnect accounting under model sharding: each device emits its
        # per-head output partial and receives the others' — the paper's
        # "only small per-head partials cross the interconnect" measured as
        # (mp-1)/mp of the concatenated head outputs, per token per layer
        mp = self.model_shards
        dv = (self.cfg.mla.v_head_dim if self.cfg.mla is not None
              else self.cfg.head_dim)
        # layers whose arenas are head-sharded pay the per-head output
        # concat: exact for the shard_map'd tiers, a LOWER BOUND for T3
        # retrieval (GSPMD chooses its own collectives there). The CPQ-X
        # tiers replicate their code pools and are not charged — their
        # residual k_rope movement is unmodeled.
        n_concat = sum(
            1 for m, _ in self.cfg.layer_kinds
            if (m == "attn" and (self.tiered or self.rt.mode in
                                 ("dense", "cpq", "decomposed", "retrieval")))
            or (m == "mla" and self.rt.mode != "cpq"))
        st.concat_bpt = (0.0 if mp <= 1 else
                         (mp - 1) / mp * self.cfg.num_heads * dv
                         * self.cfg.param_dtype.itemsize * n_concat)
        # ...plus, for storage-sharded latent tiers (T1 X / MLA c_kv), the
        # per-invocation pool all-gather — charged per model invocation, not
        # per token (zero for head-sharded tiers and unsharded engines)
        st.gather_bps = self._latent_gather_bytes_per_step(st.caches)
        self._st = st

    def _ensure_state(self) -> _ServeState:
        if self._st is None:
            self.reset()
        return self._st

    def add_request(self, req: Union[ServeRequest, Request], *,
                    stream=None) -> int:
        """Submit one request to the live session (created on first use; see
        ``reset``). Accepts the public ``ServeRequest`` spec or a raw
        scheduler ``Request`` (legacy). ``stream`` overrides the request's
        per-token ``RequestOutput`` callback. Returns the request id."""
        st = self._ensure_state()
        if isinstance(req, ServeRequest):
            rid = req.rid if req.rid is not None else st.next_rid
            req = Request(rid=rid, prompt=req.prompt,
                          max_new_tokens=req.sampling.max_tokens,
                          arrival=req.arrival, sampling=req.sampling,
                          slo=req.slo, stream=stream or req.stream,
                          session_id=req.session_id)
        elif stream is not None:
            req.stream = stream
        if (req.rid in st.results
                or any(r.rid == req.rid for r in st.sched.queue)
                or any(r is not None and r.rid == req.rid
                       for r in st.sched.slots)):
            # results and scheduler bookkeeping key on rid — a collision
            # would silently clobber another request's record
            raise SchedulerConfigError(
                f"request id {req.rid} already in use this session "
                "(omit ServeRequest.rid to auto-assign)")
        st.next_rid = max(st.next_rid, req.rid + 1)
        self._assign_deadlines(req, st)
        st.sched.submit(req)
        return req.rid

    def _assign_deadlines(self, req: Request, st: _ServeState) -> None:
        """Derive the request's absolute timeout ticks (policies
        .derive_deadlines): an explicit ``SamplingParams.deadline`` budget,
        or — with ``ServingCfg.deadline_scale > 0`` — the SLO class's
        scaled TTFT/total targets. Deterministic in the request alone, so a
        migrated snapshot re-derives identical deadlines."""
        from repro.serving.policies import derive_deadlines, slo_of

        scale = self.serving.deadline_scale
        sp = req.sampling
        if sp is None:
            if scale <= 0:
                return  # legacy request, deadlines off: nothing to derive
            sp = SamplingParams(max_tokens=req.max_new_tokens)
        req.ttft_deadline, req.deadline = derive_deadlines(
            sp, slo_of(req), req.arrival, scale)
        if np.isfinite(req.deadline) or np.isfinite(req.ttft_deadline):
            st.has_deadlines = True

    def has_unfinished(self) -> bool:
        """Whether the session still holds queued or in-flight requests."""
        return self._st is not None and self._st.sched.has_work()

    def pending_outputs(self) -> list[RequestOutput]:
        """Drain the buffered ``RequestOutput`` events (everything committed
        since the last drain; ``step()`` also returns its tick's events
        directly, and per-request ``stream`` callbacks fire inline)."""
        st = self._ensure_state()
        out, st.outputs = st.outputs, []
        return out

    def results(self) -> dict[int, dict]:
        """Finished-request records so far: rid -> {tokens, finish_reason,
        admitted_step, token_steps, slo/priority metadata, ...}. Empty
        when no session is live (does not build one)."""
        return dict(self._st.results) if self._st is not None else {}

    # ------------------------------------------------ router support surface

    def adopt_compiled(self, other: "ContinuousServeEngine") -> None:
        """Share ``other``'s jitted step functions and compile caches.
        Data-parallel replicas of the same (cfg, rt) run the same
        executables — N replicas, one compile. ``ServingCfg`` may differ
        (the jitted functions never close over it; shape changes retrace
        inside the shared jit wrappers)."""
        assert other.cfg == self.cfg and other.rt == self.rt, (
            "adopt_compiled requires an identical (cfg, rt) pair")
        for name in ("_decode", "_pack", "_escalate", "_defrag",
                     "_copy_page", "_sample_rows"):
            setattr(self, name, getattr(other, name))
        self._prefills = other._prefills
        self._chunk_fns = other._chunk_fns
        self._verify_fns = other._verify_fns

    def arena_stats(self) -> dict:
        """Public allocator surface (``Scheduler.arena_stats()``) plus the
        dense free-page fraction — the arena-pressure signal placement
        policies read before assigning a request to this engine."""
        sched = self._ensure_state().sched
        return {**sched.arena_stats(), "free_frac": sched.free_frac()}

    def health(self) -> dict:
        """Cheap liveness/progress/pressure probe surface for the router's
        ``HealthMonitor``: no device work, pure host bookkeeping.
        ``progress`` is a counter that moves whenever the engine does
        anything (tick clock + admissions + retirements) — two consecutive
        probes seeing the same value on an engine that HAS work is a stall.
        ``exhausted`` is always False here; fault injection
        (``FaultyReplica``) overrides it."""
        st = self._st
        if st is None:
            return {"alive": True, "has_work": False, "queued": 0,
                    "progress": 0, "free_frac": 1.0, "exhausted": False}
        sched = st.sched
        return {"alive": True,
                "has_work": sched.has_work(),
                "queued": len(sched.queue),
                "progress": (st.step + sched.stats["admitted"]
                             + sched.stats["retired"]),
                "free_frac": sched.free_frac(),
                "exhausted": False}

    def queued_requests(self) -> list[Request]:
        """The admission queue, in order (read-only view for the router)."""
        st = self._st
        return list(st.sched.queue) if st is not None else []

    def drain_request(self, rid: int) -> Optional[Request]:
        """Snapshot ONE incomplete request for replay elsewhere and free its
        pages — the single-request form of ``drain()`` (the router's
        ``rebalance`` migrate-without-drain primitive rides on it). A
        resident row (decoding or mid-prefill) leaves through the same
        recompute-preemption path full drain uses; a queued request is
        simply removed. Returns the Request record (context = prompt +
        generated so far, pinned SamplingParams intact) or None when the
        rid is not incomplete here."""
        st = self._st
        if st is None:
            return None
        sched = st.sched
        for req in sched.occupied():
            if req.rid == rid:
                slot = req.slot
                sched.preempt(req)          # pages freed, state -> queued
                self._clear_row_sampling(st, slot)
                sched.queue.remove(req)     # preempt requeued at the front
                return req
        for req in list(sched.queue):
            if req.rid == rid:
                sched.queue.remove(req)
                return req
        return None

    def outstanding_tokens(self) -> int:
        """Work still owed across queued and resident requests: prefill
        tokens not yet streamed into the arena plus undelivered generation
        budget. The load signal least-outstanding placement balances on."""
        st = self._st
        if st is None:
            return 0
        total = 0
        for r in list(st.sched.queue) + st.sched.occupied():
            total += max(len(r.prompt) + r.num_generated - r.length, 0)
            total += max(r.max_new_tokens - r.num_generated, 0)
        return total

    def drain(self) -> list[Request]:
        """Snapshot every incomplete request (queued, mid-prefill, or
        decoding) for replay re-admission elsewhere and free their pages.

        Slot holders leave through the existing recompute-preemption path
        (``Scheduler.preempt``: pages freed, state back to queued, context
        = prompt + generated-so-far, pinned ``SamplingParams`` preserved),
        then the whole queue is handed over. Feeding the returned records
        to ``add_request`` on another engine replays each context exactly:
        greedy rows are deterministic and seeded rows re-draw
        ``fold_in(seed, token_index)`` keys, so the remaining stream
        reproduces token-for-token after migration. Finished-request
        results and session counters stay on this engine (``results()`` /
        ``stats()``); call ``release()`` to drop the arenas afterwards."""
        st = self._st
        if st is None:
            return []
        sched = st.sched
        for req in sorted(sched.occupied(), key=lambda r: r.admitted_step):
            slot = req.slot
            sched.preempt(req)
            self._clear_row_sampling(st, slot)
        out = sorted(sched.queue, key=lambda r: (r.arrival, r.rid))
        sched.queue.clear()
        return out

    def release(self) -> None:
        """Drop the live serving session — scheduler, arenas (device
        memory goes with them), sampling arrays, output buffers. The next
        ``add_request()`` / ``reset()`` starts a fresh session."""
        self._st = None

    # ----------------------------------------------------- result plumbing

    def _result_of(self, req: Request) -> dict:
        slo = req.slo
        return {
            "tokens": np.asarray(req.generated, np.int32),
            "session": req.session_id,
            "finish_reason": req.finish_reason,
            "arrival": req.arrival,
            "admitted_step": req.admitted_step,
            "first_token_step": req.first_token_step,
            "token_steps": np.asarray(req.token_steps, np.int64),
            "done_step": req.done_step,
            "preemptions": req.preemptions,
            "escalated": req.escalated,
            "deescalations": req.deescalations,
            "slo": slo.name if slo is not None else "standard",
            "priority": slo.priority if slo is not None else 1,
            "ttft_target": slo.ttft_target if slo is not None else float("inf"),
            "itl_target": slo.itl_target if slo is not None else float("inf"),
        }

    def _clear_row_sampling(self, st: _ServeState, slot: int) -> None:
        """Reset a vacated slot's sampler rows to greedy defaults so a
        retired sampled request cannot keep defeating the all-greedy
        argmax fast path (the next admission overwrites them anyway)."""
        if slot < 0:
            return
        st.temp[slot] = 0.0
        st.top_k[slot] = 0
        st.top_p[slot] = 1.0
        st.seed[slot] = 0

    def _finish(self, st: _ServeState, req: Request, reason: str) -> None:
        slot = req.slot
        st.sched.retire(req, st.step, reason)
        self._clear_row_sampling(st, slot)
        st.results[req.rid] = self._result_of(req)

    def _emit_token(self, st: _ServeState, req: Request, tok: int, tick: int,
                    grow: bool = False) -> None:
        """Commit one emitted token. ``tick`` is the clock value at which
        the token became available (end-of-work convention: a token
        produced during tick T is stamped T+1; a one-shot admission's
        first token is stamped at the end of its charged stall).
        ``grow`` extends the cache bookkeeping (decode tokens only —
        the first token's position is written by its decode step).
        A stop-token / EOS / budget hit retires the request HERE — pages
        free immediately and the slot refills on the next tick — and the
        final ``RequestOutput`` carries the finish reason."""
        req.generated.append(tok)
        req.token_steps.append(tick)
        if grow:
            req.length += 1
            st.sched.lengths[req.slot] += 1
        st.last_tok[req.slot] = tok
        st.generated += 1
        if req.first_token_step < 0:
            req.first_token_step = tick
        reason = ""
        if st.gen.eos_id >= 0 and tok == st.gen.eos_id:
            reason = "eos"
        elif tok in req.stop_ids:
            reason = "stop"
        elif req.num_generated >= req.max_new_tokens:
            reason = "max_tokens"
        if reason:
            self._finish(st, req, reason)
        ev = RequestOutput(rid=req.rid, token=int(tok),
                           index=req.num_generated - 1, step=tick,
                           finished=bool(reason), finish_reason=reason)
        st.step_outputs.append(ev)
        st.outputs.append(ev)
        if req.stream is not None:
            req.stream(ev)

    def _emit_finish(self, st: _ServeState, req: Request, reason: str) -> None:
        """Finish-only event (no token payload): ``token == -1`` with
        ``index`` at the stream length — timeout/shed retirements, where the
        gapless token stream simply ends early."""
        ev = RequestOutput(rid=req.rid, token=-1, index=req.num_generated,
                           step=st.step, finished=True, finish_reason=reason)
        st.step_outputs.append(ev)
        st.outputs.append(ev)
        if req.stream is not None:
            req.stream(ev)

    def _deadline_blown(self, req: Request, now: int) -> bool:
        return (now >= req.deadline
                or (req.first_token_step < 0 and now >= req.ttft_deadline))

    def _expire_deadlines(self, st: _ServeState) -> None:
        """Tick-boundary deadline enforcement: any queued or resident
        request past its absolute deadline (or TTFT deadline with no first
        token yet) retires with finish_reason ``timeout`` — pages freed
        immediately, a finish-only event emitted, the ``timeouts`` stat
        bumped. Skipped entirely when no admitted request carries a finite
        deadline (the default: zero overhead)."""
        if not st.has_deadlines:
            return
        sched = st.sched
        now = st.step
        for req in list(sched.occupied()):
            if self._deadline_blown(req, now):
                self._finish(st, req, "timeout")
                sched.stats["timeouts"] += 1
                self._emit_finish(st, req, "timeout")
        for req in [r for r in sched.queue if self._deadline_blown(r, now)]:
            sched.queue.remove(req)
            req.state, req.done_step = "done", now
            req.finish_reason = "timeout"
            st.results[req.rid] = self._result_of(req)
            sched.stats["timeouts"] += 1
            self._emit_finish(st, req, "timeout")

    def _cow_guard(self, st: _ServeState, req: Request) -> bool:
        """Copy-on-write valve before ``req``'s next cache write (tail chunk
        or decode token): if the target block maps a SHARED page, the
        scheduler splits it (alloc + remap + decref) and the jitted page
        copy duplicates the payload across every attention layer's base
        pools. Page pressure applies the growth loop's valves — preempt the
        policy's victim, or ``req`` itself as the last resort. Returns False
        iff ``req`` was preempted (skip its write this tick)."""
        sched = st.sched
        while True:
            try:
                plan = sched.cow_plan(req)
            except pgc.PageAllocator.OutOfPages:
                victim = sched.preemption_victim(exclude=req)
                if victim is None:
                    vslot = req.slot
                    sched.preempt(req)
                    self._clear_row_sampling(st, vslot)
                    return False
                vslot = victim.slot
                sched.preempt(victim)
                self._clear_row_sampling(st, vslot)
                continue
            if plan is not None:
                src, dst = plan
                st.caches = self._copy_page(st.caches,
                                            jnp.asarray(src, jnp.int32),
                                            jnp.asarray(dst, jnp.int32))
            return True

    # ------------------------------------------------- speculative decoding

    def _spec_eligible(self, req: Request) -> bool:
        """Whether a row can take a speculative step this tick: running on
        tier 0 (drafts alias DENSE pages), not opted out, with generation
        budget for at least the verify draw plus one accepted candidate
        (``budget >= 2`` — a 1-token budget speculates nothing and just
        decodes)."""
        sp = req.sampling
        return (req.state == "running" and req.tier == 0
                and req.draft is None
                and (sp is None or sp.speculate)
                and req.max_new_tokens - req.num_generated >= 2)

    def _verify_draws(self, req: Request, logits: jax.Array) -> np.ndarray:
        """The request's OWN sampler draws at every chunk position: row i
        (absolute position ``length + i``) is drawn at stream index
        ``num_generated + i`` through the same jitted ``sample_token_rows``
        the normal decode path uses — a committed token is ALWAYS
        ``fold_in(seed, token_index)``'s draw (argmax for greedy rows),
        bit-identical speculative on-vs-off. ``logits`` is (C, V); padding
        rows produce garbage draws the caller never reads."""
        sp = req.sampling
        if sp is None or sp.temperature <= 0.0:
            return np.asarray(jnp.argmax(logits, axis=-1).astype(jnp.int32))
        C = logits.shape[0]
        args = (jnp.full((C,), sp.temperature, jnp.float32),
                jnp.full((C,), sp.top_k, jnp.int32),
                jnp.full((C,), sp.top_p, jnp.float32),
                jnp.full((C,), sp.seed & 0x7fffffff, jnp.int32),
                jnp.asarray(req.num_generated
                            + np.arange(C, dtype=np.int32)))
        return np.asarray(self._sample_rows(logits,
                                            *self._place_replicated(args)))

    def _speculate_row(self, st: _ServeState, req: Request) -> bool:
        """One speculative decode step for a single running row: draft up to
        ``spec_len`` candidates from the row's own context (prompt lookup),
        alias its pages + allocate scratch (``Scheduler.begin_draft``), run
        ONE verify chunk over [last_tok, draft...] at positions
        ``length..length+k``, and commit the longest prefix of candidates
        that EQUALS the request's own sampler draws — every committed token
        lands this tick (ITL 0 between them). Returns True iff the row was
        handled speculatively (the caller masks it out of the batched
        decode); False falls back to the normal decode step with the draft
        fully unwound.

        Clock model: the verify chunk is ONE model invocation and costs one
        tick — the win is tokens-per-invocation (up to k+1 per weight
        stream), never free ticks."""
        sched = st.sched
        serving = self.serving
        L = req.length
        budget = req.max_new_tokens - req.num_generated
        cap = serving.max_blocks_per_slot * serving.page_size - 1 - L
        k = min(serving.spec_len, budget - 1, cap)
        if k < 1:
            return False
        from repro.serving.speculative import propose_ngram

        # req.context ends with last_tok (length L+1 for a running row):
        # the draft continues the stream the verify chunk's first query
        # position (L, carrying last_tok) extends
        draft = propose_ngram(req.context, serving.spec_ngram, k)
        k = int(len(draft))
        if k < 1:
            return False
        d = sched.begin_draft(req, k)
        if d is None:
            return False  # arena pressure / block ceiling: normal decode
        d.tokens = [int(t) for t in draft]
        if d.copy_src >= 0:
            # partial frontier: seed the replacing scratch page's payload
            # (the same jitted copy the COW split uses)
            st.caches = self._copy_page(st.caches,
                                        jnp.asarray(d.copy_src, jnp.int32),
                                        jnp.asarray(d.scratch[0], jnp.int32))
        row = sched.draft_block_row(req)
        C = serving.spec_len + 1
        toks = np.full((C,), int(st.last_tok[req.slot]), np.int32)
        toks[1:1 + k] = draft
        valid = k + 1
        logits, st.caches = self._verify_fn(req.tier)(
            self.params, jnp.asarray(toks[None]),
            jnp.asarray(req.slot, jnp.int32), jnp.asarray(row),
            jnp.asarray(L, jnp.int32), jnp.asarray(valid, jnp.int32),
            st.caches)
        # clock + traffic: one model invocation reading L+valid positions
        st.decode_steps += 1
        st.live_steps += 1
        st.traffic += float(L + valid) * st.bpt0 * self._n_cache_layers
        st.interconnect += valid * st.concat_bpt + st.gather_bps
        util = sched.dense_alloc.utilization
        st.util_peak = max(st.util_peak, util)
        st.util_sum += util
        st.util_n += 1
        st.trace_active.append(1)
        st.trace_util.append(util)
        st.step += 1

        draws = self._verify_draws(req, logits[0])
        n_accept = 1  # position L's draw is this tick's own next token
        for j in range(k):
            if int(draws[j]) == int(draft[j]):
                n_accept += 1
            else:
                break
        sched.commit_draft(req, n_accept)
        for j in range(n_accept):
            if req.state != "running":
                break  # a draw hit eos/stop/budget: the rest never emits
            self._emit_token(st, req, int(draws[j]), st.step, grow=True)
            sched.register_prefix(req)
        return True

    # ----------------------------------------------------------------- run

    def step(self) -> list[RequestOutput]:
        """Run ONE engine tick: admissions, the watermark escalation /
        recovery policy, at most one streamed prompt chunk, page growth
        (preemption on exhaustion), and one jitted decode step + per-row
        sampling over the running rows. Returns this tick's incremental
        ``RequestOutput`` events (also buffered for ``pending_outputs``).

        Clock model: ``step`` counts model-invocation ticks. A tick that
        runs the jitted decode step costs 1, and one prompt chunk rides
        along for free (the chunked-prefill interleave). The one-shot
        oracle path charges a monolithic admission its chunk-equivalents up
        front — ``ceil(padded_len / quantum)`` ticks during which no row
        decodes — which is exactly the head-of-line stall chunked admission
        removes (quantum = ``prefill_chunk`` or, on the one-shot path,
        ``prefill_bucket``)."""
        st = self._ensure_state()
        st.step_outputs = []
        sched = st.sched
        if not sched.has_work():
            return []
        B = self.serving.num_slots

        # -1) deadline-aware shedding: blown budgets retire BEFORE this
        #     tick's admissions, so their freed slots/pages refill now
        self._expire_deadlines(st)
        if not sched.has_work():
            return st.step_outputs

        # 0) periodic base-arena compaction (defrag_every retirements):
        #    the scheduler relabels mapped pages onto the lowest ids and
        #    the jitted permutation moves every base page pool to match
        if (self.serving.defrag_every
                and sched.stats["retired"] - st.defrag_mark
                >= self.serving.defrag_every):
            st.defrag_mark = sched.stats["retired"]
            perm = sched.plan_defrag()
            if perm is not None:
                st.caches = self._defrag(st.caches, jnp.asarray(perm))

        # 1) admissions into vacated slots (the POLICY picks who and which
        #    tier). Chunked (default): the slot enters the prefilling state
        #    and its prompt streams below. One-shot oracle: prefill the
        #    whole context now and charge the clock its chunk-equivalents
        #    (the head-of-line stall).
        while (req := sched.admit_next(now=st.step, step=st.step)) is not None:
            self._resolve_sampling(req, st)
            if self.chunked:
                continue  # pump below interleaves one chunk per tick
            tok, padded = self._admit(req, st)
            st.step += -(-padded // st.quantum)  # monolithic prefill stall
            # no interconnect charge: the one-shot prefill runs as a
            # replicated global jit (no shard_map), so under a mesh it
            # pays mp-fold redundant FLOPs instead of concat traffic;
            # the pack then writes each device's arena slice from the
            # locally-present replicated payload
            st.prefill_tokens += req.length
            st.prefill_write_bytes += (req.length
                                       * (st.bpt1 if req.tier else st.bpt0)
                                       * self._n_cache_layers)
            self._emit_token(st, req, tok, st.step)  # ready after the stall

        # 2) watermark policy: escalate running dense requests under
        #    critical memory pressure (dense -> T2, pages freed)
        while (cand := sched.escalation_candidate()) is not None:
            slot, length = cand.slot, cand.length
            dense_row, cpq_row = sched.apply_escalation(cand)
            st.caches = self._escalate(st.caches, jnp.asarray(dense_row),
                                       jnp.asarray(cpq_row),
                                       jnp.asarray(slot, jnp.int32),
                                       jnp.asarray(length, jnp.int32))

        # 2b) recovery: when the dense free fraction sits above the HIGH
        #     watermark, the policy may de-escalate ONE T2 row per tick
        #     back to dense via chunked re-admission (bounded churn; CPQ
        #     codes are lossy, so the dense K/V is rebuilt by exact
        #     context replay through the admission path)
        if (cand := sched.deescalation_candidate()) is not None:
            slot = cand.slot
            sched.deescalate(cand)
            self._clear_row_sampling(st, slot)

        # 3) chunked-prefill pump: at most ONE prompt chunk per tick
        #    (the per-step prefill token budget), written straight into
        #    the slot's arena pages and interleaved with the decode step
        #    below — long prompts no longer freeze running rows
        did_chunk = False
        fresh_slot = -1  # row whose prefill finished THIS tick
        if self.chunked and (pre := sched.prefilling()):
            req = pre[0]
            # the first tail write of a shared-prefix admission may land
            # inside a shared page (divergence mid-page): split it first
            if self._cow_guard(st, req):
                tok, valid = self._prefill_chunk(req, st)
                did_chunk = True
                st.prefill_chunks += 1
                st.prefill_tokens += valid
                st.prefill_write_bytes += (valid
                                           * (st.bpt1 if req.tier else st.bpt0)
                                           * self._n_cache_layers)
                st.interconnect += valid * st.concat_bpt + st.gather_bps
                # every page the chunk just FILLED is immutable from here on
                # (later chunks write strictly past req.length), so register
                # eagerly — concurrent admissions can mount a prefix that is
                # still mid-prefill, and the entries outlive this request's
                # retirement for as long as any borrower keeps them resident
                sched.register_prefix(req)
                if tok is not None:
                    # the final chunk runs during THIS tick: its first token
                    # is available at the tick's end (step + 1), and the row
                    # joins the decode batch from the NEXT tick
                    self._emit_token(st, req, tok, st.step + 1)
                    if req.state == "running":
                        fresh_slot = req.slot

        # 4) growth: map a page for every running row's next write.
        #    Out of pages: a dense grower first escalates itself to the
        #    CPQ arena (frees its dense pages), else the policy's victim
        #    (default: youngest same-arena) is preempted (recompute)
        for req in sorted(sched.running(), key=lambda r: r.admitted_step):
            if req.state != "running":
                continue
            while not sched.ensure_writable(req):
                if req.length // self.serving.page_size >= \
                        self.serving.max_blocks_per_slot:
                    self._finish(st, req, "length_cap")
                    break
                if self.tiered and req.tier == 0 and sched.cpq_alloc.can_alloc(
                        pgc.pages_needed(req.length + 1,
                                         self.serving.page_size)):
                    slot, length = req.slot, req.length
                    dense_row, cpq_row = sched.apply_escalation(req)
                    st.caches = self._escalate(st.caches,
                                               jnp.asarray(dense_row),
                                               jnp.asarray(cpq_row),
                                               jnp.asarray(slot, jnp.int32),
                                               jnp.asarray(length, jnp.int32))
                    continue
                victim = sched.preemption_victim(exclude=req)
                if victim is None:
                    self._finish(st, req, "oom")
                    break
                vslot = victim.slot
                sched.preempt(victim)
                self._clear_row_sampling(st, vslot)
            if req.state == "running":
                # a decode write into a still-shared page splits it first
                # (reachable only via adversarial schedules — tail chunks
                # normally privatize the write frontier — but the refcount
                # invariant must hold for ANY interleaving)
                self._cow_guard(st, req)

        active = sched.active_mask()
        if fresh_slot >= 0:
            active[fresh_slot] = False

        # 4b) speculative decoding: eligible rows take a per-row verify
        #     chunk instead of joining the batched decode (each verify is
        #     its own model invocation / tick — see _speculate_row). A row
        #     whose draft cannot open (no recurring n-gram, arena pressure)
        #     stays in ``active`` and decodes normally below.
        did_spec = False
        if self.spec_on:
            for req in sorted(sched.running(), key=lambda r: r.admitted_step):
                slot = req.slot
                if slot < 0 or not active[slot]:
                    continue
                if not self._spec_eligible(req):
                    continue
                if self._speculate_row(st, req):
                    active[slot] = False
                    did_spec = True

        if not active.any():
            if did_spec:
                # the verify invocations already charged their ticks (and
                # this tick's prompt chunk, if any, rode along with them)
                return st.step_outputs
            if did_chunk:
                st.step += 1     # prefill-only tick still costs a tick
                return st.step_outputs
            if not sched.occupied():
                # a slot may have been vacated AFTER this tick's admission
                # phase (growth-cap retirement, de-escalation requeue): if
                # the policy can place someone NOW, just end the tick — the
                # next tick's admission phase admits them normally
                if sched.queue and sched.policy.select_admission(
                        sched, st.step) is not None:
                    return st.step_outputs
                cands = sched.policy.admission_order(sched, st.step)
                if cands and cands[0].arrival <= st.step:
                    # empty machine (every page free) and the policy's pick
                    # STILL does not fit => it can never fit
                    req = cands[0]
                    sched.queue.remove(req)
                    req.state, req.done_step = "done", st.step
                    req.finish_reason = "unschedulable"
                    st.results[req.rid] = self._result_of(req)
                    return st.step_outputs
                # idle: jump the clock to the arrival that unblocks
                # admission — the policy's blocked pick if it has one
                # (a no-bypass FIFO head gates everyone behind it), else
                # the earliest arrival in the queue
                if sched.queue:
                    nxt = (cands[0].arrival if cands
                           else min(r.arrival for r in sched.queue))
                    st.step = max(st.step + 1, int(np.ceil(nxt)))
            return st.step_outputs

        # 5) one jitted decode step over per-row positions (rows still
        #    prefilling — and a row whose final chunk landed this very
        #    tick — are inactive: their writes hit the null page), then
        #    ONE jitted per-row sampling call for the whole mixed batch
        rows = self._row_state(sched, active)
        logits, st.caches = self._decode(self.params,
                                         jnp.asarray(st.last_tok[:, None]),
                                         rows, st.caches)
        toks = self._sample_active(st, logits)
        st.decode_steps += 1
        st.live_steps += int(active.sum())
        tier_arr = sched.tiers
        st.traffic += float(sum(
            (sched.lengths[s] + 1.0) * (st.bpt1 if tier_arr[s] else st.bpt0)
            for s in range(B) if active[s])) * self._n_cache_layers
        st.interconnect += int(active.sum()) * st.concat_bpt + st.gather_bps
        util = sched.dense_alloc.utilization
        st.util_peak = max(st.util_peak, util)
        st.util_sum += util
        st.util_n += 1
        st.trace_active.append(int(active.sum()))
        st.trace_util.append(util)
        st.step += 1

        for slot in range(B):
            if not active[slot]:
                continue
            req = sched.slots[slot]
            self._emit_token(st, req, int(toks[slot]), st.step, grow=True)
            # decode just completed a page? register it — multi-turn
            # follow-ups then mount this request's whole history
            sched.register_prefix(req)
        return st.step_outputs

    def stats(self) -> dict:
        """Session counters in the same shape ``serve`` has always returned
        (throughput, latency inputs, traffic accounting, allocator surface),
        plus the policy name and the per-tick utilization traces."""
        st = self._ensure_state()
        sched = st.sched
        B = self.serving.num_slots
        wall = time.time() - st.t0
        total_bytes = pgc.arena_bytes(st.caches)
        device_bytes = self._per_device_arena_bytes(st.caches, total_bytes)
        return {
            "cache_mode": self.rt.mode,
            "tiered": self.tiered,
            "chunked_prefill": self.chunked,
            "prefix_sharing": self.share_prefix,
            "spec_on": self.spec_on,
            "spec_accept_rate": (sched.stats["spec_accepted"]
                                 / max(sched.stats["spec_drafted"], 1)),
            "policy": sched.policy.name,
            "model_shards": self.model_shards,
            "arena_bytes_total": total_bytes,
            "arena_bytes_per_device": device_bytes,
            "interconnect_bytes": st.interconnect,
            "interconnect_bytes_per_token": st.interconnect / max(st.generated, 1),
            "decode_steps": st.decode_steps,
            "prefill_chunks": st.prefill_chunks,
            "prefill_tokens": st.prefill_tokens,
            "generated_tokens": st.generated,
            "tokens_per_step": st.generated / max(st.decode_steps, 1),
            "slot_utilization": st.live_steps / max(st.decode_steps * B, 1),
            "arena_utilization_mean": st.util_sum / max(st.util_n, 1),
            "arena_utilization_peak": st.util_peak,
            # per-decode-tick idle-vs-active series (live rows / arena fill):
            # bench_serving folds these into bench_e2e_energy's device model
            "trace_active_rows": np.asarray(st.trace_active, np.int32),
            "trace_arena_util": np.asarray(st.trace_util, np.float64),
            "decode_traffic_bytes": st.traffic,
            "prefill_write_bytes": st.prefill_write_bytes,
            "bytes_per_token_layer": st.bpt0,
            "wall_time_s": wall,
            "tokens_per_s": st.generated / max(wall, 1e-9),
            # invariant: every page freed once all requests retired
            "dense_pages_leaked": sched.dense_alloc.num_used,
            "cpq_pages_leaked": sched.cpq_alloc.num_used if sched.cpq_alloc else 0,
            **sched.stats,
            # public allocator surface (utilization + defrag counts): what
            # bench_serving and the sharded watermark read instead of the
            # private dense_alloc/cpq_alloc state
            **sched.arena_stats(),
        }

    def serve(self, requests: list[Union[Request, ServeRequest]],
              gen: GenerationConfig = GenerationConfig()):
        """Batch-shaped wrapper over the request-centric API (kept for
        backward compatibility): resets the session, submits every request
        in arrival order, and drains with ``step()``. Returns
        (results, stats) exactly as before; ``ServeRequest`` specs are
        accepted alongside scheduler ``Request`` records, and greedy FIFO
        serving is token-identical to the pre-request-API engine."""
        self.reset(gen)
        st = self._st
        for r in sorted(requests, key=lambda r: r.arrival):
            self.add_request(r)
        while st.sched.has_work():
            self.step()
        return dict(st.results), self.stats()

    def _latent_gather_bytes_per_step(self, caches) -> float:
        """Interconnect bytes ONE model invocation moves re-assembling the
        storage-sharded latent pools (PagedXCache.x all-gather inside the
        shard_map, serving/sharded.py): each device ships its feature shard
        to the mp-1 others, per latent cache layer. Zero when unsharded.
        This dwarfs the per-head output concat — the price of latent
        HBM-capacity sharding paid on every step (gathering only mapped
        pages is the open optimization, see ROADMAP)."""
        mp = self.model_shards
        if mp <= 1:
            return 0.0
        total = 0
        for c in caches["prefix"] + caches["blocks"]:
            if isinstance(c, pgc.PagedXCache) and c.x.shape[-1] % mp == 0:
                total += c.x.size * c.x.dtype.itemsize  # stacked axis included
        return total * (mp - 1) / mp

    def _per_device_arena_bytes(self, caches, total_bytes: int) -> float:
        """Physical arena bytes each device holds (sharded leaves shrink,
        replicated leaves don't) — the HBM-capacity win the kv-head
        partitioning exists for."""
        if self.mesh is None:
            return float(total_bytes)
        import math

        def leaf_bytes(a, ns) -> float:
            return math.prod(ns.shard_shape(a.shape)) * a.dtype.itemsize

        return float(sum(jax.tree.leaves(
            jax.tree.map(leaf_bytes, caches, self._cache_shardings))))

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """Static-engine-compatible convenience: one batch of equal-priority
        requests; returns (tokens (B, max_new) right-padded with eos/last,
        stats)."""
        prompt = np.asarray(batch["tokens"])
        reqs = [Request(rid=i, prompt=prompt[i], max_new_tokens=gen.max_new_tokens)
                for i in range(prompt.shape[0])]
        results, stats = self.serve(reqs, gen)
        pad = gen.eos_id if gen.eos_id >= 0 else 0
        out = np.full((prompt.shape[0], gen.max_new_tokens), pad, np.int32)
        for i in range(prompt.shape[0]):
            t = results[i]["tokens"]
            out[i, :len(t)] = t[:gen.max_new_tokens]
        return out, stats
