"""Serving engines: the paper's end-to-end inference path.

Two engines share the sampling / generation config machinery:

``ServeEngine`` — the original static-batch engine (kept as the back-compat
baseline and as the benchmark foil): one right-padded batch runs prefill then
a jitted decode loop to completion; every row owns a contiguous
``(n_max, ...)`` arena slice for the whole run.

``ContinuousServeEngine`` — continuous batching over block-paged arenas
(serving/paged_cache.py) driven by the host-side scheduler
(serving/scheduler.py): requests are admitted into vacated slots as soon as
pages are free, every row decodes at its own position (one jitted step over
per-row lengths), rows retire at EOS and free their pages immediately, and
the memory watermark policy escalates cache tiers (dense -> T2 CPQ) under
pressure — the paper's "dynamically compress and prune" story operationalized
at the request level.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionRuntime, CPQCfg, ModelConfig, ServingCfg
from repro.models import model as M
from repro.serving import paged_cache as pgc
from repro.serving.scheduler import Request, Scheduler, SchedulerConfigError


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_p: float = 1.0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


def sample_tokens(logits: jax.Array, key, gen: GenerationConfig) -> jax.Array:
    """(B, V) logits -> (B,) int32 samples (greedy / temperature / top-p)."""
    if gen.temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / gen.temperature
    if gen.top_p < 1.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        k = jnp.sum(cum < gen.top_p, axis=-1, keepdims=True)
        thresh = jnp.take_along_axis(sorted_l, k, axis=-1)
        logits = jnp.where(logits < thresh, -1e30, logits)
    return jax.random.categorical(key, logits).astype(jnp.int32)


# --------------------------------------------------------------- static engine


class ServeEngine:
    """Static-batch engine: fixed batch, right-padded prompts, run to
    completion. Kept as the contiguous-arena baseline."""

    def __init__(self, cfg: ModelConfig, params, rt: Optional[AttentionRuntime] = None,
                 max_len: int = 4096):
        self.cfg = cfg
        self.rt = rt or cfg.attention
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(partial(M.prefill, cfg, self.rt))
        self._decode = jax.jit(partial(M.decode_step, cfg, self.rt))

    def _sample(self, logits: jax.Array, key, gen: GenerationConfig) -> jax.Array:
        return sample_tokens(logits, key, gen)

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """batch: {'tokens': (B, S)} (+frames/patches per input_kind).
        Returns (generated (B, max_new_tokens) int32, stats dict)."""
        cfg = self.cfg
        prompt = batch.get("tokens", batch.get("frames"))
        B, S = prompt.shape[0], prompt.shape[1]
        n_max = S + gen.max_new_tokens
        assert n_max <= self.max_len + gen.max_new_tokens

        caches = M.init_caches(cfg, self.rt, B, n_max)
        logits, caches = self._prefill(self.params, batch, caches)

        key = jax.random.PRNGKey(gen.seed)
        toks = []
        done = jnp.zeros((B,), bool)
        live_tokens = 0
        decode_calls = 0
        tok = self._sample(logits, key, gen)
        for t in range(gen.max_new_tokens):
            if gen.eos_id >= 0:
                # rows past their EOS emit eos_id, not fresh samples
                tok = jnp.where(done, gen.eos_id, tok)
            toks.append(np.asarray(tok))
            live_tokens += int(jnp.sum(~done))  # EOS itself counts; padding doesn't
            if gen.eos_id >= 0:
                done = done | (tok == gen.eos_id)
                if bool(jnp.all(done)):
                    break
            if t == gen.max_new_tokens - 1:
                break  # the last appended token needs no further decode
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None],
                                          jnp.asarray(S + t, jnp.int32), caches)
            decode_calls += 1
            tok = self._sample(logits, sub, gen)
        out = np.stack(toks, axis=1)
        stats = {
            "prompt_tokens": int(B * S),
            "generated_tokens": live_tokens,
            "decode_steps": decode_calls,
            "cache_mode": self.rt.mode,
        }
        return out, stats


# ----------------------------------------------------------- continuous engine


class ContinuousServeEngine:
    """Continuous batching over block-paged arenas.

    One engine instance holds the jitted step functions; each ``serve`` call
    builds a fresh scheduler + paged cache pytree and drains the request list.
    The decode clock is the simulation time base: a request with
    ``arrival=t`` becomes admissible after t decode steps (Poisson-arrival
    benchmarks feed arrivals in these units; online use passes 0.0).
    """

    def __init__(self, cfg: ModelConfig, params, rt: Optional[AttentionRuntime] = None,
                 serving: ServingCfg = ServingCfg(), mesh=None):
        self.cfg = cfg
        self.params = params
        self.serving = serving
        rt = rt or cfg.attention
        if mesh is not None:
            if getattr(rt, "mesh", None) is not None and rt.mesh != mesh:
                raise SchedulerConfigError(
                    "conflicting device meshes: rt.mesh and the mesh= "
                    "argument disagree — set one or make them equal")
            rt = dataclasses.replace(rt, mesh=mesh)
        self.mesh = getattr(rt, "mesh", None)
        if (serving.use_paged_kernels is not None
                and rt.paged_kernels != serving.use_paged_kernels):
            # explicit serving-config override of the decode-kernel choice
            # (fused paged kernels vs the jnp gather path); None defers to rt
            rt = dataclasses.replace(rt, paged_kernels=serving.use_paged_kernels)
        self.tiered = bool(serving.enable_escalation and rt.mode == "dense")
        if self.tiered and rt.cpq is None:
            rt = dataclasses.replace(rt, cpq=CPQCfg())
        if self.tiered and any(m == "mla" for m, _ in cfg.layer_kinds):
            raise SchedulerConfigError(
                "tier escalation supports plain-attention stacks only "
                "(MLA already caches the compressed latent)")
        if cfg.input_kind != "tokens":
            raise SchedulerConfigError(
                "continuous serving drives token prompts; "
                f"input_kind={cfg.input_kind!r} needs the static engine")
        self.rt = rt
        # mesh-native serving: validate the model axis divides every head /
        # latent axis it shards, pin the replicated params once, and build
        # the fitted NamedSharding tree the paged arenas are placed with
        from repro.serving import sharded as _sharded

        self.model_shards = _sharded.validate_serve_mesh(cfg, rt, self.tiered)
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec as PS

            from repro.distributed.cache_specs import paged_cache_pspecs
            from repro.distributed.sharding import fit_spec_to_shape

            self.params = jax.device_put(
                params, NamedSharding(self.mesh, PS()))
            shapes = jax.eval_shape(partial(M.init_paged_caches, cfg, rt,
                                            serving, self.tiered))
            specs = paged_cache_pspecs(cfg, rt, serving, self.tiered)
            self._cache_shardings = jax.tree.map(
                lambda sp, a: NamedSharding(
                    self.mesh, fit_spec_to_shape(sp, a.shape, self.mesh)),
                specs, shapes, is_leaf=lambda x: isinstance(x, PS))
        # recurrent mixers integrate every prefill token into their state, so
        # bucket padding would pollute it (attention only masks); those archs
        # prefill at exact lengths (more jit variants, exact math)
        self._exact_prefill = any(m in ("mamba", "mlstm", "slstm")
                                  for m, _ in cfg.layer_kinds)
        self._decode = jax.jit(partial(M.decode_step_rows, cfg, rt))
        self._pack = jax.jit(partial(M.pack_prefill_caches, cfg, rt))
        self._escalate = jax.jit(partial(M.escalate_slot, cfg, rt))
        self._defrag = jax.jit(partial(M.defrag_caches, cfg, rt))
        self._prefills: dict[str, object] = {}   # one-shot oracle path only
        self._chunk_fns: dict[tuple[int, bool], object] = {}
        # two layer families keep the exact one-shot admission: recurrent
        # mixers integrate every token into O(1) state that cannot be cut at
        # page boundaries, and capacity-factor MoE routing makes prefill a
        # function of the token GROUP (chunking the group changes the drop
        # pattern). Everything else streams chunks into the arena.
        self._group_routed = any(mlp == "moe" for _, mlp in cfg.layer_kinds)
        self.chunked = (bool(serving.prefill_chunk) and not self._exact_prefill
                        and not self._group_routed)
        # cache-bearing layer count for the traffic model
        self._n_cache_layers = sum(1 for m, _ in cfg.layer_kinds if m in ("attn", "mla"))

    # ------------------------------------------------------------- helpers

    def _rt_for_tier(self, tier: int) -> AttentionRuntime:
        if tier == 0:
            return self.rt
        return AttentionRuntime(mode="cpq", cpq=self.rt.cpq,
                                paged_kernels=self.rt.paged_kernels,
                                mesh=self.mesh)

    def _prefill_for(self, rt: AttentionRuntime):
        if rt.mode not in self._prefills:
            self._prefills[rt.mode] = jax.jit(partial(M.prefill, self.cfg, rt))
        return self._prefills[rt.mode]

    def _chunk_fn(self, tier: int, first: bool):
        """Jitted chunk-prefill step: ONE compiled shape per (tier mode,
        first-chunk) pair — every prompt length reuses it (the old
        per-(mode x padded-length) prefill variant zoo is gone)."""
        key = (tier, first)
        if key not in self._chunk_fns:
            rt_t = self._rt_for_tier(tier)
            self._chunk_fns[key] = jax.jit(
                partial(M.prefill_chunk_rows, self.cfg, rt_t, tier, first))
        return self._chunk_fns[key]

    def _bucketed(self, ctx: np.ndarray) -> tuple[np.ndarray, int]:
        """Right-pad to the prefill bucket with the edge token (padding never
        enters attention: causal mask + true-length logits index; cache slots
        beyond the true length map to the null page)."""
        S = len(ctx)
        b = 1 if self._exact_prefill else self.serving.prefill_bucket
        S_pad = max(b, -(-S // b) * b)
        if S_pad == S:
            return ctx, S
        return np.concatenate([ctx, np.full((S_pad - S,), ctx[-1], np.int32)]), S

    def _admit(self, req: Request, sched: Scheduler, caches, key, gen):
        """ONE-SHOT admission (the construction-exact oracle path, selected
        by ``prefill_chunk == 0`` and kept for recurrent stacks): B=1 prefill
        of the whole context into a contiguous scratch cache, scatter-packed
        into the slot's pages. Samples the request's first token. Returns
        (caches, first_token, padded_len)."""
        padded, S = self._bucketed(req.context)
        rt_t = self._rt_for_tier(req.tier)
        ctg = M.init_caches(self.cfg, rt_t, 1, len(padded))
        logits, ctg = self._prefill_for(rt_t)(
            self.params, {"tokens": jnp.asarray(padded[None])}, ctg,
            jnp.asarray(S - 1, jnp.int32))
        tables = sched.alt_block_tables if req.tier == 1 else sched.block_tables
        caches = self._pack(caches, ctg, jnp.asarray(tables[req.slot]),
                            jnp.asarray(req.slot, jnp.int32))
        sched.finish_prefill(req)
        tok = int(np.asarray(sample_tokens(logits, key, gen))[0])
        return caches, tok, len(padded)

    def _prefill_chunk(self, req: Request, sched: Scheduler, caches, key, gen):
        """Stream the next ``prefill_chunk`` prompt tokens STRAIGHT into the
        request's arena pages (no scratch cache, no pack copy); on the final
        chunk, samples the first token from the last valid position's logits.
        Returns (caches, first_token | None, valid_tokens_this_chunk)."""
        C = self.serving.prefill_chunk
        ctx = req.context
        off = req.length
        valid = min(C, req.prefill_target - off)
        chunk = ctx[off:off + valid]
        if valid < C:  # jit padding with the edge token (masked everywhere)
            chunk = np.concatenate(
                [chunk, np.full((C - valid,), chunk[-1], np.int32)])
        tables = sched.alt_block_tables if req.tier == 1 else sched.block_tables
        logits, caches = self._chunk_fn(req.tier, off == 0)(
            self.params, jnp.asarray(chunk[None]),
            jnp.asarray(req.slot, jnp.int32),
            jnp.asarray(tables[req.slot]),
            jnp.asarray(off, jnp.int32), jnp.asarray(valid, jnp.int32), caches)
        sched.note_chunk(req, valid)
        if req.length < req.prefill_target:
            return caches, None, valid
        sched.finish_prefill(req)
        tok = int(np.asarray(sample_tokens(logits, key, gen))[0])
        return caches, tok, valid

    def _row_state(self, sched: Scheduler, active=None) -> pgc.RowState:
        return pgc.RowState(
            lengths=jnp.asarray(sched.lengths),
            block_table=jnp.asarray(sched.block_tables),
            active=jnp.asarray(sched.active_mask() if active is None else active),
            tier=jnp.asarray(sched.tiers),
            alt_block_table=(jnp.asarray(sched.alt_block_tables)
                             if sched.tiered else None))

    def _tier_bpt(self, caches) -> tuple[float, float]:
        """(base, escalated) per-token decode traffic per cache-bearing layer."""
        n_prefix = len(self.cfg.prefix_pattern)
        entries = list(zip(self.cfg.prefix_pattern + self.cfg.block_pattern,
                           caches["prefix"] + caches["blocks"]))
        for i, (kind, c) in enumerate(entries):
            if kind[0] not in ("attn", "mla"):
                continue
            c0 = jax.tree.map(lambda a: a[0], c) if i >= n_prefix else c
            ps = self.serving.page_size
            if isinstance(c0, pgc.TieredPagedCache):
                return (pgc.bytes_per_token(c0.dense, ps),
                        pgc.bytes_per_token(c0.cpq, ps, self.rt.cpq))
            b = pgc.bytes_per_token(c0, ps, self.rt.cpq)
            return b, b
        return 0.0, 0.0

    # ----------------------------------------------------------------- run

    def serve(self, requests: list[Request],
              gen: GenerationConfig = GenerationConfig()):
        """Drain ``requests`` (admission-queue order = list order; arrivals in
        decode-step units must be non-decreasing). Returns (results, stats):
        results[rid] = {tokens, finish_reason, admitted_step, done_step, ...}.

        Clock model: ``step`` counts model-invocation ticks. A tick that runs
        the jitted decode step costs 1, and one prompt chunk rides along for
        free (the chunked-prefill interleave). The one-shot oracle path
        charges a monolithic admission its chunk-equivalents up front —
        ``ceil(padded_len / quantum)`` ticks during which no row decodes —
        which is exactly the head-of-line stall chunked admission removes
        (quantum = ``prefill_chunk`` or, on the one-shot path,
        ``prefill_bucket``)."""
        sched = Scheduler(self.serving, self.tiered)
        for r in sorted(requests, key=lambda r: r.arrival):
            sched.submit(r)
        caches = M.init_paged_caches(self.cfg, self.rt, self.serving, self.tiered)
        if self.mesh is not None:
            # place the arenas per the paged cache specs: kv-head / latent
            # feature axes over "model", pools and slot state replicated
            caches = jax.device_put(caches, self._cache_shardings)
        bpt0, bpt1 = self._tier_bpt(caches)
        quantum = self.serving.prefill_chunk or self.serving.prefill_bucket
        # interconnect accounting under model sharding: each device emits its
        # per-head output partial and receives the others' — the paper's
        # "only small per-head partials cross the interconnect" measured as
        # (mp-1)/mp of the concatenated head outputs, per token per layer
        mp = self.model_shards
        dv = (self.cfg.mla.v_head_dim if self.cfg.mla is not None
              else self.cfg.head_dim)
        # layers whose arenas are head-sharded pay the per-head output
        # concat: exact for the shard_map'd tiers, a LOWER BOUND for T3
        # retrieval (GSPMD chooses its own collectives there). The CPQ-X
        # tiers replicate their code pools and are not charged — their
        # residual k_rope movement is unmodeled.
        n_concat = sum(
            1 for m, _ in self.cfg.layer_kinds
            if (m == "attn" and (self.tiered or self.rt.mode in
                                 ("dense", "cpq", "decomposed", "retrieval")))
            or (m == "mla" and self.rt.mode != "cpq"))
        concat_bpt = (0.0 if mp <= 1 else
                      (mp - 1) / mp * self.cfg.num_heads * dv
                      * self.cfg.param_dtype.itemsize * n_concat)
        # ...plus, for storage-sharded latent tiers (T1 X / MLA c_kv), the
        # per-invocation pool all-gather — charged per model invocation, not
        # per token (zero for head-sharded tiers and unsharded engines)
        gather_bps = self._latent_gather_bytes_per_step(caches)

        B = self.serving.num_slots
        last_tok = np.zeros((B,), np.int32)
        key = jax.random.PRNGKey(gen.seed)
        results: dict[int, dict] = {}
        step = 0                     # model-invocation tick clock
        decode_steps = live_steps = prefill_chunks = 0
        prefill_tokens = generated = 0
        traffic = prefill_write_bytes = interconnect = 0.0
        util_peak, util_sum, util_n = 0.0, 0.0, 0
        defrag_mark = 0              # retirements at the last compaction
        t0 = time.time()

        def result_of(req: Request) -> dict:
            return {
                "tokens": np.asarray(req.generated, np.int32),
                "finish_reason": req.finish_reason,
                "arrival": req.arrival,
                "admitted_step": req.admitted_step,
                "first_token_step": req.first_token_step,
                "token_steps": np.asarray(req.token_steps, np.int64),
                "done_step": req.done_step,
                "preemptions": req.preemptions,
                "escalated": req.escalated,
            }

        def finish(req: Request, reason: str):
            sched.retire(req, step, reason)
            results[req.rid] = result_of(req)

        def emit_token(req: Request, tok: int, tick: int, grow: bool = False):
            """Commit one emitted token. ``tick`` is the clock value at which
            the token became available (end-of-work convention: a token
            produced during tick T is stamped T+1; a one-shot admission's
            first token is stamped at the end of its charged stall).
            ``grow`` extends the cache bookkeeping (decode tokens only —
            the first token's position is written by its decode step)."""
            nonlocal generated
            req.generated.append(tok)
            req.token_steps.append(tick)
            if grow:
                req.length += 1
                sched.lengths[req.slot] += 1
            last_tok[req.slot] = tok
            generated += 1
            if req.first_token_step < 0:
                req.first_token_step = tick
            if gen.eos_id >= 0 and tok == gen.eos_id:
                finish(req, "eos")
            elif req.num_generated >= req.max_new_tokens:
                finish(req, "max_tokens")

        while sched.has_work():
            # 0) periodic base-arena compaction (defrag_every retirements):
            #    the scheduler relabels mapped pages onto the lowest ids and
            #    the jitted permutation moves every base page pool to match
            if (self.serving.defrag_every
                    and sched.stats["retired"] - defrag_mark
                    >= self.serving.defrag_every):
                defrag_mark = sched.stats["retired"]
                perm = sched.plan_defrag()
                if perm is not None:
                    caches = self._defrag(caches, jnp.asarray(perm))

            # 1) admissions into vacated slots. Chunked (default): the slot
            #    enters the prefilling state and its prompt streams below.
            #    One-shot oracle: prefill the whole context now and charge
            #    the clock its chunk-equivalents (the head-of-line stall).
            while (req := sched.admit_next(now=step, step=step)) is not None:
                if self.chunked:
                    continue  # pump below interleaves one chunk per tick
                key, sub = jax.random.split(key)
                caches, tok, padded = self._admit(req, sched, caches, sub, gen)
                step += -(-padded // quantum)   # monolithic prefill stall
                # no interconnect charge: the one-shot prefill runs as a
                # replicated global jit (no shard_map), so under a mesh it
                # pays mp-fold redundant FLOPs instead of concat traffic;
                # the pack then writes each device's arena slice from the
                # locally-present replicated payload
                prefill_tokens += req.length
                prefill_write_bytes += (req.length
                                        * (bpt1 if req.tier else bpt0)
                                        * self._n_cache_layers)
                emit_token(req, tok, step)      # available after the stall

            # 2) watermark policy: escalate running dense requests under
            #    critical memory pressure (dense -> T2, pages freed)
            while (cand := sched.escalation_candidate()) is not None:
                slot, length = cand.slot, cand.length
                dense_row, cpq_row = sched.apply_escalation(cand)
                caches = self._escalate(caches, jnp.asarray(dense_row),
                                        jnp.asarray(cpq_row),
                                        jnp.asarray(slot, jnp.int32),
                                        jnp.asarray(length, jnp.int32))

            # 3) chunked-prefill pump: at most ONE prompt chunk per tick
            #    (the per-step prefill token budget), written straight into
            #    the slot's arena pages and interleaved with the decode step
            #    below — long prompts no longer freeze running rows
            did_chunk = False
            fresh_slot = -1  # row whose prefill finished THIS tick
            if self.chunked and (pre := sched.prefilling()):
                req = pre[0]
                key, sub = jax.random.split(key)
                caches, tok, valid = self._prefill_chunk(req, sched, caches,
                                                         sub, gen)
                did_chunk = True
                prefill_chunks += 1
                prefill_tokens += valid
                prefill_write_bytes += (valid * (bpt1 if req.tier else bpt0)
                                        * self._n_cache_layers)
                interconnect += valid * concat_bpt + gather_bps
                if tok is not None:
                    # the final chunk runs during THIS tick: its first token
                    # is available at the tick's end (step + 1), and the row
                    # joins the decode batch from the NEXT tick
                    emit_token(req, tok, step + 1)
                    if req.state == "running":
                        fresh_slot = req.slot

            # 4) growth: map a page for every running row's next write.
            #    Out of pages: a dense grower first escalates itself to the
            #    CPQ arena (frees its dense pages), else the youngest
            #    same-arena request is preempted (recompute)
            for req in sorted(sched.running(), key=lambda r: r.admitted_step):
                if req.state != "running":
                    continue
                while not sched.ensure_writable(req):
                    if req.length // self.serving.page_size >= \
                            self.serving.max_blocks_per_slot:
                        finish(req, "length_cap")
                        break
                    if self.tiered and req.tier == 0 and sched.cpq_alloc.can_alloc(
                            pgc.pages_needed(req.length + 1,
                                             self.serving.page_size)):
                        slot, length = req.slot, req.length
                        dense_row, cpq_row = sched.apply_escalation(req)
                        caches = self._escalate(caches, jnp.asarray(dense_row),
                                                jnp.asarray(cpq_row),
                                                jnp.asarray(slot, jnp.int32),
                                                jnp.asarray(length, jnp.int32))
                        continue
                    victim = sched.preemption_victim(exclude=req)
                    if victim is None:
                        finish(req, "oom")
                        break
                    sched.preempt(victim)

            active = sched.active_mask()
            if fresh_slot >= 0:
                active[fresh_slot] = False
            if not active.any():
                if did_chunk:
                    step += 1       # prefill-only tick still costs a tick
                    continue
                if not sched.occupied():
                    if sched.queue and sched.queue[0].arrival <= step:
                        # empty machine and still unadmissible => never fits
                        req = sched.queue.popleft()
                        req.state, req.done_step = "done", step
                        req.finish_reason = "unschedulable"
                        results[req.rid] = result_of(req)
                        continue
                    # idle: jump the clock to the next arrival
                    if sched.queue:
                        step = max(step + 1, int(np.ceil(sched.queue[0].arrival)))
                continue

            # 5) one jitted decode step over per-row positions (rows still
            #    prefilling — and a row whose final chunk landed this very
            #    tick — are inactive: their writes hit the null page)
            rows = self._row_state(sched, active)
            logits, caches = self._decode(self.params, jnp.asarray(last_tok[:, None]),
                                          rows, caches)
            key, sub = jax.random.split(key)
            toks = np.asarray(sample_tokens(logits, sub, gen))
            decode_steps += 1
            live_steps += int(active.sum())
            tier_arr = sched.tiers
            traffic += float(sum(
                (sched.lengths[s] + 1.0) * (bpt1 if tier_arr[s] else bpt0)
                for s in range(B) if active[s])) * self._n_cache_layers
            interconnect += int(active.sum()) * concat_bpt + gather_bps
            util = sched.dense_alloc.utilization
            util_peak = max(util_peak, util)
            util_sum += util
            util_n += 1
            step += 1

            for slot in range(B):
                if not active[slot]:
                    continue
                emit_token(sched.slots[slot], int(toks[slot]), step, grow=True)

        wall = time.time() - t0
        total_bytes = pgc.arena_bytes(caches)
        device_bytes = self._per_device_arena_bytes(caches, total_bytes)
        stats = {
            "cache_mode": self.rt.mode,
            "tiered": self.tiered,
            "chunked_prefill": self.chunked,
            "model_shards": self.model_shards,
            "arena_bytes_total": total_bytes,
            "arena_bytes_per_device": device_bytes,
            "interconnect_bytes": interconnect,
            "interconnect_bytes_per_token": interconnect / max(generated, 1),
            "decode_steps": decode_steps,
            "prefill_chunks": prefill_chunks,
            "prefill_tokens": prefill_tokens,
            "generated_tokens": generated,
            "tokens_per_step": generated / max(decode_steps, 1),
            "slot_utilization": live_steps / max(decode_steps * B, 1),
            "arena_utilization_mean": util_sum / max(util_n, 1),
            "arena_utilization_peak": util_peak,
            "decode_traffic_bytes": traffic,
            "prefill_write_bytes": prefill_write_bytes,
            "bytes_per_token_layer": bpt0,
            "wall_time_s": wall,
            "tokens_per_s": generated / max(wall, 1e-9),
            # invariant: every page freed once all requests retired
            "dense_pages_leaked": sched.dense_alloc.num_used,
            "cpq_pages_leaked": sched.cpq_alloc.num_used if sched.cpq_alloc else 0,
            **sched.stats,
            # public allocator surface (utilization + defrag counts): what
            # bench_serving and the sharded watermark read instead of the
            # private dense_alloc/cpq_alloc state
            **sched.arena_stats(),
        }
        return results, stats

    def _latent_gather_bytes_per_step(self, caches) -> float:
        """Interconnect bytes ONE model invocation moves re-assembling the
        storage-sharded latent pools (PagedXCache.x all-gather inside the
        shard_map, serving/sharded.py): each device ships its feature shard
        to the mp-1 others, per latent cache layer. Zero when unsharded.
        This dwarfs the per-head output concat — the price of latent
        HBM-capacity sharding paid on every step (gathering only mapped
        pages is the open optimization, see ROADMAP)."""
        mp = self.model_shards
        if mp <= 1:
            return 0.0
        total = 0
        for c in caches["prefix"] + caches["blocks"]:
            if isinstance(c, pgc.PagedXCache) and c.x.shape[-1] % mp == 0:
                total += c.x.size * c.x.dtype.itemsize  # stacked axis included
        return total * (mp - 1) / mp

    def _per_device_arena_bytes(self, caches, total_bytes: int) -> float:
        """Physical arena bytes each device holds (sharded leaves shrink,
        replicated leaves don't) — the HBM-capacity win the kv-head
        partitioning exists for."""
        if self.mesh is None:
            return float(total_bytes)
        import math

        def leaf_bytes(a, ns) -> float:
            return math.prod(ns.shard_shape(a.shape)) * a.dtype.itemsize

        return float(sum(jax.tree.leaves(
            jax.tree.map(leaf_bytes, caches, self._cache_shardings))))

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """Static-engine-compatible convenience: one batch of equal-priority
        requests; returns (tokens (B, max_new) right-padded with eos/last,
        stats)."""
        prompt = np.asarray(batch["tokens"])
        reqs = [Request(rid=i, prompt=prompt[i], max_new_tokens=gen.max_new_tokens)
                for i in range(prompt.shape[0])]
        results, stats = self.serve(reqs, gen)
        pad = gen.eos_id if gen.eos_id >= 0 else 0
        out = np.full((prompt.shape[0], gen.max_new_tokens), pad, np.int32)
        for i in range(prompt.shape[0]):
            t = results[i]["tokens"]
            out[i, :len(t)] = t[:gen.max_new_tokens]
        return out, stats
