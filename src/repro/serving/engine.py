"""Batched serving engine: prefill -> decode loop with sampling, EOS
handling, and mode-selectable caches (dense / T1 decomposed / T2 CPQ /
T3 retrieval). The paper's end-to-end inference path.

Static-shape design (TPU-friendly): the request batch is padded to a fixed
size; prompts are right-padded to a common length (per-row lengths masked at
sampling); the decode loop is one jitted step reused every token. Cache
traffic per token is the mode's bytes/token (see kv_cache.bytes_per_token and
benchmarks/bench_e2e_energy.py for the traffic model).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionRuntime, ModelConfig
from repro.models import model as M


@dataclasses.dataclass(frozen=True)
class GenerationConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0      # 0 => greedy
    top_p: float = 1.0
    eos_id: int = -1              # -1 => never stop early
    seed: int = 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, rt: Optional[AttentionRuntime] = None,
                 max_len: int = 4096):
        self.cfg = cfg
        self.rt = rt or cfg.attention
        self.params = params
        self.max_len = max_len
        self._prefill = jax.jit(partial(M.prefill, cfg, self.rt))
        self._decode = jax.jit(partial(M.decode_step, cfg, self.rt))

    def _sample(self, logits: jax.Array, key, gen: GenerationConfig) -> jax.Array:
        if gen.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        logits = logits / gen.temperature
        if gen.top_p < 1.0:
            sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
            probs = jax.nn.softmax(sorted_l, axis=-1)
            cum = jnp.cumsum(probs, axis=-1)
            k = jnp.sum(cum < gen.top_p, axis=-1, keepdims=True)
            thresh = jnp.take_along_axis(sorted_l, k, axis=-1)
            logits = jnp.where(logits < thresh, -1e30, logits)
        return jax.random.categorical(key, logits).astype(jnp.int32)

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """batch: {'tokens': (B, S)} (+frames/patches per input_kind).
        Returns (generated (B, max_new_tokens) int32, stats dict)."""
        cfg = self.cfg
        prompt = batch.get("tokens", batch.get("frames"))
        B, S = prompt.shape[0], prompt.shape[1]
        n_max = S + gen.max_new_tokens
        assert n_max <= self.max_len + gen.max_new_tokens

        caches = M.init_caches(cfg, self.rt, B, n_max)
        logits, caches = self._prefill(self.params, batch, caches)

        key = jax.random.PRNGKey(gen.seed)
        toks = []
        done = jnp.zeros((B,), bool)
        tok = self._sample(logits, key, gen)
        for t in range(gen.max_new_tokens):
            toks.append(np.asarray(tok))
            if gen.eos_id >= 0:
                done = done | (tok == gen.eos_id)
                if bool(jnp.all(done)):
                    break
            key, sub = jax.random.split(key)
            logits, caches = self._decode(self.params, tok[:, None],
                                          jnp.asarray(S + t, jnp.int32), caches)
            tok = self._sample(logits, sub, gen)
        out = np.stack(toks, axis=1)
        stats = {
            "prompt_tokens": int(B * S),
            "generated_tokens": int(out.size),
            "cache_mode": self.rt.mode,
        }
        return out, stats
