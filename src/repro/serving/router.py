"""Multi-replica serving router: data-parallel scale-out of the engine.

The paper's end-to-end claim is that PIM serving scales by adding memory
channels, not by fattening one compute unit — every extra DIMM brings its
own bandwidth AND its own capacity. The serving analogue is data
parallelism over whole engines: ``ReplicaRouter`` owns N independent
``ContinuousServeEngine`` replicas (each with its own ``Scheduler``, paged
arenas, and tick loop) and fronts them with the SAME request-centric
surface — ``add_request() / step() / pending_outputs() / results() /
stats()`` plus the ``serve()/generate()`` wrappers — so callers written
against one engine drive N without change. One router ``step()`` ticks
every healthy replica once (the replicas of a real deployment tick in
parallel; aggregate tokens/step is measured against the slowest replica's
clock).

Three concerns the single engine cannot express live here:

  placement         WHERE a new request runs. Pluggable ``PlacementPolicy``
                    (serving/policies.py): ``rr`` round-robin, ``load``
                    least-outstanding-tokens, ``slo`` SLO/arena-pressure-
                    aware (reads each replica's ``arena_stats()`` free-page
                    fraction and the request's ``SloClass`` before
                    assigning).
  session affinity  a ``ServeRequest.session_id`` pins every follow-up turn
                    of a conversation to the replica that served its earlier
                    turns — the replica holding the session's arena pages —
                    bypassing placement until the session's replica drains.
  drain             ``drain(i)`` removes a replica from service: placements
                    stop, its incomplete requests are snapshotted by the
                    engine's ``drain()`` (the recompute-preemption replay
                    path: context = prompt + generated-so-far, pinned
                    SamplingParams) and re-queued onto healthy replicas —
                    seeded sampling reproduces token-for-token after the
                    migration because draws are ``fold_in(seed,
                    token_index)``, a function of the request alone — and
                    the replica's arenas are freed (``release()``). Sessions
                    pinned to it are remapped with their migrated requests.

Request ids are router-global (collisions across replicas would corrupt the
merged ``results()``), and every ``RequestOutput`` is delivered exactly
once: engine buffers drain into the router buffer each tick, and a drain
hands un-emitted work over BEFORE the source session is dropped.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.configs.base import AttentionRuntime, ModelConfig, ServingCfg
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.policies import PlacementPolicy, ReplicaView, make_placement
from repro.serving.request import RequestOutput, ServeRequest
from repro.serving.scheduler import Request, SchedulerConfigError


class ReplicaRouter:
    """Front end over N data-parallel ``ContinuousServeEngine`` replicas.

    Construction builds the replicas (replica 0 compiles; the rest adopt
    its jitted step functions — same (cfg, rt), same executables).
    ``placement`` is a ``PlacementPolicy`` object or name (``rr`` | ``load``
    | ``slo``); ``policy``/``serving``/``rt``/``mesh`` are forwarded to
    every replica engine (under a mesh each replica model-shards its arenas
    over the same devices — the ``data`` axis of a real deployment is the
    replica set itself)."""

    def __init__(self, cfg: ModelConfig, params, num_replicas: int = 2,
                 rt: Optional[AttentionRuntime] = None,
                 serving: ServingCfg = ServingCfg(),
                 placement: Union[str, PlacementPolicy] = "rr",
                 policy=None, mesh=None):
        if num_replicas < 1:
            raise SchedulerConfigError("num_replicas must be >= 1")
        self.serving = serving
        self.engines: list[ContinuousServeEngine] = []
        for _ in range(num_replicas):
            eng = ContinuousServeEngine(cfg, params, rt=rt, serving=serving,
                                        mesh=mesh, policy=policy)
            if self.engines:
                eng.adopt_compiled(self.engines[0])
            self.engines.append(eng)
        self.placement = (make_placement(placement)
                          if isinstance(placement, str) else placement)
        self._fresh()

    # ------------------------------------------------------- session state

    def _fresh(self) -> None:
        self._draining: set[int] = set()
        self._sessions: dict[str, int] = {}     # session_id -> replica
        self._rid_replica: dict[int, int] = {}  # rid -> current replica
        self._archived: dict[int, dict] = {}    # results of drained replicas
        self._drained_stats: dict[int, dict] = {}
        self._outputs: list[RequestOutput] = []
        self._next_rid = 0
        self._ticks = 0
        self._migrated = 0

    def reset(self, gen: GenerationConfig = GenerationConfig()) -> None:
        """Fresh serving session on every replica (drained replicas rejoin);
        clears the session map, rid registry, and output buffer."""
        for eng in self.engines:
            eng.reset(gen)
        self._fresh()

    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    @property
    def chunked(self) -> bool:
        """Admission-path flag, mirrored from the replicas (engine-surface
        compatibility for callers that report it)."""
        return self.engines[0].chunked

    def healthy(self) -> list[int]:
        """Replica indices currently accepting placements."""
        return [i for i in range(len(self.engines)) if i not in self._draining]

    def replica_of(self, rid: int) -> Optional[int]:
        """The replica currently (or last) responsible for ``rid`` — the
        placement record, updated on migration."""
        return self._rid_replica.get(rid)

    # ---------------------------------------------------------- placement

    def _views(self) -> list[ReplicaView]:
        return [ReplicaView(index=i,
                            outstanding_tokens=self.engines[i]
                            .outstanding_tokens(),
                            free_frac=self.engines[i]
                            .arena_stats()["free_frac"])
                for i in self.healthy()]

    def _place(self, req: Union[ServeRequest, Request]) -> int:
        """Session affinity first (a mapped session bypasses placement while
        its replica is healthy), then the placement policy over the healthy
        replicas; a session's first request records the mapping."""
        views = self._views()
        if not views:
            raise SchedulerConfigError(
                "no healthy replicas: every replica is draining")
        sid = req.session_id
        if sid is not None:
            pinned = self._sessions.get(sid)
            if pinned is not None and pinned not in self._draining:
                return pinned
        target = self.placement.select(views, req)
        if sid is not None:
            self._sessions[sid] = target
        return target

    # ------------------------------------------------- request-centric API

    def add_request(self, req: Union[ServeRequest, Request], *,
                    stream=None) -> int:
        """Place one request on a replica (session affinity, then the
        placement policy) and submit it there. Request ids are router-global
        — an explicit rid colliding with any live or archived request
        raises; omitted rids auto-assign from the router's counter."""
        if isinstance(req, ServeRequest) and req.rid is None:
            req = dataclasses.replace(req, rid=self._next_rid)
        rid = req.rid
        if rid in self._rid_replica or rid in self._archived:
            raise SchedulerConfigError(
                f"request id {rid} already in use this session "
                "(omit ServeRequest.rid to auto-assign)")
        target = self._place(req)
        self.engines[target].add_request(req, stream=stream)
        self._rid_replica[rid] = target
        self._next_rid = max(self._next_rid, rid + 1)
        return rid

    def step(self) -> list[RequestOutput]:
        """One router tick: every healthy replica with work runs one engine
        tick (a real deployment's replicas tick in parallel — the router
        tick is the wall-clock unit). Returns the tick's merged
        ``RequestOutput`` events in replica order (also buffered for
        ``pending_outputs``; per-request ``stream`` callbacks fire inline,
        on the owning replica)."""
        events: list[RequestOutput] = []
        worked = False
        for i, eng in enumerate(self.engines):
            if i in self._draining or not eng.has_unfinished():
                continue
            worked = True
            eng.step()
            events.extend(eng.pending_outputs())
        if worked:
            self._ticks += 1
        self._outputs.extend(events)
        return events

    def has_unfinished(self) -> bool:
        return any(i not in self._draining and eng.has_unfinished()
                   for i, eng in enumerate(self.engines))

    def pending_outputs(self) -> list[RequestOutput]:
        """Drain the router-level buffer of everything committed since the
        last drain (``step()`` also returns its tick's events directly)."""
        out, self._outputs = self._outputs, []
        return out

    def results(self) -> dict[int, dict]:
        """Merged finished-request records: drained replicas' archives plus
        every live replica's results. rids are router-global, so the merge
        is collision-free."""
        out = dict(self._archived)
        for eng in self.engines:
            out.update(eng.results())
        return out

    # --------------------------------------------------------------- drain

    def drain(self, replica: int) -> int:
        """Take ``replica`` out of service: stop placements to it, snapshot
        its incomplete requests through ``engine.drain()`` (the recompute-
        preemption replay path), archive its finished results and stats,
        free its arenas (``engine.release()``), and re-queue the snapshot
        onto healthy replicas via the normal placement path — sessions
        pinned to the drained replica are remapped with their requests.
        Returns the number of requests migrated. Refuses to drain the last
        healthy replica (its work would have nowhere to go)."""
        if replica in self._draining:
            return 0
        if not (0 <= replica < len(self.engines)):
            raise SchedulerConfigError(f"no replica {replica}")
        if set(self.healthy()) == {replica}:
            raise SchedulerConfigError(
                "cannot drain the last healthy replica")
        eng = self.engines[replica]
        self._draining.add(replica)
        had_state = eng._st is not None
        if had_state:
            self._outputs.extend(eng.pending_outputs())  # nothing left behind
            self._archived.update(eng.results())
        moved = eng.drain()
        if had_state:
            # snapshot AFTER drain: pages freed, drain preemptions counted
            self._drained_stats[replica] = eng.stats()
        eng.release()
        self._sessions = {s: r for s, r in self._sessions.items()
                          if r != replica}
        for req in moved:
            target = self._place(req)
            self.engines[target].add_request(req)
            self._rid_replica[req.rid] = target
        self._migrated += len(moved)
        return len(moved)

    # --------------------------------------------------------------- stats

    _SUM_KEYS = ("generated_tokens", "prefill_tokens", "prefill_chunks",
                 "decode_steps", "arena_bytes_total", "arena_bytes_per_device",
                 "interconnect_bytes", "decode_traffic_bytes",
                 "prefill_write_bytes", "defrags", "preemptions",
                 "escalations", "deescalations", "admitted", "retired",
                 "dense_pages_leaked", "cpq_pages_leaked")
    _REPLICA_KEYS = ("tokens_per_step", "generated_tokens", "decode_steps",
                     "prefill_tokens", "arena_bytes_total",
                     "interconnect_bytes", "defrags", "preemptions",
                     "escalations", "deescalations", "slot_utilization",
                     "dense_arena_utilization", "policy")

    def stats(self) -> dict:
        """One aggregated surface over all replicas plus the per-replica
        breakdown. Counters sum; ``tokens_per_step`` is the AGGREGATE
        throughput — total generated tokens against the slowest replica's
        decode clock (replicas tick in parallel, so the busiest replica is
        the wall clock). Drained replicas contribute their drain-time
        snapshot."""
        per_replica = []
        for i, eng in enumerate(self.engines):
            s = self._drained_stats.get(i)
            if s is None:
                # a replica with no serving session yet (or released) has no
                # counters to report — don't build arenas just to read zeros
                s = eng.stats() if eng._st is not None else {}
            row = {"replica": i, "draining": i in self._draining}
            row.update({k: s.get(k) for k in self._REPLICA_KEYS})
            per_replica.append((row, s))
        agg: dict = {
            "replicas": len(self.engines),
            "placement": self.placement.name,
            "draining": sorted(self._draining),
            "drains": len(self._draining),
            "migrated_requests": self._migrated,
            "router_ticks": self._ticks,
        }
        for k in self._SUM_KEYS:
            agg[k] = sum(s.get(k, 0) or 0 for _, s in per_replica)
        busiest = max((s.get("decode_steps", 0) for _, s in per_replica),
                      default=0)
        agg["decode_steps_max"] = busiest
        agg["tokens_per_step"] = agg["generated_tokens"] / max(busiest, 1)
        agg["interconnect_bytes_per_token"] = (
            agg["interconnect_bytes"] / max(agg["generated_tokens"], 1))
        agg["wall_time_s"] = max(s.get("wall_time_s", 0.0)
                                 for _, s in per_replica)
        agg["tokens_per_s"] = agg["generated_tokens"] / max(
            agg["wall_time_s"], 1e-9)
        agg["per_replica"] = [row for row, _ in per_replica]
        return agg

    # ----------------------------------------------------- batch wrappers

    def serve(self, requests: list[Union[Request, ServeRequest]],
              gen: GenerationConfig = GenerationConfig()):
        """Batch-shaped wrapper, signature-compatible with the engine's:
        resets every replica, places and submits all requests in arrival
        order, ticks to completion. Returns (merged results, aggregate
        stats)."""
        self.reset(gen)
        for r in sorted(requests, key=lambda r: r.arrival):
            self.add_request(r)
        while self.has_unfinished():
            self.step()
        return self.results(), self.stats()

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """Static-engine-compatible convenience (same contract as
        ``ContinuousServeEngine.generate``), spread over the replicas."""
        prompt = np.asarray(batch["tokens"])
        reqs = [Request(rid=i, prompt=prompt[i],
                        max_new_tokens=gen.max_new_tokens)
                for i in range(prompt.shape[0])]
        results, stats = self.serve(reqs, gen)
        pad = gen.eos_id if gen.eos_id >= 0 else 0
        out = np.full((prompt.shape[0], gen.max_new_tokens), pad, np.int32)
        for i in range(prompt.shape[0]):
            t = results[i]["tokens"]
            out[i, :len(t)] = t[:gen.max_new_tokens]
        return out, stats
