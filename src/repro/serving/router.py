"""Multi-replica serving router: data-parallel scale-out of the engine.

The paper's end-to-end claim is that PIM serving scales by adding memory
channels, not by fattening one compute unit — every extra DIMM brings its
own bandwidth AND its own capacity. The serving analogue is data
parallelism over whole engines: ``ReplicaRouter`` owns N independent
``ContinuousServeEngine`` replicas (each with its own ``Scheduler``, paged
arenas, and tick loop) and fronts them with the SAME request-centric
surface — ``add_request() / step() / pending_outputs() / results() /
stats()`` plus the ``serve()/generate()`` wrappers — so callers written
against one engine drive N without change. One router ``step()`` ticks
every healthy replica once (the replicas of a real deployment tick in
parallel; aggregate tokens/step is measured against the slowest replica's
clock).

Concerns the single engine cannot express live here:

  placement         WHERE a new request runs. Pluggable ``PlacementPolicy``
                    (serving/policies.py): ``rr`` round-robin, ``load``
                    least-outstanding-tokens, ``slo`` SLO/arena-pressure-
                    aware (reads each replica's ``arena_stats()`` free-page
                    fraction and the request's ``SloClass`` before
                    assigning).
  session affinity  a ``ServeRequest.session_id`` pins every follow-up turn
                    of a conversation to the replica that served its earlier
                    turns — the replica holding the session's arena pages —
                    bypassing placement until the session's replica drains.
  drain             ``drain(i)`` removes a replica from service: placements
                    stop, its incomplete requests are snapshotted by the
                    engine's ``drain()`` (the recompute-preemption replay
                    path: context = prompt + generated-so-far, pinned
                    SamplingParams) and re-queued onto healthy replicas —
                    seeded sampling reproduces token-for-token after the
                    migration because draws are ``fold_in(seed,
                    token_index)``, a function of the request alone — and
                    the replica's arenas are freed (``release()``). Sessions
                    pinned to it are remapped with their migrated requests.
  health            a ``HealthMonitor`` (serving/health.py) probes every
                    replica on ``ServingCfg.probe_interval`` and — with
                    ``auto_drain`` — drains one that fails
                    ``probe_failures`` consecutive probes (or raises from
                    ``step()``), then re-admits it when a backoff recovery
                    probe succeeds. Fault injection (serving/faults.py)
                    drives this machinery deterministically in CI.
  rebalance         ``rebalance(rid, dst)`` migrates ONE request without
                    draining its replica: the engine's ``drain_request``
                    snapshot re-queues on ``dst`` through the same replay
                    path — token-exact for greedy and seeded sampling.
  backpressure      with zero healthy replicas (or every replica saturated,
                    for deadline-free batch work) new requests PARK in a
                    router-level backlog instead of raising, and place on
                    the first recovery. A bounded backlog
                    (``ServingCfg.max_backlog``) sheds batch-class overflow
                    with a counted ``shed`` finish instead of growing
                    without bound.

Request ids are router-global (collisions across replicas would corrupt the
merged ``results()``), and every ``RequestOutput`` is delivered exactly
once: engine buffers drain into the router buffer each tick, and a drain
hands un-emitted work over BEFORE the source session is dropped.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import numpy as np

from repro.configs.base import AttentionRuntime, ModelConfig, ServingCfg
from repro.serving.engine import ContinuousServeEngine, GenerationConfig
from repro.serving.faults import FaultPlan, FaultyReplica, ReplicaFault
from repro.serving.health import HealthMonitor
from repro.serving.policies import (PlacementPolicy, ReplicaView,
                                    derive_deadlines, make_placement, slo_of)
from repro.serving.request import RequestOutput, SamplingParams, ServeRequest
from repro.serving.scheduler import Request, SchedulerConfigError


@dataclasses.dataclass
class _Parked:
    """One backlog entry: the request, its stream override, and its absolute
    deadlines on the ROUTER clock (the monitor tick count — it upper-bounds
    no engine clock exactly, but every parked tick is a tick not served, so
    expiring against it is conservative in spirit and deterministic)."""

    req: Union[ServeRequest, Request]
    stream: object = None
    ttft_deadline: float = math.inf
    deadline: float = math.inf


class ReplicaRouter:
    """Front end over N data-parallel ``ContinuousServeEngine`` replicas.

    Construction builds the replicas (replica 0 compiles; the rest adopt
    its jitted step functions — same (cfg, rt), same executables).
    ``placement`` is a ``PlacementPolicy`` object or name (``rr`` | ``load``
    | ``slo``); ``policy``/``serving``/``rt``/``mesh`` are forwarded to
    every replica engine (under a mesh each replica model-shards its arenas
    over the same devices — the ``data`` axis of a real deployment is the
    replica set itself). ``fault_plans`` (one ``FaultPlan`` per replica,
    None entries = no faults) wraps replicas in ``FaultyReplica`` for
    deterministic chaos testing."""

    def __init__(self, cfg: ModelConfig, params, num_replicas: int = 2,
                 rt: Optional[AttentionRuntime] = None,
                 serving: ServingCfg = ServingCfg(),
                 placement: Union[str, PlacementPolicy] = "rr",
                 policy=None, mesh=None,
                 fault_plans: Optional[list] = None):
        if num_replicas < 1:
            raise SchedulerConfigError("num_replicas must be >= 1")
        self.serving = serving
        engines = []
        for _ in range(num_replicas):
            eng = ContinuousServeEngine(cfg, params, rt=rt, serving=serving,
                                        mesh=mesh, policy=policy)
            if engines:
                eng.adopt_compiled(engines[0])
            engines.append(eng)
        if fault_plans is not None:
            assert len(fault_plans) == num_replicas, (
                "fault_plans must have one entry (FaultPlan or None) "
                "per replica")
            engines = [e if p is None else FaultyReplica(e, p)
                       for e, p in zip(engines, fault_plans)]
        self.engines = engines
        self.placement = (make_placement(placement)
                          if isinstance(placement, str) else placement)
        self._fresh()

    # ------------------------------------------------------- session state

    def _fresh(self) -> None:
        self._draining: set[int] = set()        # manual + auto
        self._manual_drained: set[int] = set()  # caller drains: never probed
        self._auto_drained: set[int] = set()    # monitor drains: re-admitted
        self._sessions: dict[str, int] = {}     # session_id -> replica
        self._rid_replica: dict[int, int] = {}  # rid -> current replica
        self._archived: dict[int, dict] = {}    # results of drained replicas
        self._drained_stats: dict[int, dict] = {}
        self._stats_archive: list[dict] = []    # epochs of re-admitted drains
        self._router_results: dict[int, dict] = {}  # shed / parked-timeout
        self._backlog: list[_Parked] = []
        self._outputs: list[RequestOutput] = []
        self._next_rid = 0
        self._ticks = 0
        self._mclock = 0                        # monitor clock: every step()
        self._migrated = 0
        self._rebalanced = 0
        self._shed = 0
        self._backlog_timeouts = 0
        s = self.serving
        self.monitor = HealthMonitor(
            self, interval=s.probe_interval, fail_threshold=s.probe_failures,
            backoff=s.probe_backoff, exhaust_frac=s.probe_exhaust_frac,
            auto_drain=s.auto_drain)

    def reset(self, gen: GenerationConfig = GenerationConfig()) -> None:
        """Fresh serving session on every replica (drained replicas rejoin);
        clears the session map, rid registry, backlog, health state, and
        output buffer."""
        for eng in self.engines:
            eng.reset(gen)
        self._fresh()

    @property
    def num_replicas(self) -> int:
        return len(self.engines)

    @property
    def chunked(self) -> bool:
        """Admission-path flag, mirrored from the replicas (engine-surface
        compatibility for callers that report it)."""
        return self.engines[0].chunked

    def healthy(self) -> list[int]:
        """Replica indices currently accepting placements."""
        return [i for i in range(len(self.engines)) if i not in self._draining]

    def replica_of(self, rid: int) -> Optional[int]:
        """The replica currently (or last) responsible for ``rid`` — the
        placement record, updated on migration."""
        return self._rid_replica.get(rid)

    # ---------------------------------------------------------- placement

    def _views(self) -> list[ReplicaView]:
        return [ReplicaView(index=i,
                            outstanding_tokens=self.engines[i]
                            .outstanding_tokens(),
                            free_frac=self.engines[i]
                            .arena_stats()["free_frac"],
                            queued=len(self.engines[i].queued_requests()))
                for i in self.healthy()]

    def _try_place(self, req: Union[ServeRequest, Request]) -> Optional[int]:
        """Session affinity first (a mapped session bypasses placement while
        its replica is healthy), then the placement policy over the healthy
        replicas; a session's first request records the mapping. Returns
        None when the request must PARK: zero healthy replicas, or — for
        deadline-free batch-class work — every healthy replica saturated
        (free fraction under the low watermark AND a non-empty admission
        queue on all of them: admitting more batch work would only deepen
        the churn the latency classes are fighting)."""
        views = self._views()
        if not views:
            return None
        sid = req.session_id
        if sid is not None:
            pinned = self._sessions.get(sid)
            if pinned is not None and pinned not in self._draining:
                return pinned
        slo = slo_of(req) if isinstance(req, Request) else req.slo
        if (slo is not None and slo.priority <= 0
                and all(v.free_frac < self.serving.low_watermark
                        and v.queued > 0 for v in views)):
            return None
        target = self.placement.select(views, req)
        if sid is not None:
            self._sessions[sid] = target
        return target

    def _park_deadlines(self, req) -> tuple[float, float]:
        """Absolute (ttft, total) deadlines for a parked request, on the
        router's monitor clock (same derivation as the engine's)."""
        sp = req.sampling
        if sp is None:
            sp = SamplingParams(max_tokens=req.max_new_tokens)
        slo = slo_of(req) if isinstance(req, Request) else req.slo
        return derive_deadlines(sp, slo, req.arrival,
                                self.serving.deadline_scale)

    def _record_of(self, req, reason: str) -> dict:
        """Finished-request record for work that never reached an engine
        this epoch (shed arrivals, parked timeouts) — same shape the engine
        writes, with whatever history the snapshot carries."""
        slo = req.slo
        gen = getattr(req, "generated", [])
        steps = getattr(req, "token_steps", [])
        return {
            "tokens": np.asarray(gen, np.int32),
            "session": req.session_id,
            "finish_reason": reason,
            "arrival": req.arrival,
            "admitted_step": getattr(req, "admitted_step", -1),
            "first_token_step": getattr(req, "first_token_step", -1),
            "token_steps": np.asarray(steps, np.int64),
            "done_step": self._mclock,
            "preemptions": getattr(req, "preemptions", 0),
            "escalated": getattr(req, "escalated", False),
            "deescalations": getattr(req, "deescalations", 0),
            "slo": slo.name if slo is not None else "standard",
            "priority": slo.priority if slo is not None else 1,
            "ttft_target": slo.ttft_target if slo is not None else math.inf,
            "itl_target": slo.itl_target if slo is not None else math.inf,
        }

    def _finish_unplaced(self, entry: _Parked, reason: str) -> None:
        req = entry.req
        n = getattr(req, "num_generated", 0)
        self._router_results[req.rid] = self._record_of(req, reason)
        ev = RequestOutput(rid=req.rid, token=-1, index=n, step=self._mclock,
                           finished=True, finish_reason=reason)
        self._outputs.append(ev)
        stream = entry.stream or getattr(req, "stream", None)
        if stream is not None:
            stream(ev)

    def _park(self, req, stream) -> None:
        ttft, dl = self._park_deadlines(req)
        self._backlog.append(_Parked(req, stream, ttft, dl))

    def _flush_backlog(self) -> None:
        """Place parked requests in FIFO order onto recovered/unsaturated
        replicas; the first unplaceable entry stops the flush (arrival order
        is preserved — backpressure is a queue, not a lottery)."""
        while self._backlog:
            entry = self._backlog[0]
            target = self._try_place(entry.req)
            if target is None:
                return
            self._backlog.pop(0)
            self.engines[target].add_request(entry.req, stream=entry.stream)
            self._rid_replica[entry.req.rid] = target

    def _expire_backlog(self) -> None:
        """Parked requests past their deadline (router clock) finish with
        ``timeout`` — counted separately from engine timeouts so the stats
        can tell "waited too long for a replica" from "served too slowly"."""
        now = self._mclock
        blown = [e for e in self._backlog
                 if now >= e.deadline
                 or (getattr(e.req, "first_token_step", -1) < 0
                     and now >= e.ttft_deadline)]
        for entry in blown:
            self._backlog.remove(entry)
            self._backlog_timeouts += 1
            self._finish_unplaced(entry, "timeout")

    # ------------------------------------------------- request-centric API

    def add_request(self, req: Union[ServeRequest, Request], *,
                    stream=None) -> int:
        """Place one request on a replica (session affinity, then the
        placement policy) and submit it there. Request ids are router-global
        — an explicit rid colliding with any live or archived request
        raises; omitted rids auto-assign from the router's counter.

        NEVER raises for lack of capacity: with zero healthy replicas (or
        every replica saturated, for batch-class work) the request parks in
        the router backlog and places on the first recovery — unless the
        backlog is bounded (``ServingCfg.max_backlog``) and full, where
        deadline-free batch-class arrivals are shed with a counted ``shed``
        finish instead."""
        if isinstance(req, ServeRequest) and req.rid is None:
            req = dataclasses.replace(req, rid=self._next_rid)
        rid = req.rid
        if (rid in self._rid_replica or rid in self._archived
                or rid in self._router_results
                or any(e.req.rid == rid for e in self._backlog)):
            raise SchedulerConfigError(
                f"request id {rid} already in use this session "
                "(omit ServeRequest.rid to auto-assign)")
        self._next_rid = max(self._next_rid, rid + 1)
        target = self._try_place(req)
        if target is None:
            slo = req.slo if not isinstance(req, Request) else slo_of(req)
            if (self.serving.max_backlog
                    and len(self._backlog) >= self.serving.max_backlog
                    and slo is not None and slo.priority <= 0):
                self._shed += 1
                self._finish_unplaced(_Parked(req, stream), "shed")
                return rid
            self._park(req, stream)
            return rid
        self.engines[target].add_request(req, stream=stream)
        self._rid_replica[rid] = target
        return rid

    def step(self) -> list[RequestOutput]:
        """One router tick: probe health, flush/expire the parked backlog,
        then every healthy replica with work runs one engine tick (a real
        deployment's replicas tick in parallel — the router tick is the
        wall-clock unit). A replica whose ``step()`` raises ``ReplicaFault``
        (injected, or any wrapped failure) is charged a health failure
        instead of propagating — with ``auto_drain`` it drains through the
        snapshot path once it hits the threshold. Returns the tick's merged
        ``RequestOutput`` events in replica order (also buffered for
        ``pending_outputs``; per-request ``stream`` callbacks fire inline,
        on the owning replica)."""
        start = len(self._outputs)      # everything this tick lands after
        now = self._mclock
        self._mclock += 1
        self.monitor.tick(now)          # may auto-drain / re-admit replicas
        self._flush_backlog()
        self._expire_backlog()
        worked = False
        for i, eng in enumerate(self.engines):
            if i in self._draining or not eng.has_unfinished():
                continue
            worked = True
            try:
                eng.step()
            except ReplicaFault as e:
                self.monitor.note_fault(i, e, now)
                continue
            self._outputs.extend(eng.pending_outputs())
        if worked:
            self._ticks += 1
        return list(self._outputs[start:])

    def has_unfinished(self) -> bool:
        return bool(self._backlog) or any(
            i not in self._draining and eng.has_unfinished()
            for i, eng in enumerate(self.engines))

    def pending_outputs(self) -> list[RequestOutput]:
        """Drain the router-level buffer of everything committed since the
        last drain (``step()`` also returns its tick's events directly)."""
        out, self._outputs = self._outputs, []
        return out

    def results(self) -> dict[int, dict]:
        """Merged finished-request records: drained replicas' archives,
        router-level finishes (shed / parked timeouts), plus every live
        replica's results. rids are router-global, so the merge is
        collision-free."""
        out = dict(self._archived)
        out.update(self._router_results)
        for eng in self.engines:
            out.update(eng.results())
        return out

    # --------------------------------------------------------------- drain

    def drain(self, replica: int, force: bool = False) -> int:
        """Take ``replica`` out of service: stop placements to it, snapshot
        its incomplete requests through ``engine.drain()`` (the recompute-
        preemption replay path), archive its finished results and stats,
        free its arenas (``engine.release()``), and re-queue the snapshot
        onto healthy replicas via the normal placement path — sessions
        pinned to the drained replica are remapped with their requests.
        Returns the number of requests migrated.

        A manual drain (``force=False``) refuses to drain the last healthy
        replica (its work would have nowhere to go) and is permanent: the
        HealthMonitor neither probes nor re-admits it. ``force=True`` (the
        auto-drain path) may drain the LAST replica — snapshots that cannot
        place park in the router backlog and place on recovery."""
        if replica in self._draining:
            return 0
        if not (0 <= replica < len(self.engines)):
            raise SchedulerConfigError(f"no replica {replica}")
        if not force and set(self.healthy()) == {replica}:
            raise SchedulerConfigError(
                "cannot drain the last healthy replica")
        eng = self.engines[replica]
        self._draining.add(replica)
        if not force:
            self._manual_drained.add(replica)
        had_state = eng._st is not None
        if had_state:
            self._outputs.extend(eng.pending_outputs())  # nothing left behind
            self._archived.update(eng.results())
        moved = eng.drain()
        if had_state:
            # snapshot AFTER drain: pages freed, drain preemptions counted
            self._drained_stats[replica] = eng.stats()
        eng.release()
        self._sessions = {s: r for s, r in self._sessions.items()
                          if r != replica}
        for req in moved:
            target = self._try_place(req)
            if target is None:
                self._park(req, None)
                continue
            self.engines[target].add_request(req)
            self._rid_replica[req.rid] = target
        self._migrated += len(moved)
        return len(moved)

    def _auto_drain(self, replica: int) -> None:
        """HealthMonitor-initiated drain: forced (may drain the last
        replica — work parks) and re-admittable (``readmit`` on a
        successful recovery probe)."""
        if replica in self._draining:
            return
        self._auto_drained.add(replica)
        self.drain(replica, force=True)

    def readmit(self, replica: int) -> None:
        """Return a recovered auto-drained replica to service: it rejoins
        placement immediately (the next ``step()`` flushes parked work onto
        it). Its pre-drain counters move to the cumulative stats archive —
        the replica starts a fresh engine session, and the aggregate stats
        keep summing both epochs."""
        if replica not in self._auto_drained:
            return
        self._auto_drained.discard(replica)
        self._draining.discard(replica)
        epoch = self._drained_stats.pop(replica, None)
        if epoch is not None:
            self._stats_archive.append(epoch)

    # ----------------------------------------------------------- rebalance

    def rebalance(self, rid: int, dst: int) -> bool:
        """Migrate ONE request to replica ``dst`` WITHOUT draining its
        current replica: the engine's ``drain_request`` snapshots it (pages
        freed, context = prompt + generated-so-far, pinned SamplingParams)
        and it re-queues on ``dst`` through the same recompute-replay path
        a full drain uses — greedy and seeded streams continue token-exact.
        Works on queued, mid-prefill, and decoding requests alike. Returns
        False when ``rid`` is finished, unknown, or already on ``dst``;
        raises only for an invalid/draining destination."""
        if not (0 <= dst < len(self.engines)):
            raise SchedulerConfigError(f"no replica {dst}")
        if dst in self._draining:
            raise SchedulerConfigError(f"replica {dst} is draining")
        src = self._rid_replica.get(rid)
        if src is None or src == dst:
            return False
        snap = self.engines[src].drain_request(rid)
        if snap is None:
            return False  # already finished on src
        self.engines[dst].add_request(snap)
        self._rid_replica[rid] = dst
        if snap.session_id is not None:
            self._sessions[snap.session_id] = dst
        self._rebalanced += 1
        self._migrated += 1
        return True

    # --------------------------------------------------------------- stats

    _SUM_KEYS = ("generated_tokens", "prefill_tokens", "prefill_chunks",
                 "decode_steps", "arena_bytes_total", "arena_bytes_per_device",
                 "interconnect_bytes", "decode_traffic_bytes",
                 "prefill_write_bytes", "defrags", "preemptions",
                 "escalations", "deescalations", "admitted", "retired",
                 "timeouts", "dense_pages_leaked", "cpq_pages_leaked")
    _REPLICA_KEYS = ("tokens_per_step", "generated_tokens", "decode_steps",
                     "prefill_tokens", "arena_bytes_total",
                     "interconnect_bytes", "defrags", "preemptions",
                     "escalations", "deescalations", "timeouts",
                     "slot_utilization", "dense_arena_utilization", "policy")

    def stats(self) -> dict:
        """One aggregated surface over all replicas plus the per-replica
        breakdown. Counters sum — including archived epochs of replicas
        that were auto-drained and re-admitted; ``tokens_per_step`` is the
        AGGREGATE throughput — total generated tokens against the slowest
        replica's decode clock (replicas tick in parallel, so the busiest
        replica is the wall clock). Draining replicas contribute their
        drain-time snapshot. Health state (per replica and router-wide
        auto-drain/recovery counts), the parked backlog depth, and the
        ``timeouts``/``shed``/``rebalanced`` counters ride along."""
        per_replica = []
        for i, eng in enumerate(self.engines):
            s = self._drained_stats.get(i)
            if s is None:
                # a replica with no serving session yet (or released) has no
                # counters to report — don't build arenas just to read zeros
                s = eng.stats() if eng._st is not None else {}
            rh = self.monitor.replicas[i]
            row = {"replica": i, "draining": i in self._draining,
                   "health": rh.state,
                   "consecutive_failures": rh.consecutive_failures,
                   "probe_failures": rh.probe_failures,
                   "auto_drained": i in self._auto_drained}
            row.update({k: s.get(k) for k in self._REPLICA_KEYS})
            per_replica.append((row, s))
        epochs = [s for _, s in per_replica] + self._stats_archive
        agg: dict = {
            "replicas": len(self.engines),
            "placement": self.placement.name,
            "draining": sorted(self._draining),
            "drains": len(self._draining),
            "migrated_requests": self._migrated,
            "rebalanced": self._rebalanced,
            "shed": self._shed,
            "backlog": len(self._backlog),
            "backlog_timeouts": self._backlog_timeouts,
            "router_ticks": self._ticks,
            **self.monitor.stats(),
        }
        for k in self._SUM_KEYS:
            agg[k] = sum(s.get(k, 0) or 0 for s in epochs)
        agg["timeouts"] += self._backlog_timeouts
        busiest = max((s.get("decode_steps", 0) for _, s in per_replica),
                      default=0)
        agg["decode_steps_max"] = busiest
        agg["tokens_per_step"] = agg["generated_tokens"] / max(busiest, 1)
        agg["interconnect_bytes_per_token"] = (
            agg["interconnect_bytes"] / max(agg["generated_tokens"], 1))
        agg["wall_time_s"] = max((s.get("wall_time_s", 0.0)
                                  for _, s in per_replica), default=0.0)
        agg["tokens_per_s"] = agg["generated_tokens"] / max(
            agg["wall_time_s"], 1e-9)
        agg["per_replica"] = [row for row, _ in per_replica]
        return agg

    # ----------------------------------------------------- batch wrappers

    def serve(self, requests: list[Union[Request, ServeRequest]],
              gen: GenerationConfig = GenerationConfig()):
        """Batch-shaped wrapper, signature-compatible with the engine's:
        resets every replica, places and submits all requests in arrival
        order, ticks to completion. Returns (merged results, aggregate
        stats)."""
        self.reset(gen)
        for r in sorted(requests, key=lambda r: r.arrival):
            self.add_request(r)
        while self.has_unfinished():
            self.step()
        return self.results(), self.stats()

    def generate(self, batch: dict, gen: GenerationConfig = GenerationConfig()):
        """Static-engine-compatible convenience (same contract as
        ``ContinuousServeEngine.generate``), spread over the replicas."""
        prompt = np.asarray(batch["tokens"])
        reqs = [Request(rid=i, prompt=prompt[i],
                        max_new_tokens=gen.max_new_tokens)
                for i in range(prompt.shape[0])]
        results, stats = self.serve(reqs, gen)
        pad = gen.eos_id if gen.eos_id >= 0 else 0
        out = np.full((prompt.shape[0], gen.max_new_tokens), pad, np.int32)
        for i in range(prompt.shape[0]):
            t = results[i]["tokens"]
            out[i, :len(t)] = t[:gen.max_new_tokens]
        return out, stats
