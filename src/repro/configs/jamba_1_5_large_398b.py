"""Jamba-1.5-Large (398B total / 94B active) [arXiv:2403.19887; hf].

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536; Mamba:attention 7:1
(one attention layer per 8-layer block, at position 4), MoE 16e top-2 every
other layer. Hybrid => runs long_500k natively (Mamba state is O(1); the
single KV cache per 8 layers is sequence-sharded).
"""
from repro.configs.base import MambaCfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=(
        ("mamba", "dense"),
        ("mamba", "moe"),
        ("mamba", "dense"),
        ("mamba", "moe"),
        ("attn", "dense"),
        ("mamba", "moe"),
        ("mamba", "dense"),
        ("mamba", "moe"),
    ),
    num_blocks=9,
    mlp_act="swiglu",
    norm="rmsnorm",
    moe=MoECfg(num_experts=16, num_shared=0, top_k=2, d_ff_expert=24576),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
)
