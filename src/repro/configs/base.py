"""Config system.

A ``ModelConfig`` fully determines the parameter tree, sharding, and step
functions. Architectures are expressed as a repeating ``block_pattern`` of
(mixer, mlp) layer kinds (plus an optional unrolled ``prefix_pattern``) so the
decoder stack can be lowered as a ``lax.scan`` over stacked blocks — this
keeps the HLO (and compile time / remat behaviour) independent of depth.

The paper's techniques are runtime-selectable through ``AttentionRuntime``:
  mode = dense | decomposed (T1 X-cache) | cpq (T2) | retrieval (T3)
MLA layers (deepseek-v2-lite) always use the absorbed/decomposed path — see
DESIGN.md for why MLA *is* an instance of the paper's decomposition.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp

# ---------------------------------------------------------------- sub-configs


@dataclass(frozen=True)
class MoECfg:
    num_experts: int = 64
    num_shared: int = 2
    top_k: int = 6
    d_ff_expert: int = 1408
    capacity_factor: float = 1.25
    router_jitter: float = 0.0


@dataclass(frozen=True)
class MLACfg:
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    q_lora_rank: int = 0  # 0 => direct q projection (V2-Lite)


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => d_model // 16


@dataclass(frozen=True)
class XLSTMCfg:
    proj_factor: float = 2.0
    conv_kernel: int = 4
    chunk: int = 256  # chunkwise-parallel block for mLSTM training


@dataclass(frozen=True)
class CPQCfg:
    """T2: cascade pruning-quantization of the KV / X cache."""

    prune_ratio: float = 0.4      # fraction of elements zeroed (per channel, magnitude)
    bits: int = 4                 # quantized code width (4 or 8)
    max_levels: int = 4           # HQE: max hierarchical extension levels
    tolerance: float = 1.0        # TR multiplier: token spawns new level if |x| > tol * range
    residual_window: int = 32     # most-recent tokens kept in full precision


@dataclass(frozen=True)
class RetrievalCfg:
    """T3: attention as nearest-neighbor retrieval."""

    top_k: int = 512              # exact re-score candidates per query
    proxy_bits: int = 8           # proxy similarity precision (CAM analogue)
    proxy_dim: int = 0            # 0 => full d_head at low precision; else low-rank proxy
    recent_window: int = 64       # always-attended recent tokens (dense tail)


@dataclass(frozen=True)
class AttentionRuntime:
    # dense | decomposed (T1) | cpq (T2) | retrieval (T3)
    # | decomposed_cpq (T1+T2: CPQ-compressed X cache)
    mode: str = "dense"
    cpq: Optional[CPQCfg] = None
    retrieval: Optional[RetrievalCfg] = None
    # paged serving decode: fuse the block-table gather into the Pallas
    # kernels (dense/CPQ/X-MLA tiers) instead of materializing logical views.
    # False falls back to the jnp gather path (oracle / benchmark foil).
    paged_kernels: bool = True
    # serving device mesh (jax.sharding.Mesh with a "model" axis, or None =
    # single device). When set, decode_attend_paged / chunk_attend_paged
    # route the supported tiers through shard_map over the kv-head axis so
    # each device sweeps only its local head shard of the paged arena
    # (serving/sharded.py); None keeps today's single-device path untouched.
    mesh: Optional[object] = None

    def __post_init__(self):
        assert self.mode in ("dense", "decomposed", "cpq", "retrieval",
                             "decomposed_cpq"), self.mode
        if self.mode in ("cpq", "decomposed_cpq") and self.cpq is None:
            object.__setattr__(self, "cpq", CPQCfg())
        if self.mode == "retrieval" and self.retrieval is None:
            object.__setattr__(self, "retrieval", RetrievalCfg())


@dataclass(frozen=True)
class ServingCfg:
    """Continuous-batching serving layer (serving/scheduler.py + engine.py).

    The physical arena is ``num_pages`` pages of ``page_size`` tokens per
    attention layer (page 0 reserved as the null page); each request slot may
    map at most ``max_blocks_per_slot`` logical pages (its context ceiling).
    Watermarks are FREE-page fractions of the base arena: below ``low`` new
    admissions are assigned the compressed tier, below ``critical`` the
    longest running dense request is escalated in place (dense -> T2; pages
    freed back to the dense pool). Escalation needs ``enable_escalation`` and
    a base mode of "dense"."""

    num_slots: int = 4
    page_size: int = 16
    num_pages: int = 129           # incl. the reserved null page 0
    max_blocks_per_slot: int = 16
    escalated_pages: int = 65      # CPQ arena pages (tiered engines only)
    low_watermark: float = 0.25
    critical_watermark: float = 0.10
    # recovery threshold: when the dense free fraction climbs back ABOVE
    # this, policies with de-escalation enabled restore escalated (T2) rows
    # to the dense tier via chunked re-admission (hysteresis: must be >= low;
    # the 1.0 default can never be exceeded, so recovery is opt-in)
    high_watermark: float = 1.0
    enable_escalation: bool = False
    # admission/preemption/escalation decision policy (serving/policies.py):
    # fifo (default; decision-identical to the pre-policy scheduler) |
    # priority (strict SloClass levels + aging) | slo (TTFT-slack EDF
    # admission + de-escalation). An engine ``policy=`` object overrides it.
    policy: str = "fifo"
    prefill_bucket: int = 16       # prompts padded up to a multiple of this
    # chunked paged prefill (the DEFAULT admission path): prompts stream into
    # their slot's arena pages in page-aligned chunks of this many tokens,
    # at most one chunk interleaved per decode step — no contiguous scratch
    # prefill cache, no monolithic admission stall. 0 restores the one-shot
    # B=1 prefill + pack path (the construction-exact admission oracle).
    prefill_chunk: int = 16
    # fused paged-attention decode kernels: None defers to the engine's
    # AttentionRuntime.paged_kernels (default on); True/False overrides it
    use_paged_kernels: Optional[bool] = None
    # prefix sharing + copy-on-write pages: admission mounts a request's
    # longest indexed page-aligned prefix as refcount bumps on already-
    # resident pages (zero arena writes) and chunked prefill streams only
    # the unshared tail; a write into a still-shared page splits it first.
    # Token-exact (greedy and seeded sampling outputs are bit-identical to
    # sharing off); active only for chunked admissions in the dense / T1 /
    # MLA / tiered modes — CPQ and retrieval pages read through per-slot
    # side state and never share.
    share_prefix: bool = False
    # base-arena compaction: every N retirements the engine applies the
    # scheduler's defrag plan (mapped pages relabel onto the lowest physical
    # ids — locality for the fused kernels' sequential page reads). 0 = off.
    # Logical contents are invariant (property-tested, incl. sharded arenas);
    # the count surfaces as the ``defrags`` serve stat.
    defrag_every: int = 0
    # ---- fault tolerance (serving/health.py + router) -------------------
    # health-probe cadence in router ticks (HealthMonitor; 0 disables
    # probing entirely — the router then only reacts to step() faults)
    probe_interval: int = 4
    # consecutive failed probes (liveness / progress / arena pressure)
    # before the monitor auto-drains a replica
    probe_failures: int = 3
    # initial re-probe backoff (router ticks) after an auto-drain; doubles
    # per failed recovery probe up to 8x (bounded so a recovered replica
    # re-admits within a handful of probes)
    probe_backoff: int = 4
    # dense free-page fraction at/below which a replica WITH queued work
    # counts as arena-exhausted for probing purposes (negative disables the
    # pressure check; injected exhaust faults also set an explicit flag)
    probe_exhaust_frac: float = 0.0
    # auto-drain: let the HealthMonitor drain an unhealthy replica through
    # the normal engine.drain() snapshot path (and re-admit it after
    # recovery probes succeed). Off by default: drains are caller-driven
    # exactly as before unless opted in.
    auto_drain: bool = False
    # deadline-aware load shedding: scale applied to SloClass-derived
    # per-request budgets (deadline = arrival + scale * (ttft_target +
    # max_tokens * itl_target), enforced at tick boundaries with a counted
    # ``timeout`` finish reason). 0 = deadlines off; explicit
    # SamplingParams.deadline budgets are honored regardless.
    deadline_scale: float = 0.0
    # router-level admission backpressure: parked-request backlog capacity.
    # When every replica is draining or saturated, new work PARKS in the
    # router backlog instead of raising; beyond this many parked requests,
    # deadline-free batch-class arrivals are SHED (counted, never raised).
    # 0 = unbounded parking, never shed.
    max_backlog: int = 0
    # ---- speculative decoding (serving/speculative.py) ------------------
    # n-gram / prompt-lookup speculative decoding: propose up to this many
    # draft tokens per running row from its own context (no second model),
    # land them in refcount-aliased scratch pages, and score all of them in
    # ONE Q-chunk>1 paged attend — amortizing a full weight stream over
    # spec_len candidates where decode is weight-stream-bound (low
    # occupancy). 0 = off. Accepted tokens are ALWAYS re-drawn through the
    # per-request fold_in(seed, token_index) sampler, so speculative
    # on-vs-off is bit-exact for greedy rows and replay-stable for seeded
    # ones. Active only for chunked engines in dense/T1/MLA/tiered modes
    # (same gate as share_prefix).
    spec_len: int = 0
    # longest suffix n-gram matched against the row's earlier context when
    # drafting (falls back to shorter n-grams down to 1; no match = normal
    # single-token decode for that row this tick)
    spec_ngram: int = 3

    def __post_init__(self):
        self.validate(strict=False)

    def validate(self, strict: bool = True) -> "ServingCfg":
        """Raise ``ValueError`` (with the knob names spelled out) for
        inconsistent configurations, instead of letting them fail deep in
        the scheduler or silently gate features off.

        ``strict=False`` checks only the hard construction invariants
        (ranges, page alignment of the prefill chunk, watermark ordering) —
        this is what ``__post_init__`` runs, so an invalid combination can
        never be constructed. ``strict=True`` (the default; called at
        ``ContinuousServeEngine`` construction and by the auto-tuner after
        ``validate_and_repair``) additionally rejects config-level
        cross-knob inconsistencies: knobs that REQUEST a feature the rest of
        the config gates off (speculative decoding without chunked
        admission) and capacity settings no request could ever run under.
        Returns ``self`` so call sites can chain it."""

        def bad(msg: str):
            raise ValueError(f"ServingCfg: {msg}")

        if not (self.num_pages >= 2 and self.escalated_pages >= 2):
            bad(f"num_pages={self.num_pages} and escalated_pages="
                f"{self.escalated_pages} must each be >= 2 (page 0 is the "
                "reserved null page)")
        if not (self.page_size >= 1 and self.num_slots >= 1
                and self.max_blocks_per_slot >= 1):
            bad(f"page_size={self.page_size}, num_slots={self.num_slots}, "
                f"max_blocks_per_slot={self.max_blocks_per_slot} must all "
                "be >= 1")
        if not 0.0 <= self.critical_watermark <= self.low_watermark <= 1.0:
            bad(f"watermarks must satisfy 0 <= critical_watermark "
                f"({self.critical_watermark}) <= low_watermark "
                f"({self.low_watermark}) <= 1")
        if not self.low_watermark <= self.high_watermark <= 1.0:
            bad(f"high_watermark ({self.high_watermark}) must lie in "
                f"[low_watermark ({self.low_watermark}), 1] — it is the "
                "de-escalation hysteresis threshold above low")
        if self.policy not in ("fifo", "priority", "slo"):
            bad(f"policy={self.policy!r} not one of fifo|priority|slo")
        if self.prefill_bucket < 1:
            bad(f"prefill_bucket={self.prefill_bucket} must be >= 1")
        if self.prefill_chunk < 0:
            bad(f"prefill_chunk={self.prefill_chunk} must be >= 0 "
                "(0 = one-shot admission)")
        if self.defrag_every < 0:
            bad(f"defrag_every={self.defrag_every} must be >= 0 (0 = off)")
        if self.probe_interval < 0:
            bad(f"probe_interval={self.probe_interval} must be >= 0")
        if self.probe_failures < 1 or self.probe_backoff < 1:
            bad(f"probe_failures={self.probe_failures} and probe_backoff="
                f"{self.probe_backoff} must be >= 1")
        if self.probe_exhaust_frac > 1.0:
            bad(f"probe_exhaust_frac={self.probe_exhaust_frac} must be "
                "<= 1.0 (negative disables the pressure check)")
        if self.deadline_scale < 0.0:
            bad(f"deadline_scale={self.deadline_scale} must be >= 0 "
                "(0 = deadlines off)")
        if self.max_backlog < 0:
            bad(f"max_backlog={self.max_backlog} must be >= 0 "
                "(0 = unbounded parking)")
        if self.spec_len < 0:
            bad(f"spec_len={self.spec_len} must be >= 0 (0 = off)")
        if self.spec_ngram < 1:
            bad(f"spec_ngram={self.spec_ngram} must be >= 1")
        if self.prefill_chunk and self.prefill_chunk % self.page_size != 0:
            bad("prefill_chunk must be page-aligned (chunks stream whole "
                f"arena pages): prefill_chunk={self.prefill_chunk} % "
                f"page_size={self.page_size} != 0")
        if not strict:
            return self
        # ---- strict: cross-knob consistency (engine-construction checks) --
        if self.spec_len > 0 and self.prefill_chunk == 0:
            bad(f"spec_len={self.spec_len} requires chunked admission "
                "(prefill_chunk > 0): the verify pass IS a spec_len+1 wide "
                "prefill chunk. Set prefill_chunk to a page-aligned value "
                "or spec_len=0")
        if self.max_len < 2:
            bad(f"max_len = page_size*max_blocks_per_slot = {self.max_len} "
                "< 2: no request could hold a prompt token plus one "
                "generated token")
        return self

    @property
    def max_len(self) -> int:
        """Per-request logical context ceiling (tokens)."""
        return self.page_size * self.max_blocks_per_slot

    @classmethod
    def preset_path(cls) -> str:
        """Packaged presets file written by ``launch/tune.py`` (the
        materialized Pareto frontier of the serving auto-tuner)."""
        import os
        return os.path.join(os.path.dirname(__file__), "serving_presets.json")

    @classmethod
    def list_presets(cls, path: Optional[str] = None) -> list[str]:
        import json
        with open(path or cls.preset_path()) as f:
            return sorted(json.load(f)["presets"])

    @classmethod
    def from_preset(cls, name: str, path: Optional[str] = None,
                    **overrides) -> "ServingCfg":
        """Load a named operating point from the tuner-materialized presets
        file (``latency`` / ``throughput`` / ``energy`` / ``default``, see
        ``docs/tuning.md``). ``overrides`` replace preset fields — the serve
        CLI uses this to re-derive arena capacity for its own context
        ceiling while keeping the tuned knobs."""
        import json
        with open(path or cls.preset_path()) as f:
            data = json.load(f)
        if name not in data["presets"]:
            raise ValueError(
                f"unknown serving preset {name!r}; available: "
                f"{sorted(data['presets'])}")
        kwargs = dict(data["presets"][name]["serving"])
        kwargs.update(overrides)
        return cls(**kwargs).validate()


# ------------------------------------------------------------------- model


MIXERS = ("attn", "xattn", "mla", "mamba", "mlstm", "slstm")
MLPS = ("dense", "moe", "none")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | ssm | hybrid
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # layer layout: prefix (unrolled) + num_blocks x block_pattern (scanned)
    block_pattern: tuple[tuple[str, str], ...]
    num_blocks: int
    prefix_pattern: tuple[tuple[str, str], ...] = ()
    # flavor knobs
    mlp_act: str = "swiglu"  # swiglu | geglu | gelu
    norm: str = "rmsnorm"    # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    pos_embedding: str = "rope"  # rope | absolute | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    logit_softcap: float = 0.0
    input_kind: str = "tokens"  # tokens | audio_frames | text+patches
    num_patch_tokens: int = 0   # vlm: visual tokens per sample (stub frontend)
    # sub-configs
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    attention: AttentionRuntime = AttentionRuntime()
    dtype: str = "bfloat16"

    def __post_init__(self):
        for mixer, mlp in self.prefix_pattern + self.block_pattern:
            assert mixer in MIXERS, mixer
            assert mlp in MLPS, mlp

    @property
    def num_layers(self) -> int:
        return len(self.prefix_pattern) + self.num_blocks * len(self.block_pattern)

    @property
    def layer_kinds(self) -> tuple[tuple[str, str], ...]:
        return self.prefix_pattern + self.block_pattern * self.num_blocks

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_mha(self) -> bool:
        return self.num_kv_heads == self.num_heads

    @property
    def attention_free(self) -> bool:
        return not any(m in ("attn", "xattn", "mla") for m, _ in self.layer_kinds)

    @property
    def sub_quadratic(self) -> bool:
        """True if long contexts are tractable without dense attention
        (SSM/hybrid family, or T3 retrieval attention enabled)."""
        fams = self.family in ("ssm", "hybrid")
        return fams or self.attention.mode == "retrieval"

    def with_attention(self, mode: str, **kw) -> "ModelConfig":
        return dataclasses.replace(self, attention=AttentionRuntime(mode=mode, **kw))


# ------------------------------------------------------------------- shapes


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    def __post_init__(self):
        assert self.kind in ("train", "prefill", "decode")


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeCfg) -> tuple[bool, str]:
    """Whether (arch x shape) is a defined cell, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (enable attention.mode='retrieval' "
            "— the paper's T3 — to run this cell)"
        )
    return True, ""
