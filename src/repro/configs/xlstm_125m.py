"""xLSTM-125M [arXiv:2405.04517; unverified].

12L d_model=768 4H, sLSTM + mLSTM blocks (no separate FFN: d_ff=0 — the
blocks carry their own up/down projections with proj_factor=2).
Attention-free: the paper's KV-cache techniques are inapplicable (DESIGN.md
§5); the mLSTM matrix memory is itself the associative-memory view of §V.
Runs long_500k natively (O(1) recurrent state).
"""
from repro.configs.base import ModelConfig, XLSTMCfg

# ratio ~5:1 mLSTM:sLSTM; 12 layers = 2 blocks of [m m m m m s]
CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    num_heads=4,
    num_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(
        ("mlstm", "none"),
        ("mlstm", "none"),
        ("mlstm", "none"),
        ("mlstm", "none"),
        ("mlstm", "none"),
        ("slstm", "none"),
    ),
    num_blocks=2,
    norm="layernorm",
    pos_embedding="none",
    xlstm=XLSTMCfg(proj_factor=2.0, conv_kernel=4, chunk=256),
)
