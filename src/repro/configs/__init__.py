"""Architecture registry + smoke-config reduction.

``get_config(arch_id)`` returns the full published config; ``smoke_config``
shrinks any config to a CPU-runnable variant of the same family (same layer
pattern / mixer kinds / MoE topology, tiny widths) for the per-arch smoke
tests. The FULL configs are only exercised via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    SHAPES,
    AttentionRuntime,
    CPQCfg,
    MLACfg,
    MambaCfg,
    ModelConfig,
    MoECfg,
    RetrievalCfg,
    ServingCfg,
    ShapeCfg,
    XLSTMCfg,
    cell_supported,
)

from repro.configs import (  # noqa: E402
    deepseek_v2_lite_16b,
    deepseek_moe_16b,
    llama_3_2_vision_11b,
    musicgen_large,
    xlstm_125m,
    qwen1_5_0_5b,
    gemma_2b,
    phi4_mini_3_8b,
    qwen3_4b,
    jamba_1_5_large_398b,
    opt_6_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        deepseek_v2_lite_16b,
        deepseek_moe_16b,
        llama_3_2_vision_11b,
        musicgen_large,
        xlstm_125m,
        qwen1_5_0_5b,
        gemma_2b,
        phi4_mini_3_8b,
        qwen3_4b,
        jamba_1_5_large_398b,
        opt_6_7b,  # paper's eval model (not part of the 10-arch assignment)
    )
}

ASSIGNED = tuple(n for n in ARCHS if n != "opt-6.7b")


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config: tiny widths, 1 block, small vocab."""
    kw: dict = dict(
        name=cfg.name + "-smoke",
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads > 1 else 1,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 96,
        vocab_size=256,
        num_blocks=1,
        num_patch_tokens=16 if cfg.num_patch_tokens else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = MoECfg(
            num_experts=8,
            num_shared=min(cfg.moe.num_shared, 1),
            top_k=2,
            d_ff_expert=32,
            capacity_factor=2.0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLACfg(kv_lora_rank=32, qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16)
    if cfg.mamba is not None:
        kw["mamba"] = MambaCfg(d_state=8, d_conv=4, expand=2)
    if cfg.xlstm is not None:
        kw["xlstm"] = XLSTMCfg(proj_factor=2.0, conv_kernel=4, chunk=16)
    return dataclasses.replace(cfg, **kw)


__all__ = [
    "ARCHS",
    "ASSIGNED",
    "SHAPES",
    "AttentionRuntime",
    "CPQCfg",
    "MLACfg",
    "MambaCfg",
    "ModelConfig",
    "MoECfg",
    "RetrievalCfg",
    "ServingCfg",
    "ShapeCfg",
    "XLSTMCfg",
    "cell_supported",
    "get_config",
    "smoke_config",
]
