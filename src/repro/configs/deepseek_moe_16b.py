"""DeepSeekMoE-16B [arXiv:2401.06066; hf].

28L d_model=2048 16H (MHA: kv=16), fine-grained MoE: 2 shared + 64 routed
top-6, expert d_ff=1408, first layer dense (d_ff=10944), vocab=102400.
MHA (kv == heads) makes this a strong T1 X-cache arch: caching X halves
decode cache traffic vs K+V.
"""
from repro.configs.base import ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=10944,
    vocab_size=102400,
    prefix_pattern=(("attn", "dense"),),
    block_pattern=(("attn", "moe"),),
    num_blocks=27,
    mlp_act="swiglu",
    norm="rmsnorm",
    moe=MoECfg(num_experts=64, num_shared=2, top_k=6, d_ff_expert=1408),
)
