"""MusicGen-large decoder backbone [arXiv:2306.05284; hf].

48L d_model=2048 32H (MHA: kv=32) d_ff=8192 vocab=2048 (EnCodec codes).
The EnCodec frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, S, d_model). MHA makes this the best T1 arch (2x decode cache
traffic reduction) — it is the paper-representative hillclimb cell.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    block_pattern=(("attn", "dense"),),
    num_blocks=48,
    mlp_act="gelu",
    norm="layernorm",
    pos_embedding="absolute",
    input_kind="audio_frames",
)
