"""DeepSeek-V2-Lite (15.7B total / 2.4B active) [arXiv:2405.04434; hf].

27L d_model=2048 16H, MLA (kv_lora=512, nope=128, rope=64, v=128),
MoE: 2 shared + 64 routed top-6, expert d_ff=1408; first layer dense MLP.
Note: the assignment header lists both "MoE 64e top-6" and "160 routed"; 160
is the DeepSeek-V2 (236B) value — V2-Lite uses 64 routed, which we follow.
MLA is implemented with the absorbed decode path, which is exactly the
paper's T1 matrix decomposition applied to a learned 512-d latent cache.
"""
from repro.configs.base import MLACfg, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,  # v head dim; qk dims come from MLACfg
    d_ff=10944,    # dense first layer (V2-Lite value)
    vocab_size=102400,
    prefix_pattern=(("mla", "dense"),),
    block_pattern=(("mla", "moe"),),
    num_blocks=26,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=10000.0,
    moe=MoECfg(num_experts=64, num_shared=2, top_k=6, d_ff_expert=1408),
    mla=MLACfg(kv_lora_rank=512, qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128),
)
