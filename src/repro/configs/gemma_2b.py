"""Gemma-2B [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA: kv=1) d_ff=16384 GeGLU, head_dim=256, vocab=256000,
embeddings scaled by sqrt(d_model), tied LM head.
MQA: K+V cache (2*256 per token) is already 4x smaller than X (2048), so the
T1 X-cache is a regression here — supported but off (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    block_pattern=(("attn", "dense"),),
    num_blocks=18,
    mlp_act="geglu",
    norm="rmsnorm",
    tie_embeddings=True,
    embed_scale=True,
)
