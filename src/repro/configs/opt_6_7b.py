"""OPT-6.7B [arXiv:2205.01068] — the paper's end-to-end evaluation model.

32L d_model=4096 32H (MHA) d_ff=16384 GELU LayerNorm vocab=50272.
Used by benchmarks/bench_e2e_energy.py to reproduce the 159.9x / 34.8x
energy-efficiency comparison methodology.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="opt-6.7b",
    family="dense",
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=16384,
    vocab_size=50272,
    block_pattern=(("attn", "dense"),),
    num_blocks=32,
    mlp_act="gelu",
    norm="layernorm",
    pos_embedding="absolute",
)
