"""Phi-4-mini-3.8B [arXiv:2412.08905].

32L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=200064, RoPE + SwiGLU.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    block_pattern=(("attn", "dense"),),
    num_blocks=32,
    mlp_act="swiglu",
    norm="rmsnorm",
)
