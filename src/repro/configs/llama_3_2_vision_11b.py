"""Llama-3.2-11B-Vision backbone [hf:meta-llama/Llama-3.2-11B-Vision; unverified].

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256; every 5th layer is a
gated cross-attention layer over precomputed patch embeddings (the vision
frontend is a STUB per the assignment: ``input_specs`` provides patch
embeddings already projected to d_model).
Cross-attention K/V are static per request (computed once at prefill) — no
CWC issue, so T1 applies only to self-attn layers; with GQA kv=8 the X-cache
is larger than K+V, so T1 is off by default (see DESIGN.md §5).
"""
from repro.configs.base import ModelConfig

# 40 layers, cross-attn at indices 3, 8, 13, ... => block of 5 with xattn at pos 3
CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    block_pattern=(
        ("attn", "dense"),
        ("attn", "dense"),
        ("attn", "dense"),
        ("xattn", "dense"),
        ("attn", "dense"),
    ),
    num_blocks=8,
    mlp_act="swiglu",
    norm="rmsnorm",
    rope_theta=500000.0,
    input_kind="text+patches",
    num_patch_tokens=1600,
)
