"""Typed knob space over ``ServingCfg`` for the serving auto-tuner.

A *genome* is a plain dict of knob name -> value, drawn from per-knob
categorical choice sets (every knob the engine exposes behaves like an
operating-point selector, so categorical choices keep mutation/crossover
trivially deterministic and the evaluation memo exact). Capacity fields
(``num_pages`` / ``max_blocks_per_slot`` / ``escalated_pages``) are NOT
genes: they are derived from a FIXED token budget (the hand-tuned baseline
arena, ``equal_arena_serving(budget_slots, max_len, budget_page)``), so the
search cannot win throughput by simply provisioning more memory — every
genome serves the trace from the same arena bytes, and ``num_slots`` trades
parallelism against oversubscription/preemption instead.

``validate_and_repair`` maps ANY dict into the space: unknown knobs are
dropped, missing knobs filled from the hand-tuned default, off-choice values
snapped to the nearest choice, and cross-knob constraints (watermark
ordering) repaired — never raised. The repaired genome always materializes
into a ``ServingCfg`` that passes ``ServingCfg.validate()``: prefill chunks
are page-aligned BY CONSTRUCTION (the gene is ``chunk_pages``, the chunk
length in pages, so ``prefill_chunk = chunk_pages * page_size`` can never
misalign), and speculation is always paired with chunked admission
(``chunk_pages >= 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs import ServingCfg
from repro.serving.paged_cache import pages_needed


@dataclasses.dataclass(frozen=True)
class Knob:
    name: str
    choices: tuple

    def snap(self, value):
        """Nearest in-space choice (numeric by distance; everything else
        falls back to exact membership, else the first choice)."""
        if value in self.choices and not isinstance(value, bool):
            return value
        if isinstance(value, bool):
            return value if value in self.choices else self.choices[0]
        if isinstance(value, (int, float)) and all(
                isinstance(c, (int, float)) for c in self.choices):
            return min(self.choices, key=lambda c: (abs(c - value), c))
        return self.choices[0]


DEFAULT_KNOBS: tuple[Knob, ...] = (
    Knob("num_slots", (2, 4, 6, 8)),
    Knob("page_size", (4, 8, 16)),
    # prefill chunk length IN PAGES: prefill_chunk = chunk_pages * page_size
    # is page-aligned by construction (the repair the ISSUE names)
    Knob("chunk_pages", (1, 2, 4)),
    Knob("policy", ("fifo", "priority", "slo")),
    Knob("low_watermark", (0.1, 0.25, 0.4)),
    Knob("critical_watermark", (0.02, 0.05, 0.1, 0.25)),
    Knob("high_watermark", (0.6, 0.8, 1.0)),
    Knob("enable_escalation", (False, True)),
    Knob("spec_len", (0, 2, 4)),
    Knob("spec_ngram", (2, 3)),
    Knob("defrag_every", (0, 4, 16)),
)

# the hand-tuned baseline every benchmark uses: equal_arena_serving(4, L, 8)
DEFAULT_GENOME = {
    "num_slots": 4, "page_size": 8, "chunk_pages": 2, "policy": "fifo",
    "low_watermark": 0.25, "critical_watermark": 0.1, "high_watermark": 1.0,
    "enable_escalation": False, "spec_len": 0, "spec_ngram": 3,
    "defrag_every": 0,
}


@dataclasses.dataclass(frozen=True)
class KnobSpace:
    """Search space bound to a trace's context ceiling ``max_len`` and the
    baseline arena budget (``budget_slots`` rows of ``budget_page`` pages —
    the equal-arena-bytes contract all genomes share)."""

    max_len: int
    knobs: tuple[Knob, ...] = DEFAULT_KNOBS
    budget_slots: int = 4
    budget_page: int = 8

    def __post_init__(self):
        names = [k.name for k in self.knobs]
        assert len(names) == len(set(names)), "duplicate knob names"
        for k in self.knobs:
            assert k.choices, f"knob {k.name} has no choices"

    @property
    def budget_tokens(self) -> int:
        """Fixed arena token capacity (excl. the null page) every genome
        materializes under — the hand-tuned baseline's provisioning."""
        return (self.budget_slots
                * pages_needed(self.max_len, self.budget_page)
                * self.budget_page)

    def knob(self, name: str) -> Knob:
        for k in self.knobs:
            if k.name == name:
                return k
        raise KeyError(name)

    def default_genome(self) -> dict:
        return self.validate_and_repair(dict(DEFAULT_GENOME))

    # ------------------------------------------------------------ operators

    def sample(self, rng: np.random.Generator) -> dict:
        g = {k.name: k.choices[int(rng.integers(len(k.choices)))]
             for k in self.knobs}
        return self.validate_and_repair(g)

    def mutate(self, genome: dict, rng: np.random.Generator,
               p: float = 0.35) -> dict:
        """Each knob reassigns (to a DIFFERENT choice) with probability
        ``p``; if no knob fired, one random knob is forced — a mutation
        always moves."""
        g = dict(genome)
        moved = False
        for k in self.knobs:
            if len(k.choices) > 1 and rng.random() < p:
                alts = [c for c in k.choices if c != g.get(k.name)]
                g[k.name] = alts[int(rng.integers(len(alts)))]
                moved = True
        if not moved:
            movable = [k for k in self.knobs if len(k.choices) > 1]
            k = movable[int(rng.integers(len(movable)))]
            alts = [c for c in k.choices if c != g.get(k.name)]
            g[k.name] = alts[int(rng.integers(len(alts)))]
        return self.validate_and_repair(g)

    def crossover(self, a: dict, b: dict, rng: np.random.Generator) -> dict:
        g = {k.name: (a if rng.random() < 0.5 else b)[k.name]
             for k in self.knobs}
        return self.validate_and_repair(g)

    # ------------------------------------------------------ repair + encode

    def validate_and_repair(self, genome: dict) -> dict:
        """Any dict -> an in-space genome: fill from the default, snap to
        choices, repair watermark ordering (critical <= low <= high).
        Invalid combinations are repaired, never raised."""
        g = {}
        for k in self.knobs:
            v = genome.get(k.name, DEFAULT_GENOME.get(k.name, k.choices[0]))
            g[k.name] = k.snap(v)
        names = {k.name for k in self.knobs}
        # watermark ordering repair only applies when a restricted space
        # actually searches those knobs (un-searched ones fall back to
        # ServingCfg defaults, which are already ordered)
        if "low_watermark" in names:
            low = g["low_watermark"]
            if "critical_watermark" in names and \
                    g["critical_watermark"] > low:
                crit = [c for c in self.knob("critical_watermark").choices
                        if c <= low]
                g["critical_watermark"] = max(crit) if crit else low
            if "high_watermark" in names and g["high_watermark"] < low:
                high = [c for c in self.knob("high_watermark").choices
                        if c >= low]
                g["high_watermark"] = min(high) if high else 1.0
        return g

    def genome_key(self, genome: dict) -> tuple:
        """Canonical hashable identity (knob order pinned by the space) —
        the evaluation-memo / checkpoint key."""
        return tuple((k.name, genome[k.name]) for k in self.knobs)

    def to_serving(self, genome: dict) -> ServingCfg:
        """Materialize a genome into a ``ServingCfg`` under the fixed arena
        budget. The result always passes ``ServingCfg.validate()``."""
        g = dict(DEFAULT_GENOME)  # un-searched knobs of a restricted space
        g.update(self.validate_and_repair(genome))
        ps = g["page_size"]
        max_blocks = pages_needed(self.max_len, ps)
        # same token capacity for every page size (+1 reserved null page);
        # at least one full-length row must fit
        num_pages = max(self.budget_tokens // ps, max_blocks) + 1
        chunk = g["chunk_pages"] * ps
        return ServingCfg(
            num_slots=g["num_slots"],
            page_size=ps,
            num_pages=num_pages,
            max_blocks_per_slot=max_blocks,
            # tiered genomes spill to a half-budget CPQ arena (compressed
            # pages are ~4x cheaper per token, so this stays within spirit
            # of the equal-bytes contract; non-tiered genomes never allocate it)
            escalated_pages=max(2, self.budget_tokens // (2 * ps) + 1),
            low_watermark=g["low_watermark"],
            critical_watermark=g["critical_watermark"],
            high_watermark=g["high_watermark"],
            enable_escalation=g["enable_escalation"],
            policy=g["policy"],
            prefill_bucket=chunk,
            prefill_chunk=chunk,
            defrag_every=g["defrag_every"],
            spec_len=g["spec_len"],
            spec_ngram=g["spec_ngram"],
        ).validate()


def space_for_trace(work, *, knobs: Optional[tuple[Knob, ...]] = None
                    ) -> KnobSpace:
    """KnobSpace whose context ceiling covers every request in ``work``
    (prompt + target tokens), budgeted to the hand-tuned baseline arena."""
    max_len = max(len(w.prompt) + w.target for w in work)
    if knobs is None:
        return KnobSpace(max_len=max_len)
    return KnobSpace(max_len=max_len, knobs=knobs)
