"""Objective evaluation for the serving auto-tuner.

One evaluation = materialize the genome into a ``ServingCfg``
(``KnobSpace.to_serving``), serve the FIXED seeded trace through the real
``ContinuousServeEngine`` (``repro.serving.trace.run_trace``), and reduce
the run to a minimized objective vector:

  0. throughput:  -tokens/step (useful generated tokens per engine tick)
  1. latency:     p95 TTFT of the interactive SLO class, engine ticks
                  (overall p95 TTFT when the trace carries no classes)
  2. energy:      mJ/token from the ``bench_e2e_energy`` measured-
                  utilization device model — the paper-scale model
                  (OPT-6.7B on TPU v5e constants) charged at THIS run's
                  measured utilization and page-table traffic

Determinism: the trace is fixed and seeded, decoding is greedy, and every
objective lives on the engine's tick clock (never wall time), so the same
genome always maps to the same objective vector — which is what makes the
search memoizable, checkpoint-resumable, and bit-reproducible.

The energy axis follows ``bench_e2e_energy``'s methodology: the smoke model
measures SCHEDULING behaviour (utilization, tokens per invocation, paged
bytes/token for the genome's page size), and the analytical model prices
that behaviour at paper scale. Utilization here is useful tokens per
slot-invocation (``tokens_per_step / num_slots``) — speculation's accepted
drafts raise it, idle slots lower it — so the 1/u weight-stream
amplification and the idle static-power share both respond to the knobs
being searched. Requires the ``benchmarks`` package on ``sys.path`` (run
from the repo root, as the CLI and CI do).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.serving.engine import ContinuousServeEngine
from repro.serving.trace import make_slo_workload, make_workload, run_trace
from repro.tuning.space import KnobSpace, space_for_trace

OBJECTIVE_NAMES = ("throughput", "latency", "energy")

# scalar run metrics carried into checkpoints / presets (JSON-safe, wall-
# time free: timers would break bit-identical reproducibility claims)
_METRIC_KEYS = (
    "tokens_per_step", "decode_steps", "useful_tokens", "ttft_p50",
    "ttft_p95", "itl_p50", "itl_p95", "itl_mean", "ttft_p95_interactive",
    "itl_p95_interactive", "ttft_p95_batch", "itl_p95_batch",
    "unserved_interactive", "unserved_batch", "slot_utilization",
    "arena_utilization", "preemptions", "escalations", "deescalations",
    "spec_accept_rate", "spec_accepted_per_step", "prefill_chunks",
    "defrags", "prefill_write_bytes",
)


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """The fixed seeded workload an entire search is scored on."""

    kind: str = "slo"        # slo (mixed interactive/batch) | mixed (Poisson)
    seed: int = 0
    n_requests: int = 12
    rate: float = 2.0

    def build(self, vocab: int):
        if self.kind == "slo":
            return make_slo_workload(self.seed, self.n_requests, vocab,
                                     self.rate)
        if self.kind == "mixed":
            return make_workload(self.seed, self.n_requests, vocab,
                                 self.rate), None
        raise ValueError(f"unknown trace kind {self.kind!r}")


_PAPER_SCALE: dict = {}      # lazy: (n_params, num_layers, ModelConfig)
_KV_PAGED: dict[int, float] = {}   # page_size -> paged bytes/token/layer


def _paper_scale():
    if not _PAPER_SCALE:
        from repro.common.param import count_params
        from repro.configs import get_config
        from repro.models.model import model_defs

        mc = get_config("opt-6.7b")
        _PAPER_SCALE["cfg"] = mc
        _PAPER_SCALE["n_params"] = count_params(model_defs(mc))
        _PAPER_SCALE["L"] = mc.num_layers
    return _PAPER_SCALE


def _kv_paged_bytes(page_size: int) -> float:
    if page_size not in _KV_PAGED:
        from repro.serving import paged_cache as pgc

        mc = _paper_scale()["cfg"]
        arena = pgc.init_paged_dense(2, page_size, mc.num_kv_heads,
                                     mc.head_dim)
        _KV_PAGED[page_size] = pgc.bytes_per_token(arena, page_size)
    return _KV_PAGED[page_size]


def energy_mj_per_token(run: dict, serving) -> float:
    """Price the measured run at paper scale (OPT-6.7B / TPU v5e) through
    ``bench_e2e_energy.decode_token_cost``: block-table-amortized paged
    bytes for THIS page size, chunked-prefill write amortization, and the
    measured tokens-per-slot-invocation utilization."""
    try:
        from benchmarks.bench_e2e_energy import TrafficCfg, decode_token_cost
        from benchmarks.hw import TPU_V5E
    except ImportError as e:  # pragma: no cover - mislocated invocation
        raise ImportError(
            "the energy objective prices runs through benchmarks/"
            "bench_e2e_energy.py — run from the repository root so the "
            "'benchmarks' package imports") from e

    ps = _paper_scale()
    kv = _kv_paged_bytes(serving.page_size)
    util = min(1.0, max(run["tokens_per_step"] / serving.num_slots, 1e-6))
    tc = TrafficCfg(batch=serving.num_slots,
                    kv_bytes_per_token_layer=kv,
                    prefill_ctx=2048, gen_tokens=256,
                    prefill_write_bytes_per_token_layer=kv,
                    slot_util=util)
    _, e = decode_token_cost(TPU_V5E, ps["n_params"], ps["L"], tc)
    return e * 1e3


class ServingObjective:
    """Callable evaluation harness: genome -> (objectives, metrics).

    Builds the trace once, then serves it through a fresh engine per
    evaluation. A donor engine per (cfg, rt) variant shares its jitted step
    functions with every evaluation engine (``adopt_compiled``), so the
    whole search compiles each step shape once."""

    names = OBJECTIVE_NAMES

    def __init__(self, cfg, params, trace: TraceSpec = TraceSpec(),
                 space: Optional[KnobSpace] = None):
        self.cfg = cfg
        self.params = params
        self.trace = trace
        self.work, self.slos = trace.build(cfg.vocab_size)
        self.space = space or space_for_trace(self.work)
        assert self.space.max_len >= max(
            len(w.prompt) + w.target for w in self.work), (
            "KnobSpace.max_len does not cover the trace")
        self._donors: dict[bool, ContinuousServeEngine] = {}

    def _donor(self, serving) -> ContinuousServeEngine:
        # tiered engines resolve a different rt (cpq filled in), so they
        # need their own donor — adopt_compiled requires identical (cfg, rt)
        tiered = bool(serving.enable_escalation)
        if tiered not in self._donors:
            base = self.space.to_serving(self.space.default_genome())
            if tiered:
                base = dataclasses.replace(base, enable_escalation=True)
            self._donors[tiered] = ContinuousServeEngine(
                self.cfg, self.params, serving=base)
        return self._donors[tiered]

    def __call__(self, genome: dict) -> tuple[tuple[float, ...], dict]:
        serving = self.space.to_serving(genome)
        run = run_trace(self.cfg, self.params, self.work, serving,
                        slos=self.slos, donor=self._donor(serving))
        energy = energy_mj_per_token(run, serving)
        latency = float(run.get("ttft_p95_interactive", run["ttft_p95"]))
        # unscheduled requests (never produced a token) are a hard miss:
        # their sentinel stamps are excluded from the percentiles, so make
        # the latency axis reflect them instead of rewarding starvation
        unserved = sum(v for k, v in run.items()
                       if k.startswith("unserved_"))
        if unserved:
            latency += 1e3 * unserved
        objectives = (-float(run["tokens_per_step"]), latency, float(energy))
        import numbers
        metrics = {}
        for k in _METRIC_KEYS:
            v = run.get(k)
            if isinstance(v, numbers.Real) and not isinstance(v, bool):
                fv = float(v)  # numpy scalars -> JSON-native numbers
                metrics[k] = int(fv) if fv.is_integer() else fv
        metrics["energy_mj_per_token"] = float(energy)
        return objectives, metrics
