"""Pareto machinery: dominance, non-dominated sort, hypervolume.

All objectives are MINIMIZED. Objective vectors are plain tuples/lists of
floats; everything here is deterministic and pure (no numpy RNG, no engine
imports) so the search loop's bookkeeping stays bit-reproducible.
"""
from __future__ import annotations

from typing import Iterable, Sequence


def dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a dominates b: no worse on every objective, strictly better on one."""
    assert len(a) == len(b), (a, b)
    return all(x <= y for x, y in zip(a, b)) and any(
        x < y for x, y in zip(a, b))


def pareto_front(points: Sequence[Sequence[float]]) -> list[int]:
    """Indices of the non-dominated points, in input order. Duplicates of a
    frontier point all survive (they dominate nothing and nothing dominates
    them) — callers dedupe by genome key if they need distinct points."""
    out = []
    for i, p in enumerate(points):
        if not any(dominates(q, p) for j, q in enumerate(points) if j != i):
            out.append(i)
    return out


def non_dominated_sort(points: Sequence[Sequence[float]]) -> list[list[int]]:
    """NSGA-style fronts: front 0 is the Pareto set, front 1 the Pareto set
    of the remainder, and so on. Returns lists of input indices."""
    remaining = list(range(len(points)))
    fronts: list[list[int]] = []
    while remaining:
        sub = [points[i] for i in remaining]
        keep = set(pareto_front(sub))
        front = [remaining[k] for k in sorted(keep)]
        fronts.append(front)
        remaining = [i for k, i in enumerate(remaining) if k not in keep]
    return fronts


def _pareto_min(points: list[tuple]) -> list[tuple]:
    return [points[i] for i in pareto_front(points)]


def hypervolume(points: Iterable[Sequence[float]],
                ref: Sequence[float]) -> float:
    """Exact hypervolume (minimization) dominated by ``points`` w.r.t. the
    reference point ``ref``: the measure of the region every point must
    dominate for the frontier to 'cover' it. Points not strictly better than
    ``ref`` on every axis contribute nothing. Recursive slicing on the first
    objective — exponential in dimensions but exact, and the tuner runs at 3
    objectives over a few dozen frontier points."""
    ref = tuple(float(r) for r in ref)
    pts = sorted({tuple(float(x) for x in p) for p in points
                  if all(x < r for x, r in zip(p, ref))})
    pts = _pareto_min(pts)

    def hv(pts: list[tuple], ref: tuple) -> float:
        if not pts:
            return 0.0
        if len(ref) == 1:
            return ref[0] - min(p[0] for p in pts)
        vals = sorted({p[0] for p in pts})
        total = 0.0
        for i, v in enumerate(vals):
            upper = vals[i + 1] if i + 1 < len(vals) else ref[0]
            width = upper - v
            if width <= 0:
                continue
            slab = [p[1:] for p in pts if p[0] <= v]
            total += width * hv(_pareto_min(slab), ref[1:])
        return total

    return hv(pts, ref)
