"""Seeded, resumable μ+λ evolutionary Pareto search over a ``KnobSpace``.

Shape of the loop (budget counted in EVALUATIONS, not generations):

  * seeding: evaluation 0 is always the hand-tuned default genome — the
    frontier therefore dominates-or-ties the baseline on every axis by
    construction, which is what lets presets claim "no worse than the
    hand-tuned default on its own objective". Evaluations 1..μ-1 are
    uniform random samples.
  * generations: λ offspring per generation, each bred from the μ
    survivors of all evaluations before the generation boundary
    (non-dominated sort, lexicographic tie-break) by crossover of two
    distinct survivors (prob ``crossover_p``, needs >= 2) or mutation of
    one. Duplicate genomes are skipped via the evaluation memo (re-used,
    never re-evaluated) so a tiny space cannot stall the loop.

Determinism and resume: the only randomness is ``np.random.default_rng
(seed)``, proposals depend solely on (records-so-far, rng state), and the
JSON checkpoint stores both after EVERY evaluation — so resuming from a
checkpoint continues bit-identically with a fresh process, and re-running
the same seed reproduces the identical record sequence and frontier.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Optional

import numpy as np

from repro.tuning.frontier import hypervolume, non_dominated_sort, pareto_front
from repro.tuning.space import Knob, KnobSpace

CHECKPOINT_VERSION = 1


@dataclasses.dataclass
class EvalRecord:
    genome: dict
    objectives: tuple[float, ...]
    metrics: dict


def _space_signature(space: KnobSpace) -> dict:
    return {
        "max_len": space.max_len,
        "budget_slots": space.budget_slots,
        "budget_page": space.budget_page,
        "knobs": [[k.name, list(k.choices)] for k in space.knobs],
    }


class ParetoSearch:
    """``search = ParetoSearch(space, evaluate, seed=0); front =
    search.run(budget)``. ``evaluate(genome) -> (objectives, metrics)``
    must be deterministic (same genome -> same objectives) for the memo,
    checkpoint, and reproducibility contracts to hold."""

    def __init__(self, space: KnobSpace,
                 evaluate: Callable[[dict], tuple],
                 *, seed: int = 0, mu: int = 6, lam: int = 6,
                 mutate_p: float = 0.35, crossover_p: float = 0.5,
                 checkpoint: Optional[str] = None):
        assert mu >= 1 and lam >= 1
        self.space = space
        self.evaluate = evaluate
        self.seed = int(seed)
        self.mu, self.lam = int(mu), int(lam)
        self.mutate_p, self.crossover_p = float(mutate_p), float(crossover_p)
        self.checkpoint = checkpoint
        self.rng = np.random.default_rng(self.seed)
        self.records: list[EvalRecord] = []
        self.seen: dict[tuple, EvalRecord] = {}
        if checkpoint and os.path.exists(checkpoint):
            self.load(checkpoint)

    # ------------------------------------------------------------ the loop

    def run(self, budget: int) -> list[EvalRecord]:
        """Evaluate until ``len(records) == budget``; returns the frontier.
        Safe to call again with a larger budget (continues), or after
        constructing with an existing checkpoint (resumes)."""
        while len(self.records) < budget:
            genome = self._propose()
            key = self.space.genome_key(genome)
            if key in self.seen:
                # memo hit (space smaller than the budget): record the
                # cached result — budget still advances, nothing re-runs
                prev = self.seen[key]
                rec = EvalRecord(dict(genome), tuple(prev.objectives),
                                 dict(prev.metrics))
            else:
                objectives, metrics = self.evaluate(genome)
                rec = EvalRecord(dict(genome),
                                 tuple(float(x) for x in objectives),
                                 dict(metrics))
                self.seen[key] = rec
            self.records.append(rec)
            if self.checkpoint:
                self.save(self.checkpoint)
        return self.frontier()

    def _propose(self) -> dict:
        n = len(self.records)
        if n == 0:
            return self.space.default_genome()
        if n < self.mu:
            return self._fresh()
        # generation boundary: parents are the μ survivors of everything
        # evaluated before it (deterministic from the records list, so a
        # resumed process re-derives the same parent set)
        boundary = self.mu + ((n - self.mu) // self.lam) * self.lam
        parents = self.survivors(self.records[:boundary])
        for _ in range(64):
            if len(parents) >= 2 and self.rng.random() < self.crossover_p:
                i = int(self.rng.integers(len(parents)))
                j = int(self.rng.integers(len(parents) - 1))
                j += j >= i
                child = self.space.crossover(parents[i].genome,
                                             parents[j].genome, self.rng)
            else:
                p = parents[int(self.rng.integers(len(parents)))]
                child = self.space.mutate(p.genome, self.rng, self.mutate_p)
            if self.space.genome_key(child) not in self.seen:
                return child
        return self._fresh()

    def _fresh(self) -> dict:
        for _ in range(256):
            g = self.space.sample(self.rng)
            if self.space.genome_key(g) not in self.seen:
                return g
        return g  # space exhausted: duplicate, resolved via the memo

    # ------------------------------------------------------------ selection

    def survivors(self, records: list[EvalRecord]) -> list[EvalRecord]:
        """μ+λ survivor selection: flatten the non-dominated fronts, order
        within a front by (objectives, genome key) — fully deterministic —
        and keep the first μ distinct genomes."""
        objs = [r.objectives for r in records]
        out, used = [], set()
        for front in non_dominated_sort(objs):
            ranked = sorted(front, key=lambda i: (
                records[i].objectives,
                self.space.genome_key(records[i].genome)))
            for i in ranked:
                key = self.space.genome_key(records[i].genome)
                if key not in used:
                    used.add(key)
                    out.append(records[i])
                if len(out) >= self.mu:
                    return out
        return out

    def frontier(self) -> list[EvalRecord]:
        """Non-dominated records, distinct by genome, deterministically
        ordered by (objectives, genome key)."""
        objs = [r.objectives for r in self.records]
        keep = [self.records[i] for i in pareto_front(objs)]
        out, used = [], set()
        for r in sorted(keep, key=lambda r: (
                r.objectives, self.space.genome_key(r.genome))):
            key = self.space.genome_key(r.genome)
            if key not in used:
                used.add(key)
                out.append(r)
        return out

    def frontier_hypervolume(self) -> float:
        """Hypervolume of the current frontier against the nadir of ALL
        evaluated points (worst per axis, nudged out so every frontier
        point contributes) — comparable across runs of the same trace."""
        if not self.records:
            return 0.0
        objs = [r.objectives for r in self.records]
        ref = [max(o[i] for o in objs) + 1e-9 + 0.05 * (
            max(o[i] for o in objs) - min(o[i] for o in objs))
            for i in range(len(objs[0]))]
        return hypervolume([r.objectives for r in self.frontier()], ref)

    def baseline(self) -> EvalRecord:
        """The seeded hand-tuned default's evaluation (record 0)."""
        assert self.records, "run() first"
        return self.records[0]

    # ---------------------------------------------------------- checkpoint

    def save(self, path: str) -> None:
        doc = {
            "version": CHECKPOINT_VERSION,
            "seed": self.seed,
            "mu": self.mu, "lam": self.lam,
            "mutate_p": self.mutate_p, "crossover_p": self.crossover_p,
            "space": _space_signature(self.space),
            "rng_state": self.rng.bit_generator.state,
            "records": [{"genome": r.genome,
                         "objectives": list(r.objectives),
                         "metrics": r.metrics} for r in self.records],
        }
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(doc, f, indent=1)
        os.replace(tmp, path)

    def load(self, path: str) -> None:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("version") != CHECKPOINT_VERSION:
            raise ValueError(f"checkpoint {path}: unsupported version "
                             f"{doc.get('version')!r}")
        for field in ("seed", "mu", "lam", "mutate_p", "crossover_p"):
            if doc[field] != getattr(self, field):
                raise ValueError(
                    f"checkpoint {path}: {field}={doc[field]!r} does not "
                    f"match this search ({getattr(self, field)!r}) — resume "
                    "with identical search parameters or delete the file")
        if doc["space"] != _space_signature(self.space):
            raise ValueError(
                f"checkpoint {path}: knob space changed since the "
                "checkpoint was written — evaluated points would be "
                "incomparable; delete the file to start fresh")
        self.records = []
        self.seen = {}
        for r in doc["records"]:
            genome = self.space.validate_and_repair(r["genome"])
            rec = EvalRecord(genome, tuple(r["objectives"]), r["metrics"])
            self.records.append(rec)
            self.seen.setdefault(self.space.genome_key(genome), rec)
        self.rng.bit_generator.state = doc["rng_state"]


def make_space_from_signature(sig: dict) -> KnobSpace:
    """Rebuild a ``KnobSpace`` from a checkpoint's space signature."""
    return KnobSpace(
        max_len=sig["max_len"], budget_slots=sig["budget_slots"],
        budget_page=sig["budget_page"],
        knobs=tuple(Knob(name, tuple(ch)) for name, ch in sig["knobs"]))
