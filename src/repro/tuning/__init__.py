"""Hardware-aware serving auto-tuner (ROADMAP item 5).

Searches the ``ServingCfg`` knob space with a seeded, resumable μ+λ
evolutionary Pareto loop against three minimized objectives measured on the
REAL ``ContinuousServeEngine`` over a fixed seeded trace — throughput
(-tokens/step), latency (interactive p95 TTFT), energy (mJ/token via the
``bench_e2e_energy`` measured-utilization device model) — and materializes
the frontier into named presets loadable via ``ServingCfg.from_preset()``.

Modules:
  space      — typed knob space: sampling / mutation / crossover with a
               ``validate_and_repair`` pass (invalid combos repaired, not
               crashed); capacity derived from a fixed arena byte budget
  objectives — the evaluation harness over ``repro.serving.trace.run_trace``
  evolution  — the μ+λ loop: deterministic under a seed, JSON-checkpoint
               resumable after every evaluation
  frontier   — dominance, non-dominated sort, exact hypervolume
  presets    — frontier -> named operating points (latency / throughput /
               energy / default) + the presets JSON document

CLI: ``python -m launch.tune --budget 24 --seed 0 --smoke``.
"""
from repro.tuning.evolution import EvalRecord, ParetoSearch
from repro.tuning.frontier import (dominates, hypervolume,
                                   non_dominated_sort, pareto_front)
from repro.tuning.objectives import (OBJECTIVE_NAMES, ServingObjective,
                                     TraceSpec, energy_mj_per_token)
from repro.tuning.presets import (load_presets, materialize, select_presets,
                                  write_presets)
from repro.tuning.space import (DEFAULT_GENOME, DEFAULT_KNOBS, Knob,
                                KnobSpace, space_for_trace)

__all__ = [
    "EvalRecord", "ParetoSearch", "dominates", "hypervolume",
    "non_dominated_sort", "pareto_front", "OBJECTIVE_NAMES",
    "ServingObjective", "TraceSpec", "energy_mj_per_token", "load_presets",
    "materialize", "select_presets", "write_presets", "DEFAULT_GENOME",
    "DEFAULT_KNOBS", "Knob", "KnobSpace", "space_for_trace",
]
