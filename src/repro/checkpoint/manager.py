"""Sharded, compressed, async, mesh-elastic checkpoints (no orbax here —
built from scratch on zstd + msgpack + npy).

Layout per step:
  <dir>/step_<k>/meta.msgpack        treedef, shapes, dtypes, step, user meta
  <dir>/step_<k>/leaf_<i>.npz.zst    one compressed array per leaf
  <dir>/step_<k>/COMMIT              written LAST -> crash-safe visibility

Fault-tolerance properties:
  * atomic-by-rename + COMMIT marker: a step is either fully there or ignored
  * ``save_async`` snapshots to host (device_get) then writes on a background
    thread — training continues during I/O
  * ELASTIC restore: leaves are stored as logical (global) arrays, so a
    checkpoint taken on one mesh restores onto ANY mesh/shape — restore
    device_puts each leaf with the target sharding (the new mesh's
    PartitionSpec), which re-chunks automatically
  * retention: keep the newest ``keep`` complete steps
"""
from __future__ import annotations

import concurrent.futures as cf
import io
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import msgpack
import numpy as np

try:
    import zstandard
except ImportError:  # optional dep: fall back to stdlib zlib
    zstandard = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _dump_leaf(path: Path, arr: np.ndarray):
    buf = io.BytesIO()
    np.save(buf, arr, allow_pickle=False)
    if zstandard is not None:
        path.write_bytes(zstandard.ZstdCompressor(level=3).compress(buf.getvalue()))
    else:
        path.write_bytes(zlib.compress(buf.getvalue(), 3))


def _load_leaf(path: Path) -> np.ndarray:
    blob = path.read_bytes()
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(f"{path} is zstd-compressed but zstandard is "
                               "not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob, max_output_size=1 << 38)
    else:
        raw = zlib.decompress(blob)
    return np.load(io.BytesIO(raw), allow_pickle=False)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree: Any, extra: dict | None = None):
        """Blocking save (waits for any pending async write first)."""
        self.wait()
        if step in self.all_steps():
            return  # already durably saved (e.g. by a prior save_async)
        self._write(step, jax.device_get(tree), extra or {})

    def save_async(self, step: int, tree: Any, extra: dict | None = None):
        """Snapshot now, write in the background. Overlaps I/O with compute."""
        self.wait()
        host = jax.device_get(tree)  # snapshot before training mutates buffers
        self._pending = self._pool.submit(self._write, step, host, extra or {})

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree: Any, extra: dict):
        leaves, treedef = jax.tree.flatten(host_tree)
        tmp = self.dir / f".tmp_step_{step}"
        final = self.dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        meta = {
            "step": step,
            "treedef": str(treedef),
            "num_leaves": len(leaves),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
            "extra": extra,
        }
        for i, leaf in enumerate(leaves):
            arr = np.ascontiguousarray(np.asarray(leaf))
            if arr.dtype.kind not in "biufc":  # ml_dtypes (bf16, ...) -> bit view
                arr = arr.view(f"u{arr.dtype.itemsize}")
            _dump_leaf(tmp / f"leaf_{i}.npz.zst", arr)
        (tmp / "meta.msgpack").write_bytes(msgpack.packb(meta))
        (tmp / "COMMIT").write_bytes(b"ok")
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "COMMIT").exists():
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.all_steps()
        return s[-1] if s else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; if ``shardings`` (a tree of
        jax.sharding.Sharding) is given, device_put each leaf with it —
        elastic re-chunking onto the current mesh happens here."""
        d = self.dir / f"step_{step}"
        assert (d / "COMMIT").exists(), f"incomplete checkpoint {d}"
        meta = msgpack.unpackb((d / "meta.msgpack").read_bytes())
        leaves_like, treedef = jax.tree.flatten(like)
        assert meta["num_leaves"] == len(leaves_like), "tree structure changed"
        out = []
        sh_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                     else [None] * len(leaves_like))
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)

        for i, (proto, sh) in enumerate(zip(leaves_like, sh_leaves)):
            arr = _load_leaf(d / f"leaf_{i}.npz.zst")
            want = np.dtype(meta["dtypes"][i])
            if arr.dtype != want:
                arr = arr.view(want)  # bit view back to ml_dtypes
            out.append(jax.device_put(arr, sh) if sh is not None else arr)
        return jax.tree.unflatten(treedef, out)
