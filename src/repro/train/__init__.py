from repro.train.step import TrainStepCfg, make_train_step  # noqa: F401
