"""Training step: loss -> grads -> optimizer update, with microbatch
gradient accumulation (lax.scan) and remat. Pure function of
(params, opt_state, step_idx, batch) -> (params, opt_state, metrics) so it
jits/pjits directly; sharding comes from in/out_shardings at the call site.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import loss_fn
from repro.optim.optimizers import Optimizer, apply_updates


@dataclasses.dataclass(frozen=True)
class TrainStepCfg:
    microbatches: int = 1
    remat: bool = True
    aux_weight: float = 0.01


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, tcfg: TrainStepCfg):
    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            partial(loss_fn, cfg), has_aux=True)(
                params, batch=batch, remat=tcfg.remat, aux_weight=tcfg.aux_weight)
        return loss, metrics, grads

    def train_step(params, opt_state, step_idx, batch):
        k = tcfg.microbatches
        if k == 1:
            loss, metrics, grads = grads_of(params, batch)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            mb = jax.tree.map(lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]),
                              batch)

            def acc(carry, mbatch):
                gacc, lacc = carry
                loss, _, grads = grads_of(params, mbatch)
                gacc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gacc, grads)
                return (gacc, lacc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / k, grads)
            loss = loss_sum / k
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}

        updates, opt_state = optimizer.update(grads, opt_state, params, step_idx)
        params = apply_updates(params, updates)
        metrics = dict(metrics, loss=loss)
        return params, opt_state, metrics

    return train_step
