"""Layer dispatch + block assembly.

A layer is (mixer, mlp) from the config's block_pattern. The decoder stack is
lowered as ``lax.scan`` over stacked same-position layers (HLO size — and
hence compile time and remat behaviour — is independent of depth).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.common.param import stack_defs
from repro.configs.base import AttentionRuntime, ModelConfig
from repro.models import attention_layer as attn
from repro.models import mamba as mamba_lib
from repro.models import mla as mla_lib
from repro.models import moe as moe_lib
from repro.models import xlstm as xlstm_lib
from repro.models.layers import apply_mlp, apply_norm, mlp_defs, norm_defs


# --------------------------------------------------------------------- defs


def layer_defs(cfg: ModelConfig, mixer: str, mlp: str):
    d: dict[str, Any] = {"norm1": norm_defs(cfg)}
    if mixer == "attn":
        d["mixer"] = attn.attn_defs(cfg)
    elif mixer == "xattn":
        d["mixer"] = attn.attn_defs(cfg, cross=True)
    elif mixer == "mla":
        d["mixer"] = mla_lib.mla_defs(cfg)
    elif mixer == "mamba":
        d["mixer"] = mamba_lib.mamba_defs(cfg)
    elif mixer == "mlstm":
        d["mixer"] = xlstm_lib.mlstm_defs(cfg)
    elif mixer == "slstm":
        d["mixer"] = xlstm_lib.slstm_defs(cfg)
    else:
        raise ValueError(mixer)
    if mlp == "dense":
        d["norm2"] = norm_defs(cfg)
        d["mlp"] = mlp_defs(cfg)
    elif mlp == "moe":
        d["norm2"] = norm_defs(cfg)
        d["mlp"] = moe_lib.moe_defs(cfg)
    return d


def stacked_block_defs(cfg: ModelConfig):
    """One stacked def-tree per position in the block pattern."""
    return [stack_defs(layer_defs(cfg, mixer, mlp), cfg.num_blocks, axis_name="layers")
            for mixer, mlp in cfg.block_pattern]


# -------------------------------------------------------------------- train


def _apply_mlp_part(cfg: ModelConfig, mlp: str, p, x):
    if mlp == "none":
        return x, jnp.zeros((), jnp.float32)
    h = apply_norm(cfg, p["norm2"], x)
    if mlp == "moe":
        y, aux = moe_lib.apply_moe(cfg, p["mlp"], h)
        return x + y, aux
    return x + apply_mlp(cfg, p["mlp"], h), jnp.zeros((), jnp.float32)


def layer_train(cfg: ModelConfig, kind: tuple[str, str], p, x: jax.Array,
                positions: jax.Array, patches: Optional[jax.Array]):
    mixer, mlp = kind
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        x = x + attn.attn_train(cfg, p["mixer"], h, positions)
    elif mixer == "xattn":
        x = x + attn.xattn_train(cfg, p["mixer"], h, patches)
    elif mixer == "mla":
        x = x + mla_lib.mla_train(cfg, p["mixer"], h, positions)
    elif mixer == "mamba":
        y, _ = mamba_lib.mamba_forward(cfg, p["mixer"], h)
        x = x + y
    elif mixer == "mlstm":
        y, _ = xlstm_lib.mlstm_forward(cfg, p["mixer"], h)
        x = x + y
    elif mixer == "slstm":
        y, _ = xlstm_lib.slstm_forward(cfg, p["mixer"], h)
        x = x + y
    return _apply_mlp_part(cfg, mlp, p, x)


# ------------------------------------------------------------------ serving


def layer_cache_init(cfg: ModelConfig, rt: AttentionRuntime, kind: tuple[str, str],
                     batch: int, n_max: int, n_patches: int):
    mixer, _ = kind
    if mixer == "attn":
        return attn.init_attn_cache(cfg, rt, batch, n_max)
    if mixer == "xattn":
        from repro.core import kv_cache as kvc
        return kvc.init_dense(batch, n_patches, cfg.num_kv_heads, cfg.head_dim,
                              cfg.param_dtype)
    if mixer == "mla":
        return mla_lib.init_mla_cache(cfg, rt, batch, n_max)
    if mixer == "mamba":
        return mamba_lib.init_mamba_state(cfg, batch)
    if mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(cfg, batch)
    if mixer == "slstm":
        return xlstm_lib.init_slstm_state(cfg, batch)
    raise ValueError(mixer)


def layer_paged_cache_init(cfg: ModelConfig, rt: AttentionRuntime,
                           kind: tuple[str, str], serving, tiered: bool):
    """Paged arena for attention mixers; slot-indexed contiguous state for
    everything else (recurrent state is O(1)/request, xattn K/V is static
    per request — neither needs paging)."""
    mixer, _ = kind
    if mixer == "attn":
        return attn.init_paged_attn_cache(cfg, rt, serving, tiered)
    if mixer == "mla":
        return mla_lib.init_paged_mla_cache(cfg, rt, serving)
    return layer_cache_init(cfg, rt, kind, serving.num_slots,
                            serving.max_len, cfg.num_patch_tokens)


def layer_decode_rows(cfg: ModelConfig, rt: AttentionRuntime, kind: tuple[str, str],
                      p, x_t: jax.Array, rows, cache):
    """Continuous-batching decode: per-row positions/lengths via ``rows``
    (serving.paged_cache.RowState). Non-attention mixers are position-free and
    reuse their contiguous decode; retired slots' garbage state is overwritten
    at the next admission."""
    mixer, mlp = kind
    h = apply_norm(cfg, p["norm1"], x_t)
    if mixer == "attn":
        y, cache = attn.attn_decode_rows(cfg, rt, p["mixer"], h, rows, cache)
    elif mixer == "xattn":
        y, cache = attn.xattn_decode(cfg, p["mixer"], h, cache)
    elif mixer == "mla":
        y, cache = mla_lib.mla_decode_rows(cfg, rt, p["mixer"], h, rows, cache)
    elif mixer == "mamba":
        y, cache = mamba_lib.mamba_decode(cfg, p["mixer"], h, cache)
    elif mixer == "mlstm":
        y, cache = xlstm_lib.mlstm_decode(cfg, p["mixer"], h, cache)
    elif mixer == "slstm":
        y, cache = xlstm_lib.slstm_decode(cfg, p["mixer"], h, cache)
    x_t = x_t + y
    x_t, _ = _apply_mlp_part(cfg, mlp, p, x_t)
    return x_t, cache


def layer_prefill_chunk(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                        first: bool, kind: tuple[str, str], p, x: jax.Array,
                        positions: jax.Array, slot, block_row, offset, valid,
                        cache):
    """Chunked paged prefill of one prompt chunk for one request slot: the
    chunk's cache payload is written straight into the slot's arena pages
    (serving/paged_cache.chunk_attend_paged). Recurrent and cross-attention
    mixers never reach here — the engine keeps their exact one-shot
    admission (state integration cannot be cut at page boundaries)."""
    mixer, mlp = kind
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        y, cache = attn.attn_prefill_chunk(cfg, rt, tier, first, p["mixer"], h,
                                           positions, slot, block_row, offset,
                                           valid, cache)
    elif mixer == "mla":
        y, cache = mla_lib.mla_prefill_chunk(cfg, rt, tier, first, p["mixer"],
                                             h, positions, slot, block_row,
                                             offset, valid, cache)
    else:
        raise ValueError(f"chunked prefill has no {mixer!r} path "
                         "(engine falls back to one-shot admission)")
    x = x + y
    x, _ = _apply_mlp_part(cfg, mlp, p, x)
    return x, cache


def layer_prefill(cfg: ModelConfig, rt: AttentionRuntime, kind: tuple[str, str], p,
                  x: jax.Array, positions: jax.Array, patches: Optional[jax.Array],
                  cache):
    mixer, mlp = kind
    h = apply_norm(cfg, p["norm1"], x)
    if mixer == "attn":
        y, cache = attn.attn_prefill(cfg, rt, p["mixer"], h, positions, cache)
    elif mixer == "xattn":
        y, cache = attn.xattn_prefill(cfg, p["mixer"], h, patches)
    elif mixer == "mla":
        y, cache = mla_lib.mla_prefill(cfg, rt, p["mixer"], h, positions, cache)
    elif mixer == "mamba":
        y, cache = mamba_lib.mamba_forward(cfg, p["mixer"], h)
    elif mixer == "mlstm":
        y, cache = xlstm_lib.mlstm_forward(cfg, p["mixer"], h)
    elif mixer == "slstm":
        y, cache = xlstm_lib.slstm_forward(cfg, p["mixer"], h)
    x = x + y
    x, _ = _apply_mlp_part(cfg, mlp, p, x)
    return x, cache


def layer_decode(cfg: ModelConfig, rt: AttentionRuntime, kind: tuple[str, str], p,
                 x_t: jax.Array, pos: jax.Array, cache):
    mixer, mlp = kind
    h = apply_norm(cfg, p["norm1"], x_t)
    if mixer == "attn":
        y, cache = attn.attn_decode(cfg, rt, p["mixer"], h, pos, cache)
    elif mixer == "xattn":
        y, cache = attn.xattn_decode(cfg, p["mixer"], h, cache)
    elif mixer == "mla":
        y, cache = mla_lib.mla_decode(cfg, rt, p["mixer"], h, pos, cache)
    elif mixer == "mamba":
        y, cache = mamba_lib.mamba_decode(cfg, p["mixer"], h, cache)
    elif mixer == "mlstm":
        y, cache = xlstm_lib.mlstm_decode(cfg, p["mixer"], h, cache)
    elif mixer == "slstm":
        y, cache = xlstm_lib.slstm_decode(cfg, p["mixer"], h, cache)
    x_t = x_t + y
    x_t, _ = _apply_mlp_part(cfg, mlp, p, x_t)
    return x_t, cache
