from repro.models import model  # noqa: F401
from repro.models.model import (  # noqa: F401
    abstract_params,
    decode_step,
    forward_train,
    init_caches,
    init_params,
    loss_fn,
    model_defs,
    param_specs,
    prefill,
)
