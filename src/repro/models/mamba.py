"""Mamba (S6) selective-state-space block for the Jamba hybrid.

Training/prefill use a CHUNKED parallel scan: an outer ``lax.scan`` over
sequence chunks carries the SSM state while an inner ``associative_scan``
parallelizes within the chunk — the production-standard trade between
parallelism and the (B, T, d_in, d_state) memory blow-up of a fully parallel
scan. Decode is the O(1) recurrent step (conv ring buffer + state update).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain

CHUNK = 128


def _dims(cfg: ModelConfig):
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or max(cfg.d_model // 16, 1)
    return d_in, dt_rank, m.d_state, m.d_conv


def mamba_defs(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    d_in, dt_rank, d_state, d_conv = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * d_in), dt, ("embed", "mlp"), init="fan_in"),
        "conv_w": ParamDef((d_conv, d_in), dt, (None, "mlp"), init="fan_in"),
        "conv_b": ParamDef((d_in,), jnp.float32, (None,), init="zeros"),
        "x_proj": ParamDef((d_in, dt_rank + 2 * d_state), dt, ("mlp", None), init="fan_in"),
        "dt_w": ParamDef((dt_rank, d_in), dt, (None, "mlp"), init="fan_in"),
        "dt_bias": ParamDef((d_in,), jnp.float32, (None,), init="zeros"),
        "A_log": ParamDef((d_in, d_state), jnp.float32, ("mlp", None), init="s4d"),
        "D": ParamDef((d_in,), jnp.float32, (None,), init="ones"),
        "out_proj": ParamDef((d_in, d), dt, ("mlp", "embed"), init="fan_in"),
    }


class MambaState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_in) input ring buffer
    h: jax.Array     # (B, d_in, d_state) f32 SSM state


def init_mamba_state(cfg: ModelConfig, batch: int) -> MambaState:
    d_in, _, d_state, d_conv = _dims(cfg)
    return MambaState(
        conv=jnp.zeros((batch, d_conv - 1, d_in), cfg.param_dtype),
        h=jnp.zeros((batch, d_in, d_state), jnp.float32),
    )


def _conv_full(p, x: jax.Array) -> jax.Array:
    """Causal depthwise conv over time. x: (B, T, d_in)."""
    K = p["conv_w"].shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    y = jax.lax.conv_general_dilated(
        xp, p["conv_w"][:, None, :].astype(x.dtype),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1],
    )
    return y + p["conv_b"].astype(x.dtype)


def _dt_bc(cfg: ModelConfig, p, x_c: jax.Array):
    """Small per-token SSM projections. x_c: (B, T, d_in).

    Returns dt (B,T,d_in) f32, Bm/Cm (B,T,d_state) f32."""
    d_in, dt_rank, d_state, _ = _dims(cfg)
    proj = x_c @ p["x_proj"]
    dt_raw = proj[..., :dt_rank]
    Bm = proj[..., dt_rank:dt_rank + d_state].astype(jnp.float32)
    Cm = proj[..., dt_rank + d_state:].astype(jnp.float32)
    dt = jax.nn.softplus((dt_raw @ p["dt_w"]).astype(jnp.float32) + p["dt_bias"])
    return dt, Bm, Cm


# "assoc" (default): log-depth associative scan — parallel, tree costs
#   ~2 x (B,L,d,N) per level in HBM (67% of jamba train bytes).
# "sequential": per-token recurrence — REFUTED as an optimization: lax.scan's
#   backward stacks per-step residuals (measured (128,B,d,N) stacks, 209 TB),
#   re-materializing exactly what it avoided, plus 128-deep dependency chains.
#   Kept for the record; see EXPERIMENTS.md §Perf cell C.
SCAN_IMPL = "assoc"


def _ssm_chunked(cfg: ModelConfig, p, x_c, dt, Bm, Cm, h0, remat: bool = True):
    """Selective scan, chunked (outer lax.scan over chunks of CHUNK tokens,
    remat'd so backward recomputes one chunk at a time).

    Inner implementations:
      * "sequential": per-token recurrence inside the chunk — the discretized
        (B, L, d_in, d_state) tensors NEVER materialize (per-step transients
        only). The associative-scan tree was measured at 67% of jamba
        train_4k HBM bytes (150 TB/device/step) — EXPERIMENTS.md §Perf
        cell C; the sequential form trades a 128-long dependency chain per
        chunk (µs-scale loop latency) for a ~10x byte cut on the SSM part.
      * "assoc": log-depth associative scan (more parallel, byte-heavy).

    Returns (y (B,T,d_in) f32, h_last)."""
    B, T, d_in = x_c.shape
    d_state = Bm.shape[-1]
    A = -jnp.exp(p["A_log"])  # (d_in, d_state)
    L = min(CHUNK, T)
    pad = (-T) % L
    zp = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))  # noqa: E731
    if pad:
        x_c, dt, Bm, Cm = zp(x_c), zp(dt), zp(Bm), zp(Cm)
    nc = (T + pad) // L
    ch = lambda a: a.reshape(B, nc, L, *a.shape[2:]).swapaxes(0, 1)  # noqa: E731

    def combine(lhs, rhs):
        al, bl = lhs
        ar, br = rhs
        return al * ar, bl * ar + br

    def chunk_step(h, inp):
        xc_c, dt_c, B_c, C_c = inp
        if SCAN_IMPL == "assoc":
            dA = jnp.exp(dt_c[..., None] * A)                             # (B,L,d,N)
            dBx = (dt_c * xc_c.astype(jnp.float32))[..., None] * B_c[:, :, None, :]
            acc_a, acc_b = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
            h_all = acc_a * h[:, None] + acc_b
            y = jnp.einsum("blds,bls->bld", h_all, C_c)
            return h_all[:, -1], y

        def tok(hc, t_inp):
            xc_t, dt_t, B_t, C_t = t_inp                                  # (B,d)/(B,N)
            dA_t = jnp.exp(dt_t[..., None] * A)                           # (B,d,N)
            dBx_t = (dt_t * xc_t.astype(jnp.float32))[..., None] * B_t[:, None, :]
            hc = dA_t * hc + dBx_t
            y_t = jnp.einsum("bds,bs->bd", hc, C_t)
            return hc, y_t

        sw = lambda a: a.swapaxes(0, 1)  # noqa: E731  (L, B, ...)
        h2, ys = jax.lax.scan(tok, h, (sw(xc_c), sw(dt_c), sw(B_c), sw(C_c)))
        return h2, ys.swapaxes(0, 1)

    if remat:
        chunk_step = jax.checkpoint(chunk_step)
    h_last, ys = jax.lax.scan(chunk_step, h0, (ch(x_c), ch(dt), ch(Bm), ch(Cm)))
    y = ys.swapaxes(0, 1).reshape(B, T + pad, d_in)[:, :T]
    return y, h_last


def mamba_forward(cfg: ModelConfig, p, x: jax.Array,
                  state: MambaState | None = None):
    """Full-sequence forward. Returns (y, final_state)."""
    B, T, _ = x.shape
    d_in, _, d_state, d_conv = _dims(cfg)
    xz = x @ p["in_proj"]
    xz = constrain(xz, "act_batch", None, "act_mlp")
    x_m, z = xz[..., :d_in], xz[..., d_in:]

    if state is None:
        state = init_mamba_state(cfg, B)
        x_conv_in = x_m
    else:
        x_conv_in = jnp.concatenate([state.conv.astype(x_m.dtype), x_m], axis=1)

    y_c = _conv_full(p, x_conv_in)[:, -T:]
    x_c = jax.nn.silu(y_c)
    dt, Bm, Cm = _dt_bc(cfg, p, x_c)
    y, h_last = _ssm_chunked(cfg, p, x_c, dt, Bm, Cm, state.h)
    y = y.astype(x.dtype) + x_c * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]

    tail = jnp.concatenate([state.conv.astype(x_m.dtype), x_m], axis=1)[:, -(d_conv - 1):]
    return constrain(out, "act_batch", None, None), MambaState(tail, h_last)


def mamba_decode(cfg: ModelConfig, p, x_t: jax.Array, state: MambaState):
    """One-token step. x_t: (B, 1, D)."""
    B = x_t.shape[0]
    d_in, _, d_state, d_conv = _dims(cfg)
    xz = x_t @ p["in_proj"]
    x_m, z = xz[..., :d_in], xz[..., d_in:]

    window = jnp.concatenate([state.conv.astype(x_m.dtype), x_m], axis=1)  # (B, d_conv, d_in)
    y_c = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x_m.dtype)) + p["conv_b"].astype(x_m.dtype)
    x_c = jax.nn.silu(y_c)[:, None]  # (B, 1, d_in)

    dt, Bm, Cm = _dt_bc(cfg, p, x_c)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBx = (dt * x_c.astype(jnp.float32))[..., None] * Bm[:, :, None, :]
    h = dA[:, 0] * state.h + dBx[:, 0]
    y = jnp.einsum("bds,bs->bd", h, Cm[:, 0]).astype(x_t.dtype)[:, None]
    y = y + x_c * p["D"].astype(x_t.dtype)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, MambaState(window[:, 1:], h)
