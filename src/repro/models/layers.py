"""Shared model layers: norms, RoPE / sinusoidal positions, MLP variants,
embeddings. Pure functions over ParamDef-declared parameter pytrees."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


# -------------------------------------------------------------------- norms


def norm_defs(cfg: ModelConfig, dim: int | None = None):
    d = dim or cfg.d_model
    if cfg.norm == "layernorm":
        return {
            "scale": ParamDef((d,), jnp.float32, (None,), init="ones"),
            "bias": ParamDef((d,), jnp.float32, (None,), init="zeros"),
        }
    return {"scale": ParamDef((d,), jnp.float32, (None,), init="ones")}


def apply_norm(cfg: ModelConfig, p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"]
    return y.astype(x.dtype)


def rms_norm_vec(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMS-norm along the last axis with an explicit scale vector (qk-norm etc.)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale).astype(x.dtype)


# --------------------------------------------------------------------- rope


def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """cos/sin tables. positions: (T,) int32 -> (T, dim/2) each, f32."""
    assert dim % 2 == 0, dim
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, T, H, D) with D even; cos/sin: (T, D/2). Pairing: (x1, x2) halves."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[None, :, None, :].astype(x.dtype)
    s = sin[None, :, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def apply_rope_rows(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """Per-row rope for one-token decode: x: (B, 1, H, D); cos/sin: (B, D/2)
    built from per-row positions (continuous-batching serving, where every
    request sits at its own position)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[:, None, None, :].astype(x.dtype)
    s = sin[:, None, None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_embedding(positions: jax.Array, dim: int) -> jax.Array:
    """Absolute sinusoidal position embeddings (musicgen/opt): (T, dim)."""
    half = dim // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------- MLP


def mlp_defs(cfg: ModelConfig, d_ff: int | None = None):
    ff = d_ff or cfg.d_ff
    d = cfg.d_model
    dt = cfg.param_dtype
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {
            "w_gate": ParamDef((d, ff), dt, ("embed", "mlp"), init="fan_in"),
            "w_up": ParamDef((d, ff), dt, ("embed", "mlp"), init="fan_in"),
            "w_down": ParamDef((ff, d), dt, ("mlp", "embed"), init="fan_in"),
        }
    return {  # plain gelu MLP
        "w_in": ParamDef((d, ff), dt, ("embed", "mlp"), init="fan_in"),
        "b_in": ParamDef((ff,), jnp.float32, (None,), init="zeros"),
        "w_out": ParamDef((ff, d), dt, ("mlp", "embed"), init="fan_in"),
        "b_out": ParamDef((d,), jnp.float32, (None,), init="zeros"),
    }


def apply_mlp(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    if cfg.mlp_act in ("swiglu", "geglu"):
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
        h = constrain(h, "act_batch", None, "act_mlp")
        return h @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_in"] + p["b_in"].astype(x.dtype))
    h = constrain(h, "act_batch", None, "act_mlp")
    return (h @ p["w_out"] + p["b_out"].astype(x.dtype)).astype(x.dtype)


# --------------------------------------------------------------- embeddings


def embed_defs(cfg: ModelConfig):
    dt = cfg.param_dtype
    out = {
        # audio archs keep a code-embedding table too (decode feeds tokens;
        # the EnCodec frontend stub supplies "frames" at train/prefill)
        "tok": ParamDef((cfg.vocab_size, cfg.d_model), dt, ("vocab", "embed"),
                        init="normal", scale=0.02)
    }
    if cfg.input_kind == "text+patches":
        # stub frontend adapter: patches arrive pre-projected to d_model
        out["mm_proj"] = ParamDef((cfg.d_model, cfg.d_model), dt, ("embed", "mlp"),
                                  init="fan_in")
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size), dt, ("embed", "vocab"),
                                  init="fan_in")
    return out


def embed_inputs(cfg: ModelConfig, p, batch: dict, positions: jax.Array) -> jax.Array:
    """batch: {'tokens': (B,S) i32} and/or {'frames': (B,S,D)} / {'patches': (B,P,D)}."""
    if "frames" in batch:
        x = batch["frames"].astype(cfg.param_dtype)
    else:
        x = p["tok"][batch["tokens"]]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.pos_embedding == "absolute":
        if positions.ndim == 2:  # per-row positions (B, T): continuous batching
            emb = sinusoidal_embedding(positions.reshape(-1), cfg.d_model)
            x = x + emb.reshape(*positions.shape, cfg.d_model).astype(x.dtype)
        else:
            x = x + sinusoidal_embedding(positions, cfg.d_model).astype(x.dtype)[None]
    return constrain(x, "act_batch", None, None)


def lm_logits(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    w = params["embed"]["tok"].T if cfg.tie_embeddings else params["embed"]["lm_head"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.logit_softcap > 0:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    return constrain(logits, "act_batch", None, "act_vocab")
