"""xLSTM blocks (sLSTM + mLSTM) [arXiv:2405.04517].

mLSTM: matrix-memory LSTM with exponential gating — per head a (dh x dh)
covariance state updated as C_t = f_t C_{t-1} + i_t v_t k_t^T, read out with
q. Training/prefill run a CHUNKWISE-PARALLEL form (intra-chunk quadratic with
log-gate cumsums + inter-chunk recurrent state, all with the max-stabilizer
m); decode is the O(dh^2) recurrent step. A pure sequential reference
(``mlstm_seq_ref``) exists for property tests.

The mLSTM matrix memory is itself an associative memory — the paper's §V
"attention as nearest-neighbor retrieval" view; but there is no KV cache, so
T1-T3 are inapplicable (DESIGN.md §5).

sLSTM: scalar-memory LSTM with recurrent (block-diagonal per head) gate
connections — inherently sequential; lax.scan over time in all phases.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain


def _mdims(cfg: ModelConfig):
    d_in = int(cfg.xlstm.proj_factor * cfg.d_model)
    H = cfg.num_heads
    return d_in, H, d_in // H


# ===================================================================== mLSTM


def mlstm_defs(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    d_in, H, dh = _mdims(cfg)
    K = cfg.xlstm.conv_kernel
    return {
        "up": ParamDef((d, 2 * d_in), dt, ("embed", "mlp"), init="fan_in"),
        "conv_w": ParamDef((K, d_in), dt, (None, "mlp"), init="fan_in"),
        "conv_b": ParamDef((d_in,), jnp.float32, (None,), init="zeros"),
        "wq": ParamDef((d_in, d_in), dt, ("mlp", None), init="fan_in"),
        "wk": ParamDef((d_in, d_in), dt, ("mlp", None), init="fan_in"),
        "wv": ParamDef((d_in, d_in), dt, ("mlp", None), init="fan_in"),
        "w_if": ParamDef((d_in, 2 * H), jnp.float32, ("mlp", None), init="fan_in"),
        "b_if": ParamDef((2 * H,), jnp.float32, (None,), init="zeros"),
        "norm": ParamDef((d_in,), jnp.float32, (None,), init="ones"),
        "down": ParamDef((d_in, d), dt, ("mlp", "embed"), init="fan_in"),
    }


class MLSTMState(NamedTuple):
    C: jax.Array     # (B, H, dh, dh) f32
    n: jax.Array     # (B, H, dh) f32
    m: jax.Array     # (B, H) f32 stabilizer
    conv: jax.Array  # (B, K-1, d_in)


def init_mlstm_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    d_in, H, dh = _mdims(cfg)
    K = cfg.xlstm.conv_kernel
    return MLSTMState(
        C=jnp.zeros((batch, H, dh, dh), jnp.float32),
        n=jnp.zeros((batch, H, dh), jnp.float32),
        m=jnp.full((batch, H), -30.0, jnp.float32),
        conv=jnp.zeros((batch, K - 1, d_in), cfg.param_dtype),
    )


def _mlstm_qkv_gates(cfg: ModelConfig, p, x: jax.Array, conv_state):
    """Shared pre-processing. x: (B, T, d_model)."""
    B, T, _ = x.shape
    d_in, H, dh = _mdims(cfg)
    K = cfg.xlstm.conv_kernel
    up = x @ p["up"]
    up = constrain(up, "act_batch", None, "act_mlp")
    xm, z = up[..., :d_in], up[..., d_in:]

    xin = xm if conv_state is None else jnp.concatenate(
        [conv_state.astype(xm.dtype), xm], axis=1)
    xp = jnp.pad(xin, ((0, 0), (K - 1, 0), (0, 0))) if conv_state is None else xin
    y = jax.lax.conv_general_dilated(
        xp, p["conv_w"][:, None, :].astype(xm.dtype), (1,), "VALID",
        dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=d_in)
    xc = jax.nn.silu(y[:, -T:] + p["conv_b"].astype(xm.dtype))

    q = (xc @ p["wq"]).reshape(B, T, H, dh)
    k = ((xc @ p["wk"]) * (dh ** -0.5)).reshape(B, T, H, dh)
    v = (xm @ p["wv"]).reshape(B, T, H, dh)
    gif = (xc.astype(jnp.float32) @ p["w_if"]) + p["b_if"]
    ig, fg = gif[..., :H], gif[..., H:]          # (B, T, H) pre-activations
    logf = jax.nn.log_sigmoid(fg)
    conv_tail = xin[:, -(K - 1):] if conv_state is not None else (
        jnp.pad(xm, ((0, 0), (K - 1, 0), (0, 0)))[:, -(K - 1):])
    return q, k, v, ig, logf, z, xc, conv_tail


def _chunk(x, L):
    """(B, T, ...) -> (nc, B, L, ...) with T % L == 0."""
    B, T = x.shape[:2]
    return x.reshape(B, T // L, L, *x.shape[2:]).swapaxes(0, 1)


def mlstm_forward(cfg: ModelConfig, p, x: jax.Array, state: MLSTMState | None = None):
    """Chunkwise-parallel full-sequence forward. Returns (y, final_state)."""
    B, T, _ = x.shape
    d_in, H, dh = _mdims(cfg)
    if state is None:
        st = init_mlstm_state(cfg, B)
        conv0 = None
    else:
        st = state
        conv0 = state.conv
    q, k, v, ig, logf, z, xc, conv_tail = _mlstm_qkv_gates(cfg, p, x, conv0)

    L = min(cfg.xlstm.chunk, T)
    pad = (-T) % L
    valid = jnp.arange(T + pad, dtype=jnp.int32) < T  # pad-token mask
    if pad:
        zpad = lambda a: jnp.pad(  # noqa: E731
            a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        q, k, v, ig, logf = zpad(q), zpad(k), zpad(v), zpad(ig), zpad(logf)
    Tp = T + pad

    cq, ck, cv = _chunk(q, L), _chunk(k, L), _chunk(v, L)
    cig, clogf = _chunk(ig, L), _chunk(logf, L)
    cvalid = valid.reshape(Tp // L, L)

    def chunk_step(carry, inp):
        # NUMERICS: masked log-weights are handled by exp(clip(. , -80, 0))
        # FOLLOWED by a multiplicative 0/1 mask — never exp of a +-1e9
        # sentinel. (XLA fusions of exp around huge sentinels produced
        # NaN gradients under jit; exact-zero masking after a clipped exp
        # is both exact and safe. See EXPERIMENTS.md §Perf notes.)
        C, n, m = carry                      # (B,H,dh,dh), (B,H,dh), (B,H)
        q, k, v, ig, logf, vmask = inp       # (B,L,H,dh) / (B,L,H) / (L,)
        qf = q.astype(jnp.float32)
        kf = k.astype(jnp.float32)
        vf = v.astype(jnp.float32)
        b = jnp.cumsum(logf, axis=1)         # (B,L,H) within-chunk log decay
        # raw log weight of source j at target i: b_i - b_j + ig_j (finite)
        li = b[:, :, None, :] - b[:, None, :, :] + ig[:, None, :, :]  # (B,i,j,H)
        causal = jnp.tril(jnp.ones((L, L), bool))
        allowed = (causal & vmask[None, :])[None, :, :, None]          # (1,i,j,1)
        # stabilizer per target: max over allowed sources vs inter-chunk
        m_intra = jnp.max(jnp.where(allowed, li, -1e9), axis=2)        # (B,L,H)
        m_inter = b + m[:, None, :]
        m_t = jnp.maximum(m_intra, m_inter)
        # allowed entries satisfy li <= m_t, so clipping at 0 is exact
        w = jnp.exp(jnp.clip(li - m_t[:, :, None, :], -80.0, 0.0)) * allowed
        # intra-chunk numerator / denominator
        s = jnp.einsum("bihd,bjhd->bijh", qf, kf)           # raw q.k
        num_intra = jnp.einsum("bijh,bijh,bjhd->bihd", w, s, vf)
        den_intra = jnp.einsum("bijh,bijh->bih", w, s)
        # inter-chunk via carried state
        scale_in = jnp.exp(jnp.clip(m_inter - m_t, -80.0, 0.0))
        num_inter = jnp.einsum("bihe,bhde->bihd", qf, C) * scale_in[..., None]
        den_inter = jnp.einsum("bihd,bhd->bih", qf, n) * scale_in
        num = num_intra + num_inter
        den = den_intra + den_inter
        floor = jnp.exp(jnp.clip(-m_t, -80.0, 80.0))
        h = num / jnp.maximum(jnp.abs(den), floor)[..., None]
        # state update to end of chunk
        sS = b[:, -1:, :] - b + ig                           # (B,L,H) raw
        m_new = jnp.maximum(b[:, -1] + m,
                            jnp.max(jnp.where(vmask[None, :, None], sS, -1e9),
                                    axis=1))
        wS = jnp.exp(jnp.clip(sS - m_new[:, None, :], -80.0, 0.0)) \
            * vmask[None, :, None]
        decay = jnp.exp(jnp.clip(b[:, -1, :] + m - m_new, -80.0, 0.0))
        C_new = (decay[..., None, None] * C
                 + jnp.einsum("bjh,bjhd,bjhe->bhde", wS, vf, kf))
        n_new = (decay[..., None] * n
                 + jnp.einsum("bjh,bjhd->bhd", wS, kf))
        return (C_new, n_new, m_new), h

    (C, n, m), hs = jax.lax.scan(
        chunk_step, (st.C, st.n, st.m), (cq, ck, cv, cig, clogf, cvalid))
    h = hs.swapaxes(0, 1).reshape(B, Tp, H, dh)[:, :T]

    # per-head RMS norm (GroupNorm analogue), gate, project down
    hf = h.astype(jnp.float32)
    hn = hf * jax.lax.rsqrt(jnp.mean(hf * hf, -1, keepdims=True) + 1e-6)
    hn = (hn.reshape(B, T, d_in) * p["norm"]).astype(x.dtype)
    y = hn * jax.nn.silu(z)
    out = y @ p["down"]
    return constrain(out, "act_batch", None, None), MLSTMState(C, n, m, conv_tail)


def mlstm_decode(cfg: ModelConfig, p, x_t: jax.Array, state: MLSTMState):
    """O(dh^2) recurrent step. x_t: (B, 1, d_model)."""
    B = x_t.shape[0]
    d_in, H, dh = _mdims(cfg)
    q, k, v, ig, logf, z, xc, conv_tail = _mlstm_qkv_gates(cfg, p, x_t, state.conv)
    qf, kf, vf = (a[:, 0].astype(jnp.float32) for a in (q, k, v))
    ig, logf = ig[:, 0], logf[:, 0]  # (B, H)

    m_new = jnp.maximum(logf + state.m, ig)
    i_p = jnp.exp(ig - m_new)
    f_p = jnp.exp(logf + state.m - m_new)
    C = f_p[..., None, None] * state.C + i_p[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", vf, kf)
    n = f_p[..., None] * state.n + i_p[..., None] * kf
    num = jnp.einsum("bhde,bhe->bhd", C, qf)
    den = jnp.einsum("bhd,bhd->bh", n, qf)
    floor = jnp.exp(jnp.clip(-m_new, -80.0, 80.0))
    h = num / jnp.maximum(jnp.abs(den), floor)[..., None]  # (B,H,dh)

    hf = h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
    hn = (hf.reshape(B, 1, d_in) * p["norm"]).astype(x_t.dtype)
    y = hn * jax.nn.silu(z)
    return y @ p["down"], MLSTMState(C, n, m_new, conv_tail)


def mlstm_seq_ref(cfg: ModelConfig, p, x: jax.Array):
    """Pure sequential oracle for the chunkwise form (tests only)."""
    B, T, _ = x.shape
    state = init_mlstm_state(cfg, B)
    outs = []
    for t in range(T):
        y, state = mlstm_decode(cfg, p, x[:, t:t + 1], state)
        outs.append(y)
    return jnp.concatenate(outs, axis=1), state


# ===================================================================== sLSTM


def slstm_defs(cfg: ModelConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    H = cfg.num_heads
    dh = d // H
    pf = cfg.xlstm.proj_factor
    up = int(pf * d)
    return {
        "w": ParamDef((d, 4 * d), dt, ("embed", "mlp"), init="fan_in"),
        "r": ParamDef((4, H, dh, dh), dt, (None, "heads", None, None), init="fan_in"),
        "b": ParamDef((4 * d,), jnp.float32, (None,), init="zeros"),
        "norm": ParamDef((d,), jnp.float32, (None,), init="ones"),
        "up_1": ParamDef((d, up), dt, ("embed", "mlp"), init="fan_in"),
        "up_2": ParamDef((d, up), dt, ("embed", "mlp"), init="fan_in"),
        "down": ParamDef((up, d), dt, ("mlp", "embed"), init="fan_in"),
    }


class SLSTMState(NamedTuple):
    c: jax.Array  # (B, d) f32
    n: jax.Array
    h: jax.Array
    m: jax.Array


def init_slstm_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return SLSTMState(z, z, z, jnp.full((batch, d), -30.0, jnp.float32))


def _slstm_step(cfg: ModelConfig, p, wx_t: jax.Array, st: SLSTMState) -> tuple[SLSTMState, jax.Array]:
    """wx_t: (B, 4d) precomputed input projection for one step."""
    B = wx_t.shape[0]
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    hh = st.h.reshape(B, H, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hh.astype(p["r"].dtype), p["r"])  # (B,4,H,dh)
    pre = wx_t.reshape(B, 4, d).astype(jnp.float32) + rec.reshape(B, 4, d).astype(jnp.float32)
    zt, it, ft, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + st.m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + st.m - m_new)
    c = f_p * st.c + i_p * jnp.tanh(zt)
    n = f_p * st.n + i_p
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return SLSTMState(c, n, h, m_new), h


def slstm_forward(cfg: ModelConfig, p, x: jax.Array, state: SLSTMState | None = None):
    B, T, d = x.shape
    st = state or init_slstm_state(cfg, B)
    wx = x @ p["w"] + p["b"].astype(x.dtype)  # (B, T, 4d)

    def step(s, wx_t):
        s2, h = _slstm_step(cfg, p, wx_t, s)
        return s2, h

    st, hs = jax.lax.scan(step, st, wx.swapaxes(0, 1))
    h = hs.swapaxes(0, 1)  # (B, T, d) f32
    hn = (h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
          * p["norm"]).astype(x.dtype)
    y = jax.nn.gelu(hn @ p["up_1"]) * (hn @ p["up_2"])
    out = y @ p["down"]
    return constrain(out, "act_batch", None, None), st


def slstm_decode(cfg: ModelConfig, p, x_t: jax.Array, state: SLSTMState):
    wx = (x_t @ p["w"] + p["b"].astype(x_t.dtype))[:, 0]
    st, h = _slstm_step(cfg, p, wx, state)
    hn = (h * jax.lax.rsqrt(jnp.mean(h * h, -1, keepdims=True) + 1e-6)
          * p["norm"]).astype(x_t.dtype)[:, None]
    y = jax.nn.gelu(hn @ p["up_1"]) * (hn @ p["up_2"])
    return y @ p["down"], st
