"""GQA/MQA/MHA attention layer with runtime-selectable paper modes.

Phases:
  train    — dense causal SDA (paper techniques target inference traffic)
  prefill  — dense compute; builds the mode-specific decode cache
  decode   — one token; T1/T2/T3 paths via repro.core.attention

Decomposed (T1) rope handling: position rotations do not commute with W_K, so
on RoPE architectures the decomposed mode uses the *decoupled* form (a small
roped slice of each head cached verbatim; content dims decomposed through the
X-cache) — exactly DeepSeek-MLA's construction. On absolute-position archs
(musicgen, opt) rope_dims == 0 and T1 is EXACT vs dense. See DESIGN.md §2.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.common.param import ParamDef
from repro.configs.base import AttentionRuntime, CPQCfg, ModelConfig
from repro.core import attention as core_attn
from repro.core import kv_cache as kvc
from repro.core.flash_ref import attention_auto
from repro.distributed.sharding import constrain
from repro.models.layers import apply_rope, apply_rope_rows, rms_norm_vec, rope_tables


def decoupled_rope_dims(cfg: ModelConfig) -> int:
    """Roped head-dim slice cached verbatim in decomposed mode (0 => exact T1)."""
    if cfg.pos_embedding != "rope":
        return 0
    return min(32, (cfg.head_dim // 4) * 2)


# -------------------------------------------------------------------- defs


def attn_defs(cfg: ModelConfig, cross: bool = False):
    d, H, KV, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.param_dtype
    p = {
        "wq": ParamDef((d, H * Dh), dt, ("embed", "heads"), init="fan_in"),
        "wk": ParamDef((d, KV * Dh), dt, ("embed", "kv_heads"), init="fan_in"),
        "wv": ParamDef((d, KV * Dh), dt, ("embed", "kv_heads"), init="fan_in"),
        "wo": ParamDef((H * Dh, d), dt, ("heads", "embed"), init="fan_in"),
    }
    if cfg.qkv_bias:
        p["bq"] = ParamDef((H * Dh,), jnp.float32, (None,), init="zeros")
        p["bk"] = ParamDef((KV * Dh,), jnp.float32, (None,), init="zeros")
        p["bv"] = ParamDef((KV * Dh,), jnp.float32, (None,), init="zeros")
    if cfg.qk_norm:
        p["q_norm"] = ParamDef((Dh,), jnp.float32, (None,), init="ones")
        p["k_norm"] = ParamDef((Dh,), jnp.float32, (None,), init="ones")
    if cross:
        p["gate"] = ParamDef((), jnp.float32, (), init="zeros")
    return p


# ----------------------------------------------------------------- helpers


def _project_qkv(cfg: ModelConfig, p, x: jax.Array, xkv: Optional[jax.Array] = None):
    """x: (B, T, D) -> q (B,T,H,Dh), k/v (B,S,KV,Dh). xkv overrides the kv
    source (cross-attention)."""
    B, T, _ = x.shape
    H, KV, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    src = x if xkv is None else xkv
    S = src.shape[1]
    q = x @ p["wq"]
    k = src @ p["wk"]
    v = src @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    q = constrain(q.reshape(B, T, H, Dh), "act_batch", None, "act_heads", None)
    k = constrain(k.reshape(B, S, KV, Dh), "act_batch", None, "act_kv", None)
    v = constrain(v.reshape(B, S, KV, Dh), "act_batch", None, "act_kv", None)
    if cfg.qk_norm:
        q = rms_norm_vec(q, p["q_norm"])
        k = rms_norm_vec(k, p["k_norm"])
    return q, k, v


def _rope_qk(cfg: ModelConfig, q, k, positions_q, positions_k, dims: int | None = None):
    """Apply rope to the first ``dims`` head dims (all if None)."""
    if cfg.pos_embedding != "rope":
        return q, k
    d = q.shape[-1] if dims is None else dims
    if d == 0:
        return q, k
    cq, sq = rope_tables(positions_q, d, cfg.rope_theta)
    ck, sk = rope_tables(positions_k, d, cfg.rope_theta)
    q = q.at[..., :d].set(apply_rope(q[..., :d], cq, sq)) if d < q.shape[-1] else apply_rope(q, cq, sq)
    k = k.at[..., :d].set(apply_rope(k[..., :d], ck, sk)) if d < k.shape[-1] else apply_rope(k, ck, sk)
    return q, k


def _rope_qk_rows(cfg: ModelConfig, q, k, positions, dims: int | None = None):
    """Per-row decode rope: positions (B,), q/k (B, 1, H|KV, D) — every
    request row sits at its own position (continuous batching)."""
    if cfg.pos_embedding != "rope":
        return q, k
    d = q.shape[-1] if dims is None else dims
    if d == 0:
        return q, k
    cos, sin = rope_tables(positions, d, cfg.rope_theta)  # (B, d/2)
    q = (q.at[..., :d].set(apply_rope_rows(q[..., :d], cos, sin))
         if d < q.shape[-1] else apply_rope_rows(q, cos, sin))
    k = (k.at[..., :d].set(apply_rope_rows(k[..., :d], cos, sin))
         if d < k.shape[-1] else apply_rope_rows(k, cos, sin))
    return q, k


def _wk_wv_heads(cfg: ModelConfig, p):
    """Weight views for the T1 decomposed path: (Dm, KV, Dh) each, with the
    roped slice removed from W_K (content dims only)."""
    d, KV, Dh = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    r = decoupled_rope_dims(cfg)
    wk = p["wk"].reshape(d, KV, Dh)
    wv = p["wv"].reshape(d, KV, Dh)
    return wk[..., r:], wv, r


def _out(cfg: ModelConfig, p, o: jax.Array) -> jax.Array:
    B, T = o.shape[:2]
    y = o.reshape(B, T, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return constrain(y, "act_batch", None, None)


def _scale(cfg: ModelConfig) -> float:
    return cfg.head_dim ** -0.5


# ------------------------------------------------------------------- train


def attn_train(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array) -> jax.Array:
    q, k, v = _project_qkv(cfg, p, x)
    q, k = _rope_qk(cfg, q, k, positions, positions)
    o = attention_auto(q, k, v, _scale(cfg), causal=True)
    return _out(cfg, p, o)


def xattn_train(cfg: ModelConfig, p, x: jax.Array, patches: jax.Array) -> jax.Array:
    """Gated cross-attention over (stub) patch embeddings; non-causal."""
    q, k, v = _project_qkv(cfg, p, x, xkv=patches)
    o = attention_auto(q, k, v, _scale(cfg), causal=False)
    return _out(cfg, p, o) * jnp.tanh(p["gate"]).astype(x.dtype)


# ----------------------------------------------------------------- serving


class AttnCacheBundle(NamedTuple):
    """Cache plus the static per-layer side data decode needs."""

    cache: kvc.Cache


def init_attn_cache(cfg: ModelConfig, rt: AttentionRuntime, batch: int, n_max: int):
    return core_attn.init_cache(
        rt, batch=batch, n_max=n_max, kv=cfg.num_kv_heads, dh=cfg.head_dim,
        d_model=cfg.d_model, rope_dims=decoupled_rope_dims(cfg), dtype=cfg.param_dtype)


def attn_prefill(cfg: ModelConfig, rt: AttentionRuntime, p, x: jax.Array,
                 positions: jax.Array, cache: kvc.Cache):
    """Dense prefill compute + mode-specific cache build. x is the NORMED
    block input (the exact T1 operand)."""
    q, k, v = _project_qkv(cfg, p, x)
    r = decoupled_rope_dims(cfg)
    if rt.mode in ("decomposed", "decomposed_cpq"):
        # decoupled: rope only the cached slice; content dims stay position-free
        q, k = _rope_qk(cfg, q, k, positions, positions, dims=r)
        k_rope = k[..., :r]
        scores_k, scores_v = k, v  # exact dense math for the prefill pass
        cache = core_attn.prefill_into_cache(
            rt, cache, k=k, v=v, x=x, k_rope=k_rope,
            length=jnp.asarray(x.shape[1], jnp.int32))
    else:
        q, k = _rope_qk(cfg, q, k, positions, positions)
        scores_k, scores_v = k, v
        cache = core_attn.prefill_into_cache(
            rt, cache, k=k, v=v, x=x, k_rope=None,
            length=jnp.asarray(x.shape[1], jnp.int32))
    o = attention_auto(q, scores_k, scores_v, _scale(cfg), causal=True)
    return _out(cfg, p, o), cache


def attn_decode(cfg: ModelConfig, rt: AttentionRuntime, p, x_t: jax.Array,
                pos: jax.Array, cache: kvc.Cache):
    """One-token decode. x_t: (B, 1, D) normed block input; pos: () int32."""
    q, k, v = _project_qkv(cfg, p, x_t)
    r = decoupled_rope_dims(cfg)
    positions_t = pos[None] if pos.ndim == 0 else pos

    if rt.mode in ("decomposed", "decomposed_cpq"):
        q, k = _rope_qk(cfg, q, k, positions_t, positions_t, dims=r)
        wk_nope, wv, _ = _wk_wv_heads(cfg, p)
        out, cache = core_attn.decode_attend(
            rt, cache, q=q, k_t=k, v_t=v, x_t=x_t, k_rope_t=k[..., :r],
            q_nope=q[..., r:], q_rope=q[..., :r], w_k_nope=wk_nope, w_v=wv,
            scale=_scale(cfg))
    else:
        q, k = _rope_qk(cfg, q, k, positions_t, positions_t)
        out, cache = core_attn.decode_attend(
            rt, cache, q=q, k_t=k, v_t=v, x_t=None, k_rope_t=None,
            q_nope=None, q_rope=None, w_k_nope=None, w_v=None, scale=_scale(cfg))
    return _out(cfg, p, out), cache


def attn_prefill_chunk(cfg: ModelConfig, rt: AttentionRuntime, tier: int,
                       first: bool, p, x: jax.Array, positions: jax.Array,
                       slot, block_row, offset, valid, cache):
    """Chunked paged prefill: one prompt chunk's K/V (or X / CPQ codes) is
    written straight into slot ``slot``'s arena pages and its C queries
    attend the slot's pages [0, offset + valid) — the streaming admission
    path (no contiguous scratch cache). x: (1, C, D) normed block input at
    absolute ``positions``; ``tier``/``first`` are host-static."""
    from repro.serving import paged_cache as pgc

    q, k, v = _project_qkv(cfg, p, x)
    r = decoupled_rope_dims(cfg)

    if rt.mode in ("decomposed", "decomposed_cpq"):
        q, k = _rope_qk(cfg, q, k, positions, positions, dims=r)
        wk_nope, wv, _ = _wk_wv_heads(cfg, p)
        out, cache = pgc.chunk_attend_paged(
            rt, cache, tier=tier, first=first, slot=slot, block_row=block_row,
            offset=offset, valid=valid, q=q, k_c=k, v_c=v, x_c=x,
            k_rope_c=k[..., :r], q_nope=q[..., r:], q_rope=q[..., :r],
            w_k_nope=wk_nope, w_v=wv, scale=_scale(cfg))
    else:
        q, k = _rope_qk(cfg, q, k, positions, positions)
        out, cache = pgc.chunk_attend_paged(
            rt, cache, tier=tier, first=first, slot=slot, block_row=block_row,
            offset=offset, valid=valid, q=q, k_c=k, v_c=v, x_c=None,
            k_rope_c=None, q_nope=None, q_rope=None, w_k_nope=None, w_v=None,
            scale=_scale(cfg))
    return _out(cfg, p, out), cache


def init_paged_attn_cache(cfg: ModelConfig, rt: AttentionRuntime, serving,
                          tiered: bool = False):
    """Per-layer paged arena for the configured mode (serving/paged_cache.py).
    ``tiered`` adds the CPQ escalation arena next to the dense base arena."""
    from repro.serving import paged_cache as pgc

    kw = dict(kv=cfg.num_kv_heads, dh=cfg.head_dim)
    if tiered:
        assert rt.mode == "dense", "tier escalation starts from a dense base"
        return pgc.TieredPagedCache(
            dense=pgc.init_paged_dense(serving.num_pages, serving.page_size,
                                       dtype=cfg.param_dtype, **kw),
            cpq=pgc.init_paged_cpq(serving.escalated_pages, serving.page_size,
                                   serving.num_slots, cfg.num_kv_heads,
                                   cfg.head_dim, rt.cpq or CPQCfg()))
    if rt.mode == "dense":
        return pgc.init_paged_dense(serving.num_pages, serving.page_size,
                                    dtype=cfg.param_dtype, **kw)
    if rt.mode == "decomposed":
        return pgc.init_paged_x(serving.num_pages, serving.page_size, cfg.d_model,
                                cfg.num_kv_heads, decoupled_rope_dims(cfg),
                                cfg.param_dtype)
    if rt.mode == "cpq":
        return pgc.init_paged_cpq(serving.num_pages, serving.page_size,
                                  serving.num_slots, cfg.num_kv_heads,
                                  cfg.head_dim, rt.cpq)
    if rt.mode == "decomposed_cpq":
        return pgc.init_paged_cpq_x(serving.num_pages, serving.page_size,
                                    serving.num_slots, cfg.d_model,
                                    cfg.num_kv_heads, decoupled_rope_dims(cfg),
                                    rt.cpq, cfg.param_dtype)
    if rt.mode == "retrieval":
        return pgc.init_paged_retrieval(serving.num_pages, serving.page_size,
                                        serving.num_slots, cfg.num_kv_heads,
                                        cfg.head_dim, rt.retrieval, cfg.param_dtype)
    raise ValueError(rt.mode)


def attn_decode_rows(cfg: ModelConfig, rt: AttentionRuntime, p, x_t: jax.Array,
                     rows, cache):
    """One-token decode against a paged arena. x_t: (B, 1, D) normed block
    input; ``rows`` is a serving.paged_cache.RowState (per-row positions =
    rows.lengths)."""
    from repro.serving import paged_cache as pgc

    q, k, v = _project_qkv(cfg, p, x_t)
    r = decoupled_rope_dims(cfg)

    if rt.mode in ("decomposed", "decomposed_cpq"):
        q, k = _rope_qk_rows(cfg, q, k, rows.lengths, dims=r)
        wk_nope, wv, _ = _wk_wv_heads(cfg, p)
        out, cache = pgc.decode_attend_paged(
            rt, cache, rows, q=q, k_t=k, v_t=v, x_t=x_t, k_rope_t=k[..., :r],
            q_nope=q[..., r:], q_rope=q[..., :r], w_k_nope=wk_nope, w_v=wv,
            scale=_scale(cfg))
    else:
        q, k = _rope_qk_rows(cfg, q, k, rows.lengths)
        out, cache = pgc.decode_attend_paged(
            rt, cache, rows, q=q, k_t=k, v_t=v, x_t=None, k_rope_t=None,
            q_nope=None, q_rope=None, w_k_nope=None, w_v=None, scale=_scale(cfg))
    return _out(cfg, p, out), cache


# cross-attention serving: K/V are static per request (computed at prefill),
# decode just attends — no append, no CWC dependency (DESIGN.md §5).


def xattn_prefill(cfg: ModelConfig, p, x: jax.Array, patches: jax.Array):
    q, k, v = _project_qkv(cfg, p, x, xkv=patches)
    o = attention_auto(q, k, v, _scale(cfg), causal=False)
    cache = kvc.DenseKVCache(k, v, jnp.asarray(patches.shape[1], jnp.int32))
    return _out(cfg, p, o) * jnp.tanh(p["gate"]).astype(x.dtype), cache


def xattn_decode(cfg: ModelConfig, p, x_t: jax.Array, cache: kvc.DenseKVCache):
    q = (x_t @ p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
    B, T = x_t.shape[:2]
    q = q.reshape(B, T, cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm_vec(q, p["q_norm"])
    o = core_attn.dense_attention(q, cache.k, cache.v, _scale(cfg), causal=False,
                                  kv_length=cache.length)
    return _out(cfg, p, o) * jnp.tanh(p["gate"]).astype(x_t.dtype), cache
